//! Property-based invariant tests (custom mini-harness in util::prop, since
//! proptest isn't available offline). Each property is checked over many
//! seeded random cases with shrinking on failure.

use wisparse::model::ModelConfig;
use wisparse::sparse_kernel::{
    dense_gemv, sparse_gemv_scored, sparse_gemv_threshold, ColMajorMatrix,
};
use wisparse::sparsity::evo::{mutate, EvoCfg};
use wisparse::sparsity::plan::SparsityPlan;
use wisparse::sparsity::score::{pow_clamped, realized_keep_fraction, tau_from_rows};
use wisparse::tensor::Tensor;
use wisparse::util::prop::{check, check2, CheckConfig, F64In, UsizeIn, VecF32};
use wisparse::util::rng::Pcg64;

fn cfgc(cases: usize) -> CheckConfig {
    CheckConfig {
        cases,
        ..CheckConfig::default()
    }
}

#[test]
fn prop_kept_channels_monotone_in_tau() {
    // For any activation vector, raising tau never keeps MORE channels.
    check(
        &cfgc(100),
        &VecF32 {
            min_len: 1,
            max_len: 128,
            lo: -3.0,
            hi: 3.0,
        },
        |x| {
            let n = x.len();
            let ga = vec![1.0f32; n];
            let w = ColMajorMatrix::from_row_major(&Tensor::full(&[2, n], 0.5));
            let mut out = vec![0.0f32; 2];
            let mut prev = usize::MAX;
            for tau in [0.0f32, 0.5, 1.0, 2.0, 4.0] {
                let kept = sparse_gemv_scored(&w, x, &ga, tau, &mut out);
                if kept > prev {
                    return Err(format!("kept rose from {prev} to {kept} at tau {tau}"));
                }
                prev = kept;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scored_gemv_equals_masked_dense() {
    // sparse_gemv_scored == dense_gemv on the explicitly-masked input.
    check2(
        &cfgc(60),
        &VecF32 {
            min_len: 2,
            max_len: 64,
            lo: -2.0,
            hi: 2.0,
        },
        &F64In(0.0, 2.0),
        |x, &tau| {
            let n = x.len();
            let mut rng = Pcg64::new(n as u64);
            let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[5, n], 1.0, &mut rng));
            let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.01).collect();
            let mut scored = vec![0.0f32; 5];
            sparse_gemv_scored(&w, x, &ga, tau as f32, &mut scored);
            let masked: Vec<f32> = x
                .iter()
                .zip(&ga)
                .map(|(&xv, &g)| if xv.abs() * g >= tau as f32 { xv } else { 0.0 })
                .collect();
            let mut dense = vec![0.0f32; 5];
            dense_gemv(&w, &masked, &mut dense);
            for i in 0..5 {
                if (scored[i] - dense[i]).abs() > 1e-4 {
                    return Err(format!("row {i}: {} vs {}", scored[i], dense[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tau_calibration_hits_keep_ratio() {
    // Eq. 7: the calibrated threshold realizes ~the requested keep ratio on
    // the pool it was calibrated on.
    check2(
        &cfgc(40),
        &UsizeIn(4, 64),
        &F64In(0.05, 0.95),
        |&dim, &keep| {
            let mut rng = Pcg64::new(dim as u64 ^ 0xFEED);
            let rows: Vec<f32> = (0..50 * dim).map(|_| rng.normal() as f32).collect();
            let ga: Vec<f32> = (0..dim).map(|_| rng.next_f32() + 0.05).collect();
            let tau = tau_from_rows(&rows, dim, &ga, keep);
            let realized = realized_keep_fraction(&rows, dim, &ga, tau);
            if (realized - keep).abs() > 0.05 {
                return Err(format!("asked {keep}, realized {realized}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pow_clamped_positive_and_monotone() {
    // g^alpha stays >= 1e-4, and is monotone in g for any fixed alpha >= 0.
    check2(
        &cfgc(60),
        &VecF32 {
            min_len: 2,
            max_len: 32,
            lo: 0.0,
            hi: 5.0,
        },
        &F64In(0.0, 1.5),
        |g, &alpha| {
            let ga = pow_clamped(g, alpha);
            if ga.iter().any(|&v| v < 1e-4) {
                return Err("clamp violated".into());
            }
            // Monotonicity on a sorted copy.
            let mut pairs: Vec<(f32, f32)> = g.iter().cloned().zip(ga.iter().cloned()).collect();
            pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in pairs.windows(2) {
                if w[1].1 < w[0].1 - 1e-6 {
                    return Err(format!("not monotone: {:?} -> {:?}", w[0], w[1]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_evo_mutation_respects_budget_and_bounds() {
    check2(
        &cfgc(80),
        &UsizeIn(2, 24),
        &F64In(0.05, 0.9),
        |&n_blocks, &target| {
            let cfg = EvoCfg {
                eps: 0.03,
                ..EvoCfg::default()
            };
            let mut rng = Pcg64::new(n_blocks as u64);
            let parent = vec![target; n_blocks];
            for _ in 0..5 {
                let child = mutate(&parent, target, &cfg, &mut rng);
                let mean = child.iter().sum::<f64>() / child.len() as f64;
                if mean > target + 1e-9 {
                    return Err(format!("budget violated: mean {mean} > {target}"));
                }
                if child
                    .iter()
                    .any(|&p| !(cfg.min_sparsity..=cfg.max_sparsity).contains(&p))
                {
                    return Err("bounds violated".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_json_roundtrip() {
    // Any randomized plan survives JSON serialization exactly.
    check(&cfgc(30), &UsizeIn(0, 1 << 30), |&seed| {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut rng = Pcg64::new(seed as u64);
        let mut plan = SparsityPlan::uniform(&cfg, "prop", rng.next_f64());
        for lp in plan.layers.iter_mut() {
            lp.sparsity = rng.next_f64();
            lp.alpha = rng.next_f64() * 1.5;
            lp.tau = rng.next_f32();
        }
        plan.block_sparsity = (0..cfg.n_layers).map(|_| rng.next_f64()).collect();
        let j = plan.to_json().to_string_pretty();
        let back = SparsityPlan::from_json(&wisparse::util::json::Json::parse(&j).unwrap())
            .map_err(|e| e.to_string())?;
        if back != plan {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_threshold_kernel_is_scored_with_unit_ga() {
    check(
        &cfgc(60),
        &VecF32 {
            min_len: 1,
            max_len: 96,
            lo: -2.0,
            hi: 2.0,
        },
        |x| {
            let n = x.len();
            let mut rng = Pcg64::new(n as u64 ^ 0xAA);
            let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[3, n], 1.0, &mut rng));
            let ga = vec![1.0f32; n];
            let mut a = vec![0.0f32; 3];
            let mut b = vec![0.0f32; 3];
            let tau = 0.8f32;
            let ka = sparse_gemv_threshold(&w, x, tau, &mut a);
            let kb = sparse_gemv_scored(&w, x, &ga, tau, &mut b);
            if ka != kb || a != b {
                return Err(format!("kernels diverge: {ka} vs {kb}"));
            }
            Ok(())
        },
    );
}
