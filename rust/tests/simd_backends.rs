//! Property tests for the runtime-dispatched SIMD kernel backends.
//!
//! Every backend the CPU can run must match the single-pass scalar reference
//! kernels (`sparse_gemv_scored` / `sparse_gemv_threshold`) within 1e-4,
//! across odd shapes (m, n deliberately not multiples of any vector width),
//! all tau regimes (0 = keep-all, a mid quantile, +inf = keep-nothing), and
//! both the weight-aware (`ga`) and magnitude (`ga = None`) score paths.
//! Kept-channel counts must agree *exactly* — the mask predicate is the
//! semantics of the method, not an approximation.

use wisparse::sparse_kernel::gemv::{
    sparse_gemv_fused, sparse_gemv_fused_parallel_with, sparse_gemv_fused_with,
    sparse_gemv_scored, sparse_gemv_threshold,
};
use wisparse::sparse_kernel::simd::{self, Backend};
use wisparse::sparse_kernel::ColMajorMatrix;
use wisparse::tensor::Tensor;
use wisparse::util::prop::{check2, CheckConfig, UsizeIn};
use wisparse::util::rng::Pcg64;

fn setup(m: usize, n: usize, seed: u64) -> (ColMajorMatrix, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg64::new(seed);
    let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 1.0, &mut rng));
    let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
    (w, x, ga)
}

/// A tau that keeps roughly half the channels of this particular input.
fn mid_tau(x: &[f32], ga: &[f32]) -> f32 {
    let mut scores: Vec<f32> = x.iter().zip(ga).map(|(&xv, &g)| xv.abs() * g).collect();
    scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
    scores[scores.len() / 2]
}

fn cfg(cases: usize) -> CheckConfig {
    CheckConfig {
        cases,
        ..CheckConfig::default()
    }
}

#[test]
fn every_backend_matches_the_scalar_reference() {
    check2(&cfg(60), &UsizeIn(1, 67), &UsizeIn(1, 77), |&m, &n| {
        let (w, x, ga) = setup(m, n, (m * 1009 + n) as u64);
        let taus = [0.0f32, mid_tau(&x, &ga), f32::INFINITY];
        let mut kept_idx = Vec::new();
        for backend in simd::available_backends() {
            for &tau in &taus {
                // Weight-aware scored path.
                let mut reference = vec![0.0f32; m];
                let mut fused = vec![0.0f32; m];
                let kr = sparse_gemv_scored(&w, &x, &ga, tau, &mut reference);
                let kf = sparse_gemv_fused_with(
                    backend,
                    &w,
                    &x,
                    Some(&ga),
                    tau,
                    &mut fused,
                    &mut kept_idx,
                );
                if kr != kf {
                    return Err(format!(
                        "{}: scored kept {kf} != reference {kr} (m={m} n={n} tau={tau})",
                        backend.name()
                    ));
                }
                for i in 0..m {
                    if (reference[i] - fused[i]).abs() > 1e-4 {
                        return Err(format!(
                            "{}: scored out[{i}] {} vs {} (m={m} n={n} tau={tau})",
                            backend.name(),
                            fused[i],
                            reference[i]
                        ));
                    }
                }
                // Magnitude / TEAL path (ga = None).
                let kr = sparse_gemv_threshold(&w, &x, tau, &mut reference);
                let kf =
                    sparse_gemv_fused_with(backend, &w, &x, None, tau, &mut fused, &mut kept_idx);
                if kr != kf {
                    return Err(format!(
                        "{}: threshold kept {kf} != reference {kr} (m={m} n={n} tau={tau})",
                        backend.name()
                    ));
                }
                for i in 0..m {
                    if (reference[i] - fused[i]).abs() > 1e-4 {
                        return Err(format!(
                            "{}: threshold out[{i}] {} vs {} (m={m} n={n} tau={tau})",
                            backend.name(),
                            fused[i],
                            reference[i]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn intra_gemv_row_split_is_bit_exact() {
    // The row-parallel kernel must produce *bit-identical* output to the
    // serial fused kernel at any thread count (same per-element accumulation
    // order), including when rows don't divide evenly.
    check2(&cfg(40), &UsizeIn(2, 61), &UsizeIn(1, 53), |&m, &n| {
        let (w, x, ga) = setup(m, n, (m * 31 + n * 7) as u64);
        let tau = mid_tau(&x, &ga);
        let mut kept_idx = Vec::new();
        let mut serial = vec![0.0f32; m];
        let ks = sparse_gemv_fused(&w, &x, Some(&ga), tau, &mut serial, &mut kept_idx);
        for threads in [2usize, 3, 7] {
            let mut par = vec![0.0f32; m];
            // min_macs = 0 forces the split even on tiny shapes.
            let kp = sparse_gemv_fused_parallel_with(
                simd::active(),
                &w,
                &x,
                Some(&ga),
                tau,
                &mut par,
                &mut kept_idx,
                threads,
                0,
            );
            if ks != kp {
                return Err(format!("kept {kp} != serial {ks} at {threads} threads"));
            }
            if serial != par {
                return Err(format!("row-split output diverged at {threads} threads (m={m} n={n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn forced_scalar_and_dispatched_keep_identical_channels() {
    // `WISPARSE_SIMD=off` resolves to the scalar backend...
    assert_eq!(simd::choose_backend(Some("off")), Backend::Scalar);
    // ...and scalar vs dispatched runs must select the *same* channels:
    // identical kept counts and identical index lists, every tau regime.
    check2(&cfg(40), &UsizeIn(1, 57), &UsizeIn(1, 71), |&m, &n| {
        let (_, x, ga) = setup(m, n, (m * 13 + n * 3) as u64);
        let taus = [0.0f32, mid_tau(&x, &ga), f32::INFINITY];
        let mut scalar_idx = Vec::new();
        let mut simd_idx = Vec::new();
        for &tau in &taus {
            simd::scan_scored_with(Backend::Scalar, &x, &ga, tau, &mut scalar_idx);
            simd::scan_scored_with(simd::active(), &x, &ga, tau, &mut simd_idx);
            if scalar_idx != simd_idx {
                return Err(format!("scored mask diverged (n={n} tau={tau})"));
            }
            simd::scan_threshold_with(Backend::Scalar, &x, tau, &mut scalar_idx);
            simd::scan_threshold_with(simd::active(), &x, tau, &mut simd_idx);
            if scalar_idx != simd_idx {
                return Err(format!("threshold mask diverged (n={n} tau={tau})"));
            }
        }
        Ok(())
    });
}

#[test]
fn dispatched_backend_is_a_known_backend() {
    let active = simd::active();
    assert!(
        simd::available_backends().contains(&active),
        "active backend {:?} not in available set",
        active
    );
}
