//! Integration: the group-quantized weight subsystem end to end.
//!
//! - greedy decode on an int8-quantized checkpoint at ~50% sparsity is
//!   token-identical across flat KV, paged KV, and speculative decode (the
//!   ISSUE 4 acceptance differential);
//! - a quantized checkpoint survives the v2 container round trip and
//!   reloads to a bit-identical model;
//! - weight-aware `ga` scores are derived from the deployed quantized
//!   groups, not the discarded f32 weights.

use std::sync::Arc;
use wisparse::kv::KvCfg;
use wisparse::model::layers::{all_layers, LayerId};
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::{ForwardStats, Model};
use wisparse::model::ModelConfig;
use wisparse::quant::{QuantMode, WeightRepr};
use wisparse::server::engine::{Engine, EngineCfg, SpecCfg, SpecEngine};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::score::pow_clamped;
use wisparse::sparsity::{Dense, Sparsifier};

/// Weight-aware (WINA-style) sparsifier whose `ga = g^alpha` comes from the
/// model's deployed column norms — quantized groups when the model is
/// quantized.
fn weight_aware(model: &Model, tau: f32) -> Arc<dyn Sparsifier> {
    let layers: Vec<ScoredLayer> = all_layers(&model.cfg)
        .into_iter()
        .map(|id| ScoredLayer {
            ga: Some(pow_clamped(model.g(id), 1.0)),
            tau,
        })
        .collect();
    Arc::new(ScoredSparsifier::new("wina", layers))
}

fn quantized_model(mode: QuantMode, group: usize) -> Arc<Model> {
    let mut m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 0xBEEF);
    m.quantize(mode, group);
    Arc::new(m)
}

#[test]
fn int8_greedy_decode_identical_across_flat_paged_and_speculative() {
    let model = quantized_model(QuantMode::Int8, 16);
    assert_eq!(model.weight_repr_name(), "int8");
    let prod_tau = 0.3; // roughly mid-density on nano-scale ga scores
    let sp = weight_aware(&model, prod_tau);
    let cfg = EngineCfg {
        threads: 1,
        ..EngineCfg::default()
    };

    let prompts = ["the sun ", "12+34=", "abcdefgh"];
    for prompt in prompts {
        // Flat KV baseline.
        let flat = Engine::new(Arc::clone(&model), Arc::clone(&sp), cfg.clone());
        let (flat_text, stats) = flat.run_to_completion(prompt, 24, Sampling::Greedy);
        assert!(
            stats.density() > 0.05 && stats.density() < 0.95,
            "sparsity actually engaged (density {})",
            stats.density()
        );

        // Paged KV.
        let paged = Engine::paged(
            Arc::clone(&model),
            Arc::clone(&sp),
            cfg.clone(),
            &KvCfg {
                pool_blocks: 128,
                block_size: 8,
                prefix_cache: true,
            },
        );
        let (paged_text, _) = paged.run_to_completion(prompt, 24, Sampling::Greedy);
        assert_eq!(flat_text, paged_text, "paged KV diverged on {prompt:?}");

        // Speculative decode (high-sparsity draft over the same quantized
        // weights, production verify).
        let verify = Arc::new(Engine::new(
            Arc::clone(&model),
            Arc::clone(&sp),
            cfg.clone(),
        ));
        let spec = SpecEngine::new(verify, weight_aware(&model, prod_tau * 4.0), SpecCfg::default());
        let seq = spec.run_seq(0, prompt, 24, Sampling::Greedy);
        assert_eq!(flat_text, seq.text(), "speculative diverged on {prompt:?}");
        assert!(seq.spec.rounds > 0, "speculation actually ran");
    }
}

#[test]
fn int4_decode_runs_all_paths() {
    let model = quantized_model(QuantMode::Int4, 8);
    let sp = weight_aware(&model, 0.3);
    let cfg = EngineCfg {
        threads: 1,
        ..EngineCfg::default()
    };
    let flat = Engine::new(Arc::clone(&model), Arc::clone(&sp), cfg.clone());
    let (a, _) = flat.run_to_completion("hello ", 16, Sampling::Greedy);
    let paged = Engine::paged(
        Arc::clone(&model),
        Arc::clone(&sp),
        cfg,
        &KvCfg {
            pool_blocks: 64,
            block_size: 4,
            prefix_cache: true,
        },
    );
    let (b, _) = paged.run_to_completion("hello ", 16, Sampling::Greedy);
    assert_eq!(a, b);
    assert_eq!(a.len(), 16);
}

#[test]
fn quantized_checkpoint_roundtrips_through_model_dir() {
    let model = quantized_model(QuantMode::Int8, 16);
    let dir = std::env::temp_dir().join("wisparse_quant_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    model.cfg.save(&dir.join("config.json")).unwrap();
    model.export_weights().save(&dir.join("weights.bin")).unwrap();
    let reloaded = Model::load_dir(&dir).unwrap();
    assert_eq!(reloaded.weight_repr_name(), "int8");
    assert_eq!(
        reloaded.weight_bytes_resident(),
        model.weight_bytes_resident()
    );
    // Bit-identical logits: codes and scales survived the container.
    let mut s1 = ForwardStats::default();
    let mut s2 = ForwardStats::default();
    let a = model.forward_seq(&[7, 3, 9, 1], &Dense, &mut s1, None);
    let b = reloaded.forward_seq(&[7, 3, 9, 1], &Dense, &mut s2, None);
    for (x, y) in a.data.iter().zip(&b.data) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    // And the greedy continuations agree under the sparse path too.
    let sp_a = weight_aware(&model, 0.3);
    let sp_b = weight_aware(&reloaded, 0.3);
    let ea = Engine::new(Arc::clone(&model), sp_a, EngineCfg::default());
    let eb = Engine::new(Arc::new(reloaded), sp_b, EngineCfg::default());
    assert_eq!(
        ea.run_to_completion("roundtrip ", 12, Sampling::Greedy).0,
        eb.run_to_completion("roundtrip ", 12, Sampling::Greedy).0
    );
}

#[test]
fn ga_scores_come_from_deployed_quantized_groups() {
    let f32_model = Model::synthetic(ModelConfig::preset("nano").unwrap(), 0xBEEF);
    let q_model = quantized_model(QuantMode::Int4, 4);
    let mut some_differ = false;
    for id in all_layers(&q_model.cfg) {
        let gq = q_model.g(id);
        let deployed = q_model.w(id).col_l2_norms();
        for (a, b) in gq.iter().zip(&deployed) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "cached g must equal the deployed quantized norms ({})",
                id.key()
            );
        }
        let gf = f32_model.g(id);
        if gq.iter().zip(gf).any(|(a, b)| a != b) {
            some_differ = true;
        }
    }
    assert!(
        some_differ,
        "int4 norms must differ from f32 norms somewhere, or the ga \
         recompute silently kept the stale f32 values"
    );
    // Identical scored masks on identical scores: the quantized engine
    // keeps a valid WINA configuration (sanity that LayerId wiring holds).
    let id = LayerId::from_flat(0);
    assert_eq!(q_model.g(id).len(), id.kind.dims(&q_model.cfg).1);
}
