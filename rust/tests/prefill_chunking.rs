//! Chunked continuous-batch prefill (ISSUE 5): the bit-equality oracle —
//! chunked prefill must reproduce the token-by-token schedule exactly
//! across flat KV, paged KV and speculative engines, for any chunk size,
//! prompt length and `prefill_sparse_fraction` — plus the prefix-cache
//! schedule-consistency regression (hit and miss logits identical), the
//! partial-prefill terminal state, scheduler fairness under a co-running
//! long prompt, and streaming-cancellation block reclamation.

use std::sync::Arc;
use wisparse::kv::KvCfg;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg, SeqState, SpecCfg, SpecEngine};
use wisparse::server::request::StreamEvent;
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::Sparsifier;

fn teal(model: &Model, tau: f32) -> Arc<dyn Sparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau })
            .collect(),
    ))
}

fn engine(
    model: &Arc<Model>,
    sp: &Arc<dyn Sparsifier>,
    paged: bool,
    prefix_cache: bool,
    fraction: f64,
    chunk: usize,
) -> Engine {
    let cfg = EngineCfg {
        threads: 1,
        prefill_sparse_fraction: fraction,
        prefill_chunk: chunk,
        ..EngineCfg::default()
    };
    if paged {
        Engine::paged(
            Arc::clone(model),
            Arc::clone(sp),
            cfg,
            &KvCfg {
                pool_blocks: 128,
                block_size: 4,
                prefix_cache,
            },
        )
    } else {
        Engine::new(Arc::clone(model), Arc::clone(sp), cfg)
    }
}

fn assert_logits_bits_equal(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: logits length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: logits diverge at {i}");
    }
}

fn decode_to_end(e: &Engine, seq: &mut SeqState) -> String {
    while !seq.finished() {
        e.decode_one(seq);
    }
    seq.text()
}

/// The core property: for every KV backend, chunk size (dividing and not
/// dividing the prompt, straddling the dense→sparse boundary or not) and
/// sparse fraction, chunked prefill's final logits and greedy continuation
/// are bit-identical to the sequential reference.
#[test]
fn chunked_prefill_equals_sequential_property() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let sp = teal(&model, 0.45);
    let prompts = ["abcd", "the sun rises over the hill", "12+34=46 and 9*9=81!"];
    for paged in [false, true] {
        for fraction in [0.0, 0.5, 0.8, 1.0] {
            for chunk in [1usize, 2, 3, 7, 64] {
                // Prefix cache off: hit-vs-miss equality is its own test.
                let e = engine(&model, &sp, paged, false, fraction, chunk);
                for prompt in prompts {
                    let ctx = format!("paged={paged} fraction={fraction} chunk={chunk} {prompt:?}");
                    let mut a = e.admit(0, prompt, 8, Sampling::Greedy);
                    e.prefill(&mut a);
                    let mut b = e.admit(1, prompt, 8, Sampling::Greedy);
                    e.prefill_sequential(&mut b);
                    assert!(a.prefill_complete() && b.prefill_complete(), "{ctx}");
                    let expected_chunks = prompt.len().div_ceil(chunk);
                    assert_eq!(a.prefill.chunks as usize, expected_chunks, "{ctx}");
                    assert_logits_bits_equal(e.last_logits(&a), e.last_logits(&b), &ctx);
                    assert_eq!(decode_to_end(&e, &mut a), decode_to_end(&e, &mut b), "{ctx}");
                }
            }
        }
    }
}

/// Speculative decode on top of chunked prefill: identical output to the
/// same speculative engine prefilled token-by-token, flat and paged.
#[test]
fn speculative_engine_unaffected_by_prefill_chunking() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let prod = teal(&model, 0.3);
    let draft = teal(&model, 0.6);
    for paged in [false, true] {
        let e = Arc::new(engine(&model, &prod, paged, false, 0.5, 3));
        let spec = SpecEngine::new(Arc::clone(&e), Arc::clone(&draft), SpecCfg::default());
        // Chunked prefill (the default `prefill` path).
        let chunked = spec.run_seq(0, "the sun rises ", 20, Sampling::Greedy);
        // Sequential prefill, then the same speculative decode loop.
        let mut seq = spec.admit(1, "the sun rises ", 20, Sampling::Greedy);
        spec.verify.prefill_sequential(&mut seq);
        while !seq.finished() {
            spec.spec_round(&mut seq);
        }
        assert_eq!(chunked.text(), seq.text(), "paged={paged}");
        assert_eq!(chunked.generated.len(), 20);
    }
}

/// Prefix-cache schedule-consistency regression: the same prompt must
/// produce bit-identical logits on a cache hit and a cache miss — including
/// when the cached prefix was produced by a *different-length* prompt whose
/// dense→sparse boundary disagrees over part of the prefix (the pre-fix
/// bug: the hit silently adopted sparse-produced KV for positions the
/// consumer's schedule runs dense).
#[test]
fn prefix_hit_and_miss_logits_bit_identical() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let sp = teal(&model, 0.45);
    // Reference engine: no prefix cache, every prompt recomputed in full.
    let miss = engine(&model, &sp, true, false, 0.5, 5);
    // Cached engine: one manager shared across producer and consumers.
    let hit = engine(&model, &sp, true, true, 0.5, 5);

    // Producer publishes a 16-token prompt (dense_upto = 8).
    let p16: String = "abcdefghijklmnop".into();
    let mut producer = hit.admit(0, &p16, 4, Sampling::Greedy);
    hit.prefill(&mut producer);

    // Same prompt, same schedule: full-depth hit, logits bit-identical.
    // (Matching is deferred to the first prefill chunk, so the hit count
    // is observable only after prefill.)
    let mut warm = hit.admit(1, &p16, 4, Sampling::Greedy);
    hit.prefill(&mut warm);
    assert!(
        warm.prefix_hit_tokens > 0,
        "same prompt must hit the cache (got {})",
        warm.prefix_hit_tokens
    );
    let mut cold = miss.admit(1, &p16, 4, Sampling::Greedy);
    miss.prefill(&mut cold);
    assert_logits_bits_equal(hit.last_logits(&warm), miss.last_logits(&cold), "same prompt");
    assert_eq!(
        decode_to_end(&hit, &mut warm),
        decode_to_end(&miss, &mut cold),
        "same-prompt continuation"
    );

    // A longer prompt sharing the prefix: its boundary (dense_upto = 10)
    // disagrees with the producer's (8) over positions 8..12, so the hit
    // must stop at 8 tokens — and the logits must still equal a full miss.
    let p20 = format!("{p16}qrst");
    let mut warm = hit.admit(2, &p20, 4, Sampling::Greedy);
    hit.prefill(&mut warm);
    assert!(
        warm.prefix_hit_tokens <= 8,
        "schedule-inconsistent span must not be served (hit {})",
        warm.prefix_hit_tokens
    );
    let mut cold = miss.admit(2, &p20, 4, Sampling::Greedy);
    miss.prefill(&mut cold);
    assert_logits_bits_equal(hit.last_logits(&warm), miss.last_logits(&cold), "longer prompt");
    assert_eq!(
        decode_to_end(&hit, &mut warm),
        decode_to_end(&miss, &mut cold),
        "longer-prompt continuation"
    );
}

/// Pool exhaustion mid-prompt: terminal partial state, nothing published to
/// the prefix cache, and the serving path surfaces `cache_full` without
/// ever decoding the half-prefilled sequence.
#[test]
fn partial_prefill_terminal_and_unpublished() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let sp = teal(&model, 0.45);
    // 2 blocks x 4 positions = 8 tokens of backing for a 16-token prompt.
    let e = Engine::paged(
        Arc::clone(&model),
        Arc::clone(&sp),
        EngineCfg {
            threads: 1,
            prefill_chunk: 4,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 2,
            block_size: 4,
            prefix_cache: true,
        },
    );
    let prompt = "abcdefghijklmnop";
    let mut seq = e.admit(0, prompt, 4, Sampling::Greedy);
    e.prefill(&mut seq);
    assert!(!seq.prefill_complete());
    assert!(seq.finished());
    assert_eq!(seq.finish_reason().as_str(), "cache_full");
    // Nothing was published: a new identical prompt gets no prefix hit
    // (the aborted prefill must never seed the radix tree).
    drop(seq); // release the pool first
    let mut again = e.admit(1, prompt, 4, Sampling::Greedy);
    let _ = e.prefill_chunk(&mut again, 4); // first chunk runs the match
    assert_eq!(again.prefix_hit_tokens, 0, "partial prefill must not publish");
    drop(again);

    // Coordinator path: the oversized request is force-admitted, runs out
    // of pool mid-prompt with nobody to preempt, and completes cache_full.
    let coord = Coordinator::new(
        Arc::new(e),
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 2,
                max_queue: 8,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    let resp = coord
        .submit_blocking(prompt, 4, Sampling::Greedy)
        .expect("submit");
    assert_eq!(resp.finish_reason, "cache_full");
    assert_eq!(resp.n_generated, 0, "half-prefilled sequences never decode");
    coord.shutdown();
    handle.join().unwrap();
}

/// Fairness: a long prompt arriving while a short sequence decodes must not
/// stall it — the scheduler interleaves the short sequence's decode steps
/// between the long prompt's chunks, so the short request finishes while
/// the long prefill is still in flight (under the old inline prefill, the
/// whole 200-token prompt ran to completion inside one scheduler iteration
/// and every decode stalled behind it).
#[test]
fn long_prompt_does_not_stall_short_decodes() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let sp = teal(&model, 0.45);
    let chunk = 8usize;
    let long_len = 200usize;
    let engine = Arc::new(Engine::paged(
        Arc::clone(&model),
        Arc::clone(&sp),
        EngineCfg {
            threads: 1,
            prefill_chunk: chunk,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 128,
            block_size: 4,
            prefix_cache: false,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 16,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    // Short first (so it sits ahead in the FIFO active set and begins
    // decoding), then the long prompt lands behind it.
    let short_rx = coord.submit("hey", 6, Sampling::Greedy).expect("short submit");
    let long_prompt: String = (0..long_len).map(|i| (b'a' + (i % 26) as u8) as char).collect();
    let long_rx = coord
        .submit(&long_prompt, 8, Sampling::Greedy)
        .expect("long submit");
    let short = short_rx.recv().expect("short completion");
    assert_eq!(short.n_generated, 6);
    // The short request's decode steps ran between the long prompt's
    // chunks: when it completes, the long prefill (>= 25 chunk iterations
    // at 8 tokens per chunk) is still in flight.
    assert!(
        long_rx.try_recv().is_err(),
        "short request should finish while the long prompt is still prefilling"
    );
    let long = long_rx.recv().expect("long completion");
    assert_eq!(long.n_generated, 8);
    assert!(
        short.total_ms < long.total_ms,
        "short ({:.1} ms) stalled behind long ({:.1} ms)",
        short.total_ms,
        long.total_ms
    );
    let m = coord.metrics.lock().unwrap();
    // The prompt really was split: ceil(200 / budget) chunks minimum, where
    // the budget shrinks below `chunk` only by the one co-decoding seq.
    assert!(
        m.prefill_chunks_total as usize >= long_len / chunk,
        "expected >= {} chunks, got {}",
        long_len / chunk,
        m.prefill_chunks_total
    );
    assert!(m.decode_gap_ms.count > 0, "decode-gap fairness metric must have sampled");
    drop(m);
    coord.shutdown();
    handle.join().unwrap();
}

/// Streaming cancellation: dropping the stream receiver (the HTTP layer
/// also calls `cancel` explicitly on a broken pipe) must stop the decode
/// and return every KV block to the pool — no leaks, no wasted compute to
/// completion.
#[test]
fn cancelled_stream_frees_blocks_and_stops_decode() {
    // llama-micro, not nano: the generation must take long enough that the
    // cancellation always lands well before a natural completion.
    let model = Arc::new(Model::synthetic(ModelConfig::preset("llama-micro").unwrap(), 81));
    let sp = teal(&model, 0.45);
    let engine = Arc::new(Engine::paged(
        Arc::clone(&model),
        Arc::clone(&sp),
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 64,
            block_size: 4,
            prefix_cache: false,
        },
    ));
    let engine_probe = Arc::clone(&engine);
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 2,
                max_queue: 8,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    let (id, rx) = coord
        .submit_stream("stream and vanish", 200, Sampling::Greedy, true)
        .expect("stream submit");
    // Consume a few tokens, then hang up like a disconnected client.
    let mut got = 0usize;
    for ev in rx.iter() {
        if let StreamEvent::Token { .. } = ev {
            got += 1;
            if got == 3 {
                break;
            }
        }
    }
    coord.cancel(id);
    drop(rx);
    // The scheduler tears the sequence down on its next pass: wait for the
    // cancellation to land, then assert every block went back to the pool.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let cancelled = coord.metrics.lock().unwrap().cancellations_total;
        if cancelled == 1 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "cancellation never processed");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let mgr = engine_probe.kv.as_ref().expect("paged engine");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        if mgr.blocks_in_use() == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "cancelled sequence leaked {} blocks",
            mgr.blocks_in_use()
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let (allocs, frees) = mgr.pool().counters();
    assert_eq!(allocs, frees, "pool leak counters disagree after cancel");
    // A follow-up request still serves normally (the scheduler survived).
    let resp = coord
        .submit_blocking("still alive", 4, Sampling::Greedy)
        .expect("post-cancel request");
    assert_eq!(resp.n_generated, 4);
    coord.shutdown();
    handle.join().unwrap();
}

/// `submit_stream` hands back the id used for cancellation; cancelling a
/// still-queued request drops it before it ever runs.
#[test]
fn cancel_queued_request_never_runs() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let sp = teal(&model, 0.45);
    let engine = Arc::new(Engine::new(
        Arc::clone(&model),
        Arc::clone(&sp),
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 1,
                max_queue: 8,
            },
            ..CoordinatorCfg::default()
        },
    );
    // No scheduler yet: both requests queue.
    let _head = coord.submit("head", 4, Sampling::Greedy).expect("head");
    let (id, rx) = coord
        .submit_stream("queued forever", 4, Sampling::Greedy, true)
        .expect("queued stream");
    coord.cancel(id);
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    // The head request completes normally; the cancelled one's channel
    // closes without a single event ever having been produced.
    let head = _head.recv().expect("head completion");
    assert_eq!(head.n_generated, 4);
    assert!(rx.recv().is_err(), "cancelled request must never produce events");
    assert_eq!(coord.metrics.lock().unwrap().requests_total, 1);
    coord.shutdown();
    handle.join().unwrap();
}
