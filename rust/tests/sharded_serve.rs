//! Integration: the sharded serving stack — the prefix-affinity router over
//! N engine replicas, behind both front ends (epoll reactor and the legacy
//! blocking path).
//!
//! Covers the acceptance criteria for the sharded front end:
//! - prefix-affinity routing keeps a shared-prefix group on one replica,
//!   with spill-to-least-loaded only under saturation;
//! - aggregate prefix hit rate at 2 replicas matches the single-replica
//!   baseline (each group's cache locality survives sharding);
//! - reactor and blocking front ends are behaviorally equivalent (same
//!   bodies and terminal reasons, non-streaming and NDJSON streaming);
//! - the chaos invariant (every request terminates exactly once, pool
//!   counters balance) holds under the reactor with 2 replicas and
//!   scripted faults, including a replica-level scheduler crash;
//! - reactor keep-alive serves several requests per connection.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;
use wisparse::model::{Model, ModelConfig};
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::faults::Faults;
use wisparse::server::{Coordinator, CoordinatorCfg, GenRequest, ReactorCfg, Router, RouterCfg};
use wisparse::sparsity::Dense;
use wisparse::util::json::Json;

/// N replicas over one synthetic model, each with its own scheduler thread
/// and KV pool slice. `faults[r]` (when present and non-empty) arms a
/// scripted fault schedule on replica r's engine.
fn build_router(
    n: usize,
    prefix_k: usize,
    faults: &[&str],
    seed: u64,
    prefix_cache: bool,
) -> (Arc<Router>, Vec<std::thread::JoinHandle<()>>) {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), seed));
    let mut replicas = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for r in 0..n {
        let mut e = Engine::paged(
            Arc::clone(&model),
            Arc::new(Dense),
            EngineCfg {
                threads: 2,
                ..EngineCfg::default()
            },
            &wisparse::kv::KvCfg {
                pool_blocks: 128,
                block_size: 8,
                prefix_cache,
            },
        );
        if let Some(f) = faults.get(r) {
            if !f.is_empty() {
                e.faults = Faults::scripted(f);
            }
        }
        let coord = Coordinator::new(
            Arc::new(e),
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch: 4,
                    max_queue: 64,
                },
                drain_timeout: Duration::from_secs(10),
                replica_id: r,
                ..CoordinatorCfg::default()
            },
        );
        let sched = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || sched.run_scheduler()));
        replicas.push(coord);
    }
    let router = Router::new(
        replicas,
        RouterCfg {
            prefix_k,
            ..RouterCfg::default()
        },
    );
    (router, handles)
}

fn drain_and_join(router: &Arc<Router>, handles: Vec<std::thread::JoinHandle<()>>) {
    router.drain();
    for h in handles {
        h.join().unwrap();
    }
    assert!(router.is_shutdown() && router.all_schedulers_exited());
}

fn start_reactor(router: &Arc<Router>) -> String {
    let (tx, rx) = std::sync::mpsc::channel();
    let r = Arc::clone(router);
    std::thread::spawn(move || {
        wisparse::server::reactor::serve(r, "127.0.0.1:0", ReactorCfg::default(), move |a| {
            tx.send(a).unwrap();
        })
        .unwrap();
    });
    rx.recv().unwrap().to_string()
}

fn start_blocking(router: &Arc<Router>) -> String {
    let (tx, rx) = std::sync::mpsc::channel();
    let r = Arc::clone(router);
    std::thread::spawn(move || {
        wisparse::server::http::serve_blocking(r, "127.0.0.1:0", move |a| {
            tx.send(a).unwrap();
        })
        .unwrap();
    });
    rx.recv().unwrap().to_string()
}

fn send_request(stream: &mut TcpStream, method: &str, path: &str, body: &str) {
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
}

/// Read one Content-Length-framed response off `reader`, leaving the
/// connection usable for the next request (keep-alive).
fn read_response(reader: &mut BufReader<TcpStream>) -> (u16, Vec<(String, String)>, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"))
        .parse()
        .unwrap();
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.trim_end().split_once(':') {
            let k = k.to_ascii_lowercase();
            let v = v.trim().to_string();
            if k == "content-length" {
                content_length = v.parse().unwrap();
            }
            headers.push((k, v));
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf).unwrap();
    (status, headers, String::from_utf8(buf).unwrap())
}

fn request(addr: &str, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    send_request(&mut stream, method, path, body);
    let mut reader = BufReader::new(stream);
    let (status, _, body) = read_response(&mut reader);
    (status, body)
}

/// Like [`request`] but for a `Transfer-Encoding: chunked` response:
/// returns the status and the reassembled body.
fn request_chunked(addr: &str, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    send_request(&mut stream, method, path, body);
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("transfer-encoding") {
                chunked = v.trim().eq_ignore_ascii_case("chunked");
            }
        }
    }
    assert!(chunked, "streaming response must be chunked");
    let mut out = String::new();
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line).unwrap();
        let size = usize::from_str_radix(size_line.trim(), 16).unwrap();
        if size == 0 {
            break;
        }
        let mut buf = vec![0u8; size + 2]; // chunk data + trailing CRLF
        reader.read_exact(&mut buf).unwrap();
        out.push_str(std::str::from_utf8(&buf[..size]).unwrap());
    }
    (status, out)
}

/// A 48-byte shared prefix (>= prefix_k, so the prefix alone decides the
/// route for every prompt extending it) whose affinity replica is `want`.
fn prefix_with_affinity(router: &Arc<Router>, want: usize) -> String {
    for salt in 0..64 {
        let prefix = format!("{:.<48}", format!("group {salt} shared prefix "));
        assert!(prefix.len() >= router.cfg().prefix_k);
        if router.affinity_replica(&prefix) == want {
            return prefix;
        }
    }
    panic!("no 48-byte prefix found with affinity {want}");
}

// ---------------------------------------------------------------------------
// Routing
// ---------------------------------------------------------------------------

/// Prompts sharing a first-`prefix_k`-byte prefix all route to the same
/// replica, routing is all-affinity under light load, and the per-replica
/// request counters account for exactly the groups pinned to each replica.
#[test]
fn prefix_groups_route_wholly_to_one_replica() {
    let (router, handles) = build_router(2, 16, &[], 301, true);
    let group_a = prefix_with_affinity(&router, 0);
    let group_b = prefix_with_affinity(&router, 1);
    let mut sent = [0usize; 2];
    for prefix in [&group_a, &group_b] {
        let want = router.affinity_replica(prefix);
        for i in 0..3 {
            let prompt = format!("{prefix} q{i}");
            assert_eq!(
                router.affinity_replica(&prompt),
                want,
                "suffix changed the route for {prompt:?}"
            );
            let resp = router
                .submit_request_blocking(GenRequest::new(0, &prompt, 4))
                .unwrap();
            assert_eq!(resp.finish_reason, "length");
            sent[want] += 1;
        }
    }
    let m = router.metrics_json();
    let routed = m.get("router");
    assert_eq!(routed.get("routed_affinity_total").as_usize(), Some(6));
    assert_eq!(routed.get("routed_spill_total").as_usize(), Some(0));
    assert_eq!(routed.get("shed_total").as_usize(), Some(0));
    // Every group's requests landed wholly on its affinity replica.
    if let Json::Arr(reps) = m.get("replicas") {
        assert_eq!(reps.len(), 2);
        for (i, r) in reps.iter().enumerate() {
            assert_eq!(r.get("replica").as_usize(), Some(i));
            assert_eq!(
                r.get("requests_total").as_usize(),
                Some(sent[i]),
                "replica {i} request count"
            );
        }
    } else {
        panic!("metrics_json missing replicas[]: {m:?}");
    }
    // The aggregate view still carries the single-engine keys.
    assert_eq!(m.get("requests_total").as_usize(), Some(6));
    drain_and_join(&router, handles);
}

/// With the spill threshold forced to zero, a prompt pinned to a busy-by-
/// definition replica spills to the least-loaded one instead of queueing.
#[test]
fn saturated_affinity_replica_spills_to_least_loaded() {
    let (router, handles) = build_router(2, 16, &[], 302, true);
    // Rebuild with spill_at = 0 semantics by routing directly: a fresh
    // router over the same replicas with the aggressive threshold.
    let spilly = Router::new(
        router.replicas().to_vec(),
        RouterCfg {
            prefix_k: 16,
            spill_at: 0,
        },
    );
    // A prompt whose affinity is replica 1: with spill_at=0 its affinity
    // queue counts as saturated, and the least-loaded tie-break picks
    // replica 0 — a genuine spill.
    let prefix = prefix_with_affinity(&spilly, 1);
    let (idx, outcome) = spilly.route_replica(&format!("{prefix} q"));
    assert_eq!(idx, 0, "spill must pick the other replica");
    assert_eq!(outcome, wisparse::server::router::RouteOutcome::Spill);
    // A prompt already pinned to the least-loaded replica cannot spill.
    let prefix0 = prefix_with_affinity(&spilly, 0);
    let (idx, outcome) = spilly.route_replica(&format!("{prefix0} q"));
    assert_eq!(idx, 0);
    assert_eq!(outcome, wisparse::server::router::RouteOutcome::Affinity);
    drain_and_join(&router, handles);
}

/// Sharding must not cost prefix-cache locality: the aggregate hit rate at
/// 2 replicas stays within 10% of the single-replica baseline on the same
/// shared-prefix workload (affinity keeps each group's cache warm on one
/// replica).
#[test]
fn prefix_hit_rate_parity_across_shard_counts() {
    fn run(n: usize) -> f64 {
        let (router, handles) = build_router(n, 16, &[], 303, true);
        for g in 0..4 {
            let prefix = format!("{:.<48}", format!("hit rate group {g} "));
            for i in 0..3 {
                let resp = router
                    .submit_request_blocking(GenRequest::new(0, &format!("{prefix} s{i}"), 4))
                    .unwrap();
                assert_eq!(resp.finish_reason, "length");
            }
        }
        let m = router.metrics_json();
        let rate = m.get("prefix_hit_rate").as_f64().unwrap();
        drain_and_join(&router, handles);
        rate
    }
    let baseline = run(1);
    let sharded = run(2);
    assert!(
        baseline > 0.3,
        "workload must exercise the prefix cache: {baseline}"
    );
    assert!(
        (baseline - sharded).abs() <= 0.10 * baseline.max(1e-9),
        "sharded hit rate {sharded} diverged from baseline {baseline}"
    );
}

// ---------------------------------------------------------------------------
// Front-end equivalence
// ---------------------------------------------------------------------------

/// The reactor and the blocking front end serve byte-equivalent results
/// over identical 2-replica stacks: same JSON fields for non-streaming
/// generates, same reassembled NDJSON stream, same error statuses.
#[test]
fn reactor_matches_blocking_front_end() {
    let (r_reactor, h_reactor) = build_router(2, 16, &[], 304, true);
    let (r_blocking, h_blocking) = build_router(2, 16, &[], 304, true);
    let addr_r = start_reactor(&r_reactor);
    let addr_b = start_blocking(&r_blocking);

    for prompt in ["abc def", "hello world pad", "12+34=", "the sun is"] {
        let body = format!(r#"{{"prompt": "{prompt}", "max_new": 5}}"#);
        let (sr, br) = request(&addr_r, "POST", "/generate", &body);
        let (sb, bb) = request(&addr_b, "POST", "/generate", &body);
        assert_eq!(sr, 200, "{br}");
        assert_eq!(sb, 200, "{bb}");
        let jr = Json::parse(&br).unwrap();
        let jb = Json::parse(&bb).unwrap();
        for key in ["text", "finish_reason"] {
            assert_eq!(
                jr.get(key).as_str(),
                jb.get(key).as_str(),
                "{prompt:?} diverged on {key}"
            );
        }
        assert_eq!(
            jr.get("generated_tokens").as_usize(),
            jb.get("generated_tokens").as_usize()
        );
    }

    // Streaming: same token sequence, same done summary.
    let body = r#"{"prompt": "stream parity pad", "max_new": 5, "stream": true}"#;
    let (sr, nr) = request_chunked(&addr_r, "POST", "/generate", body);
    let (sb, nb) = request_chunked(&addr_b, "POST", "/generate", body);
    assert_eq!(sr, 200);
    assert_eq!(sb, 200);
    let parse_lines = |nd: &str| -> (Vec<String>, String) {
        let lines: Vec<&str> = nd.lines().filter(|l| !l.is_empty()).collect();
        let done = Json::parse(lines.last().unwrap()).unwrap();
        assert_eq!(done.get("done").as_bool(), Some(true));
        (
            lines[..lines.len() - 1]
                .iter()
                .map(|l| {
                    Json::parse(l)
                        .unwrap()
                        .get("token")
                        .as_str()
                        .unwrap()
                        .to_string()
                })
                .collect(),
            done.get("text").as_str().unwrap().to_string(),
        )
    };
    let (toks_r, text_r) = parse_lines(&nr);
    let (toks_b, text_b) = parse_lines(&nb);
    assert_eq!(toks_r, toks_b, "streamed tokens diverged");
    assert_eq!(text_r, text_b);

    // Error statuses agree.
    for (method, path, body, want) in [
        ("POST", "/generate", "not json", 400u16),
        ("GET", "/nope", "", 404),
        ("GET", "/health", "", 200),
        ("GET", "/metrics", "", 200),
    ] {
        let (sr, _) = request(&addr_r, method, path, body);
        let (sb, _) = request(&addr_b, method, path, body);
        assert_eq!(sr, want, "{method} {path} on reactor");
        assert_eq!(sb, want, "{method} {path} on blocking");
    }

    drain_and_join(&r_reactor, h_reactor);
    drain_and_join(&r_blocking, h_blocking);
}

/// Keep-alive on the reactor: one connection serves several requests
/// back to back, and non-streaming responses advertise keep-alive.
#[test]
fn reactor_keep_alive_serves_many_requests_per_connection() {
    let (router, handles) = build_router(2, 16, &[], 305, true);
    let addr = start_reactor(&router);
    let stream = TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    send_request(&mut writer, "GET", "/health", "");
    let (status, headers, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert!(body.contains("ok"));
    assert_eq!(
        headers
            .iter()
            .find(|(k, _)| k == "connection")
            .map(|(_, v)| v.as_str()),
        Some("keep-alive")
    );

    for i in 0..3 {
        send_request(
            &mut writer,
            "POST",
            "/generate",
            &format!(r#"{{"prompt": "keep alive {i}", "max_new": 3}}"#),
        );
        let (status, _, body) = read_response(&mut reader);
        assert_eq!(status, 200, "{body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("generated_tokens").as_usize(), Some(3));
    }

    send_request(&mut writer, "GET", "/metrics", "");
    let (status, _, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(
        Json::parse(&body).unwrap().get("requests_total").as_usize(),
        Some(3)
    );
    drain_and_join(&router, handles);
}

// ---------------------------------------------------------------------------
// Chaos under the sharded reactor
// ---------------------------------------------------------------------------

/// A scheduler-level crash on one replica stays contained: every request
/// still terminates exactly once, the healthy replica never notices, the
/// crashed one restarts its scheduler, and both pools balance after drain.
#[test]
fn replica_crash_contained_and_pool_balances() {
    let (router, handles) = build_router(2, 16, &["", "sched_panic@1"], 306, false);
    let crashed = 1usize;
    let healthy = 0usize;
    let p_healthy = prefix_with_affinity(&router, healthy);
    let p_crashed = prefix_with_affinity(&router, crashed);
    let results: Vec<_> = std::thread::scope(|s| {
        (0..8)
            .map(|i| {
                let router = Arc::clone(&router);
                let prefix = if i % 2 == 0 { &p_healthy } else { &p_crashed };
                let prompt = format!("{prefix} c{i}");
                s.spawn(move || router.submit_request_blocking(GenRequest::new(0, &prompt, 5)))
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    let mut ok = 0usize;
    let mut failed = 0usize;
    for r in results {
        let resp = r.expect("admission never fails under this load");
        assert!(!resp.finish_reason.is_empty());
        match resp.finish_reason.as_str() {
            "internal_error" => failed += 1,
            _ => ok += 1,
        }
    }
    assert_eq!(ok + failed, 8, "every request answered exactly once");
    assert!(ok >= 4, "healthy replica and restarted queue must complete");
    let m = router.metrics_json();
    if let Json::Arr(reps) = m.get("replicas") {
        assert_eq!(reps[healthy].get("panics_caught_total").as_usize(), Some(0));
        assert!(
            reps[crashed]
                .get("scheduler_restarts_total")
                .as_usize()
                .unwrap()
                >= 1,
            "crashed replica restarted its scheduler"
        );
    } else {
        panic!("metrics_json missing replicas[]");
    }
    drain_and_join(&router, handles);
    for i in 0..2 {
        let kv = router.replica(i).engine().kv.as_ref().unwrap();
        let (allocs, frees) = kv.pool().counters();
        assert_eq!(allocs, frees, "replica {i} pool leak");
        assert_eq!(kv.blocks_in_use(), 0, "replica {i} blocks still held");
    }
}

/// The PR-6 fault-injection invariant under the reactor with 2 replicas:
/// scripted engine faults on both replicas, concurrent HTTP clients plus a
/// mid-stream disconnect — every HTTP request gets exactly one complete
/// response with a sane status, and both pools balance after drain.
#[test]
fn fault_injection_under_reactor_with_two_replicas() {
    let (router, handles) = build_router(
        2,
        16,
        &["decode_panic@2", "sched_panic@1,decode_panic@3"],
        307,
        false,
    );
    let addr = start_reactor(&router);

    // A streaming client that hangs up mid-stream.
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        send_request(
            &mut stream,
            "POST",
            "/generate",
            r#"{"prompt": "stream chaos victim pad", "max_new": 8, "stream": true}"#,
        );
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        let _ = reader.read_line(&mut line); // at most the status line
    } // ...dropped: mid-stream disconnect

    let results: Vec<(u16, String)> = std::thread::scope(|s| {
        (0..6)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || {
                    request(
                        &addr,
                        "POST",
                        "/generate",
                        &format!(r#"{{"prompt": "chaos client {i} pad", "max_new": 5}}"#),
                    )
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (status, body) in &results {
        assert!(
            [200, 500, 503, 504].contains(status),
            "unexpected status {status}: {body}"
        );
        let j = Json::parse(body).unwrap_or_else(|e| panic!("unparseable body {body:?}: {e}"));
        if *status == 503 {
            // Shed at admission: an error body, no generation happened.
            assert!(j.get("error").as_str().is_some(), "{body}");
        } else {
            assert!(
                j.get("finish_reason").as_str().is_some_and(|r| !r.is_empty()),
                "terminal reason missing: {body}"
            );
        }
    }

    // Drain over HTTP, then let the schedulers exit.
    let (status, _) = request(&addr, "POST", "/admin/drain", "");
    assert_eq!(status, 202);
    for h in handles {
        h.join().unwrap();
    }
    assert!(router.all_schedulers_exited());
    for i in 0..2 {
        let kv = router.replica(i).engine().kv.as_ref().unwrap();
        let (allocs, frees) = kv.pool().counters();
        assert_eq!(allocs, frees, "replica {i} pool leak");
        assert_eq!(kv.blocks_in_use(), 0, "replica {i} blocks still held");
    }
}

// ---------------------------------------------------------------------------
// Aggregated observability
// ---------------------------------------------------------------------------

/// The 2-replica Prometheus page carries the merged unlabeled families,
/// the router families, and `replica`-labeled per-replica gauges.
#[test]
fn sharded_prometheus_page_has_router_and_replica_families() {
    let (router, handles) = build_router(2, 16, &[], 308, true);
    for g in 0..2 {
        let prefix = format!("{:.<48}", format!("prom group {g} "));
        router
            .submit_request_blocking(GenRequest::new(0, &format!("{prefix} p"), 3))
            .unwrap();
    }
    let page = router.metrics_prometheus();
    for family in [
        "wisparse_requests_total",
        "wisparse_router_replicas 2",
        "wisparse_router_routed_total{outcome=\"affinity\"}",
        "wisparse_replica_up{replica=\"0\"}",
        "wisparse_replica_up{replica=\"1\"}",
        "wisparse_replica_requests_total{replica=\"0\"}",
    ] {
        assert!(page.contains(family), "missing {family:?} in:\n{page}");
    }
    // The merged requests_total equals the sum of the replica-labeled ones.
    let total: f64 = page
        .lines()
        .find(|l| l.starts_with("wisparse_requests_total "))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap();
    assert_eq!(total, 2.0);
    drain_and_join(&router, handles);
}
