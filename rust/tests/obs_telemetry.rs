//! Observability integration: Prometheus exposition conformance, tracer
//! ring behavior under concurrent writers, no-op vs recording `ObsSink`
//! logit bit-identity, and end-to-end trace reconstruction of a served
//! request.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use wisparse::model::transformer::{ForwardStats, Model};
use wisparse::model::ModelConfig;
use wisparse::obs::{BlockObs, NoopSink, ObsSink, Span, Tracer};
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::Dense;
use wisparse::util::json::Json;

fn start_server() -> (Arc<Coordinator>, String) {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 99));
    let engine = Arc::new(Engine::paged(
        model,
        Arc::new(Dense),
        EngineCfg {
            threads: 2,
            ..EngineCfg::default()
        },
        &wisparse::kv::KvCfg {
            pool_blocks: 128,
            block_size: 8,
            prefix_cache: true,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 64,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    std::thread::spawn(move || sched.run_scheduler());
    let (tx, rx) = std::sync::mpsc::channel();
    let http_coord = Arc::clone(&coord);
    std::thread::spawn(move || {
        wisparse::server::http::serve(http_coord, "127.0.0.1:0", move |a| {
            tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = rx.recv().unwrap().to_string();
    (coord, addr)
}

/// Returns (status, content-type, body).
fn request(addr: &str, method: &str, path: &str, body: &str) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut content_length = 0usize;
    let mut content_type = String::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            } else if k.eq_ignore_ascii_case("content-type") {
                content_type = v.trim().to_string();
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf).unwrap();
    (status, content_type, String::from_utf8(buf).unwrap())
}

/// Minimal text-format 0.0.4 conformance check: every sample belongs to a
/// family with exactly one `# TYPE`, histogram buckets are cumulative and
/// monotone, and the `+Inf` bucket equals `_count`.
fn assert_prom_conformant(body: &str) {
    use std::collections::BTreeMap;
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap().to_string();
            let ty = it.next().unwrap().to_string();
            assert!(
                types.insert(name.clone(), ty).is_none(),
                "duplicate TYPE for {name}"
            );
        }
    }
    // (family, le) -> bucket count; family -> (_count, _sum seen).
    let mut buckets: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<String, f64> = BTreeMap::new();
    let mut sums: BTreeMap<String, f64> = BTreeMap::new();
    for line in body.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let name_end = line.find(|c| c == '{' || c == ' ').unwrap_or(line.len());
        let name = &line[..name_end];
        let value: f64 = {
            let v = line.rsplit(' ').next().unwrap();
            if v == "+Inf" {
                f64::INFINITY
            } else {
                v.parse().unwrap_or_else(|_| panic!("bad value in `{line}`"))
            }
        };
        // Resolve the declared family: exact, or histogram component.
        let family = if types.contains_key(name) {
            name.to_string()
        } else {
            let base = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_count"))
                .or_else(|| name.strip_suffix("_sum"))
                .unwrap_or_else(|| panic!("sample `{name}` has no TYPE"));
            assert_eq!(
                types.get(base).map(String::as_str),
                Some("histogram"),
                "sample `{name}` has no TYPE"
            );
            base.to_string()
        };
        if name.ends_with("_bucket") && types.get(&family).map(String::as_str) == Some("histogram")
        {
            let le_start = line.find("le=\"").unwrap_or_else(|| panic!("no le in `{line}`")) + 4;
            let le_str = &line[le_start..line[le_start..].find('"').unwrap() + le_start];
            let le = if le_str == "+Inf" {
                f64::INFINITY
            } else {
                le_str.parse().unwrap()
            };
            buckets.entry(family).or_default().push((le, value));
        } else if name.ends_with("_count") && types.contains_key(&family) {
            counts.insert(family, value);
        } else if name.ends_with("_sum") && types.contains_key(&family) {
            sums.insert(family, value);
        }
    }
    for (family, ty) in &types {
        if ty != "histogram" {
            continue;
        }
        let b = buckets
            .get(family)
            .unwrap_or_else(|| panic!("histogram {family} has no buckets"));
        assert!(
            b.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 <= w[1].1),
            "{family} buckets not monotone: {b:?}"
        );
        let (last_le, last_count) = *b.last().unwrap();
        assert!(last_le.is_infinite(), "{family} missing +Inf bucket");
        assert_eq!(
            Some(&last_count),
            counts.get(family),
            "{family}: +Inf bucket != _count"
        );
        assert!(sums.contains_key(family), "{family} missing _sum");
    }
}

#[test]
fn prometheus_exposition_is_conformant() {
    let (coord, addr) = start_server();
    let (status, _, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "conformance probe", "max_new": 4}"#,
    );
    assert_eq!(status, 200, "{body}");
    let (status, ctype, prom) = request(&addr, "GET", "/metrics?format=prometheus", "");
    assert_eq!(status, 200);
    assert_eq!(ctype, "text/plain; version=0.0.4; charset=utf-8");
    assert_prom_conformant(&prom);
    // Spot-check the families the scrape config in README names.
    for family in [
        "# TYPE wisparse_requests_total counter",
        "# TYPE wisparse_queue_ms histogram",
        "# TYPE wisparse_total_ms histogram",
        "# TYPE wisparse_decode_gap_ms histogram",
        "# TYPE wisparse_throughput_window_tok_s gauge",
        "# TYPE wisparse_finished_total counter",
    ] {
        assert!(prom.contains(family), "missing `{family}`");
    }
    assert!(
        prom.contains("wisparse_finished_total{reason=\"length\"} 1"),
        "finished counter: {prom}"
    );
    // The JSON view stays the default and keeps its keys.
    let (_, ctype, json) = request(&addr, "GET", "/metrics", "");
    assert_eq!(ctype, "application/json");
    let m = Json::parse(&json).unwrap();
    assert_eq!(m.get("requests_total").as_usize(), Some(1));
    assert!(m.get("throughput_window_tok_s").as_f64().is_some());
    assert_eq!(m.get("finished_total").get("length").as_usize(), Some(1));
    coord.shutdown();
}

#[test]
fn tracer_ring_wraps_under_concurrent_writers() {
    let t = Arc::new(Tracer::with_capacity(64));
    let threads: Vec<_> = (0..8u64)
        .map(|tid| {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                for i in 0..1000u64 {
                    let mut s = Span::new(tid + 1, t.next_span_id(), 0, "w");
                    s.start_ns = i;
                    s.dur_ns = 1;
                    t.record(s);
                }
            })
        })
        .collect();
    for h in threads {
        h.join().unwrap();
    }
    assert_eq!(t.written(), 8000);
    // The ring retains exactly `capacity` spans, all well-formed.
    let retained: Vec<Span> = (1..=8).flat_map(|tid| t.trace(tid)).collect();
    assert_eq!(retained.len(), 64);
    for s in &retained {
        assert_eq!(s.name, "w");
        assert!(s.trace_id >= 1 && s.trace_id <= 8);
        assert!(s.start_ns < 1000);
        assert_eq!(s.dur_ns, 1);
    }
    // Span ids are unique even under contention.
    let mut ids: Vec<u64> = retained.iter().map(|s| s.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 64);
}

#[test]
fn recording_sink_keeps_logits_bit_identical() {
    let cfg = ModelConfig::preset("nano").unwrap();
    let mut noop_model = Model::synthetic(cfg.clone(), 321);
    let mut rec_model = Model::synthetic(cfg, 321);
    noop_model.set_obs_sink(Arc::new(NoopSink));
    let obs = Arc::new(BlockObs::new(rec_model.cfg.n_layers));
    rec_model.set_obs_sink(Arc::clone(&obs) as Arc<dyn ObsSink>);
    let tokens = [7usize, 3, 9, 1, 14, 2];
    let mut s1 = ForwardStats::default();
    let mut s2 = ForwardStats::default();
    let a = noop_model.forward_seq(&tokens, &Dense, &mut s1, None);
    let b = rec_model.forward_seq(&tokens, &Dense, &mut s2, None);
    assert_eq!(a.data.len(), b.data.len());
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "logit {i} diverged");
    }
    // The recording sink actually saw the traffic: every (block, proj) row.
    let rows = obs.snapshot();
    assert!(!rows.is_empty());
    for r in &rows {
        assert_eq!(r.calls, tokens.len() as u64, "{:?}", r.id);
        assert!(r.dense_channels > 0 && r.bytes > 0);
        assert!((r.density() - 1.0).abs() < 1e-12, "dense pass keeps all");
    }
    assert!(noop_model.obs.snapshot().is_empty());
}

#[test]
fn served_request_reconstructs_end_to_end() {
    let (coord, addr) = start_server();
    let (status, _, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "trace me through the whole stack", "max_new": 8}"#,
    );
    assert_eq!(status, 200, "{body}");
    let resp = Json::parse(&body).unwrap();
    let trace_id = resp.get("trace_id").as_usize().unwrap();
    assert!(trace_id > 0, "served response must carry a trace id");

    let (status, ctype, body) =
        request(&addr, "GET", &format!("/debug/traces?id={trace_id}"), "");
    assert_eq!(status, 200, "{body}");
    assert_eq!(ctype, "application/json");
    let t = Json::parse(&body).unwrap();
    assert_eq!(t.get("trace_id").as_usize(), Some(trace_id));
    let spans = t.get("spans").as_arr().unwrap();
    assert_eq!(spans.len(), t.get("n_spans").as_usize().unwrap());
    let names: Vec<&str> = spans.iter().filter_map(|s| s.get("name").as_str()).collect();
    for expected in ["http_parse", "request", "queue", "prefill_chunk", "decode_step"] {
        assert!(names.contains(&expected), "missing span `{expected}` in {names:?}");
    }
    let root = spans
        .iter()
        .find(|s| s.get("name").as_str() == Some("request"))
        .unwrap();
    assert_eq!(root.get("parent").as_usize(), Some(0));
    let root_id = root.get("id").as_usize().unwrap();
    let root_start = root.get("start_ms").as_f64().unwrap();
    let root_end = root_start + root.get("dur_ms").as_f64().unwrap();
    let total_ms = root.get("attrs").get("total_ms").as_f64().unwrap();
    assert!(
        (root.get("dur_ms").as_f64().unwrap() - total_ms).abs() < 0.5,
        "root span duration must agree with total_ms"
    );
    // Every child nests inside the root's window (small clock slack).
    let mut children = 0;
    for s in spans {
        if s.get("parent").as_usize() == Some(root_id) {
            children += 1;
            let start = s.get("start_ms").as_f64().unwrap();
            let end = start + s.get("dur_ms").as_f64().unwrap();
            assert!(
                start >= root_start - 1.0 && end <= root_end + 1.0,
                "span {s:?} outside root [{root_start}, {root_end}]"
            );
        }
    }
    assert!(children >= 3, "queue + prefill + decode at minimum");

    // The slow-exemplar tables picked the request up.
    let (status, _, body) = request(&addr, "GET", "/debug/traces/slow", "");
    assert_eq!(status, 200);
    let slow = Json::parse(&body).unwrap();
    let by_total = slow.get("by_total_ms").as_arr().unwrap();
    assert!(by_total
        .iter()
        .any(|s| s.get("trace_id").as_usize() == Some(trace_id)));
    assert!(!slow.get("by_decode_gap_ms").as_arr().unwrap().is_empty());

    // Missing / malformed ids are 400s, not panics.
    assert_eq!(request(&addr, "GET", "/debug/traces", "").0, 400);
    assert_eq!(request(&addr, "GET", "/debug/traces?id=bogus", "").0, 400);
    coord.shutdown();
}
