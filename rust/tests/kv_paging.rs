//! Paged KV-cache subsystem: pool refcount invariants under concurrency,
//! bit-identical paged-vs-flat attention, prefix-cache reuse, and
//! scheduler preemption (ISSUE 2 acceptance criteria).

use std::sync::Arc;
use wisparse::kv::{BlockPool, KvCfg, KvLayout, KvManager, KvSeq, PagedSeq};
use wisparse::model::kv_cache::KvCache;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::{ForwardStats, Model, Scratch};
use wisparse::model::ModelConfig;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg, FinishReason};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::{Dense, Sparsifier};
use wisparse::util::rng::Pcg64;

/// Property: under concurrent alloc/retain/release from many threads, the
/// pool never double-frees (that panics) and never leaks — after every
/// thread drops its references, all blocks are free and lifetime allocs
/// equal lifetime frees.
#[test]
fn pool_refcount_invariant_under_concurrency() {
    let pool = BlockPool::new(
        KvLayout {
            n_layers: 1,
            d_model: 4,
            block_size: 2,
        },
        64,
    );
    let n_threads = 8;
    let iters = 2000;
    std::thread::scope(|s| {
        for t in 0..n_threads {
            let pool = Arc::clone(&pool);
            s.spawn(move || {
                let mut rng = Pcg64::new(0xB10C + t as u64);
                // Per-thread multiset of held references.
                let mut held: Vec<u32> = Vec::new();
                for _ in 0..iters {
                    match rng.below(4) {
                        0 | 1 => {
                            if let Some(id) = pool.try_alloc() {
                                held.push(id);
                            }
                        }
                        2 => {
                            if !held.is_empty() {
                                // Extra ref on a random held block.
                                let id = held[rng.below(held.len())];
                                pool.retain(id);
                                held.push(id);
                            }
                        }
                        _ => {
                            if !held.is_empty() {
                                let i = rng.below(held.len());
                                let id = held.swap_remove(i);
                                pool.release(id);
                            }
                        }
                    }
                }
                for id in held {
                    pool.release(id);
                }
            });
        }
    });
    assert_eq!(pool.blocks_in_use(), 0, "leak: blocks still referenced");
    assert_eq!(pool.blocks_free(), 64);
    let (allocs, frees) = pool.counters();
    assert_eq!(allocs, frees, "every allocated block was freed exactly once");
    assert!(allocs > 0, "the property test actually allocated");
}

fn teal(model: &Model, tau: f32) -> Arc<dyn Sparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau })
            .collect(),
    ))
}

/// Decode `tokens` twice — flat slab vs paged pool — and require logits to
/// be bit-identical at every position. The paged run uses a block size
/// that doesn't divide the sequence length, so chunk boundaries are
/// exercised mid-attention.
fn assert_paged_matches_flat(model: &Model, sp: &dyn Sparsifier, tokens: &[usize], bs: usize) {
    let mgr = KvManager::new(
        &model.cfg,
        &KvCfg {
            pool_blocks: model.cfg.max_seq.div_ceil(bs) + 2,
            block_size: bs,
            prefix_cache: true,
        },
    );
    let mut flat = KvCache::new(&model.cfg);
    let (mut paged, hit) = mgr.acquire(tokens);
    assert_eq!(hit, 0, "cold cache");
    let mut scratch_a = Scratch::new(&model.cfg);
    let mut scratch_b = Scratch::new(&model.cfg);
    let mut stats = ForwardStats::default();
    let mut la: Vec<f32> = Vec::new();
    let mut lb: Vec<f32> = Vec::new();
    for (pos, &t) in tokens.iter().enumerate() {
        model.forward_token(t, &mut flat, sp, &mut scratch_a, &mut stats, &mut la);
        assert!(mgr.try_reserve(&mut paged));
        model.forward_token(t, &mut paged, sp, &mut scratch_b, &mut stats, &mut lb);
        for v in 0..model.cfg.vocab_size {
            assert_eq!(
                la[v].to_bits(),
                lb[v].to_bits(),
                "logit mismatch at pos {pos} vocab {v}: {} vs {}",
                la[v],
                lb[v]
            );
        }
    }
}

#[test]
fn paged_attention_bit_identical_to_flat() {
    let model = Model::synthetic(ModelConfig::preset("nano").unwrap(), 42);
    let mut rng = Pcg64::new(7);
    let tokens: Vec<usize> = (0..37).map(|_| rng.below(model.cfg.vocab_size)).collect();
    // Dense and sparse execution, block sizes that straddle the length.
    for bs in [3usize, 16, 64] {
        assert_paged_matches_flat(&model, &Dense, &tokens, bs);
    }
    let sp = teal(&model, 0.4);
    assert_paged_matches_flat(&model, sp.as_ref(), &tokens, 5);
}

#[test]
fn paged_attention_bit_identical_on_larger_model() {
    let model = Model::synthetic(ModelConfig::preset("qwen-micro").unwrap(), 11);
    let mut rng = Pcg64::new(13);
    let tokens: Vec<usize> = (0..21).map(|_| rng.below(model.cfg.vocab_size)).collect();
    assert_paged_matches_flat(&model, &Dense, &tokens, 4);
}

/// A prompt served from the prefix cache must produce bit-identical logits
/// to the same prompt computed cold: the shared pages ARE the cold run's
/// pages.
#[test]
fn prefix_cache_hit_is_bit_identical() {
    let model = Model::synthetic(ModelConfig::preset("nano").unwrap(), 42);
    let cfg = &model.cfg;
    let bs = 4usize;
    let mgr = KvManager::new(
        cfg,
        &KvCfg {
            pool_blocks: 64,
            block_size: bs,
            prefix_cache: true,
        },
    );
    let mut rng = Pcg64::new(3);
    let prompt: Vec<usize> = (0..19).map(|_| rng.below(cfg.vocab_size)).collect();

    // Cold run; publish the prompt's full blocks.
    let (mut cold, hit) = mgr.acquire(&prompt);
    assert_eq!(hit, 0);
    let mut scratch = Scratch::new(cfg);
    let mut stats = ForwardStats::default();
    let mut cold_logits: Vec<f32> = Vec::new();
    for &t in &prompt {
        assert!(mgr.try_reserve(&mut cold));
        model.forward_token(t, &mut cold, &Dense, &mut scratch, &mut stats, &mut cold_logits);
    }
    mgr.insert_prefix(&prompt, &cold);

    // Warm run: adopts (19-1)/4*4 = 16 tokens, computes the last 3.
    let (mut warm, hit) = mgr.acquire(&prompt);
    assert_eq!(hit, 16);
    assert_eq!(warm.seq_len(), 16);
    // Shared blocks are physically the same pages.
    assert_eq!(&cold.blocks()[..4], warm.blocks());
    let mut scratch2 = Scratch::new(cfg);
    let mut warm_logits: Vec<f32> = Vec::new();
    for &t in &prompt[16..] {
        assert!(mgr.try_reserve(&mut warm));
        model.forward_token(t, &mut warm, &Dense, &mut scratch2, &mut stats, &mut warm_logits);
    }
    for v in 0..cfg.vocab_size {
        assert_eq!(
            cold_logits[v].to_bits(),
            warm_logits[v].to_bits(),
            "prefix-cached decode diverged at vocab {v}"
        );
    }
    let s = mgr.stats();
    assert_eq!(s.prefix_hit_tokens, 16);
}

/// Engine-level prefix sharing: identical prompts produce identical text,
/// the second sequence skips most of its prefill, and pages are shared.
#[test]
fn engine_prefix_sharing_skips_prefill_compute() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let engine = Engine::paged(
        Arc::clone(&model),
        teal(&model, 0.3),
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 64,
            block_size: 4,
            prefix_cache: true,
        },
    );
    let prompt = "a shared system prompt for everyone";
    let run = |engine: &Engine, id: u64| {
        let mut seq = engine.admit(id, prompt, 8, Sampling::Greedy);
        engine.prefill(&mut seq);
        while !seq.finished() {
            engine.decode_one(&mut seq);
        }
        seq
    };
    let a = run(&engine, 1);
    assert_eq!(a.prefix_hit_tokens, 0);
    let b = run(&engine, 2);
    let n = prompt.len();
    assert_eq!(b.prefix_hit_tokens, (n - 1) / 4 * 4, "warm prompt hits cache");
    assert_eq!(a.text(), b.text(), "shared prefix changed decoding");
    // The skipped tokens really skipped compute: fewer forward tokens.
    assert_eq!(
        b.stats.tokens + b.prefix_hit_tokens as u64,
        a.stats.tokens,
        "hit tokens were not recomputed"
    );
    assert_eq!(b.finish_reason(), FinishReason::Length);
}

/// Pool pressure with two co-resident sequences: the scheduler preempts
/// the youngest, requeues it at the head of the line, and the resumed
/// request completes with `preempted->resumed` while the older request
/// finishes normally.
#[test]
fn coordinator_preempts_youngest_and_resumes() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
    let engine = Arc::new(Engine::paged(
        model,
        Arc::new(Dense),
        EngineCfg {
            threads: 2,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 16,
            block_size: 4,
            prefix_cache: true,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 32,
            },
            ..CoordinatorCfg::default()
        },
    );
    // Two requests sharing a 16-token prompt, each needing 12 blocks worst
    // case (16 prompt + 32 new at bs=4) against a 16-block pool: admission
    // lets both in (B shares 3 prefix blocks), decode exhausts the pool,
    // B (younger) is preempted and resumed after A completes.
    let prompt = "abcdefghijklmnop"; // 16 one-byte tokens
    let rx_a = coord.submit(prompt, 32, Sampling::Greedy).unwrap();
    let rx_b = coord.submit(prompt, 32, Sampling::Greedy).unwrap();
    // Both queued before the scheduler starts: deterministic co-admission.
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    let resp_a = rx_a.recv().unwrap();
    let resp_b = rx_b.recv().unwrap();
    assert_eq!(resp_a.n_generated, 32);
    assert_eq!(resp_b.n_generated, 32);
    assert_eq!(resp_a.finish_reason, "length");
    assert_eq!(resp_b.finish_reason, "preempted->resumed");
    assert_eq!(resp_a.text, resp_b.text, "resume changed decoding");
    // Eviction skips blocks still mapped by live sequences, so the cached
    // prefix survives the pressure and the resumed request hits it again.
    assert_eq!(resp_b.prefix_hit_tokens, 12);
    let m = coord.metrics_json();
    assert!(
        m.get("preemptions_total").as_usize().unwrap() >= 1,
        "pool pressure must have preempted"
    );
    // Both of B's admissions (initial + resumed) shared A's prompt blocks:
    // 12 of 16 prompt tokens at bs=4, each time.
    assert!(
        m.get("prefix_hit_tokens").as_usize().unwrap() >= 24,
        "both admissions of the twin prompt hit the prefix cache"
    );
    assert_eq!(m.get("blocks_total").as_usize(), Some(16));
    coord.shutdown();
    handle.join().unwrap();
}

/// Oversized single request: too big for the whole pool, still makes
/// progress (force admission) and reports `cache_full` instead of hanging
/// or being silently truncated as `length`.
#[test]
fn oversized_request_finishes_cache_full() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 92));
    let engine = Arc::new(Engine::paged(
        model,
        Arc::new(Dense),
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 3,
            block_size: 4,
            prefix_cache: false,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 2,
                max_queue: 8,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    // 8 prompt tokens + 64 requested >> 12 pool positions.
    let resp = coord
        .submit_blocking("abcdefgh", 64, Sampling::Greedy)
        .unwrap();
    assert_eq!(resp.finish_reason, "cache_full");
    assert!(
        resp.n_generated < 64,
        "generated {} tokens from a 12-position pool",
        resp.n_generated
    );
    assert!(resp.n_generated > 0, "still produced output");
    coord.shutdown();
    handle.join().unwrap();
}

/// Paged decode through the engine equals the flat engine's output exactly
/// (text level), sparse path included.
#[test]
fn paged_engine_text_equals_flat_engine() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let sp = teal(&model, 0.3);
    let flat = Engine::new(
        Arc::clone(&model),
        Arc::clone(&sp),
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
    );
    let paged = Engine::paged(
        Arc::clone(&model),
        sp,
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 64,
            block_size: 8,
            prefix_cache: true,
        },
    );
    for prompt in ["12+34=", "the sun ", "abc"] {
        let (a, _) = flat.run_to_completion(prompt, 12, Sampling::Greedy);
        let (b, _) = paged.run_to_completion(prompt, 12, Sampling::Greedy);
        assert_eq!(a, b, "paged engine diverged on {prompt:?}");
    }
}

/// Property: random interleavings of append / truncate / external share on
/// a `PagedSeq` never leak or double-free pool blocks (double-free panics
/// inside the pool). After every round all references drop and the pool
/// must be empty with balanced lifetime counters. Truncation goes through
/// `KvManager::rollback`, so the prefix-cache invalidation path runs under
/// the same interleavings.
#[test]
fn truncate_interleaving_property_no_leak_no_double_free() {
    let cfg = ModelConfig::preset("nano").unwrap();
    let bs = 4usize;
    let mgr = KvManager::new(
        &cfg,
        &KvCfg {
            pool_blocks: 48,
            block_size: bs,
            prefix_cache: true,
        },
    );
    let d = cfg.d_model;
    let k = vec![0.25f32; d];
    let v = vec![0.75f32; d];
    let mut rng = Pcg64::new(0x7AC4);
    for round in 0..30 {
        let (mut seq, hit) = mgr.acquire(&[1]);
        assert_eq!(hit, 0);
        let mut external: Vec<u32> = Vec::new();
        let mut len = 0usize;
        for _ in 0..150 {
            match rng.below(6) {
                0 | 1 | 2 => {
                    // Append one position (may fail under pool pressure
                    // from external shares — that is fine).
                    if mgr.try_reserve(&mut seq) {
                        for layer in 0..cfg.n_layers {
                            seq.store(layer, len, &k, &v);
                        }
                        seq.advance();
                        len += 1;
                    }
                }
                3 => {
                    // Roll back to a random point (0..=len).
                    let to = rng.below(len + 1);
                    mgr.rollback(&mut seq, to);
                    len = to;
                }
                4 => {
                    // External share of a random mapped block (a prefix
                    // cache or forked sequence would hold such a ref).
                    if !seq.blocks().is_empty() {
                        let b = seq.blocks()[rng.below(seq.blocks().len())];
                        mgr.pool().retain(b);
                        external.push(b);
                    }
                }
                _ => {
                    // Drop an external share.
                    if let Some(b) = external.pop() {
                        mgr.pool().release(b);
                    }
                }
            }
            assert_eq!(seq.seq_len(), len, "length bookkeeping diverged");
            assert!(
                seq.blocks().len() >= len.div_ceil(bs),
                "page table lost blocks it still needs"
            );
        }
        drop(seq);
        for b in external {
            mgr.pool().release(b);
        }
        assert_eq!(mgr.blocks_in_use(), 0, "round {round} leaked blocks");
    }
    let (allocs, frees) = mgr.pool().counters();
    assert_eq!(allocs, frees, "lifetime alloc/free imbalance");
    assert!(allocs > 0, "the property test actually allocated");
}

/// Truncate-then-reappend must be invisible: decoding a detour of garbage
/// tokens, rolling them back, and continuing produces bit-identical logits
/// to the straight-line run — on the flat slab and on pages, with the cut
/// point inside a block.
#[test]
fn truncate_then_reappend_is_bit_identical() {
    let model = Model::synthetic(ModelConfig::preset("nano").unwrap(), 42);
    let cfg = &model.cfg;
    let mut rng = Pcg64::new(17);
    let tokens: Vec<usize> = (0..14).map(|_| rng.below(cfg.vocab_size)).collect();
    let garbage: Vec<usize> = (0..5).map(|_| rng.below(cfg.vocab_size)).collect();
    let cut = 7usize; // mid-block at bs=4

    // Straight-line reference (flat).
    let mut stats = ForwardStats::default();
    let mut scratch = Scratch::new(cfg);
    let mut flat_ref = KvCache::new(cfg);
    let mut logits: Vec<f32> = Vec::new();
    let mut reference: Vec<Vec<f32>> = Vec::new();
    for &t in &tokens {
        model.forward_token(t, &mut flat_ref, &Dense, &mut scratch, &mut stats, &mut logits);
        reference.push(logits.clone());
    }

    // Detour runs: decode `cut` tokens, wander into garbage, roll back,
    // continue with the real suffix.
    let mgr = KvManager::new(
        cfg,
        &KvCfg {
            pool_blocks: 64,
            block_size: 4,
            prefix_cache: true,
        },
    );
    for backend in 0..2 {
        let mut flat = KvCache::new(cfg);
        let (mut paged_seq, _) = mgr.acquire(&tokens);
        let kv: &mut dyn KvSeq = if backend == 0 { &mut flat } else { &mut paged_seq };
        let mut scratch = Scratch::new(cfg);
        let mut l: Vec<f32> = Vec::new();
        for &t in &tokens[..cut] {
            assert!(kv.try_reserve());
            model.forward_token(t, &mut *kv, &Dense, &mut scratch, &mut stats, &mut l);
        }
        for &g in &garbage {
            assert!(kv.try_reserve());
            model.forward_token(g, &mut *kv, &Dense, &mut scratch, &mut stats, &mut l);
        }
        kv.truncate(cut);
        assert_eq!(kv.seq_len(), cut);
        for (j, &t) in tokens.iter().enumerate().skip(cut) {
            assert!(kv.try_reserve());
            model.forward_token(t, &mut *kv, &Dense, &mut scratch, &mut stats, &mut l);
            for vx in 0..cfg.vocab_size {
                assert_eq!(
                    l[vx].to_bits(),
                    reference[j][vx].to_bits(),
                    "backend {backend}: post-rollback logits diverged at pos {j} vocab {vx}"
                );
            }
        }
    }
}

/// Regression (ISSUE 3 satellite): prefix-cache entries overlapping a
/// rolled-back tail must be invalidated on rollback, so a later prefix hit
/// can never adopt rejected-token KV. Clean leading blocks of the same
/// entry stay cached.
#[test]
fn rollback_invalidates_overlapping_prefix_entries() {
    let cfg = ModelConfig::preset("nano").unwrap();
    let bs = 4usize;
    let mgr = KvManager::new(
        &cfg,
        &KvCfg {
            pool_blocks: 32,
            block_size: bs,
            prefix_cache: true,
        },
    );
    let d = cfg.d_model;
    // 8 prompt tokens + 4 generated: 3 full blocks.
    let full: Vec<usize> = (0..12).collect();
    let probe: Vec<usize> = (0..16).collect();
    let (mut seq, _) = mgr.acquire(&full[..8]);
    for pos in 0..12 {
        assert!(mgr.try_reserve(&mut seq));
        for layer in 0..cfg.n_layers {
            seq.store(layer, pos, &vec![1.0; d], &vec![2.0; d]);
        }
        seq.advance();
    }
    // Publish prompt + generated continuation (the flow a
    // publish-on-completion feature would run).
    mgr.insert_prefix(&full, &seq);
    let cached_before = mgr.pool().ref_count(seq.blocks()[2]);
    assert_eq!(cached_before, 2, "tree holds a ref on the generated block");
    let (warm, hit) = mgr.acquire(&probe);
    assert_eq!(hit, 12, "warm probe sees all three cached blocks");
    drop(warm);

    // Reject the last 3 generated tokens: keep 9 positions. Block 2
    // (positions 8..12) overlaps the rolled-back tail.
    let block2 = seq.blocks()[2];
    mgr.rollback(&mut seq, 9);
    assert_eq!(seq.seq_len(), 9);
    assert_eq!(seq.blocks().len(), 3, "partially-kept tail block stays mapped");
    assert_eq!(
        mgr.pool().ref_count(block2),
        1,
        "tree ref on the overlapping block released"
    );

    // A later identical prompt must hit only the clean prompt blocks.
    let (warm, hit) = mgr.acquire(&probe);
    assert_eq!(
        hit, 8,
        "prefix hits stop before the invalidated generated block"
    );
    assert_eq!(warm.blocks(), &seq.blocks()[..2]);
    drop(warm);
    drop(seq);
    assert_eq!(mgr.blocks_in_use(), 2, "only the clean cached blocks remain");
}

/// PagedSeq is a drop-release RAII handle: engine sequences going out of
/// scope return every page, including shared prefix pages.
#[test]
fn dropped_sequences_return_all_pages() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let engine = Engine::paged(
        Arc::clone(&model),
        Arc::new(Dense),
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 32,
            block_size: 4,
            prefix_cache: true,
        },
    );
    let mgr = engine.kv.as_ref().unwrap();
    {
        let mut s1 = engine.admit(1, "shared prefix here", 4, Sampling::Greedy);
        engine.prefill(&mut s1);
        let mut s2 = engine.admit(2, "shared prefix here", 4, Sampling::Greedy);
        engine.prefill(&mut s2);
        assert!(mgr.blocks_in_use() > 0);
    }
    // Sequences dropped: only the radix tree's cached prompt blocks remain.
    let cached = mgr.blocks_in_use();
    assert_eq!(
        cached,
        "shared prefix here".len() / 4,
        "exactly the cached full prompt blocks stay resident"
    );
    // An unrelated flood evicts them when it needs the room.
    let mut big = PagedSeq::new(Arc::clone(mgr.pool()), 256);
    let mut filled = 0;
    while mgr.try_reserve(&mut big) {
        // Reserving walks block by block; advance a full block each time.
        for _ in 0..4 {
            big.advance();
        }
        filled += 1;
        if filled == 32 {
            break;
        }
    }
    assert_eq!(filled, 32, "eviction reclaimed every cached block");
    drop(big);
    assert_eq!(mgr.blocks_in_use(), 0);
}
