//! Integration: the full calibration pipeline -> sparsifier -> engine ->
//! eval chain on a synthetic nano model.

use std::sync::Arc;
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::data::tasks::full_suite;
use wisparse::eval::harness::evaluate_suite;
use wisparse::eval::kl::mean_token_kl;
use wisparse::eval::ppl::perplexity;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::{ForwardStats, Model};
use wisparse::model::ModelConfig;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::sparsity::allocator::{calibrate_wisparse, PipelineStages, WiSparseCfg};
use wisparse::sparsity::alpha_search::AlphaSearchCfg;
use wisparse::sparsity::evo::EvoCfg;
use wisparse::sparsity::greedy::GreedyCfg;
use wisparse::sparsity::methods::ScoredSparsifier;
use wisparse::sparsity::plan::SparsityPlan;
use wisparse::sparsity::Dense;

fn quick_cfg() -> WiSparseCfg {
    WiSparseCfg {
        evo: EvoCfg {
            generations: 3,
            offspring: 4,
            eps: 0.05,
            threads: 2,
            ..EvoCfg::default()
        },
        greedy: GreedyCfg {
            step: 0.1,
            threads: 2,
            ..GreedyCfg::default()
        },
        alpha: AlphaSearchCfg {
            n_grid: 5,
            threads: 2,
            ..AlphaSearchCfg::default()
        },
    }
}

fn setup() -> (Model, ModelCalib) {
    let model = Model::synthetic(ModelConfig::preset("nano").unwrap(), 101);
    let calib_set = CalibSet::synthetic(3, 16, model.cfg.vocab_size, 103);
    let calib = ModelCalib::collect(&model, &calib_set);
    (model, calib)
}

#[test]
fn full_pipeline_to_engine() {
    let (model, calib) = setup();
    let plan = calibrate_wisparse(&model, &calib, 0.5, &quick_cfg(), PipelineStages::FULL);

    // Plan round-trips through disk.
    let path = std::env::temp_dir().join("wisparse_itest_plan.json");
    plan.save(&path).unwrap();
    let plan2 = SparsityPlan::load(&path).unwrap();
    assert_eq!(plan, plan2);

    // Engine executes it with reduced density and produces tokens.
    let model = Arc::new(model);
    let sp = Arc::new(ScoredSparsifier::from_plan("wisparse", &model, &plan));
    let engine = Engine::new(Arc::clone(&model), sp, EngineCfg::default());
    let (text, stats) = engine.run_to_completion("ab+cd=", 12, Sampling::Greedy);
    assert_eq!(text.len(), 12);
    assert!(
        stats.density() < 0.95,
        "50% plan should cut density, got {}",
        stats.density()
    );
    assert!(stats.density() > 0.2, "density collapsed: {}", stats.density());
}

#[test]
fn sparse_kl_bounded_and_ordered() {
    // KL(dense||sparse) must grow with target sparsity under one plan
    // family; 0% plan must be ~exact.
    let (model, calib) = setup();
    let mut kls = Vec::new();
    for target in [0.0, 0.3, 0.7] {
        let plan = calibrate_wisparse(
            &model,
            &calib,
            target,
            &quick_cfg(),
            PipelineStages {
                weight_aware: true,
                coarse: false,
                fine: false,
            },
        );
        let sp = ScoredSparsifier::from_plan("wisparse", &model, &plan);
        let mut stats = ForwardStats::default();
        let mut kl = 0.0;
        for (seq, dense_logits) in calib.seqs.iter().zip(&calib.dense_logits) {
            let sparse_logits = model.forward_seq(seq, &sp, &mut stats, None);
            kl += mean_token_kl(dense_logits, &sparse_logits);
        }
        kls.push(kl);
    }
    assert!(kls[0] < 1e-6, "0% sparsity should be exact, kl={}", kls[0]);
    assert!(kls[1] < kls[2], "KL must grow with sparsity: {kls:?}");
}

#[test]
fn eval_suite_end_to_end() {
    let (model, calib) = setup();
    let suite = full_suite(4, 107);
    let dense = evaluate_suite(&model, &suite, &Dense, "dense", 0.0, 2);
    assert_eq!(dense.per_task.len(), 6);
    let plan = calibrate_wisparse(&model, &calib, 0.4, &quick_cfg(), PipelineStages::FULL);
    let sp = ScoredSparsifier::from_plan("wisparse", &model, &plan);
    let sparse = evaluate_suite(&model, &suite, &sp, "wisparse", 0.4, 2);
    // Both produce valid accuracies; untrained model ≈ chance either way.
    for r in [&dense, &sparse] {
        for (_, _, acc) in &r.per_task {
            assert!((0.0..=100.0).contains(acc));
        }
    }
}

#[test]
fn wisparse_beats_activation_only_on_reconstruction() {
    // The headline mechanism: at matched sparsity, weight-aware scoring
    // gives lower perplexity than activation-only on the same model.
    let (model, calib) = setup();
    let eval: Vec<Vec<usize>> = CalibSet::synthetic(3, 16, model.cfg.vocab_size, 109).seqs;
    let target = 0.6;
    let act_plan = wisparse::sparsity::allocator::calibrate_activation_only(&model, &calib, target);
    let act = ScoredSparsifier::from_plan("activation-only", &model, &act_plan);
    let wis_plan = calibrate_wisparse(
        &model,
        &calib,
        target,
        &quick_cfg(),
        PipelineStages {
            weight_aware: true,
            coarse: false,
            fine: false,
        },
    );
    let wis = ScoredSparsifier::from_plan("wisparse", &model, &wis_plan);
    let ppl_act = perplexity(&model, &eval, &act);
    let ppl_wis = perplexity(&model, &eval, &wis);
    // Alg. 2 minimizes block MSE which includes alpha=0 in its grid, so the
    // weight-aware result can only be equal or better up to eval noise.
    assert!(
        ppl_wis <= ppl_act * 1.05,
        "weight-aware ppl {ppl_wis} much worse than activation-only {ppl_act}"
    );
}
