//! Steady-state decode must perform zero heap allocations in the
//! projection/attention path (ISSUE 1 acceptance criterion).
//!
//! A counting global allocator wraps `System`; after prefill plus a few
//! warmup decode steps (which grow the reusable buffers — logits, residual,
//! kept-index scratch — to their steady-state sizes), further
//! `Engine::decode_one` calls must not touch the allocator at all.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use wisparse::model::layers::LayerId;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Build a nano engine running the scored sparse path (`with_ga = true`:
/// WiSparse/WINA weight-aware score; `false`: TEAL magnitude score).
fn sparse_engine(with_ga: bool) -> Engine {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 7));
    let layers: Vec<ScoredLayer> = (0..model.cfg.n_layers * 7)
        .map(|flat| {
            let id = LayerId::from_flat(flat);
            let n = id.kind.dims(&model.cfg).1;
            ScoredLayer {
                ga: if with_ga { Some(vec![1.0; n]) } else { None },
                tau: 0.3,
            }
        })
        .collect();
    let name = if with_ga { "wina" } else { "teal" };
    let sp = Arc::new(ScoredSparsifier::new(name, layers));
    Engine::new(
        model,
        sp,
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
    )
}

#[test]
fn decode_steady_state_allocates_nothing() {
    for with_ga in [false, true] {
        let engine = sparse_engine(with_ga);
        let mut seq = engine.admit(0, "warmup prompt", 64, Sampling::Greedy);
        engine.prefill(&mut seq);
        // Warmup: first decode steps grow logits / kept-index scratch.
        for _ in 0..4 {
            engine.decode_one(&mut seq);
        }
        assert!(!seq.finished(), "warmup exhausted the sequence");
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        for _ in 0..16 {
            engine.decode_one(&mut seq);
        }
        let allocs = ALLOC_CALLS.load(Ordering::Relaxed) - before;
        assert_eq!(
            allocs, 0,
            "steady-state decode hit the allocator {allocs} times (with_ga={with_ga})"
        );
        assert_eq!(seq.generated.len(), 20);
    }
}

/// The batched decode path must hit steady state too: `Engine::step_batch`
/// used to collect a fresh `Vec<&mut SeqState>` of active slots every step,
/// and the fused step's member lists / stacked scratch must likewise reach
/// a high-water mark during warmup and stay there.
#[test]
fn batched_decode_steady_state_allocates_nothing() {
    for fused in [true, false] {
        let mut engine = sparse_engine(true);
        engine.cfg.fused_batch = fused;
        let prompts = ["warmup prompt", "abc", "the sun is", "12+34="];
        let mut seqs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| engine.admit(i as u64, p, 64, Sampling::Greedy))
            .collect();
        for seq in seqs.iter_mut() {
            engine.prefill(seq);
        }
        // Warmup: grow logits, kernel scratch, fused member lists and the
        // stacked forward buffers to their steady-state sizes.
        for _ in 0..4 {
            engine.step_batch(&mut seqs);
        }
        assert!(seqs.iter().all(|s| !s.finished()), "warmup exhausted a sequence");
        let before = ALLOC_CALLS.load(Ordering::Relaxed);
        for _ in 0..16 {
            engine.step_batch(&mut seqs);
        }
        let allocs = ALLOC_CALLS.load(Ordering::Relaxed) - before;
        assert_eq!(
            allocs, 0,
            "steady-state batched decode hit the allocator {allocs} times (fused={fused})"
        );
        for seq in &seqs {
            assert_eq!(seq.generated.len(), 20);
        }
    }
}

#[test]
fn dense_decode_steady_state_allocates_nothing() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 9));
    let engine = Engine::dense(
        model,
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
    );
    let mut seq = engine.admit(0, "abcd", 64, Sampling::Greedy);
    engine.prefill(&mut seq);
    for _ in 0..4 {
        engine.decode_one(&mut seq);
    }
    let before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..16 {
        engine.decode_one(&mut seq);
    }
    let allocs = ALLOC_CALLS.load(Ordering::Relaxed) - before;
    assert_eq!(allocs, 0, "dense steady-state decode hit the allocator {allocs} times");
}
