//! Self-speculative decoding (ISSUE 3): the differential-equivalence
//! oracle — greedy speculative output must be token-identical to baseline
//! decode across flat and paged KV, prompts, draft-sparsity levels and
//! chain lengths — plus rejection-sampling distribution correctness,
//! adaptive draft-length behavior, and coordinator-level serving.

use std::sync::Arc;
use wisparse::kv::KvCfg;
use wisparse::model::sampler::{residual_sample, sample_from, spec_accept, Sampling};
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg, SpecCfg, SpecEngine};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::Sparsifier;
use wisparse::util::rng::Pcg64;

fn teal(model: &Model, tau: f32) -> Arc<dyn Sparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau })
            .collect(),
    ))
}

fn engine(model: &Arc<Model>, sp: &Arc<dyn Sparsifier>, paged: bool) -> Arc<Engine> {
    let cfg = EngineCfg {
        threads: 1,
        ..EngineCfg::default()
    };
    Arc::new(if paged {
        Engine::paged(
            Arc::clone(model),
            Arc::clone(sp),
            cfg,
            &KvCfg {
                pool_blocks: 96,
                block_size: 4,
                prefix_cache: true,
            },
        )
    } else {
        Engine::new(Arc::clone(model), Arc::clone(sp), cfg)
    })
}

/// The core correctness oracle: for every KV backend, draft sparsity level
/// (up to the keep-nothing extreme) and chain length, greedy speculative
/// decode must produce exactly the baseline's tokens — acceptance only
/// changes *when* work happens, never *what* is decoded.
#[test]
fn greedy_differential_equivalence() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let prod = teal(&model, 0.3);
    let prompts = ["abc", "12+34=", "the sun rises ", "zqj!"];
    for paged in [false, true] {
        let eng = engine(&model, &prod, paged);
        let baselines: Vec<String> = prompts
            .iter()
            .map(|p| eng.run_to_completion(p, 24, Sampling::Greedy).0)
            .collect();
        for draft_tau in [0.3f32, 0.6, f32::INFINITY] {
            for k in [2usize, 4, 8] {
                let spec = SpecEngine::new(
                    Arc::clone(&eng),
                    teal(&model, draft_tau),
                    SpecCfg {
                        k,
                        min_k: 2,
                        max_k: 12,
                        adaptive: true,
                    },
                );
                for (prompt, base) in prompts.iter().zip(&baselines) {
                    let seq = spec.run_seq(7, prompt, 24, Sampling::Greedy);
                    assert_eq!(
                        &seq.text(),
                        base,
                        "speculative decode diverged (paged={paged}, \
                         draft_tau={draft_tau}, k={k}, prompt={prompt:?})"
                    );
                    assert_eq!(seq.generated.len(), 24, "committed exactly max_new");
                    assert!(
                        seq.spec.accepted <= seq.spec.drafted,
                        "accepted more than drafted"
                    );
                    let r = seq.spec.acceptance_rate();
                    assert!((0.0..=1.0).contains(&r), "acceptance rate {r}");
                }
            }
        }
    }
}

/// A draft at a mildly higher sparsity than production stays close enough
/// to accept real work; the keep-nothing draft must reject most of it. The
/// counters are what `/metrics` and the bench report.
#[test]
fn acceptance_tracks_draft_quality() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let prod = teal(&model, 0.3);
    let eng = engine(&model, &prod, false);
    // Identical draft: full acceptance by construction.
    let same = SpecEngine::new(Arc::clone(&eng), teal(&model, 0.3), SpecCfg::default());
    let seq = same.run_seq(1, "hello world ", 48, Sampling::Greedy);
    assert_eq!(seq.spec.accepted, seq.spec.drafted);
    assert!(seq.spec.drafted > 0);
    // Keep-nothing draft: its proposals are a context-free function of the
    // previous token; most must be rejected by the verifier.
    let blind = SpecEngine::new(
        Arc::clone(&eng),
        teal(&model, f32::INFINITY),
        SpecCfg::default(),
    );
    let (mut accepted, mut drafted) = (0u64, 0u64);
    for (id, prompt) in ["hello world ", "12+34=", "the quick brown fox"].iter().enumerate() {
        let seq = blind.run_seq(2 + id as u64, prompt, 48, Sampling::Greedy);
        accepted += seq.spec.accepted;
        drafted += seq.spec.drafted;
    }
    assert!(
        accepted < drafted,
        "a context-free draft must see rejections (accepted {accepted}/{drafted})"
    );
}

/// Adaptive k: full acceptance walks the chain length up to the ceiling;
/// the configured bounds are never violated.
#[test]
fn adaptive_k_grows_on_full_acceptance() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let prod = teal(&model, 0.3);
    let eng = engine(&model, &prod, false);
    let cfg = SpecCfg {
        k: 2,
        min_k: 2,
        max_k: 6,
        adaptive: true,
    };
    let spec = SpecEngine::new(Arc::clone(&eng), teal(&model, 0.3), cfg.clone());
    let seq = spec.run_seq(1, "abcdef", 64, Sampling::Greedy);
    assert_eq!(seq.spec.cur_k, cfg.max_k, "full acceptance reaches the ceiling");
    // Blind draft: k must stay within bounds whatever acceptance does.
    let spec = SpecEngine::new(Arc::clone(&eng), teal(&model, f32::INFINITY), cfg.clone());
    let seq = spec.run_seq(2, "abcdef", 64, Sampling::Greedy);
    assert!((cfg.min_k..=cfg.max_k).contains(&seq.spec.cur_k));
    // Non-adaptive: the chain length never moves.
    let fixed = SpecCfg {
        adaptive: false,
        ..cfg
    };
    let spec = SpecEngine::new(Arc::clone(&eng), teal(&model, 0.6), fixed);
    let seq = spec.run_seq(3, "abcdef", 64, Sampling::Greedy);
    assert_eq!(seq.spec.cur_k, 2);
}

/// Temperature sampling through the speculative path is deterministic for a
/// fixed engine seed and commits exactly the requested budget — the
/// distributional guarantee itself is pinned by
/// `rejection_sampling_matches_direct_sampling`.
#[test]
fn temperature_spec_decode_is_seeded_deterministic() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let prod = teal(&model, 0.3);
    let eng = engine(&model, &prod, true);
    let spec = SpecEngine::new(Arc::clone(&eng), teal(&model, 0.6), SpecCfg::default());
    let a = spec.run_seq(11, "temperature ", 32, Sampling::Temperature(0.8));
    let b = spec.run_seq(11, "temperature ", 32, Sampling::Temperature(0.8));
    assert_eq!(a.text(), b.text(), "same id/seed must reproduce");
    assert_eq!(a.generated.len(), 32);
    assert!(a.spec.rounds > 0);
}

/// Proptest (seeded, deterministic): the accepted-token distribution of
/// draft-then-verify — draw from q, accept with min(1, p/q), else draw from
/// the normalized residual — must equal direct sampling from the verify
/// distribution p. Checked empirically over random (p, q) pairs on a small
/// fixed vocab.
#[test]
fn rejection_sampling_matches_direct_sampling() {
    let vocab = 8usize;
    let mut rng = Pcg64::new(0x5A3C);
    let random_probs = |rng: &mut Pcg64| -> Vec<f32> {
        let raw: Vec<f32> = (0..vocab).map(|_| (rng.normal() as f32).exp()).collect();
        let z: f32 = raw.iter().sum();
        raw.iter().map(|r| r / z).collect()
    };
    for case in 0..6 {
        let p = random_probs(&mut rng);
        let q = if case == 5 { p.clone() } else { random_probs(&mut rng) };
        let n = 40_000usize;
        let mut counts = vec![0usize; vocab];
        for _ in 0..n {
            let d = sample_from(&q, &mut rng);
            let tok = if spec_accept(&p, &q, d, &mut rng) {
                d
            } else {
                residual_sample(&p, &q, &mut rng)
            };
            counts[tok] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let emp = c as f64 / n as f64;
            assert!(
                (emp - p[i] as f64).abs() < 0.015,
                "case {case} token {i}: empirical {emp:.4} vs target {:.4}",
                p[i]
            );
        }
    }
}

/// Serving-level differential check: a speculative coordinator (paged KV,
/// prefix cache, batched scheduling) returns exactly the baseline text, and
/// `/metrics` carries the drafted/accepted counters. A per-request opt-out
/// coexists in the same batch.
#[test]
fn coordinator_spec_serving_matches_baseline() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
    let prod = teal(&model, 0.3);
    // Baseline references from a fresh flat engine.
    let reference = Engine::new(
        Arc::clone(&model),
        Arc::clone(&prod),
        EngineCfg {
            threads: 1,
            ..EngineCfg::default()
        },
    );
    let prompts = ["abc", "hello w", "1+2=", "the sun"];
    let expected: Vec<String> = prompts
        .iter()
        .map(|p| reference.run_to_completion(p, 8, Sampling::Greedy).0)
        .collect();

    let eng = engine(&model, &prod, true);
    let spec = Arc::new(SpecEngine::new(
        Arc::clone(&eng),
        teal(&model, 0.6),
        SpecCfg::default(),
    ));
    let coord = Coordinator::new_spec(
        spec,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 32,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    let handle = std::thread::spawn(move || sched.run_scheduler());
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            // Every other request opts out: speculative and plain sequences
            // share the batch and must agree.
            coord
                .submit_opts(p, 8, Sampling::Greedy, i % 2 == 0)
                .unwrap()
        })
        .collect();
    for (rx, exp) in rxs.into_iter().zip(&expected) {
        let resp = rx.recv().unwrap();
        assert_eq!(&resp.text, exp, "speculative serving diverged");
        assert_eq!(resp.n_generated, 8);
    }
    let m = coord.metrics_json();
    assert!(
        m.get("spec_rounds_total").as_f64().unwrap() > 0.0,
        "speculative rounds ran"
    );
    assert!(m.get("spec_drafted_tokens").as_f64().unwrap() > 0.0);
    let rate = m.get("spec_acceptance_rate").as_f64().unwrap();
    assert!((0.0..=1.0).contains(&rate), "acceptance rate {rate}");
    coord.shutdown();
    handle.join().unwrap();
}
