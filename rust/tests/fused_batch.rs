//! Differential harness for batch-fused decode (ISSUE 8 acceptance).
//!
//! The fused step (`EngineCfg::fused_batch = true`) must be **bit-identical**
//! to the per-sequence path across:
//!
//! - KV backends: flat and paged;
//! - weight representations: f32, int8, int4;
//! - batch sizes 1, 2 and 8 (batch-of-one exercises the fallback);
//! - mixed per-layer tau plans (TEAL-magnitude and weight-aware `ga`
//!   interleaved), including an aggressive plan where some positions keep
//!   nothing while batch-mates keep channels;
//! - speculative decode (fused chain verification across the batch).
//!
//! Plus the fused-batch edge cases: members finishing mid-step at staggered
//! `max_new`, external aborts (`finish_override`) mid-batch, a starved paged
//! pool driving some members to `cache_full` while others continue, and the
//! decode-gap regression — a busy fused batch must not charge a sequence for
//! time spent decoding its batch-mates.

use std::sync::Arc;
use wisparse::kv::KvCfg;
use wisparse::model::layers::LayerId;
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::quant::QuantMode;
use wisparse::server::engine::{Engine, EngineCfg, FinishReason, SeqState, SpecCfg, SpecEngine};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::Sparsifier;

const PROMPTS: [&str; 8] = [
    "the sun ",
    "abc",
    "12+34=",
    "hello world",
    "xyzw",
    "a quick brown fox",
    "zzz 9",
    "mid sentence t",
];

fn model(quant: Option<QuantMode>) -> Arc<Model> {
    let mut m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 29);
    if let Some(mode) = quant {
        m.quantize(mode, 16);
    }
    Arc::new(m)
}

/// Mixed tau plan: per-layer thresholds cycle through four levels around
/// `base_tau`, and every other layer is weight-aware (`ga` present) while the
/// rest run TEAL magnitude. `base_tau` around 0.3 gives mid-density masks;
/// 3.0 gives an aggressive plan where many positions keep zero channels.
fn mixed_sparsifier(m: &Model, base_tau: f32) -> Arc<dyn Sparsifier> {
    let layers: Vec<ScoredLayer> = (0..m.cfg.n_layers * 7)
        .map(|flat| {
            let id = LayerId::from_flat(flat);
            let n = id.kind.dims(&m.cfg).1;
            let tau = base_tau * (0.6 + 0.2 * (flat % 4) as f32);
            let ga = if flat % 2 == 0 {
                None
            } else {
                Some((0..n).map(|i| 1.0 + 0.07 * (i % 5) as f32).collect())
            };
            ScoredLayer { ga, tau }
        })
        .collect();
    Arc::new(ScoredSparsifier::new("wisparse", layers))
}

fn build(
    m: &Arc<Model>,
    sp: &Arc<dyn Sparsifier>,
    fused: bool,
    paged: bool,
    threads: usize,
) -> Engine {
    let cfg = EngineCfg {
        threads,
        fused_batch: fused,
        ..EngineCfg::default()
    };
    if paged {
        let kv = KvCfg {
            pool_blocks: 256,
            block_size: 8,
            prefix_cache: false,
        };
        Engine::paged(Arc::clone(m), Arc::clone(sp), cfg, &kv)
    } else {
        Engine::new(Arc::clone(m), Arc::clone(sp), cfg)
    }
}

fn run_batch(e: &Engine, prompts: &[&str], max_new: usize) -> Vec<SeqState> {
    let mut seqs: Vec<SeqState> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| e.admit(i as u64, p, max_new, Sampling::Greedy))
        .collect();
    for s in seqs.iter_mut() {
        e.prefill(s);
    }
    let mut guard = 0;
    while seqs.iter().any(|s| !s.finished()) {
        e.step_batch(&mut seqs);
        guard += 1;
        assert!(guard < 1000, "batch decode made no progress");
    }
    seqs
}

/// Fused and per-sequence runs must agree on every observable: text, finish
/// reason, MAC accounting and the raw bits of the final logits.
fn assert_identical(a: &[SeqState], b: &[SeqState], ea: &Engine, eb: &Engine, ctx: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.text(), y.text(), "[{ctx}] seq {i}: text diverged");
        assert_eq!(
            x.finish_reason(),
            y.finish_reason(),
            "[{ctx}] seq {i}: finish reason diverged"
        );
        assert_eq!(x.stats.tokens, y.stats.tokens, "[{ctx}] seq {i}: token count");
        assert_eq!(
            x.stats.macs_kept, y.stats.macs_kept,
            "[{ctx}] seq {i}: kept-MAC accounting diverged"
        );
        assert_eq!(
            x.stats.macs_dense, y.stats.macs_dense,
            "[{ctx}] seq {i}: dense-MAC accounting diverged"
        );
        let (la, lb) = (ea.last_logits(x), eb.last_logits(y));
        assert_eq!(la.len(), lb.len());
        for (j, (p, q)) in la.iter().zip(lb).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "[{ctx}] seq {i}: logit {j} bits diverged ({p} vs {q})"
            );
        }
    }
}

/// The headline differential: {flat, paged} x {f32, int8, int4} x batch
/// sizes {1, 2, 8} x {mid-density, keep-almost-nothing} tau plans.
#[test]
fn fused_decode_bit_identical_across_kv_quant_batch_and_tau() {
    for (qname, quant) in [
        ("f32", None),
        ("int8", Some(QuantMode::Int8)),
        ("int4", Some(QuantMode::Int4)),
    ] {
        let m = model(quant);
        for base_tau in [0.3f32, 3.0] {
            let sp = mixed_sparsifier(&m, base_tau);
            for paged in [false, true] {
                for n in [1usize, 2, 8] {
                    let prompts = &PROMPTS[..n];
                    let fused = build(&m, &sp, true, paged, 2);
                    let per_seq = build(&m, &sp, false, paged, 2);
                    let a = run_batch(&fused, prompts, 8);
                    let b = run_batch(&per_seq, prompts, 8);
                    let ctx =
                        format!("repr={qname} tau={base_tau} paged={paged} batch={n}");
                    assert_identical(&a, &b, &fused, &per_seq, &ctx);
                }
            }
        }
    }
}

/// Speculative decode over paged KV: the fused verify chunk (per-sequence
/// chains of different lengths in one stacked pass) must reproduce the
/// sequential rounds exactly — same text, same round/draft/accept counters.
/// At batch 8, two members are left unarmed so plain and speculative
/// members mix inside one fused step.
#[test]
fn fused_spec_decode_matches_sequential_rounds() {
    let m = model(None);
    let sp = mixed_sparsifier(&m, 0.3);
    let draft = mixed_sparsifier(&m, 1.0);
    for n in [1usize, 2, 8] {
        let run = |fused: bool| {
            let e = Arc::new(build(&m, &sp, fused, true, 2));
            let spec = SpecEngine::new(e, Arc::clone(&draft), SpecCfg::default());
            let mut seqs: Vec<SeqState> = PROMPTS[..n]
                .iter()
                .enumerate()
                .map(|(i, p)| spec.admit(i as u64, p, 12, Sampling::Greedy))
                .collect();
            if n == 8 {
                seqs[0].spec.cur_k = 0;
                seqs[3].spec.cur_k = 0;
            }
            for s in seqs.iter_mut() {
                spec.prefill(s);
            }
            let mut guard = 0;
            while seqs.iter().any(|s| !s.finished()) {
                spec.step_batch(&mut seqs);
                guard += 1;
                assert!(guard < 1000, "spec batch decode made no progress");
            }
            seqs
        };
        let a = run(true);
        let b = run(false);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.text(), y.text(), "[spec batch={n}] seq {i}: text");
            assert_eq!(
                x.generated, y.generated,
                "[spec batch={n}] seq {i}: tokens"
            );
            assert_eq!(
                x.spec.rounds, y.spec.rounds,
                "[spec batch={n}] seq {i}: round count"
            );
            assert_eq!(
                x.spec.drafted, y.spec.drafted,
                "[spec batch={n}] seq {i}: drafted"
            );
            assert_eq!(
                x.spec.accepted, y.spec.accepted,
                "[spec batch={n}] seq {i}: accepted"
            );
        }
    }
}

/// Members leave the batch at staggered `max_new`: the fused step must keep
/// decoding the survivors (dropping through the batch-of-one fallback on the
/// way down) and still match the per-sequence path exactly.
#[test]
fn fused_batch_members_finish_mid_stream() {
    let m = model(None);
    let sp = mixed_sparsifier(&m, 0.3);
    let max_news = [2usize, 9, 5, 3];
    for paged in [false, true] {
        let run = |fused: bool| {
            let e = build(&m, &sp, fused, paged, 2);
            let mut seqs: Vec<SeqState> = PROMPTS[..4]
                .iter()
                .enumerate()
                .map(|(i, p)| e.admit(i as u64, p, max_news[i], Sampling::Greedy))
                .collect();
            for s in seqs.iter_mut() {
                e.prefill(s);
            }
            let mut guard = 0;
            while seqs.iter().any(|s| !s.finished()) {
                e.step_batch(&mut seqs);
                guard += 1;
                assert!(guard < 100, "staggered batch made no progress");
            }
            (e, seqs)
        };
        let (ea, a) = run(true);
        let (eb, b) = run(false);
        assert_identical(&a, &b, &ea, &eb, &format!("staggered paged={paged}"));
        for (i, s) in a.iter().enumerate() {
            assert_eq!(s.generated.len(), max_news[i], "seq {i} token budget");
            assert_eq!(s.finish_reason(), FinishReason::Length, "seq {i} reason");
        }
    }
}

/// An externally aborted member (`finish_override` set mid-stream, e.g. a
/// deadline) must be skipped by subsequent fused steps without perturbing
/// its batch-mates' output.
#[test]
fn fused_batch_skips_externally_aborted_member() {
    let m = model(None);
    let sp = mixed_sparsifier(&m, 0.3);
    let e = build(&m, &sp, true, true, 2);
    let mut seqs: Vec<SeqState> = PROMPTS[..3]
        .iter()
        .enumerate()
        .map(|(i, p)| e.admit(i as u64, p, 10, Sampling::Greedy))
        .collect();
    for s in seqs.iter_mut() {
        e.prefill(s);
    }
    e.step_batch(&mut seqs);
    e.step_batch(&mut seqs);
    seqs[1].abort(FinishReason::DeadlineExceeded);
    let frozen = seqs[1].generated.clone();
    while seqs.iter().any(|s| !s.finished()) {
        e.step_batch(&mut seqs);
    }
    assert_eq!(seqs[1].finish_reason(), FinishReason::DeadlineExceeded);
    assert_eq!(seqs[1].generated, frozen, "aborted member kept decoding");
    // Survivors must match a solo run of the same sequence (greedy decode
    // does not draw from the rng, so per-id streams are irrelevant here).
    for i in [0usize, 2] {
        let (text, _) = e.run_to_completion(PROMPTS[i], 10, Sampling::Greedy);
        assert_eq!(seqs[i].text(), text, "survivor {i} diverged after abort");
        assert_eq!(seqs[i].finish_reason(), FinishReason::Length);
    }
}

/// A starved paged pool: some members hit `cache_full` mid-decode while
/// others keep going. With `threads = 1` the per-sequence path reserves in
/// slot order — exactly the fused phase-A order — so outcomes (who gets cut
/// off, where, and the survivors' text) must be identical.
#[test]
fn fused_batch_cache_full_matches_per_sequence() {
    let m = model(None);
    let sp = mixed_sparsifier(&m, 0.3);
    let run = |fused: bool| {
        let cfg = EngineCfg {
            threads: 1,
            fused_batch: fused,
            ..EngineCfg::default()
        };
        let kv = KvCfg {
            pool_blocks: 8,
            block_size: 4,
            prefix_cache: false,
        };
        let e = Engine::paged(Arc::clone(&m), Arc::clone(&sp), cfg, &kv);
        let mut seqs: Vec<SeqState> = PROMPTS[..3]
            .iter()
            .enumerate()
            .map(|(i, p)| e.admit(i as u64, p, 32, Sampling::Greedy))
            .collect();
        for s in seqs.iter_mut() {
            e.prefill(s);
        }
        let mut guard = 0;
        while seqs.iter().any(|s| !s.finished()) {
            e.step_batch(&mut seqs);
            guard += 1;
            assert!(guard < 200, "starved batch made no progress");
        }
        seqs
    };
    let a = run(true);
    let b = run(false);
    let mut cache_full = 0;
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.finish_reason(), y.finish_reason(), "seq {i}: reason");
        assert_eq!(x.text(), y.text(), "seq {i}: text under starvation");
        if x.finish_reason() == FinishReason::CacheFull {
            cache_full += 1;
        }
    }
    assert!(
        cache_full >= 1,
        "pool was not starved enough to exercise cache_full mid-batch"
    );
}

/// Decode-gap attribution regression (the ISSUE 8 bugfix): a sequence in a
/// busy fused batch must NOT be charged for the time spent decoding its
/// batch-mates in the same step. The old per-sequence accounting charged up
/// to a full batch step as "gap"; the batch-window accounting leaves only
/// the between-step idle time, which in a tight loop is far below one step.
/// Three trials, best worst-case taken, to shrug off scheduler preemption.
#[test]
fn fused_batch_gap_attribution_stays_near_zero() {
    let m = model(None);
    let sp = mixed_sparsifier(&m, 0.1);
    let e = build(&m, &sp, true, false, 2);
    let mut best_gap = u64::MAX;
    let mut best_avg = 0u64;
    for trial in 0..3u64 {
        let mut seqs: Vec<SeqState> = (0..16)
            .map(|i| {
                e.admit(trial * 100 + i as u64, PROMPTS[i % 8], 16, Sampling::Greedy)
            })
            .collect();
        for s in seqs.iter_mut() {
            e.prefill(s);
        }
        let t0 = std::time::Instant::now();
        let mut steps = 0u64;
        while seqs.iter().any(|s| !s.finished()) {
            e.step_batch(&mut seqs);
            steps += 1;
        }
        let avg_step_ns = t0.elapsed().as_nanos() as u64 / steps.max(1);
        let worst_gap = seqs.iter().map(|s| s.obs.max_gap_ns).max().unwrap();
        if worst_gap < best_gap {
            best_gap = worst_gap;
            best_avg = avg_step_ns;
        }
    }
    let (worst_gap, avg_step_ns) = (best_gap, best_avg);
    // Old accounting: the last batch member's gap ~= 15/16 of a step, every
    // step, in every trial. New accounting: loop overhead, microseconds.
    assert!(
        worst_gap < avg_step_ns / 2 + 200_000,
        "decode gap {worst_gap}ns looks like batch-mate decode time was \
         charged as idle (avg step {avg_step_ns}ns)"
    );
}
