//! Integration: PJRT runtime against build artifacts.
//!
//! These tests exercise the full three-layer bridge (Pallas kernel -> JAX
//! model -> HLO text -> xla crate -> native comparison). They require
//! `make artifacts` to have run; otherwise they skip (printing why), so
//! `cargo test` stays green on a fresh checkout.

use std::path::{Path, PathBuf};
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::model::transformer::Model;
use wisparse::runtime::pjrt::PjrtModel;
use wisparse::runtime::validate::cross_validate;
use wisparse::sparsity::allocator::{calibrate_wisparse, PipelineStages, WiSparseCfg};
use wisparse::sparsity::alpha_search::AlphaSearchCfg;
use wisparse::sparsity::evo::EvoCfg;
use wisparse::sparsity::greedy::GreedyCfg;

fn model_dir() -> Option<PathBuf> {
    let dir = Path::new("artifacts/models/llama-micro");
    if dir.join("dense.hlo.txt").exists() && dir.join("weights.bin").exists() {
        Some(dir.to_path_buf())
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

fn test_tokens(n: usize) -> Vec<usize> {
    wisparse::data::corpus::CorpusGen::new(0xBEEF)
        .calib_sequences(1, n)
        .remove(0)
}

#[test]
fn dense_hlo_matches_native_engine() {
    let Some(dir) = model_dir() else { return };
    let report = cross_validate(&dir, "dense", &test_tokens(48), None, 2e-3).unwrap();
    eprintln!("{}", report.line());
    assert!(
        report.pass,
        "dense PJRT vs native diverged: max {}",
        report.max_abs_diff
    );
}

#[test]
fn wisparse_hlo_matches_native_engine() {
    let Some(dir) = model_dir() else { return };
    if !dir.join("wisparse.hlo.txt").exists() {
        eprintln!("SKIP: no wisparse.hlo.txt");
        return;
    }
    // Calibrate a quick plan against the real model.
    let model = Model::load_dir(&dir).unwrap();
    let calib_set = CalibSet::load(Path::new("artifacts/data/llama-micro/calib.json"))
        .unwrap_or_else(|_| CalibSet::synthetic(4, 48, 256, 7));
    let calib = ModelCalib::collect(&model, &calib_set.subset(4, 48));
    let cfg = WiSparseCfg {
        evo: EvoCfg {
            generations: 2,
            offspring: 4,
            eps: 0.05,
            ..EvoCfg::default()
        },
        greedy: GreedyCfg {
            step: 0.1,
            ..GreedyCfg::default()
        },
        alpha: AlphaSearchCfg {
            n_grid: 4,
            ..AlphaSearchCfg::default()
        },
    };
    let plan = calibrate_wisparse(&model, &calib, 0.5, &cfg, PipelineStages::FULL);
    let report =
        cross_validate(&dir, "wisparse", &test_tokens(48), Some(&plan), 2e-3).unwrap();
    eprintln!("{}", report.line());
    assert!(
        report.pass,
        "sparse PJRT vs native diverged: max {}",
        report.max_abs_diff
    );
}

#[test]
fn manifest_covers_all_weights() {
    let Some(dir) = model_dir() else { return };
    let pjrt = PjrtModel::load(&dir, "dense").unwrap();
    let weights = wisparse::model::weights::Weights::load(&dir.join("weights.bin")).unwrap();
    assert_eq!(
        pjrt.manifest.params.len(),
        weights.tensors.len(),
        "manifest/weights count mismatch"
    );
    for p in &pjrt.manifest.params {
        let t = weights.get(&p.name).expect("manifest param has a weight");
        assert_eq!(t.shape, p.shape, "{}", p.name);
    }
}
