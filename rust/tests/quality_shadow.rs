//! Online quality observability (ISSUE 9): the shadow-dense sampling
//! monitor must be provably non-perturbing — served tokens and KV contents
//! bit-identical with sampling off vs. every-step sampling, across
//! {flat, paged, speculative} × {f32, int8} engines — its KL must be
//! exactly 0 under a dense plan and positive under a sparse one, the SLO
//! burn-rate alerts must fire and clear through `GET /alerts` under fault
//! injection, the new Prometheus families must be conformant, and the
//! Chrome trace export must round-trip the `/debug/traces` span hierarchy.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;
use wisparse::kv::{KvCfg, KvSeq};
use wisparse::model::sampler::Sampling;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::obs::{BlockObs, ObsSink, SloSpec};
use wisparse::quant::QuantMode;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg, SeqState, SpecCfg, SpecEngine};
use wisparse::server::faults::Faults;
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use wisparse::sparsity::{Dense, Sparsifier};
use wisparse::util::json::Json;

fn teal(model: &Model, tau: f32) -> Arc<dyn Sparsifier> {
    Arc::new(ScoredSparsifier::new(
        "teal",
        (0..model.cfg.n_layers * 7)
            .map(|_| ScoredLayer { ga: None, tau })
            .collect(),
    ))
}

fn engine_with_rate(
    model: &Arc<Model>,
    sp: &Arc<dyn Sparsifier>,
    paged: bool,
    rate: f64,
) -> Engine {
    let cfg = EngineCfg {
        threads: 1,
        quality_sample_rate: rate,
        ..EngineCfg::default()
    };
    if paged {
        Engine::paged(
            Arc::clone(model),
            Arc::clone(sp),
            cfg,
            &KvCfg {
                pool_blocks: 96,
                block_size: 4,
                prefix_cache: false,
            },
        )
    } else {
        Engine::new(Arc::clone(model), Arc::clone(sp), cfg)
    }
}

fn run_plain(eng: &Engine, id: u64, prompt: &str, max_new: usize, sampling: Sampling) -> SeqState {
    let mut s = eng.admit(id, prompt, max_new, sampling);
    eng.prefill(&mut s);
    while !s.finished() {
        eng.decode_one(&mut s);
    }
    s
}

/// Every K and V value of every layer, as raw bits — the strictest possible
/// "the shadow replay did not touch the cache" witness.
fn kv_bits(seq: &SeqState, n_layers: usize) -> Vec<u32> {
    let kv = seq.kv.as_dyn_ref();
    let upto = kv.seq_len();
    let mut out = Vec::new();
    for layer in 0..n_layers {
        kv.with_k(layer, upto, &mut |_start, rows| {
            out.extend(rows.iter().map(|v| v.to_bits()));
        });
        kv.with_v(layer, upto, &mut |_start, rows| {
            out.extend(rows.iter().map(|v| v.to_bits()));
        });
    }
    out
}

/// The tentpole invariant: enabling shadow sampling at rate 1.0 (a dense
/// replay after *every* decode step) changes nothing the served path
/// produces — not the sampled tokens (so the RNG was never advanced) and
/// not one bit of the KV cache — for flat and paged engines over f32 and
/// int8 weights, under greedy and temperature sampling.
#[test]
fn shadow_sampling_is_non_perturbing() {
    let prompts = ["the sun rises ", "12+34=", "zqj!"];
    for quantized in [false, true] {
        let mut m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 4242);
        if quantized {
            m.quantize(QuantMode::Int8, 16);
        }
        let model = Arc::new(m);
        let sp = teal(&model, 0.3);
        for paged in [false, true] {
            let base = engine_with_rate(&model, &sp, paged, 0.0);
            let shadowed = engine_with_rate(&model, &sp, paged, 1.0);
            assert!(base.quality.is_none(), "rate 0 must not arm the monitor");
            let q = shadowed.quality.as_ref().expect("rate 1 arms the monitor");
            assert_eq!(q.period(), 1);
            for (i, prompt) in prompts.iter().enumerate() {
                for sampling in [Sampling::Greedy, Sampling::Temperature(0.8)] {
                    let a = run_plain(&base, i as u64, prompt, 12, sampling);
                    let b = run_plain(&shadowed, i as u64, prompt, 12, sampling);
                    assert_eq!(
                        a.generated, b.generated,
                        "served tokens diverged (quantized={quantized}, \
                         paged={paged}, prompt={prompt:?}, {sampling:?})"
                    );
                    assert_eq!(
                        kv_bits(&a, model.cfg.n_layers),
                        kv_bits(&b, model.cfg.n_layers),
                        "KV contents diverged (quantized={quantized}, \
                         paged={paged}, prompt={prompt:?}, {sampling:?})"
                    );
                }
            }
            assert!(q.samples() > 0, "every-step sampling must record samples");
        }
    }
}

/// Same invariant for the speculative engine: shadow sampling on the verify
/// engine must not change what speculative decode commits.
#[test]
fn spec_shadow_sampling_is_non_perturbing() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
    let prod = teal(&model, 0.3);
    for paged in [false, true] {
        let base = Arc::new(engine_with_rate(&model, &prod, paged, 0.0));
        let shadowed = Arc::new(engine_with_rate(&model, &prod, paged, 1.0));
        let spec_a = SpecEngine::new(Arc::clone(&base), teal(&model, 0.6), SpecCfg::default());
        let spec_b = SpecEngine::new(Arc::clone(&shadowed), teal(&model, 0.6), SpecCfg::default());
        for (i, prompt) in ["abc", "the quick brown fox", "12+34="].iter().enumerate() {
            for sampling in [Sampling::Greedy, Sampling::Temperature(0.7)] {
                let a = spec_a.run_seq(i as u64, prompt, 16, sampling);
                let b = spec_b.run_seq(i as u64, prompt, 16, sampling);
                assert_eq!(
                    a.generated, b.generated,
                    "speculative tokens diverged (paged={paged}, prompt={prompt:?}, {sampling:?})"
                );
                assert_eq!(
                    kv_bits(&a, model.cfg.n_layers),
                    kv_bits(&b, model.cfg.n_layers),
                    "speculative KV diverged (paged={paged}, prompt={prompt:?}, {sampling:?})"
                );
            }
        }
        let q = shadowed.quality.as_ref().unwrap();
        assert!(q.samples() > 0, "spec rounds must feed the monitor too");
    }
}

/// Under a dense plan the shadow replay *is* the served computation, so
/// KL(dense‖sparse) must be exactly zero — not merely small — and top-1
/// agreement exact. This is also what CI's quality smoke asserts.
#[test]
fn dense_plan_has_exactly_zero_shadow_kl() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 7));
    let sp: Arc<dyn Sparsifier> = Arc::new(Dense);
    let eng = engine_with_rate(&model, &sp, false, 1.0);
    run_plain(&eng, 1, "hello world of dense shadows", 16, Sampling::Greedy);
    let q = eng.quality.as_ref().unwrap();
    assert!(q.samples() > 0);
    assert_eq!(q.max_kl(), 0.0, "dense shadow must be bit-identical");
    assert_eq!(q.mean_kl(), 0.0);
    assert_eq!(q.top1_agreement(), 1.0);
    assert_eq!(q.kl_breaches(), 0);
}

/// A genuinely sparse plan must show positive KL, and with a recording sink
/// installed the shadow replay must attribute per-(block, projection)
/// output reconstruction error — while leaving the production
/// density/bandwidth rows untouched by shadow traffic (calls stay equal to
/// the served token count).
#[test]
fn sparse_plan_records_kl_and_per_block_recon_error() {
    // Two identically-seeded models, one engine sampling every step, one
    // with sampling off — the recording sinks let us assert the shadow
    // replays recorded recon error WITHOUT inflating the production
    // density/bandwidth rows (call counts must match the quiet twin).
    let build = |rate: f64| {
        let mut m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 55);
        let obs = Arc::new(BlockObs::new(m.cfg.n_layers));
        m.set_obs_sink(Arc::clone(&obs) as Arc<dyn ObsSink>);
        let model = Arc::new(m);
        let sp = teal(&model, 0.3);
        (engine_with_rate(&model, &sp, false, rate), obs)
    };
    let (quiet_eng, quiet_obs) = build(0.0);
    let (eng, obs) = build(1.0);
    run_plain(&quiet_eng, 1, "a sparse plan drifts a little", 16, Sampling::Greedy);
    run_plain(&eng, 1, "a sparse plan drifts a little", 16, Sampling::Greedy);
    let q = eng.quality.as_ref().unwrap();
    assert!(q.samples() > 0);
    assert!(q.max_kl() > 0.0, "sparse logits must diverge from dense");
    let rows = obs.snapshot();
    assert!(rows.iter().any(|r| r.shadow_samples > 0), "recon recorded");
    assert!(
        rows.iter().any(|r| r.shadow_rel_err() > 0.0),
        "sparse projections must show reconstruction error"
    );
    // Shadow replays never pollute production telemetry: every projection's
    // call/density/byte accounting matches the sampling-off twin exactly.
    for (r, quiet) in rows.iter().zip(quiet_obs.snapshot()) {
        assert_eq!(r.id, quiet.id);
        assert_eq!(r.calls, quiet.calls, "{:?} saw shadow traffic", r.id);
        assert_eq!(r.kept_channels, quiet.kept_channels, "{:?}", r.id);
        assert_eq!(r.bytes, quiet.bytes, "{:?}", r.id);
        assert_eq!(quiet.shadow_samples, 0, "quiet twin must see no shadows");
    }
}

// ---------------------------------------------------------------------------
// HTTP-level integration: /alerts fire-and-clear, Prometheus conformance of
// the new families, and the Chrome trace export.
// ---------------------------------------------------------------------------

fn start_server(
    quality_sample_rate: f64,
    faults: &str,
    slos: Vec<SloSpec>,
) -> (Arc<Coordinator>, String) {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 99));
    let mut engine = Engine::paged(
        model,
        Arc::new(Dense),
        EngineCfg {
            threads: 2,
            quality_sample_rate,
            ..EngineCfg::default()
        },
        &KvCfg {
            pool_blocks: 128,
            block_size: 8,
            prefix_cache: true,
        },
    );
    if !faults.is_empty() {
        engine.faults = Faults::scripted(faults);
    }
    let coord = Coordinator::new(
        Arc::new(engine),
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 64,
            },
            slos,
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    std::thread::spawn(move || sched.run_scheduler());
    let (tx, rx) = std::sync::mpsc::channel();
    let http_coord = Arc::clone(&coord);
    std::thread::spawn(move || {
        wisparse::server::http::serve(http_coord, "127.0.0.1:0", move |a| {
            tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = rx.recv().unwrap().to_string();
    (coord, addr)
}

/// Returns (status, body).
fn request(addr: &str, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf).unwrap();
    (status, String::from_utf8(buf).unwrap())
}

/// A scripted decode panic burns the error-rate budget; the alert must be
/// visible at `GET /alerts` (and as the Prometheus gauge), then resolve on
/// its own once the fast window outruns the bad second.
#[test]
fn alerts_fire_and_clear_on_error_burn() {
    // A 3s fast window: the alert provably stays active for the immediate
    // scrape after the failure, and provably clears after a 4s quiet spell.
    let slos = vec![SloSpec::new("error_rate", 0.01, 0.0).windows(3, 6, 1.0)];
    let (coord, addr) = start_server(0.0, "decode_panic@1", slos);
    let (status, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "panic fodder", "max_new": 4}"#,
    );
    assert_eq!(status, 500, "decode panic surfaces as internal_error: {body}");

    let (status, body) = request(&addr, "GET", "/alerts", "");
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    let active = j.get("active").as_arr().unwrap();
    assert!(
        active.iter().any(|a| a.get("slo").as_str() == Some("error_rate")),
        "error_rate alert must be active: {body}"
    );
    let objectives = j.get("objectives").as_arr().unwrap();
    let err_obj = objectives
        .iter()
        .find(|o| o.get("slo").as_str() == Some("error_rate"))
        .unwrap();
    assert_eq!(err_obj.get("active").as_bool(), Some(true));
    assert_eq!(err_obj.get("fired_total").as_f64(), Some(1.0));
    let (_, prom) = request(&addr, "GET", "/metrics?format=prometheus", "");
    assert!(
        prom.contains("wisparse_alert_active{slo=\"error_rate\"} 1"),
        "{prom}"
    );
    assert!(prom.contains("wisparse_alerts_fired_total{slo=\"error_rate\"} 1"));

    // Quiet recovery: once the bad second leaves the 3s fast window the
    // alert resolves (the /alerts scrape itself ticks the evaluator).
    std::thread::sleep(Duration::from_millis(4200));
    let (_, body) = request(&addr, "GET", "/alerts", "");
    let j = Json::parse(&body).unwrap();
    assert!(
        j.get("active").as_arr().unwrap().is_empty(),
        "alert must clear after recovery: {body}"
    );
    let resolved = j.get("resolved").as_arr().unwrap();
    let r = resolved
        .iter()
        .find(|a| a.get("slo").as_str() == Some("error_rate"))
        .expect("resolved alert retained");
    assert!(r.get("resolved_at_s").as_f64().is_some());
    assert!(r.get("burn_fast").as_f64().unwrap() >= 1.0);
    let (_, prom) = request(&addr, "GET", "/metrics?format=prometheus", "");
    assert!(prom.contains("wisparse_alert_active{slo=\"error_rate\"} 0"));
    assert!(prom.contains("wisparse_alerts_fired_total{slo=\"error_rate\"} 1"));
    coord.shutdown();
}

/// Minimal text-format 0.0.4 conformance for the quality/SLO/build-info
/// families: every sample belongs to a family with exactly one `# TYPE`,
/// histogram buckets are cumulative-monotone and `+Inf` equals `_count`.
fn assert_prom_conformant(body: &str) {
    use std::collections::BTreeMap;
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap().to_string();
            let ty = it.next().unwrap().to_string();
            assert!(
                types.insert(name.clone(), ty).is_none(),
                "duplicate TYPE for {name}"
            );
        }
    }
    let mut buckets: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut counts: BTreeMap<String, f64> = BTreeMap::new();
    for line in body.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let name_end = line.find(|c| c == '{' || c == ' ').unwrap_or(line.len());
        let name = &line[..name_end];
        let value: f64 = {
            let v = line.rsplit(' ').next().unwrap();
            if v == "+Inf" {
                f64::INFINITY
            } else {
                v.parse().unwrap_or_else(|_| panic!("bad value in `{line}`"))
            }
        };
        let family = if types.contains_key(name) {
            name.to_string()
        } else {
            let base = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_count"))
                .or_else(|| name.strip_suffix("_sum"))
                .unwrap_or_else(|| panic!("sample `{name}` has no TYPE"));
            assert_eq!(
                types.get(base).map(String::as_str),
                Some("histogram"),
                "sample `{name}` has no TYPE"
            );
            base.to_string()
        };
        if name.ends_with("_bucket") && types.get(&family).map(String::as_str) == Some("histogram")
        {
            let le_start = line.find("le=\"").unwrap_or_else(|| panic!("no le in `{line}`")) + 4;
            let le_str = &line[le_start..line[le_start..].find('"').unwrap() + le_start];
            let le = if le_str == "+Inf" {
                f64::INFINITY
            } else {
                le_str.parse().unwrap()
            };
            buckets.entry(family).or_default().push((le, value));
        } else if name.ends_with("_count") && types.contains_key(&family) {
            counts.insert(family, value);
        }
    }
    for (family, ty) in &types {
        if ty != "histogram" {
            continue;
        }
        let b = buckets
            .get(family)
            .unwrap_or_else(|| panic!("histogram {family} has no buckets"));
        assert!(
            b.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 <= w[1].1),
            "{family} buckets not monotone: {b:?}"
        );
        let (last_le, last_count) = *b.last().unwrap();
        assert!(last_le.is_infinite(), "{family} missing +Inf bucket");
        assert_eq!(
            Some(&last_count),
            counts.get(family),
            "{family}: +Inf bucket != _count"
        );
    }
}

/// With sampling armed, both `/metrics` views must carry the quality, SLO
/// and build-info families — conformantly.
#[test]
fn quality_metrics_in_both_views() {
    let (coord, addr) = start_server(1.0, "", SloSpec::default_set(0.05));
    let (status, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "sample me densely please", "max_new": 8}"#,
    );
    assert_eq!(status, 200, "{body}");
    let (status, prom) = request(&addr, "GET", "/metrics?format=prometheus", "");
    assert_eq!(status, 200);
    assert_prom_conformant(&prom);
    for family in [
        "# TYPE wisparse_shadow_samples_total counter",
        "# TYPE wisparse_shadow_top1_agree_total counter",
        "# TYPE wisparse_shadow_kl_breaches_total counter",
        "# TYPE wisparse_shadow_kl_max gauge",
        "# TYPE wisparse_shadow_kl histogram",
        "# TYPE wisparse_shadow_margin histogram",
        "# TYPE wisparse_alert_active gauge",
        "# TYPE wisparse_alerts_fired_total counter",
        "# TYPE wisparse_build_info gauge",
    ] {
        assert!(prom.contains(family), "missing `{family}`");
    }
    assert!(prom.contains("wisparse_build_info{version=\""));
    assert!(prom.contains("wisparse_alert_active{slo=\"shadow_kl\"} 0"));
    // Dense serving plan: samples landed, none breached the ceiling.
    assert!(prom.contains("wisparse_shadow_kl_breaches_total 0"));

    let (_, json) = request(&addr, "GET", "/metrics", "");
    let m = Json::parse(&json).unwrap();
    assert!(m.get("quality").get("samples").as_f64().unwrap() > 0.0);
    assert_eq!(m.get("quality").get("max_kl").as_f64(), Some(0.0));
    assert_eq!(m.get("quality").get("top1_agreement").as_f64(), Some(1.0));
    assert!(m.get("build_info").get("version").as_str().is_some());
    coord.shutdown();
}

/// The Chrome trace export must parse back, mirror `/debug/traces?id=`
/// (same span ids, names and parents in `args`), and carry valid
/// trace-event fields for ui.perfetto.dev.
#[test]
fn chrome_trace_export_round_trips() {
    let (coord, addr) = start_server(0.0, "", SloSpec::default_set(0.05));
    let (status, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "trace me for perfetto", "max_new": 6}"#,
    );
    assert_eq!(status, 200, "{body}");
    let trace_id = Json::parse(&body)
        .unwrap()
        .get("trace_id")
        .as_usize()
        .unwrap();

    let (status, body) = request(&addr, "GET", &format!("/debug/traces?id={trace_id}"), "");
    assert_eq!(status, 200);
    let t = Json::parse(&body).unwrap();
    assert_eq!(
        t.get("truncated").as_bool(),
        Some(false),
        "complete trace must not report truncation: {body}"
    );
    let spans = t.get("spans").as_arr().unwrap();
    assert!(!spans.is_empty());

    let (status, body) = request(
        &addr,
        "GET",
        &format!("/debug/traces/export?id={trace_id}"),
        "",
    );
    assert_eq!(status, 200);
    let export = Json::parse(&body).expect("export must be valid JSON");
    assert_eq!(export.get("displayTimeUnit").as_str(), Some("ms"));
    assert_eq!(export.get("truncated").as_bool(), Some(false));
    let events = export.get("traceEvents").as_arr().unwrap();
    assert_eq!(events.len(), spans.len(), "one event per span");
    for ev in events {
        assert_eq!(ev.get("ph").as_str(), Some("X"));
        assert_eq!(ev.get("pid").as_f64(), Some(1.0));
        assert_eq!(ev.get("tid").as_usize(), Some(trace_id));
        assert!(ev.get("ts").as_f64().is_some());
        assert!(ev.get("dur").as_f64().unwrap() >= 0.0);
        assert!(ev.get("name").as_str().is_some());
    }
    // The span hierarchy `/debug/traces` reports is recoverable from the
    // export: identical (id -> parent, name) triples.
    let mut from_debug: Vec<(usize, usize, String)> = spans
        .iter()
        .map(|s| {
            (
                s.get("id").as_usize().unwrap(),
                s.get("parent").as_usize().unwrap(),
                s.get("name").as_str().unwrap().to_string(),
            )
        })
        .collect();
    let mut from_export: Vec<(usize, usize, String)> = events
        .iter()
        .map(|e| {
            (
                e.get("args").get("id").as_usize().unwrap(),
                e.get("args").get("parent").as_usize().unwrap(),
                e.get("name").as_str().unwrap().to_string(),
            )
        })
        .collect();
    from_debug.sort();
    from_export.sort();
    assert_eq!(from_debug, from_export, "span hierarchy must round-trip");

    // Missing / malformed ids are 400s on the export route too.
    assert_eq!(request(&addr, "GET", "/debug/traces/export", "").0, 400);
    assert_eq!(
        request(&addr, "GET", "/debug/traces/export?id=bogus", "").0,
        400
    );
    coord.shutdown();
}
