//! Integration: the HTTP front end over a real TCP socket.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::sparsity::Dense;
use wisparse::util::json::Json;

fn start_server() -> (Arc<Coordinator>, String) {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 201));
    // The serving path runs on the paged KV pool with prefix sharing on.
    let engine = Arc::new(Engine::paged(
        model,
        Arc::new(Dense),
        EngineCfg {
            threads: 2,
            ..EngineCfg::default()
        },
        &wisparse::kv::KvCfg {
            pool_blocks: 128,
            block_size: 8,
            prefix_cache: true,
        },
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 64,
            },
            ..CoordinatorCfg::default()
        },
    );
    let sched = Arc::clone(&coord);
    std::thread::spawn(move || sched.run_scheduler());
    let (tx, rx) = std::sync::mpsc::channel();
    let http_coord = Arc::clone(&coord);
    std::thread::spawn(move || {
        wisparse::server::http::serve(http_coord, "127.0.0.1:0", move |a| {
            tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = rx.recv().unwrap().to_string();
    (coord, addr)
}

fn request(addr: &str, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            }
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf).unwrap();
    (status, String::from_utf8(buf).unwrap())
}

/// Like [`request`] but for a `Transfer-Encoding: chunked` response:
/// returns the status and the reassembled body.
fn request_chunked(addr: &str, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("transfer-encoding") {
                chunked = v.trim().eq_ignore_ascii_case("chunked");
            }
        }
    }
    assert!(chunked, "streaming response must be chunked");
    let mut out = String::new();
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line).unwrap();
        let size = usize::from_str_radix(size_line.trim(), 16).unwrap();
        if size == 0 {
            break;
        }
        let mut buf = vec![0u8; size + 2]; // chunk data + trailing CRLF
        reader.read_exact(&mut buf).unwrap();
        out.push_str(std::str::from_utf8(&buf[..size]).unwrap());
    }
    (status, out)
}

#[test]
fn streaming_generate_emits_one_line_per_token() {
    let (coord, addr) = start_server();
    // Blocking reference first: greedy decode is deterministic, so the
    // streamed tokens must reassemble to exactly this text.
    let (status, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "stream this", "max_new": 5}"#,
    );
    assert_eq!(status, 200, "{body}");
    let expect = Json::parse(&body)
        .unwrap()
        .get("text")
        .as_str()
        .unwrap()
        .to_string();

    let (status, ndjson) = request_chunked(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "stream this", "max_new": 5, "stream": true}"#,
    );
    assert_eq!(status, 200);
    let lines: Vec<&str> = ndjson.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), 6, "5 token lines + done: {ndjson}");
    let mut text = String::new();
    for (i, line) in lines[..5].iter().enumerate() {
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("done").as_bool(), Some(false), "{line}");
        assert_eq!(j.get("index").as_usize(), Some(i), "{line}");
        text.push_str(j.get("token").as_str().unwrap());
    }
    let done = Json::parse(lines[5]).unwrap();
    assert_eq!(done.get("done").as_bool(), Some(true));
    assert_eq!(done.get("generated_tokens").as_usize(), Some(5));
    assert_eq!(done.get("text").as_str(), Some(text.as_str()));
    assert_eq!(text, expect, "streamed tokens diverge from blocking path");
    coord.shutdown();
}

#[test]
fn metrics_expose_weight_representation_gauges() {
    let (coord, addr) = start_server();
    let (status, body) = request(&addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let m = Json::parse(&body).unwrap();
    assert_eq!(m.get("weight_repr").as_str(), Some("f32"));
    assert!(m.get("weight_bytes_resident").as_usize().unwrap() > 0);
    assert!((m.get("quant_compression_ratio").as_f64().unwrap() - 1.0).abs() < 1e-9);
    assert!(m.get("decode_tok_s").get("f32").as_f64().is_some());
    coord.shutdown();
}

#[test]
fn health_metrics_generate_roundtrip() {
    let (coord, addr) = start_server();

    let (status, body) = request(&addr, "GET", "/health", "");
    assert_eq!(status, 200);
    assert!(body.contains("ok"));

    let (status, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "12+34=", "max_new": 6}"#,
    );
    assert_eq!(status, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("generated_tokens").as_usize(), Some(6));
    assert_eq!(j.get("text").as_str().map(|s| s.len()), Some(6));
    assert_eq!(j.get("finish_reason").as_str(), Some("length"));

    let (status, body) = request(&addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let m = Json::parse(&body).unwrap();
    assert_eq!(m.get("requests_total").as_usize(), Some(1));
    assert_eq!(m.get("tokens_generated").as_usize(), Some(6));
    assert_eq!(m.get("blocks_total").as_usize(), Some(128));
    assert!(m.get("blocks_in_use").as_usize().is_some());
    assert!(m.get("prefix_hit_rate").as_f64().is_some());
    assert_eq!(m.get("preemptions_total").as_usize(), Some(0));

    // Errors.
    let (status, _) = request(&addr, "POST", "/generate", "not json");
    assert_eq!(status, 400);
    let (status, _) = request(&addr, "GET", "/nope", "");
    assert_eq!(status, 404);

    coord.shutdown();
}

#[test]
fn concurrent_http_clients() {
    let (coord, addr) = start_server();
    let results: Vec<(u16, String)> = std::thread::scope(|s| {
        (0..6)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || {
                    request(
                        &addr,
                        "POST",
                        "/generate",
                        &format!(r#"{{"prompt": "client {i} says", "max_new": 5}}"#),
                    )
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });
    for (status, body) in &results {
        assert_eq!(*status, 200, "{body}");
    }
    assert_eq!(coord.metrics.lock().unwrap().requests_total, 6);
    coord.shutdown();
}

/// Like [`request`] but also returns the response headers (lowercased
/// names), for asserting on `Retry-After`.
fn request_full(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, Vec<(String, String)>, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.trim_end().split_once(':') {
            let k = k.to_ascii_lowercase();
            let v = v.trim().to_string();
            if k == "content-length" {
                content_length = v.parse().unwrap();
            }
            headers.push((k, v));
        }
    }
    let mut buf = vec![0u8; content_length];
    reader.read_exact(&mut buf).unwrap();
    (status, headers, String::from_utf8(buf).unwrap())
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
}

/// Readiness and drain over HTTP. The coordinator deliberately has NO
/// scheduler thread: a drain on it never completes, so the server stays
/// up in the draining state and every assertion below is race-free.
#[test]
fn readyz_flips_and_admission_sheds_during_drain() {
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 202));
    let engine = Arc::new(Engine::new(
        model,
        Arc::new(Dense),
        EngineCfg {
            threads: 2,
            ..EngineCfg::default()
        },
    ));
    let coord = Coordinator::new(engine, CoordinatorCfg::default());
    let (tx, rx) = std::sync::mpsc::channel();
    let http_coord = Arc::clone(&coord);
    std::thread::spawn(move || {
        wisparse::server::http::serve(http_coord, "127.0.0.1:0", move |a| {
            tx.send(a).unwrap();
        })
        .unwrap();
    });
    let addr = rx.recv().unwrap().to_string();

    let (status, body) = request(&addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert!(body.contains("ok"));
    let (status, body) = request(&addr, "GET", "/readyz", "");
    assert_eq!(status, 200);
    assert!(body.contains("ready"));

    let (status, _) = request(&addr, "POST", "/admin/drain", "");
    assert_eq!(status, 202);
    assert!(coord.is_draining());

    // Liveness is unaffected; readiness flips and carries Retry-After.
    let (status, _) = request(&addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    let (status, headers, body) = request_full(&addr, "GET", "/readyz", "");
    assert_eq!(status, 503);
    assert!(body.contains("draining"), "{body}");
    assert_eq!(header(&headers, "retry-after"), Some("1"));

    // New work is shed at admission, with backoff advice.
    let (status, headers, _) = request_full(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "too late", "max_new": 4}"#,
    );
    assert_eq!(status, 503);
    assert_eq!(header(&headers, "retry-after"), Some("1"));
    assert!(coord.metrics.lock().unwrap().shed_total >= 1);

    coord.shutdown(); // lets the accept loop exit
}

/// A queued request whose deadline lapses before a batch slot frees up
/// comes back 504 with `deadline_exceeded`, having generated nothing.
#[test]
fn queued_request_past_deadline_maps_to_504() {
    let (coord, addr) = start_server();
    // Fill every batch slot with long decodes so the HTTP request below
    // has to wait in the queue past its 1ms deadline.
    let busy: Vec<_> = (0..4)
        .map(|i| {
            coord
                .submit(
                    &format!("occupant {i} holding a slot"),
                    200,
                    wisparse::model::sampler::Sampling::Greedy,
                )
                .unwrap()
        })
        .collect();
    let (status, body) = request(
        &addr,
        "POST",
        "/generate",
        r#"{"prompt": "deadline bound", "max_new": 4, "deadline_ms": 1}"#,
    );
    assert_eq!(status, 504, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("finish_reason").as_str(), Some("deadline_exceeded"));
    assert_eq!(j.get("generated_tokens").as_usize(), Some(0));
    assert!(coord.metrics.lock().unwrap().deadline_exceeded_total >= 1);
    drop(busy);
    coord.shutdown();
}

/// The robustness counters ride on /metrics from the start.
#[test]
fn metrics_expose_robustness_counters() {
    let (coord, addr) = start_server();
    let (status, body) = request(&addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let m = Json::parse(&body).unwrap();
    assert_eq!(m.get("panics_caught_total").as_usize(), Some(0));
    assert_eq!(m.get("scheduler_restarts_total").as_usize(), Some(0));
    assert_eq!(m.get("deadline_exceeded_total").as_usize(), Some(0));
    assert_eq!(m.get("shed_total").as_usize(), Some(0));
    assert_eq!(m.get("queue_depth").as_usize(), Some(0));
    assert!(m.get("drain_duration_ms").as_f64().is_some());
    coord.shutdown();
}
