//! Failure injection: every artifact-loading path must reject corrupted
//! inputs with an error, never a panic or silent garbage — and the serving
//! runtime must survive injected runtime faults (panics, pool exhaustion,
//! disconnecting clients) with the chaos invariant intact: *every submitted
//! request terminates with exactly one response, and the KV pool's leak
//! counters balance after drain*.

use std::io::Write;
use wisparse::calib::CalibSet;
use wisparse::model::weights::Weights;
use wisparse::model::{Model, ModelConfig};
use wisparse::runtime::manifest::Manifest;
use wisparse::sparsity::plan::SparsityPlan;
use wisparse::util::json::Json;

fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("wisparse_failtest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(bytes).unwrap();
    path
}

#[test]
fn corrupted_weight_files_rejected() {
    for (name, bytes) in [
        ("empty.bin", &b""[..]),
        ("short_magic.bin", &b"WSPW"[..]),
        ("wrong_magic.bin", &b"XXXX0001\x01\x00\x00\x00"[..]),
        // Valid magic, count says 1 tensor, then truncates.
        ("truncated.bin", &b"WSPW0001\x01\x00\x00\x00\x02\x00\x00\x00ab"[..]),
    ] {
        let path = tmp(name, bytes);
        assert!(Weights::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn weight_file_with_wrong_shapes_rejected_by_model() {
    // Well-formed container, wrong tensor set for the config.
    let mut w = Weights::default();
    w.insert(
        "embed.weight",
        wisparse::tensor::Tensor::zeros(&[10, 10]), // wrong shape
    );
    let cfg = ModelConfig::preset("nano").unwrap();
    assert!(Model::from_weights(cfg, &w).is_err());
}

#[test]
fn corrupted_plans_rejected() {
    for (name, text) in [
        ("notjson.json", "{{{{"),
        ("missing_fields.json", r#"{"model": "x"}"#),
        (
            "bad_layer_key.json",
            r#"{"model":"m","method":"x","target_sparsity":0.5,
               "block_sparsity":[0.5],
               "layers":[{"layer":"0.bogus_proj","sparsity":0.5,"alpha":0,"tau":0}]}"#,
        ),
        (
            "layer_out_of_range.json",
            r#"{"model":"m","method":"x","target_sparsity":0.5,
               "block_sparsity":[0.5],
               "layers":[{"layer":"9.q_proj","sparsity":0.5,"alpha":0,"tau":0}]}"#,
        ),
    ] {
        let path = tmp(name, text.as_bytes());
        assert!(SparsityPlan::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn corrupted_manifests_rejected() {
    for (name, text) in [
        ("m1.json", "[]"),
        ("m2.json", r#"{"model":"x","variant":"dense","seq_len":4}"#),
        (
            "m3.json",
            r#"{"model":"x","variant":"dense","seq_len":4,"vocab_size":256,
               "params":[{"name":"w"}]}"#,
        ),
    ] {
        let path = tmp(name, text.as_bytes());
        assert!(Manifest::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn corrupted_calib_sets_rejected() {
    for (name, text) in [
        ("c1.json", r#"{"seqs": []}"#),          // empty set
        ("c2.json", r#"{"seqs": [[]]}"#),        // empty sequence
        ("c3.json", r#"{"noseqs": 1}"#),         // missing field
        ("c4.json", r#"{"seqs": "nope"}"#),      // wrong type
    ] {
        let path = tmp(name, text.as_bytes());
        assert!(CalibSet::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn json_parser_rejects_depth_bombs_gracefully() {
    // Deeply nested arrays: must error or parse, not crash the process
    // with a stack overflow at sane depths.
    let depth = 200;
    let text = "[".repeat(depth) + &"]".repeat(depth);
    let _ = Json::parse(&text); // any Result is fine; no panic
}

#[test]
fn generation_request_bounds() {
    use std::sync::Arc;
    use wisparse::model::sampler::Sampling;
    use wisparse::server::engine::{Engine, EngineCfg};
    use wisparse::sparsity::Dense;
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 1));
    let engine = Engine::new(model, Arc::new(Dense), EngineCfg::default());
    // max_new larger than the context: engine must clamp, not panic.
    let (text, _) = engine.run_to_completion("ab", 10_000, Sampling::Greedy);
    assert!(text.len() < 10_000);
    // Prompt longer than the context: truncated on admit.
    let (text, _) = engine.run_to_completion(&"x".repeat(5_000), 4, Sampling::Greedy);
    assert_eq!(text.len(), 4);
}

// ---------------------------------------------------------------------------
// Chaos property suite: deterministic fault schedules against the serving
// runtime. Each scenario submits a fixed workload (blocking + streaming with
// a mid-stream disconnect), injects a scripted fault schedule, drains, and
// asserts the chaos invariant.
// ---------------------------------------------------------------------------

mod chaos {
    use std::sync::Arc;
    use std::time::Duration;
    use wisparse::model::sampler::Sampling;
    use wisparse::model::{Model, ModelConfig};
    use wisparse::server::batcher::BatcherCfg;
    use wisparse::server::engine::{Engine, EngineCfg, SpecCfg, SpecEngine};
    use wisparse::server::faults::Faults;
    use wisparse::server::{Coordinator, CoordinatorCfg};
    use wisparse::sparsity::Dense;

    const ENGINE_KINDS: [&str; 3] = ["flat", "paged", "speculative"];

    /// A coordinator over one of the three engine shapes, with a scripted
    /// fault schedule armed on the (verify) engine. The prefix cache is off
    /// so "pool leak counters balance" means strictly allocs == frees with
    /// zero blocks in use — no cache retention to account for.
    fn chaos_coordinator(
        kind: &str,
        faults: &str,
    ) -> (Arc<Coordinator>, std::thread::JoinHandle<()>) {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 7));
        let ecfg = EngineCfg {
            threads: 2,
            prefill_chunk: 8,
            ..EngineCfg::default()
        };
        let kv = wisparse::kv::KvCfg {
            pool_blocks: 96,
            block_size: 8,
            prefix_cache: false,
        };
        let cfg = CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: 4,
                max_queue: 64,
            },
            drain_timeout: Duration::from_secs(10),
            ..CoordinatorCfg::default()
        };
        let coord = match kind {
            "flat" => {
                let mut e = Engine::new(model, Arc::new(Dense), ecfg);
                e.faults = Faults::scripted(faults);
                Coordinator::new(Arc::new(e), cfg)
            }
            "paged" => {
                let mut e = Engine::paged(model, Arc::new(Dense), ecfg, &kv);
                e.faults = Faults::scripted(faults);
                Coordinator::new(Arc::new(e), cfg)
            }
            "speculative" => {
                let mut e = Engine::paged(model, Arc::new(Dense), ecfg, &kv);
                e.faults = Faults::scripted(faults);
                let spec = Arc::new(SpecEngine::new(
                    Arc::new(e),
                    Arc::new(Dense),
                    SpecCfg::default(),
                ));
                Coordinator::new_spec(spec, cfg)
            }
            other => panic!("unknown engine kind {other}"),
        };
        let c = Arc::clone(&coord);
        let handle = std::thread::spawn(move || c.run_scheduler());
        (coord, handle)
    }

    /// Run one scenario: N blocking requests plus one streaming request
    /// whose client disconnects mid-stream, under the given fault schedule,
    /// then drain. Returns the finish reasons of the blocking requests.
    fn run_scenario(kind: &str, faults: &str) -> Vec<String> {
        let (coord, handle) = chaos_coordinator(kind, faults);
        let prompts = ["abc def", "hello w", "1+2= 3", "xyzw k", "the sun is"];
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| coord.submit(p, 6, Sampling::Greedy).unwrap())
            .collect();
        // Streaming client that hangs up after (at most) one event.
        let (sid, srx) = coord
            .submit_stream("stream chaos victim pad", 8, Sampling::Greedy, true)
            .unwrap();
        let _ = srx.recv_timeout(Duration::from_secs(10));
        drop(srx); // mid-stream disconnect...
        coord.cancel(sid); // ...and the explicit hangup path
        // Chaos invariant, part 1: every submitted request terminates with
        // exactly one response.
        let mut reasons = Vec::new();
        for (rx, p) in rxs.into_iter().zip(prompts) {
            let resp = rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|e| panic!("[{kind}/{faults}] {p:?} got no response: {e}"));
            assert!(!resp.finish_reason.is_empty());
            assert!(
                rx.recv_timeout(Duration::from_millis(20)).is_err(),
                "[{kind}/{faults}] second response for {p:?}"
            );
            reasons.push(resp.finish_reason);
        }
        coord.drain();
        handle.join().unwrap();
        assert!(coord.is_shutdown() && coord.scheduler_exited());
        // Chaos invariant, part 2: the pool leaks nothing — every alloc has
        // a matching free and nothing is left in use (prefix cache is off).
        if let Some(kv) = coord.engine().kv.as_ref() {
            let (allocs, frees) = kv.pool().counters();
            assert_eq!(
                allocs, frees,
                "[{kind}/{faults}] pool leak: {allocs} allocs vs {frees} frees"
            );
            assert_eq!(kv.blocks_in_use(), 0, "[{kind}/{faults}] blocks still held");
        }
        reasons
    }

    /// The full matrix: seeded fault schedules x engine shapes (all with
    /// chunked prefill) x a mid-stream disconnect in every scenario.
    #[test]
    fn chaos_matrix_every_request_terminates_and_pool_balances() {
        let schedules = [
            "decode_panic@1",
            "decode_panic@2,decode_panic@5",
            "prefill_panic@1",
            "pool_dry@1,decode_panic@3",
            "sched_panic@1",
            "sched_panic@2,pool_dry@2,decode_panic@4",
        ];
        for kind in ENGINE_KINDS {
            for faults in schedules {
                run_scenario(kind, faults);
            }
        }
    }

    /// No-fault A/B: with an empty schedule the chaos harness must decode
    /// bit-identically to a plain engine — the fault layer is genuinely
    /// inert when nothing is scripted.
    #[test]
    fn chaos_harness_without_faults_matches_reference() {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 7));
        let reference = Engine::new(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 2,
                prefill_chunk: 8,
                ..EngineCfg::default()
            },
        );
        let prompts = ["abc def", "hello w", "1+2= 3", "xyzw k", "the sun is"];
        let expected: Vec<String> = prompts
            .iter()
            .map(|p| reference.run_to_completion(p, 6, Sampling::Greedy).0)
            .collect();
        for kind in ENGINE_KINDS {
            let (coord, handle) = chaos_coordinator(kind, "");
            for (p, exp) in prompts.iter().zip(&expected) {
                let resp = coord.submit_blocking(p, 6, Sampling::Greedy).unwrap();
                assert_eq!(resp.finish_reason, "length", "[{kind}] {p:?}");
                assert_eq!(&resp.text, exp, "[{kind}] {p:?} diverged");
            }
            let m = coord.metrics.lock().unwrap();
            assert_eq!(m.panics_caught_total, 0, "[{kind}]");
            assert_eq!(m.scheduler_restarts_total, 0, "[{kind}]");
            drop(m);
            coord.drain();
            handle.join().unwrap();
        }
    }

    /// Supervisor restart: a scheduler-level panic on the second iteration
    /// fails only implicated in-flight requests; still-queued requests
    /// survive the restart and complete normally.
    #[test]
    fn sched_panic_fails_only_inflight_requests() {
        for kind in ENGINE_KINDS {
            let (coord, handle) = chaos_coordinator(kind, "sched_panic@3");
            let rxs: Vec<_> = (0..6)
                .map(|i| {
                    coord
                        .submit(&format!("chaos queued {i}"), 5, Sampling::Greedy)
                        .unwrap()
                })
                .collect();
            let mut ok = 0usize;
            let mut failed = 0usize;
            for rx in rxs {
                let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                match resp.finish_reason.as_str() {
                    "internal_error" => failed += 1,
                    _ => ok += 1,
                }
            }
            assert_eq!(ok + failed, 6, "[{kind}] every request answered");
            assert!(
                ok >= 1,
                "[{kind}] queued survivors must complete after the restart"
            );
            assert!(
                coord.metrics.lock().unwrap().scheduler_restarts_total >= 1,
                "[{kind}] supervisor restarted"
            );
            coord.drain();
            handle.join().unwrap();
            if let Some(kv) = coord.engine().kv.as_ref() {
                let (allocs, frees) = kv.pool().counters();
                assert_eq!(allocs, frees, "[{kind}] pool leak after restart");
            }
        }
    }

    /// One batch member panics mid-fused-step (its sequential sample/reserve
    /// phase): only that member fails `internal_error`, the rest of the
    /// fused batch keeps decoding to completion, and the pool's leak
    /// counters balance once the failed sequence's blocks drop.
    #[test]
    fn fused_batch_member_panic_isolated_and_pool_balances() {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 7));
        let mut e = Engine::paged(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 2,
                ..EngineCfg::default()
            },
            &wisparse::kv::KvCfg {
                pool_blocks: 96,
                block_size: 8,
                prefix_cache: false,
            },
        );
        assert!(e.cfg.fused_batch, "fused decode is the default");
        e.faults = Faults::scripted("decode_panic@2");
        let prompts = ["abc def", "hello w", "1+2= 3", "xyzw k"];
        let mut seqs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| e.admit(i as u64, p, 6, Sampling::Greedy))
            .collect();
        for s in seqs.iter_mut() {
            e.prefill(s);
        }
        let mut steps = 0;
        while seqs.iter().any(|s| !s.finished()) {
            e.step_batch(&mut seqs);
            steps += 1;
            assert!(steps < 100, "fused batch stopped making progress");
        }
        let reasons: Vec<_> = seqs.iter().map(|s| s.finish_reason()).collect();
        let failed = reasons
            .iter()
            .filter(|r| **r == wisparse::server::engine::FinishReason::InternalError)
            .count();
        assert_eq!(failed, 1, "exactly one member fails: {reasons:?}");
        for (s, r) in seqs.iter().zip(&reasons) {
            if *r == wisparse::server::engine::FinishReason::InternalError {
                continue;
            }
            assert_eq!(
                *r,
                wisparse::server::engine::FinishReason::Length,
                "surviving members decode to completion"
            );
            assert_eq!(s.generated.len(), 6);
        }
        let kv = e.kv.clone().expect("paged engine");
        drop(seqs);
        let (allocs, frees) = kv.pool().counters();
        assert_eq!(allocs, frees, "pool leak: {allocs} allocs vs {frees} frees");
        assert_eq!(kv.blocks_in_use(), 0, "blocks still held after drop");
    }

    /// Deadline enforcement end to end: an already-expired request fails
    /// `deadline_exceeded` without running, under every engine shape.
    #[test]
    fn expired_requests_fail_terminally_without_leaking() {
        for kind in ENGINE_KINDS {
            let (coord, handle) = chaos_coordinator(kind, "");
            let mut req = wisparse::server::GenRequest::new(0, "expired already", 6);
            req.deadline = Some(Duration::ZERO);
            let resp = coord.submit_request_blocking(req).unwrap();
            assert_eq!(resp.finish_reason, "deadline_exceeded", "[{kind}]");
            assert_eq!(resp.n_generated, 0, "[{kind}]");
            coord.drain();
            handle.join().unwrap();
            if let Some(kv) = coord.engine().kv.as_ref() {
                let (allocs, frees) = kv.pool().counters();
                assert_eq!(allocs, frees, "[{kind}]");
            }
        }
    }
}
