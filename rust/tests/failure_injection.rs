//! Failure injection: every artifact-loading path must reject corrupted
//! inputs with an error, never a panic or silent garbage.

use std::io::Write;
use wisparse::calib::CalibSet;
use wisparse::model::weights::Weights;
use wisparse::model::{Model, ModelConfig};
use wisparse::runtime::manifest::Manifest;
use wisparse::sparsity::plan::SparsityPlan;
use wisparse::util::json::Json;

fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("wisparse_failtest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(bytes).unwrap();
    path
}

#[test]
fn corrupted_weight_files_rejected() {
    for (name, bytes) in [
        ("empty.bin", &b""[..]),
        ("short_magic.bin", &b"WSPW"[..]),
        ("wrong_magic.bin", &b"XXXX0001\x01\x00\x00\x00"[..]),
        // Valid magic, count says 1 tensor, then truncates.
        ("truncated.bin", &b"WSPW0001\x01\x00\x00\x00\x02\x00\x00\x00ab"[..]),
    ] {
        let path = tmp(name, bytes);
        assert!(Weights::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn weight_file_with_wrong_shapes_rejected_by_model() {
    // Well-formed container, wrong tensor set for the config.
    let mut w = Weights::default();
    w.insert(
        "embed.weight",
        wisparse::tensor::Tensor::zeros(&[10, 10]), // wrong shape
    );
    let cfg = ModelConfig::preset("nano").unwrap();
    assert!(Model::from_weights(cfg, &w).is_err());
}

#[test]
fn corrupted_plans_rejected() {
    for (name, text) in [
        ("notjson.json", "{{{{"),
        ("missing_fields.json", r#"{"model": "x"}"#),
        (
            "bad_layer_key.json",
            r#"{"model":"m","method":"x","target_sparsity":0.5,
               "block_sparsity":[0.5],
               "layers":[{"layer":"0.bogus_proj","sparsity":0.5,"alpha":0,"tau":0}]}"#,
        ),
        (
            "layer_out_of_range.json",
            r#"{"model":"m","method":"x","target_sparsity":0.5,
               "block_sparsity":[0.5],
               "layers":[{"layer":"9.q_proj","sparsity":0.5,"alpha":0,"tau":0}]}"#,
        ),
    ] {
        let path = tmp(name, text.as_bytes());
        assert!(SparsityPlan::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn corrupted_manifests_rejected() {
    for (name, text) in [
        ("m1.json", "[]"),
        ("m2.json", r#"{"model":"x","variant":"dense","seq_len":4}"#),
        (
            "m3.json",
            r#"{"model":"x","variant":"dense","seq_len":4,"vocab_size":256,
               "params":[{"name":"w"}]}"#,
        ),
    ] {
        let path = tmp(name, text.as_bytes());
        assert!(Manifest::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn corrupted_calib_sets_rejected() {
    for (name, text) in [
        ("c1.json", r#"{"seqs": []}"#),          // empty set
        ("c2.json", r#"{"seqs": [[]]}"#),        // empty sequence
        ("c3.json", r#"{"noseqs": 1}"#),         // missing field
        ("c4.json", r#"{"seqs": "nope"}"#),      // wrong type
    ] {
        let path = tmp(name, text.as_bytes());
        assert!(CalibSet::load(&path).is_err(), "{name} must be rejected");
    }
}

#[test]
fn json_parser_rejects_depth_bombs_gracefully() {
    // Deeply nested arrays: must error or parse, not crash the process
    // with a stack overflow at sane depths.
    let depth = 200;
    let text = "[".repeat(depth) + &"]".repeat(depth);
    let _ = Json::parse(&text); // any Result is fine; no panic
}

#[test]
fn generation_request_bounds() {
    use std::sync::Arc;
    use wisparse::model::sampler::Sampling;
    use wisparse::server::engine::{Engine, EngineCfg};
    use wisparse::sparsity::Dense;
    let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 1));
    let engine = Engine::new(model, Arc::new(Dense), EngineCfg::default());
    // max_new larger than the context: engine must clamp, not panic.
    let (text, _) = engine.run_to_completion("ab", 10_000, Sampling::Greedy);
    assert!(text.len() < 10_000);
    // Prompt longer than the context: truncated on admit.
    let (text, _) = engine.run_to_completion(&"x".repeat(5_000), 4, Sampling::Greedy);
    assert_eq!(text.len(), 4);
}
