//! Native histograms (fixed log-spaced buckets, Prometheus-renderable) and
//! a sliding-window event rate.
//!
//! The `Summary` type in `util::stats` keeps exact recent percentiles for
//! the JSON view; these histograms sit alongside it so `/metrics?format=`
//! `prometheus` can expose aggregatable `_bucket/_sum/_count` series.

use std::time::Instant;

/// Log-spaced 1-2.5-5 millisecond bounds covering ~50µs .. 30s: wide enough
/// for queue waits, per-token steps and whole-request latencies to share one
/// bucket layout (Prometheus joins across families then stay trivial).
pub const MS_BUCKETS: [f64; 18] = [
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0,
    5000.0, 10000.0, 30000.0,
];

/// Fixed-bucket histogram. Bounds are upper-inclusive (`v <= le`), matching
/// Prometheus `le` semantics.
#[derive(Clone, Debug)]
pub struct Hist {
    bounds: &'static [f64],
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Hist {
    pub fn with_bounds(bounds: &'static [f64]) -> Self {
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Self {
            bounds,
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    /// The standard millisecond layout used by every latency family.
    pub fn new_ms() -> Self {
        Self::with_bounds(&MS_BUCKETS)
    }

    pub fn observe(&mut self, v: f64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn bounds(&self) -> &[f64] {
        self.bounds
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Add another histogram's observations bucket-wise (both sides must
    /// share a bucket layout — every latency family uses [`MS_BUCKETS`]).
    /// Used by the router to aggregate per-replica histograms at scrape
    /// time; cumulative monotonicity is preserved by construction.
    pub fn merge_from(&mut self, o: &Hist) {
        assert_eq!(
            self.bounds.len(),
            o.bounds.len(),
            "histogram merge requires identical bucket layouts"
        );
        for (a, b) in self.counts.iter_mut().zip(o.counts.iter()) {
            *a += b;
        }
        self.sum += o.sum;
        self.count += o.count;
    }

    /// Cumulative `(le, count)` pairs ending with `(+Inf, total)`.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.bounds.len() + 1);
        for (i, &b) in self.bounds.iter().enumerate() {
            acc += self.counts[i];
            out.push((b, acc));
        }
        acc += self.counts[self.bounds.len()];
        out.push((f64::INFINITY, acc));
        out
    }
}

/// Sliding-window event rate over the last [`RATE_WINDOW_SECS`] seconds,
/// kept as per-second buckets tagged with their absolute second index so
/// reads need no mutation (stale slots are simply out of range).
pub const RATE_WINDOW_SECS: u64 = 30;

const RATE_SLOTS: usize = 32;

#[derive(Clone, Debug)]
pub struct RateWindow {
    started: Instant,
    /// (absolute second index, events in that second)
    slots: [(u64, u64); RATE_SLOTS],
}

impl Default for RateWindow {
    fn default() -> Self {
        Self::new()
    }
}

impl RateWindow {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            slots: [(0, 0); RATE_SLOTS],
        }
    }

    fn now_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn add(&mut self, n: u64) {
        let sec = self.now_s() as u64;
        self.add_at(n, sec);
    }

    /// Deterministic-time variant used by tests.
    pub fn add_at(&mut self, n: u64, sec: u64) {
        let slot = &mut self.slots[(sec % RATE_SLOTS as u64) as usize];
        if slot.0 != sec {
            *slot = (sec, 0);
        }
        slot.1 += n;
    }

    /// Fold another window's per-second buckets into this one, translating
    /// second indices between the two epochs. Replica windows are created
    /// within milliseconds of each other, so the rounded shift is 0 in
    /// practice and the aggregate rate reads as the sum of replica rates.
    pub fn merge_from(&mut self, o: &RateWindow) {
        if self.slots.iter().all(|(_, c)| *c == 0) {
            // Fresh aggregate: adopt the other window wholesale.
            self.started = o.started;
            self.slots = o.slots;
            return;
        }
        let delta = if o.started >= self.started {
            (o.started - self.started).as_secs_f64().round()
        } else {
            -(self.started - o.started).as_secs_f64().round()
        };
        for &(sec, n) in o.slots.iter() {
            if n > 0 {
                self.add_at(n, (sec as f64 + delta).max(0.0) as u64);
            }
        }
    }

    /// Events/second over the trailing window (or since start, if younger).
    pub fn rate(&self) -> f64 {
        self.rate_at(self.now_s())
    }

    pub fn rate_at(&self, now_s: f64) -> f64 {
        let now_sec = now_s as u64;
        let lo = now_sec.saturating_sub(RATE_WINDOW_SECS - 1);
        let total: u64 = self
            .slots
            .iter()
            .filter(|(s, _)| *s >= lo && *s <= now_sec)
            .map(|(_, c)| c)
            .sum();
        let span = now_s.min(RATE_WINDOW_SECS as f64).max(1.0);
        total as f64 / span
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hist_buckets_and_cumulative() {
        let mut h = Hist::new_ms();
        for v in [0.04, 0.05, 0.3, 7.0, 1e9] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - (0.04 + 0.05 + 0.3 + 7.0 + 1e9)).abs() < 1.0);
        let cum = h.cumulative();
        assert_eq!(cum.len(), MS_BUCKETS.len() + 1);
        // Monotone, ends at +Inf with the full count.
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1));
        let (last_le, last_n) = *cum.last().unwrap();
        assert!(last_le.is_infinite());
        assert_eq!(last_n, 5);
        // 0.05 lands in the le=0.05 bucket (upper-inclusive).
        assert_eq!(cum[0], (0.05, 2));
    }

    #[test]
    fn rate_window_steady_state() {
        let mut w = RateWindow::new();
        // 100 tok/s for 60 simulated seconds.
        for sec in 0..60 {
            w.add_at(100, sec);
        }
        let r = w.rate_at(60.0);
        assert!((r - 100.0).abs() < 5.0, "rate {r}");
    }

    #[test]
    fn rate_window_decays_when_idle() {
        let mut w = RateWindow::new();
        for sec in 0..10 {
            w.add_at(100, sec);
        }
        // Burst just ended: window still sees it.
        assert!(w.rate_at(10.0) > 50.0);
        // 40s later every bucket is stale: rate is 0, unlike the lifetime
        // average which would still read ~20 tok/s and keep decaying.
        assert_eq!(w.rate_at(50.0), 0.0);
    }

    #[test]
    fn rate_window_reuses_slots() {
        let mut w = RateWindow::new();
        w.add_at(7, 3);
        // Same slot index 35 seconds later must reset, not accumulate.
        w.add_at(5, 3 + RATE_SLOTS as u64);
        let r = w.rate_at((4 + RATE_SLOTS) as f64);
        assert!((r - 5.0 / 30.0).abs() < 1e-9, "rate {r}");
    }

    #[test]
    fn rate_window_young_process() {
        let mut w = RateWindow::new();
        w.add_at(50, 0);
        // Half a second in, denominator clamps to 1s: no divide-by-zero blowup.
        assert_eq!(w.rate_at(0.5), 50.0);
    }
}
