//! Online quality monitor: shadow-dense sampling.
//!
//! WiSparse's headline claim is quality under sparsity, so quality must be
//! an *online* signal next to the GB/s telemetry: every Nth committed
//! decode step is replayed dense ([`Model::forward_shadow`]) against the
//! same residual and KV state, and the divergence between the dense logits
//! and the served sparse logits is recorded here — KL(dense‖sparse), top-1
//! agreement and the served logit margin — without perturbing the served
//! output (pinned bit-for-bit by `rust/tests/quality_shadow.rs`).
//!
//! [`Model::forward_shadow`]: crate::model::Model::forward_shadow

use crate::obs::hist::Hist;
use crate::obs::prom::PromText;
use crate::util::json::Json;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Log-spaced KL bounds (nats): a healthy 50%-sparsity plan sits well under
/// 0.1, a dense plan at exactly 0.
pub const KL_BOUNDS: [f64; 14] = [
    1e-6, 1e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 0.01, 0.025, 0.05, 0.1, 0.5, 2.5,
];

/// Served top1−top2 logit-margin bounds: small margins mean the sparse
/// decision was fragile even when top-1 agreed.
pub const MARGIN_BOUNDS: [f64; 12] = [
    0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 20.0, 50.0,
];

/// Per-thread shadow-replay buffers (dense logits + projection recon tmp),
/// lazily grown and reused so steady-state decode with sampling *on* is
/// allocation-free after the first sample, and sampling *off* never touches
/// them at all (`rust/tests/alloc_steady_state.rs` stays green).
#[derive(Default)]
pub struct ShadowCtx {
    pub logits: Vec<f32>,
    pub recon: Vec<f32>,
}

thread_local! {
    static SHADOW_CTX: RefCell<ShadowCtx> = RefCell::new(ShadowCtx::default());
}

/// Run `f` with this thread's shadow buffers.
pub fn with_shadow_ctx<R>(f: impl FnOnce(&mut ShadowCtx) -> R) -> R {
    SHADOW_CTX.with(|cell| f(&mut cell.borrow_mut()))
}

struct QualityHists {
    kl: Hist,
    margin: Hist,
}

/// Aggregated shadow-sample quality state, shared across sequences and
/// worker threads. Counters are atomics (hot-ish path), the histograms sit
/// behind a mutex taken once per sample — samples are rare by construction
/// (default 1-in-100 steps), so contention is negligible.
pub struct QualityObs {
    /// Sample every `period`-th decode step of each sequence (deterministic
    /// per-sequence counter, so runs are reproducible).
    period: u64,
    /// One sample's KL above this is an SLO-relevant breach (nats).
    kl_ceiling: f64,
    samples: AtomicU64,
    top1_agree: AtomicU64,
    kl_breaches: AtomicU64,
    /// Max single-sample KL, as `f64::to_bits` (KL ≥ 0, so the bit pattern
    /// ordering matches the value ordering).
    kl_max_bits: AtomicU64,
    hists: Mutex<QualityHists>,
}

impl QualityObs {
    /// `rate` is the sampled fraction of decode steps, in `(0, 1]`.
    pub fn new(rate: f64, kl_ceiling: f64) -> Self {
        assert!(
            rate > 0.0 && rate <= 1.0,
            "quality sample rate must be in (0, 1], got {rate}"
        );
        assert!(kl_ceiling > 0.0, "kl ceiling must be positive");
        Self {
            period: (1.0 / rate).round().max(1.0) as u64,
            kl_ceiling,
            samples: AtomicU64::new(0),
            top1_agree: AtomicU64::new(0),
            kl_breaches: AtomicU64::new(0),
            kl_max_bits: AtomicU64::new(0),
            hists: Mutex::new(QualityHists {
                kl: Hist::with_bounds(&KL_BOUNDS),
                margin: Hist::with_bounds(&MARGIN_BOUNDS),
            }),
        }
    }

    /// Steps between samples (≥ 1; 1 means every step).
    pub fn period(&self) -> u64 {
        self.period
    }

    pub fn kl_ceiling(&self) -> f64 {
        self.kl_ceiling
    }

    /// Record one shadow sample: `kl` is KL(dense‖sparse) in nats,
    /// `top1_agree` whether the dense and served argmax matched, `margin`
    /// the served logits' top1−top2 gap.
    pub fn record_sample(&self, kl: f64, top1_agree: bool, margin: f64) {
        self.samples.fetch_add(1, Ordering::Relaxed);
        if top1_agree {
            self.top1_agree.fetch_add(1, Ordering::Relaxed);
        }
        if kl > self.kl_ceiling {
            self.kl_breaches.fetch_add(1, Ordering::Relaxed);
        }
        self.kl_max_bits
            .fetch_max(kl.max(0.0).to_bits(), Ordering::Relaxed);
        let mut h = self.hists.lock().expect("quality hists poisoned");
        h.kl.observe(kl);
        h.margin.observe(margin);
    }

    pub fn samples(&self) -> u64 {
        self.samples.load(Ordering::Relaxed)
    }

    pub fn kl_breaches(&self) -> u64 {
        self.kl_breaches.load(Ordering::Relaxed)
    }

    /// Mean KL across samples (0 before any sample).
    pub fn mean_kl(&self) -> f64 {
        let h = self.hists.lock().expect("quality hists poisoned");
        if h.kl.count() == 0 {
            0.0
        } else {
            h.kl.sum() / h.kl.count() as f64
        }
    }

    pub fn max_kl(&self) -> f64 {
        f64::from_bits(self.kl_max_bits.load(Ordering::Relaxed))
    }

    /// Fraction of samples where dense and served argmax agreed (1.0 before
    /// any sample — no evidence of disagreement).
    pub fn top1_agreement(&self) -> f64 {
        let n = self.samples();
        if n == 0 {
            1.0
        } else {
            self.top1_agree.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn snapshot_json(&self) -> Json {
        let (mean_margin, kl_count) = {
            let h = self.hists.lock().expect("quality hists poisoned");
            let m = if h.margin.count() == 0 {
                0.0
            } else {
                h.margin.sum() / h.margin.count() as f64
            };
            (m, h.kl.count())
        };
        debug_assert_eq!(kl_count, self.samples());
        Json::obj(vec![
            ("samples", Json::Num(self.samples() as f64)),
            ("period", Json::Num(self.period as f64)),
            ("mean_kl", Json::Num(self.mean_kl())),
            ("max_kl", Json::Num(self.max_kl())),
            ("top1_agreement", Json::Num(self.top1_agreement())),
            ("kl_ceiling", Json::Num(self.kl_ceiling)),
            ("kl_breaches", Json::Num(self.kl_breaches() as f64)),
            ("mean_margin", Json::Num(mean_margin)),
        ])
    }

    pub fn render_prometheus(&self, p: &mut PromText) {
        p.counter(
            "wisparse_shadow_samples_total",
            "Shadow-dense replay samples taken",
            &[],
            self.samples() as f64,
        );
        p.counter(
            "wisparse_shadow_top1_agree_total",
            "Shadow samples where dense and served argmax agreed",
            &[],
            self.top1_agree.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "wisparse_shadow_kl_breaches_total",
            "Shadow samples whose KL(dense||sparse) exceeded the ceiling",
            &[],
            self.kl_breaches() as f64,
        );
        p.gauge(
            "wisparse_shadow_kl_max",
            "Maximum single-sample KL(dense||sparse) in nats",
            &[],
            self.max_kl(),
        );
        let h = self.hists.lock().expect("quality hists poisoned");
        p.histogram(
            "wisparse_shadow_kl",
            "KL(dense||sparse) per shadow sample, nats",
            &h.kl,
        );
        p.histogram(
            "wisparse_shadow_margin",
            "Served logits top1-top2 margin per shadow sample",
            &h.margin,
        );
    }
}

/// Top1−top2 gap of a logit vector (0 for fewer than two entries).
pub fn top2_margin(logits: &[f32]) -> f64 {
    let (mut top1, mut top2) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
    for &v in logits {
        if v > top1 {
            top2 = top1;
            top1 = v;
        } else if v > top2 {
            top2 = v;
        }
    }
    if top2 == f32::NEG_INFINITY {
        0.0
    } else {
        (top1 - top2) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn period_from_rate() {
        assert_eq!(QualityObs::new(1.0, 0.5).period(), 1);
        assert_eq!(QualityObs::new(0.01, 0.5).period(), 100);
        assert_eq!(QualityObs::new(0.5, 0.5).period(), 2);
    }

    #[test]
    #[should_panic(expected = "sample rate")]
    fn zero_rate_rejected() {
        QualityObs::new(0.0, 0.5);
    }

    #[test]
    fn samples_aggregate() {
        let q = QualityObs::new(1.0, 0.1);
        q.record_sample(0.02, true, 3.0);
        q.record_sample(0.3, false, 0.05);
        assert_eq!(q.samples(), 2);
        assert_eq!(q.kl_breaches(), 1);
        assert!((q.top1_agreement() - 0.5).abs() < 1e-12);
        assert!((q.mean_kl() - 0.16).abs() < 1e-12);
        assert!((q.max_kl() - 0.3).abs() < 1e-12);
        let j = q.snapshot_json();
        assert_eq!(j.get("samples").as_f64(), Some(2.0));
        assert_eq!(j.get("kl_breaches").as_f64(), Some(1.0));
    }

    #[test]
    fn no_samples_is_benign() {
        let q = QualityObs::new(0.01, 0.5);
        assert_eq!(q.mean_kl(), 0.0);
        assert_eq!(q.max_kl(), 0.0);
        assert_eq!(q.top1_agreement(), 1.0);
    }

    #[test]
    fn prometheus_families_render() {
        let q = QualityObs::new(1.0, 0.5);
        q.record_sample(0.001, true, 1.0);
        let mut p = PromText::new();
        q.render_prometheus(&mut p);
        let s = p.finish();
        assert!(s.contains("# TYPE wisparse_shadow_samples_total counter"));
        assert!(s.contains("wisparse_shadow_samples_total 1"));
        assert!(s.contains("# TYPE wisparse_shadow_kl histogram"));
        assert!(s.contains("wisparse_shadow_kl_bucket{le=\"+Inf\"} 1"));
        assert!(s.contains("wisparse_shadow_margin_count 1"));
    }

    #[test]
    fn margin_of_logits() {
        assert!((top2_margin(&[1.0, 4.0, 2.5]) - 1.5).abs() < 1e-6);
        assert_eq!(top2_margin(&[7.0]), 0.0);
        assert_eq!(top2_margin(&[]), 0.0);
    }
}
