//! Prometheus text exposition format (version 0.0.4) renderer.
//!
//! Zero-dependency: a small builder that emits `# HELP`/`# TYPE` once per
//! family (even when a family carries several label sets), escapes label
//! values, and renders [`Hist`] as conformant `_bucket/_sum/_count` series.

use crate::obs::hist::Hist;
use std::collections::BTreeSet;
use std::fmt::Write;

/// Escape a label value per the exposition format: backslash, double-quote
/// and line-feed.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 { "+Inf" } else { "-Inf" }.to_string()
    } else if v.is_nan() {
        "NaN".to_string()
    } else {
        format!("{v}")
    }
}

fn fmt_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

/// Incremental exposition-text builder.
#[derive(Default)]
pub struct PromText {
    out: String,
    typed: BTreeSet<String>,
}

impl PromText {
    pub fn new() -> Self {
        Self::default()
    }

    fn family(&mut self, name: &str, kind: &str, help: &str) {
        if self.typed.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {kind}");
        }
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.family(name, "counter", help);
        let _ = writeln!(self.out, "{name}{} {}", fmt_labels(labels), fmt_value(value));
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.family(name, "gauge", help);
        let _ = writeln!(self.out, "{name}{} {}", fmt_labels(labels), fmt_value(value));
    }

    pub fn histogram(&mut self, name: &str, help: &str, h: &Hist) {
        self.family(name, "histogram", help);
        for (le, count) in h.cumulative() {
            let _ = writeln!(
                self.out,
                "{name}_bucket{{le=\"{}\"}} {count}",
                fmt_value(le)
            );
        }
        let _ = writeln!(self.out, "{name}_sum {}", fmt_value(h.sum()));
        let _ = writeln!(self.out, "{name}_count {}", h.count());
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(escape_label("x\ny"), "x\\ny");
        assert_eq!(escape_label("plain"), "plain");
    }

    #[test]
    fn type_emitted_once_per_family() {
        let mut p = PromText::new();
        p.counter("m_total", "help", &[("reason", "stop")], 1.0);
        p.counter("m_total", "help", &[("reason", "length")], 2.0);
        let s = p.finish();
        assert_eq!(s.matches("# TYPE m_total counter").count(), 1);
        assert!(s.contains("m_total{reason=\"stop\"} 1"));
        assert!(s.contains("m_total{reason=\"length\"} 2"));
    }

    #[test]
    fn histogram_render_has_inf_sum_count() {
        let mut h = Hist::new_ms();
        h.observe(0.3);
        h.observe(40.0);
        let mut p = PromText::new();
        p.histogram("lat_ms", "latency", &h);
        let s = p.finish();
        assert!(s.contains("# TYPE lat_ms histogram"));
        assert!(s.contains("lat_ms_bucket{le=\"+Inf\"} 2"));
        assert!(s.contains("lat_ms_count 2"));
        assert!(s.contains("lat_ms_sum 40.3"));
    }

    #[test]
    fn infinity_and_plain_values() {
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(2.5), "2.5");
        assert_eq!(fmt_value(3.0), "3");
    }
}
