//! Declarative SLOs with multi-window burn-rate alerting.
//!
//! Each objective is event-based: a feeder turns observations into
//! cumulative `(total, bad)` counters (request over the latency threshold,
//! decode gap over budget, shadow-KL sample over the ceiling, request
//! finishing `internal_error`), and [`SloEngine::tick`] differences those
//! counters into per-second sliding windows ([`RateWindow`]-style slots:
//! absolute-second tags, stale slots reset on write). An alert fires when
//! the burn rate — `(bad/total) / budget` — exceeds the threshold on both
//! the fast and the slow window, and resolves when the fast window
//! recovers; the classic multi-window pattern that pages quickly on hard
//! outages without flapping on single bad seconds.
//!
//! [`RateWindow`]: crate::obs::hist::RateWindow

use crate::obs::prom::PromText;
use crate::util::json::Json;
use std::time::Instant;

/// How many recently-resolved alerts `/alerts` retains.
const RESOLVED_KEEP: usize = 32;

/// One declarative objective.
#[derive(Clone, Debug)]
pub struct SloSpec {
    /// Stable identifier, used as the `slo` label (`latency_p95_ms`, ...).
    pub name: String,
    /// Fraction of events allowed to be bad (e.g. 0.05 ⇒ "p95 objective").
    pub budget: f64,
    /// Threshold the event feeder applies to call an event bad — ms for
    /// latency/gap objectives, nats for shadow-KL, unused (0) for pure
    /// error-rate objectives. Advisory metadata surfaced in `/alerts`.
    pub threshold: f64,
    /// Fast evaluation window, seconds (short: detects, resolves).
    pub fast_s: u64,
    /// Slow evaluation window, seconds (long: confirms, de-flaps).
    pub slow_s: u64,
    /// Burn-rate multiple that fires the alert (1.0 = burning exactly at
    /// budget; SRE-style paging uses ~14 for fast, here one knob for both
    /// windows keeps the config small).
    pub burn: f64,
}

impl SloSpec {
    pub fn new(name: &str, budget: f64, threshold: f64) -> Self {
        assert!(budget > 0.0 && budget < 1.0, "budget in (0,1): {budget}");
        Self {
            name: name.to_string(),
            budget,
            threshold,
            fast_s: 60,
            slow_s: 600,
            burn: 2.0,
        }
    }

    pub fn windows(mut self, fast_s: u64, slow_s: u64, burn: f64) -> Self {
        assert!(fast_s >= 1 && slow_s >= fast_s && burn > 0.0);
        self.fast_s = fast_s;
        self.slow_s = slow_s;
        self.burn = burn;
        self
    }

    /// The default serving objectives; thresholds are deliberately loose —
    /// operators tune them per deployment (`CoordinatorCfg::slos`).
    pub fn default_set(kl_ceiling: f64) -> Vec<SloSpec> {
        vec![
            SloSpec::new("latency_p95_ms", 0.05, 2500.0),
            SloSpec::new("decode_gap_p95_ms", 0.05, 500.0),
            SloSpec::new("shadow_kl", 0.05, kl_ceiling),
            SloSpec::new("error_rate", 0.01, 0.0),
        ]
    }
}

/// A fired alert, active or recently resolved.
#[derive(Clone, Debug)]
pub struct Alert {
    pub slo: String,
    pub fired_at_s: u64,
    pub resolved_at_s: Option<u64>,
    /// Burn rates observed when the alert fired.
    pub burn_fast: f64,
    pub burn_slow: f64,
}

impl Alert {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("slo", Json::Str(self.slo.clone())),
            ("fired_at_s", Json::Num(self.fired_at_s as f64)),
            (
                "resolved_at_s",
                match self.resolved_at_s {
                    Some(s) => Json::Num(s as f64),
                    None => Json::Null,
                },
            ),
            ("burn_fast", Json::Num(self.burn_fast)),
            ("burn_slow", Json::Num(self.burn_slow)),
        ])
    }
}

struct SloState {
    spec: SloSpec,
    /// Last cumulative counters seen, for differencing.
    last_total: u64,
    last_bad: u64,
    /// Per-second deltas: (absolute second, total, bad); stale slots are
    /// reset on write, reads filter by second range.
    slots: Vec<(u64, u64, u64)>,
    active: Option<Alert>,
    fired_total: u64,
}

impl SloState {
    fn new(spec: SloSpec) -> Self {
        let n = (spec.slow_s as usize + 2).max(8);
        Self {
            spec,
            last_total: 0,
            last_bad: 0,
            slots: vec![(u64::MAX, 0, 0); n],
            active: None,
            fired_total: 0,
        }
    }

    fn push(&mut self, sec: u64, d_total: u64, d_bad: u64) {
        let n = self.slots.len() as u64;
        let slot = &mut self.slots[(sec % n) as usize];
        if slot.0 != sec {
            *slot = (sec, 0, 0);
        }
        slot.1 += d_total;
        slot.2 += d_bad;
    }

    /// Burn rate over the trailing `w`-second window ending at `now_sec`:
    /// `(bad/total) / budget`, 0 when the window saw no events.
    fn burn(&self, now_sec: u64, w: u64) -> f64 {
        let lo = now_sec.saturating_sub(w - 1);
        let (mut total, mut bad) = (0u64, 0u64);
        for &(s, t, b) in &self.slots {
            if s >= lo && s <= now_sec {
                total += t;
                bad += b;
            }
        }
        if total == 0 {
            0.0
        } else {
            (bad as f64 / total as f64) / self.spec.budget
        }
    }
}

/// The burn-rate evaluator over a fixed set of [`SloSpec`]s.
pub struct SloEngine {
    started: Instant,
    slos: Vec<SloState>,
    resolved: Vec<Alert>,
}

impl SloEngine {
    pub fn new(specs: Vec<SloSpec>) -> Self {
        Self {
            started: Instant::now(),
            slos: specs.into_iter().map(SloState::new).collect(),
            resolved: Vec::new(),
        }
    }

    pub fn specs(&self) -> impl Iterator<Item = &SloSpec> {
        self.slos.iter().map(|s| &s.spec)
    }

    fn now_sec(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    /// Ingest the current cumulative `(name, total, bad)` counters and
    /// evaluate every objective. Call it from the scheduler loop and on
    /// scrape — per-second slots make the cadence irrelevant.
    pub fn tick(&mut self, inputs: &[(&str, u64, u64)]) {
        self.tick_at(self.now_sec(), inputs)
    }

    /// Deterministic-time variant used by tests.
    pub fn tick_at(&mut self, now_sec: u64, inputs: &[(&str, u64, u64)]) {
        for slo in self.slos.iter_mut() {
            if let Some(&(_, total, bad)) =
                inputs.iter().find(|(n, _, _)| *n == slo.spec.name)
            {
                // Counters are cumulative and monotone; saturate defensively
                // so a feeder reset cannot underflow.
                let d_total = total.saturating_sub(slo.last_total);
                let d_bad = bad.saturating_sub(slo.last_bad);
                slo.last_total = total;
                slo.last_bad = bad;
                if d_total > 0 || d_bad > 0 {
                    slo.push(now_sec, d_total, d_bad);
                }
            }
            let fast = slo.burn(now_sec, slo.spec.fast_s);
            let slow = slo.burn(now_sec, slo.spec.slow_s);
            match &mut slo.active {
                None => {
                    if fast >= slo.spec.burn && slow >= slo.spec.burn {
                        slo.fired_total += 1;
                        slo.active = Some(Alert {
                            slo: slo.spec.name.clone(),
                            fired_at_s: now_sec,
                            resolved_at_s: None,
                            burn_fast: fast,
                            burn_slow: slow,
                        });
                    }
                }
                Some(alert) => {
                    if fast < slo.spec.burn {
                        let mut done = alert.clone();
                        done.resolved_at_s = Some(now_sec);
                        slo.active = None;
                        self.resolved.push(done);
                        if self.resolved.len() > RESOLVED_KEEP {
                            let drop = self.resolved.len() - RESOLVED_KEEP;
                            self.resolved.drain(..drop);
                        }
                    }
                }
            }
        }
    }

    pub fn active(&self) -> Vec<&Alert> {
        self.slos.iter().filter_map(|s| s.active.as_ref()).collect()
    }

    pub fn fired_total(&self) -> u64 {
        self.slos.iter().map(|s| s.fired_total).sum()
    }

    /// The `GET /alerts` body: objectives, active alerts, recently resolved.
    pub fn alerts_json(&self) -> Json {
        let objectives = self
            .slos
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("slo", Json::Str(s.spec.name.clone())),
                    ("budget", Json::Num(s.spec.budget)),
                    ("threshold", Json::Num(s.spec.threshold)),
                    ("fast_s", Json::Num(s.spec.fast_s as f64)),
                    ("slow_s", Json::Num(s.spec.slow_s as f64)),
                    ("burn", Json::Num(s.spec.burn)),
                    ("active", Json::Bool(s.active.is_some())),
                    ("fired_total", Json::Num(s.fired_total as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("objectives", Json::Arr(objectives)),
            (
                "active",
                Json::Arr(self.active().iter().map(|a| a.to_json()).collect()),
            ),
            (
                "resolved",
                Json::Arr(self.resolved.iter().rev().map(|a| a.to_json()).collect()),
            ),
        ])
    }

    pub fn render_prometheus(&self, p: &mut PromText) {
        for s in &self.slos {
            p.gauge(
                "wisparse_alert_active",
                "1 while the SLO's burn-rate alert is firing",
                &[("slo", &s.spec.name)],
                if s.active.is_some() { 1.0 } else { 0.0 },
            );
        }
        for s in &self.slos {
            p.counter(
                "wisparse_alerts_fired_total",
                "Burn-rate alerts fired since start",
                &[("slo", &s.spec.name)],
                s.fired_total as f64,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(fast_s: u64, slow_s: u64, burn: f64) -> SloEngine {
        SloEngine::new(vec![
            SloSpec::new("error_rate", 0.01, 0.0).windows(fast_s, slow_s, burn)
        ])
    }

    #[test]
    fn quiet_traffic_never_fires() {
        let mut e = engine(5, 30, 2.0);
        for sec in 0..60 {
            e.tick_at(sec, &[("error_rate", sec * 10, 0)]);
        }
        assert!(e.active().is_empty());
        assert_eq!(e.fired_total(), 0);
        let j = e.alerts_json();
        assert_eq!(j.get("active").as_arr().unwrap().len(), 0);
    }

    #[test]
    fn fires_on_burn_and_resolves_on_recovery() {
        let mut e = engine(5, 30, 2.0);
        // 20 good events/sec for 10s, then 10s of 50% errors (error rate
        // 0.5 ≫ budget 0.01 ⇒ burn 50), then recovery.
        let (mut total, mut bad) = (0u64, 0u64);
        for sec in 0..10 {
            total += 20;
            e.tick_at(sec, &[("error_rate", total, bad)]);
        }
        assert!(e.active().is_empty());
        let mut fired_at = None;
        for sec in 10..20 {
            total += 20;
            bad += 10;
            e.tick_at(sec, &[("error_rate", total, bad)]);
            if !e.active().is_empty() && fired_at.is_none() {
                fired_at = Some(sec);
            }
        }
        let fired_at = fired_at.expect("burn alert fired");
        assert_eq!(e.fired_total(), 1);
        // Recovery: the fast window (5s) clears once it holds only good
        // seconds; the alert moves to resolved.
        for sec in 20..40 {
            total += 20;
            e.tick_at(sec, &[("error_rate", total, bad)]);
        }
        assert!(e.active().is_empty(), "alert must clear after recovery");
        let j = e.alerts_json();
        let resolved = j.get("resolved").as_arr().unwrap();
        assert_eq!(resolved.len(), 1);
        assert_eq!(resolved[0].get("slo").as_str(), Some("error_rate"));
        assert_eq!(
            resolved[0].get("fired_at_s").as_f64(),
            Some(fired_at as f64)
        );
        assert!(resolved[0].get("resolved_at_s").as_f64().unwrap() >= 20.0);
    }

    #[test]
    fn slow_window_suppresses_single_bad_second() {
        // One bad second inside an otherwise-clean long history: the fast
        // window burns but the slow window stays under threshold.
        let mut e = engine(2, 30, 2.0);
        let (mut total, mut bad) = (0u64, 0u64);
        for sec in 0..29 {
            total += 100;
            e.tick_at(sec, &[("error_rate", total, bad)]);
        }
        total += 100;
        bad += 3; // 3% of one second's 100 events; ~0.1% of the slow window
        e.tick_at(29, &[("error_rate", total, bad)]);
        assert!(
            e.active().is_empty(),
            "slow window must veto a blip: {:?}",
            e.active()
        );
    }

    #[test]
    fn no_events_means_no_burn() {
        let mut e = engine(5, 30, 1.0);
        e.tick_at(0, &[("error_rate", 0, 0)]);
        e.tick_at(1, &[]);
        assert!(e.active().is_empty());
    }

    #[test]
    fn prometheus_families() {
        let mut e = engine(1, 1, 1.0);
        e.tick_at(0, &[("error_rate", 10, 10)]);
        assert_eq!(e.active().len(), 1);
        let mut p = PromText::new();
        e.render_prometheus(&mut p);
        let s = p.finish();
        assert!(s.contains("# TYPE wisparse_alert_active gauge"));
        assert!(s.contains("wisparse_alert_active{slo=\"error_rate\"} 1"));
        assert!(s.contains("wisparse_alerts_fired_total{slo=\"error_rate\"} 1"));
    }

    #[test]
    fn default_set_names() {
        let names: Vec<String> = SloSpec::default_set(0.5)
            .into_iter()
            .map(|s| s.name)
            .collect();
        assert_eq!(
            names,
            vec!["latency_p95_ms", "decode_gap_p95_ms", "shadow_kl", "error_rate"]
        );
    }
}
