//! STREAM-style memory-bandwidth ceiling for roofline accounting.
//!
//! Sparse decode on CPU is weight-streaming-bound, so the honest "speed of
//! light" for a projection is the machine's sustained memory bandwidth, not
//! peak FLOPs. This measures the classic STREAM *scale* kernel
//! (`b[i] = s * a[i]`) over buffers far larger than cache, split across the
//! same number of threads the engine uses, and reports the best-of-reps
//! GB/s. `wisparse profile` prints every block's achieved GB/s against it.

use std::hint::black_box;
use std::time::Instant;

/// Floats per buffer for the default measurement (32 MiB per buffer).
pub const STREAM_FLOATS: usize = 1 << 23;

/// Best-of-`reps` scale-kernel bandwidth in GB/s using `threads` workers.
/// One extra warm-up reps runs first and is discarded.
pub fn stream_gb_per_s_with(n: usize, reps: usize, threads: usize) -> f64 {
    let threads = threads.max(1);
    let a = vec![1.0f32; n];
    let mut b = vec![0.0f32; n];
    let chunk = n.div_ceil(threads);
    let mut best = 0.0f64;
    for rep in 0..=reps {
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for (dst, src) in b.chunks_mut(chunk).zip(a.chunks(chunk)) {
                scope.spawn(move || {
                    for (d, s) in dst.iter_mut().zip(src) {
                        *d = 1.000001 * *s;
                    }
                    black_box(&dst[0]);
                });
            }
        });
        let ns = t0.elapsed().as_nanos().max(1) as f64;
        // 4 bytes read + 4 bytes written per element; bytes/ns == GB/s.
        let gb_s = (n * 8) as f64 / ns;
        if rep > 0 {
            best = best.max(gb_s);
        }
    }
    black_box(&b);
    best
}

/// Default measurement: 32 MiB buffers, 3 timed reps, engine thread count.
pub fn stream_gb_per_s() -> f64 {
    stream_gb_per_s_with(
        STREAM_FLOATS,
        3,
        crate::util::threadpool::num_threads(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_is_positive_and_sane() {
        // Small buffer keeps the test fast; any real machine moves >0.1 GB/s
        // and <10 TB/s.
        let gb_s = stream_gb_per_s_with(1 << 18, 2, 2);
        assert!(gb_s > 0.1 && gb_s < 10_000.0, "gb/s {gb_s}");
    }
}
