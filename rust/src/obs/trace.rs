//! In-process span tracer: a fixed-capacity ring of [`Span`] records with
//! cheap RAII guards, plus a bounded worst-N exemplar table.
//!
//! Design constraints (enforced by `rust/tests/alloc_steady_state.rs`):
//! recording a span in the decode hot path must not allocate. Spans carry
//! `&'static str` names and a fixed-size attribute array; the ring is
//! preallocated at construction and writers only take the per-slot lock of
//! the slot they overwrite ("lock-free-ish": the head index is a single
//! `fetch_add`, contention is spread over the whole ring).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Fixed attribute capacity per span (numeric key/value pairs).
pub const MAX_ATTRS: usize = 3;

/// Default global ring capacity: ~260 spans per 256-token generation means
/// roughly the last ~125 requests stay reconstructable.
pub const DEFAULT_CAPACITY: usize = 1 << 15;

/// How many worst exemplars each slow table retains.
pub const SLOW_KEEP: usize = 32;

/// One timed event. `start_ns`/`dur_ns` are offsets from the tracer's epoch
/// (process start, effectively), so spans from different threads share a
/// timeline. `parent == 0` means "no parent".
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub trace_id: u64,
    pub id: u64,
    pub parent: u64,
    pub name: &'static str,
    pub start_ns: u64,
    pub dur_ns: u64,
    n_attrs: u8,
    attrs: [(&'static str, f64); MAX_ATTRS],
}

impl Span {
    pub fn new(trace_id: u64, id: u64, parent: u64, name: &'static str) -> Self {
        Self {
            trace_id,
            id,
            parent,
            name,
            start_ns: 0,
            dur_ns: 0,
            n_attrs: 0,
            attrs: [("", 0.0); MAX_ATTRS],
        }
    }

    /// Attach a numeric attribute; silently dropped past [`MAX_ATTRS`].
    pub fn push_attr(&mut self, key: &'static str, value: f64) {
        if (self.n_attrs as usize) < MAX_ATTRS {
            self.attrs[self.n_attrs as usize] = (key, value);
            self.n_attrs += 1;
        }
    }

    pub fn attrs(&self) -> &[(&'static str, f64)] {
        &self.attrs[..self.n_attrs as usize]
    }
}

/// Per-trace rollup kept by the slow-exemplar tables.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceSummary {
    pub trace_id: u64,
    pub total_ms: f64,
    pub decode_gap_max_ms: f64,
}

pub struct Tracer {
    epoch: Instant,
    head: AtomicU64,
    next_span: AtomicU64,
    next_trace: AtomicU64,
    slots: Vec<Mutex<Option<Span>>>,
    slow_total: Mutex<Vec<TraceSummary>>,
    slow_gap: Mutex<Vec<TraceSummary>>,
}

impl Tracer {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            epoch: Instant::now(),
            head: AtomicU64::new(0),
            next_span: AtomicU64::new(1),
            next_trace: AtomicU64::new(1),
            slots: (0..cap).map(|_| Mutex::new(None)).collect(),
            slow_total: Mutex::new(Vec::new()),
            slow_gap: Mutex::new(Vec::new()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total spans ever recorded (monotonic, exceeds capacity once wrapped).
    pub fn written(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Nanoseconds since the tracer's epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Epoch offset of an `Instant`; clamps to 0 for pre-epoch instants.
    pub fn ns_of(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
    }

    /// Allocate a span id (never 0).
    pub fn next_span_id(&self) -> u64 {
        self.next_span.fetch_add(1, Ordering::Relaxed)
    }

    /// Allocate a trace id (never 0); distinct from request ids so that
    /// several coordinators in one process cannot collide.
    pub fn next_trace_id(&self) -> u64 {
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    /// Write a fully-formed span into the ring (wraps, overwriting oldest).
    pub fn record(&self, span: Span) {
        let i = (self.head.fetch_add(1, Ordering::Relaxed) % self.slots.len() as u64) as usize;
        // A poisoned slot only means a writer panicked mid-copy; the slot
        // content is a plain Copy value, safe to overwrite.
        let mut slot = match self.slots[i].lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        *slot = Some(span);
    }

    /// Record a completed interval measured by the caller. Returns the new
    /// span's id so children recorded after the fact can parent onto it.
    pub fn record_at(
        &self,
        trace_id: u64,
        parent: u64,
        name: &'static str,
        start: Instant,
        dur_ns: u64,
        attrs: &[(&'static str, f64)],
    ) -> u64 {
        let id = self.next_span_id();
        let mut s = Span::new(trace_id, id, parent, name);
        s.start_ns = self.ns_of(start);
        s.dur_ns = dur_ns;
        for &(k, v) in attrs {
            s.push_attr(k, v);
        }
        self.record(s);
        id
    }

    /// Start an RAII-timed span; recorded on drop.
    pub fn start(&self, trace_id: u64, parent: u64, name: &'static str) -> SpanGuard<'_> {
        SpanGuard {
            tracer: self,
            span: Span::new(trace_id, self.next_span_id(), parent, name),
            started: Instant::now(),
        }
    }

    /// Roll a finished trace into the slow-exemplar tables.
    pub fn note_trace(&self, summary: TraceSummary) {
        fn push(
            table: &Mutex<Vec<TraceSummary>>,
            s: TraceSummary,
            key: impl Fn(&TraceSummary) -> f64,
        ) {
            let mut t = match table.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            t.push(s);
            t.sort_by(|a, b| key(b).partial_cmp(&key(a)).unwrap_or(std::cmp::Ordering::Equal));
            t.truncate(SLOW_KEEP);
        }
        push(&self.slow_total, summary, |s| s.total_ms);
        push(&self.slow_gap, summary, |s| s.decode_gap_max_ms);
    }

    /// Worst exemplars: (by total latency, by max decode gap), worst first.
    pub fn slow(&self) -> (Vec<TraceSummary>, Vec<TraceSummary>) {
        let total = match self.slow_total.lock() {
            Ok(g) => g.clone(),
            Err(p) => p.into_inner().clone(),
        };
        let gap = match self.slow_gap.lock() {
            Ok(g) => g.clone(),
            Err(p) => p.into_inner().clone(),
        };
        (total, gap)
    }

    /// All retained spans of one trace, in start order.
    pub fn trace(&self, trace_id: u64) -> Vec<Span> {
        let mut out: Vec<Span> = self
            .slots
            .iter()
            .filter_map(|s| {
                let g = match s.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                g.filter(|sp| sp.trace_id == trace_id)
            })
            .collect();
        out.sort_by_key(|s| (s.start_ns, s.id));
        out
    }
}

/// Whether a retrieved trace is missing ancestry: some retained span
/// parents onto a span id that is neither 0 (root marker) nor present in
/// the set. That happens when the ring wrapped mid-request and evicted an
/// ancestor, and also while a request is still in flight (its pre-reserved
/// root span is only recorded at completion) — either way the timeline is
/// incomplete and consumers must not render it as authoritative.
pub fn is_truncated(spans: &[Span]) -> bool {
    spans.iter().any(|s| {
        s.parent != 0 && !spans.iter().any(|p| p.id == s.parent)
    })
}

/// Render spans as Chrome trace-event JSON (the `chrome://tracing` /
/// ui.perfetto.dev interchange format): one complete (`"ph": "X"`) event
/// per span, timestamps/durations in microseconds, `tid` = trace id so each
/// request gets its own track. Span ids, parent ids and numeric attributes
/// ride along in `args` so the hierarchy `/debug/traces?id=` reports stays
/// recoverable from the export.
pub fn chrome_trace(spans: &[Span]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let events = spans
        .iter()
        .map(|s| {
            let mut args = vec![
                ("id", Json::Num(s.id as f64)),
                ("parent", Json::Num(s.parent as f64)),
            ];
            for &(k, v) in s.attrs() {
                args.push((k, Json::Num(v)));
            }
            Json::obj(vec![
                ("name", Json::Str(s.name.to_string())),
                ("cat", Json::Str("wisparse".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(s.start_ns as f64 / 1000.0)),
                ("dur", Json::Num(s.dur_ns as f64 / 1000.0)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.trace_id as f64)),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("truncated", Json::Bool(is_truncated(spans))),
    ])
}

/// RAII span: times from construction to drop, then records.
pub struct SpanGuard<'a> {
    tracer: &'a Tracer,
    span: Span,
    started: Instant,
}

impl SpanGuard<'_> {
    /// This span's id, for parenting children onto it.
    pub fn id(&self) -> u64 {
        self.span.id
    }

    pub fn attr(&mut self, key: &'static str, value: f64) {
        self.span.push_attr(key, value);
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        self.span.start_ns = self.tracer.ns_of(self.started);
        self.span.dur_ns = self.started.elapsed().as_nanos() as u64;
        self.tracer.record(self.span);
    }
}

static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer used by the serving path. First call fixes the
/// epoch; the coordinator touches it at construction so request arrival
/// times never predate it.
pub fn tracer() -> &'static Tracer {
    GLOBAL.get_or_init(|| Tracer::with_capacity(DEFAULT_CAPACITY))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_records_on_drop() {
        let t = Tracer::with_capacity(8);
        {
            let mut g = t.start(7, 0, "work");
            g.attr("tokens", 3.0);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let spans = t.trace(7);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "work");
        assert!(spans[0].dur_ns >= 1_000_000, "dur {}", spans[0].dur_ns);
        assert_eq!(spans[0].attrs(), &[("tokens", 3.0)]);
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let t = Tracer::with_capacity(4);
        for i in 0..10u64 {
            let mut s = Span::new(1, t.next_span_id(), 0, "e");
            s.start_ns = i;
            t.record(s);
        }
        let spans = t.trace(1);
        assert_eq!(spans.len(), 4);
        // Only the last 4 writes survive.
        assert_eq!(spans[0].start_ns, 6);
        assert_eq!(spans[3].start_ns, 9);
        assert_eq!(t.written(), 10);
    }

    #[test]
    fn attr_overflow_dropped_not_panicking() {
        let mut s = Span::new(1, 1, 0, "x");
        for i in 0..(MAX_ATTRS + 2) {
            s.push_attr("k", i as f64);
        }
        assert_eq!(s.attrs().len(), MAX_ATTRS);
    }

    #[test]
    fn slow_tables_rank_independently() {
        let t = Tracer::with_capacity(4);
        for i in 0..40u64 {
            t.note_trace(TraceSummary {
                trace_id: i,
                total_ms: i as f64,
                decode_gap_max_ms: (40 - i) as f64,
            });
        }
        let (by_total, by_gap) = t.slow();
        assert_eq!(by_total.len(), SLOW_KEEP);
        assert_eq!(by_total[0].trace_id, 39);
        assert_eq!(by_gap[0].trace_id, 0);
        assert!(by_total.windows(2).all(|w| w[0].total_ms >= w[1].total_ms));
        assert!(by_gap
            .windows(2)
            .all(|w| w[0].decode_gap_max_ms >= w[1].decode_gap_max_ms));
    }

    #[test]
    fn ids_start_nonzero() {
        let t = Tracer::with_capacity(1);
        assert!(t.next_span_id() >= 1);
        assert!(t.next_trace_id() >= 1);
    }

    /// A tiny ring wrapping mid-request evicts the early spans children
    /// still parent onto: the retrieved timeline must say so.
    #[test]
    fn wrapped_ring_reports_truncated() {
        let t = Tracer::with_capacity(4);
        let root = t.next_span_id();
        let mut r = Span::new(1, root, 0, "request");
        r.start_ns = 0;
        r.dur_ns = 100;
        t.record(r);
        for i in 0..6u64 {
            let mut s = Span::new(1, t.next_span_id(), root, "decode_step");
            s.start_ns = 10 + i;
            s.dur_ns = 1;
            t.record(s);
        }
        let spans = t.trace(1);
        assert_eq!(spans.len(), 4);
        assert!(
            is_truncated(&spans),
            "root evicted by the wrap: children orphaned"
        );
        // A complete trace in a roomy ring is not truncated.
        let t2 = Tracer::with_capacity(16);
        let root2 = t2.next_span_id();
        let mut r2 = Span::new(9, root2, 0, "request");
        r2.dur_ns = 100;
        t2.record(r2);
        let mut c = Span::new(9, t2.next_span_id(), root2, "decode_step");
        c.start_ns = 5;
        t2.record(c);
        assert!(!is_truncated(&t2.trace(9)));
    }

    #[test]
    fn chrome_trace_shape() {
        let t = Tracer::with_capacity(16);
        let root = t.next_span_id();
        let mut r = Span::new(3, root, 0, "request");
        r.start_ns = 2_000;
        r.dur_ns = 10_000;
        t.record(r);
        let mut c = Span::new(3, t.next_span_id(), root, "decode_step");
        c.start_ns = 3_000;
        c.dur_ns = 1_000;
        c.push_attr("tokens", 4.0);
        t.record(c);
        let spans = t.trace(3);
        let j = chrome_trace(&spans);
        // Parses back through the same JSON layer (it was built in-memory;
        // round-trip through text like an external consumer would).
        let txt = j.to_string_compact();
        let back = crate::util::json::Json::parse(&txt).unwrap();
        assert_eq!(back.get("displayTimeUnit").as_str(), Some("ms"));
        assert_eq!(back.get("truncated").as_bool(), Some(false));
        let events = back.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let req = &events[0];
        assert_eq!(req.get("ph").as_str(), Some("X"));
        assert_eq!(req.get("name").as_str(), Some("request"));
        assert_eq!(req.get("ts").as_f64(), Some(2.0)); // µs
        assert_eq!(req.get("dur").as_f64(), Some(10.0));
        assert_eq!(req.get("tid").as_f64(), Some(3.0));
        let step = &events[1];
        assert_eq!(step.get("args").get("parent").as_f64(), Some(root as f64));
        assert_eq!(step.get("args").get("tokens").as_f64(), Some(4.0));
        // "X" events nest by time containment: the child interval must lie
        // inside the root's.
        let (rts, rdur) = (req.get("ts").as_f64().unwrap(), req.get("dur").as_f64().unwrap());
        let (cts, cdur) = (step.get("ts").as_f64().unwrap(), step.get("dur").as_f64().unwrap());
        assert!(cts >= rts && cts + cdur <= rts + rdur);
    }
}
