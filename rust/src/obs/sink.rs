//! Forward-path telemetry behind the [`ObsSink`] trait.
//!
//! The transformer's projection loop calls `enabled()` once per projection;
//! only when a recording sink is installed does it also time the projection
//! and call `record_proj`. The no-op sink therefore costs one virtual call
//! on the hot path and never touches activations, which is what keeps the
//! recording/no-op logits bit-identical (pinned by `tests/obs_telemetry.rs`).

use crate::model::layers::{LayerId, LayerKind};
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-(block, projection) accumulated telemetry.
#[derive(Clone, Copy, Debug)]
pub struct BlockStat {
    pub id: LayerId,
    /// Projection invocations (== tokens processed through this linear).
    pub calls: u64,
    /// Input channels actually kept across all calls.
    pub kept_channels: u64,
    /// Input channels a dense pass would have used (`calls * in_dim`).
    pub dense_channels: u64,
    /// Wall time spent inside the projection, summed.
    pub ns: u64,
    /// Weight bytes touched, estimated as `resident_bytes * kept / in_dim`
    /// per call (channel skipping saves proportional weight traffic).
    pub bytes: u64,
    /// Shadow-dense replay samples recorded against this projection.
    pub shadow_samples: u64,
    /// Σ‖dense_out − sparse_out‖² across shadow samples.
    pub shadow_err_sq: f64,
    /// Σ‖dense_out‖² across shadow samples (the relative-error denominator).
    pub shadow_ref_sq: f64,
}

impl BlockStat {
    /// Achieved density (kept / dense channel fraction); 1.0 before any call.
    pub fn density(&self) -> f64 {
        if self.dense_channels == 0 {
            1.0
        } else {
            self.kept_channels as f64 / self.dense_channels as f64
        }
    }

    /// Achieved weight-streaming bandwidth. bytes/ns == GB/s.
    pub fn gb_per_s(&self) -> f64 {
        if self.ns == 0 {
            0.0
        } else {
            self.bytes as f64 / self.ns as f64
        }
    }

    /// Relative L2 reconstruction error of the sparse projection output
    /// against the dense shadow replay: `sqrt(Σerr² / Σref²)`. 0.0 before
    /// any shadow sample lands (and for an exactly-dense plan).
    pub fn shadow_rel_err(&self) -> f64 {
        if self.shadow_ref_sq <= 0.0 {
            0.0
        } else {
            (self.shadow_err_sq / self.shadow_ref_sq).sqrt()
        }
    }
}

/// Near-zero-cost sink for per-projection forward-path telemetry.
pub trait ObsSink: Send + Sync {
    /// Whether `record_proj` wants data; checked before any timing work.
    fn enabled(&self) -> bool {
        false
    }

    #[allow(unused_variables)]
    fn record_proj(
        &self,
        layer: LayerId,
        kept: usize,
        in_dim: usize,
        resident_bytes: usize,
        dur_ns: u64,
    ) {
    }

    /// One batch-fused projection call covering `positions` tokens that
    /// shared a single weight walk. `kept_sum` is the per-position kept
    /// counts summed (density accounting); `streamed` is the number of
    /// weight columns actually read — the *union* of the batch's masks —
    /// so weight-bytes are charged once per fused call instead of once per
    /// position (the per-position accounting over-reported bandwidth N×).
    #[allow(unused_variables)]
    fn record_proj_batch(
        &self,
        layer: LayerId,
        positions: usize,
        kept_sum: usize,
        streamed: usize,
        in_dim: usize,
        resident_bytes: usize,
        dur_ns: u64,
    ) {
    }

    /// One shadow-dense replay sample for a projection: `err_sq` is
    /// ‖dense_out − sparse_out‖², `ref_sq` is ‖dense_out‖². Recorded only
    /// by the quality monitor's dense replay, never by the served forward,
    /// so the density/bandwidth rows above stay pure production traffic.
    #[allow(unused_variables)]
    fn record_shadow(&self, layer: LayerId, err_sq: f64, ref_sq: f64) {}

    /// Accumulated per-(block, projection) rows; empty for non-recording sinks.
    fn snapshot(&self) -> Vec<BlockStat> {
        Vec::new()
    }
}

/// The default sink: records nothing.
pub struct NoopSink;

impl ObsSink for NoopSink {}

/// Recording sink: one atomic row per `LayerId::flat()` index.
pub struct BlockObs {
    calls: Vec<AtomicU64>,
    kept: Vec<AtomicU64>,
    dense: Vec<AtomicU64>,
    ns: Vec<AtomicU64>,
    bytes: Vec<AtomicU64>,
    shadow_samples: Vec<AtomicU64>,
    /// f64 sums stored as `to_bits`, accumulated with a CAS loop (shadow
    /// samples are rare — contention is negligible).
    shadow_err: Vec<AtomicU64>,
    shadow_ref: Vec<AtomicU64>,
}

/// Add `add` to an `f64::to_bits`-encoded atomic accumulator.
fn f64_fetch_add(a: &AtomicU64, add: f64) {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + add).to_bits();
        match a.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

impl BlockObs {
    pub fn new(n_blocks: usize) -> Self {
        let n = n_blocks * LayerKind::ALL.len();
        let zeros = |n: usize| (0..n).map(|_| AtomicU64::new(0)).collect();
        Self {
            calls: zeros(n),
            kept: zeros(n),
            dense: zeros(n),
            ns: zeros(n),
            bytes: zeros(n),
            shadow_samples: zeros(n),
            shadow_err: zeros(n),
            shadow_ref: zeros(n),
        }
    }

    /// Zero every row. Lets a caller that must install the sink early (the
    /// sink needs `&mut Model`, calibration only `&Model`) discard
    /// calibration-forward traffic before the real workload starts.
    pub fn reset(&self) {
        for v in [
            &self.calls,
            &self.kept,
            &self.dense,
            &self.ns,
            &self.bytes,
            &self.shadow_samples,
            &self.shadow_err,
            &self.shadow_ref,
        ] {
            for a in v {
                a.store(0, Ordering::Relaxed);
            }
        }
    }
}

impl ObsSink for BlockObs {
    fn enabled(&self) -> bool {
        true
    }

    fn record_proj(
        &self,
        layer: LayerId,
        kept: usize,
        in_dim: usize,
        resident_bytes: usize,
        dur_ns: u64,
    ) {
        let i = layer.flat();
        if i >= self.calls.len() || in_dim == 0 {
            return;
        }
        let touched = (resident_bytes as u128 * kept as u128 / in_dim as u128) as u64;
        self.calls[i].fetch_add(1, Ordering::Relaxed);
        self.kept[i].fetch_add(kept as u64, Ordering::Relaxed);
        self.dense[i].fetch_add(in_dim as u64, Ordering::Relaxed);
        self.ns[i].fetch_add(dur_ns, Ordering::Relaxed);
        self.bytes[i].fetch_add(touched, Ordering::Relaxed);
    }

    fn record_proj_batch(
        &self,
        layer: LayerId,
        positions: usize,
        kept_sum: usize,
        streamed: usize,
        in_dim: usize,
        resident_bytes: usize,
        dur_ns: u64,
    ) {
        let i = layer.flat();
        if i >= self.calls.len() || in_dim == 0 {
            return;
        }
        // Bytes follow the columns the fused walk actually streamed (the
        // mask union), charged once for the whole batch; density keeps the
        // per-position sums so the achieved-vs-planned drift stays per-token.
        let touched = (resident_bytes as u128 * streamed as u128 / in_dim as u128) as u64;
        self.calls[i].fetch_add(positions as u64, Ordering::Relaxed);
        self.kept[i].fetch_add(kept_sum as u64, Ordering::Relaxed);
        self.dense[i].fetch_add((positions * in_dim) as u64, Ordering::Relaxed);
        self.ns[i].fetch_add(dur_ns, Ordering::Relaxed);
        self.bytes[i].fetch_add(touched, Ordering::Relaxed);
    }

    fn record_shadow(&self, layer: LayerId, err_sq: f64, ref_sq: f64) {
        let i = layer.flat();
        if i >= self.calls.len() {
            return;
        }
        self.shadow_samples[i].fetch_add(1, Ordering::Relaxed);
        f64_fetch_add(&self.shadow_err[i], err_sq);
        f64_fetch_add(&self.shadow_ref[i], ref_sq);
    }

    fn snapshot(&self) -> Vec<BlockStat> {
        (0..self.calls.len())
            .map(|i| BlockStat {
                id: LayerId::from_flat(i),
                calls: self.calls[i].load(Ordering::Relaxed),
                kept_channels: self.kept[i].load(Ordering::Relaxed),
                dense_channels: self.dense[i].load(Ordering::Relaxed),
                ns: self.ns[i].load(Ordering::Relaxed),
                bytes: self.bytes[i].load(Ordering::Relaxed),
                shadow_samples: self.shadow_samples[i].load(Ordering::Relaxed),
                shadow_err_sq: f64::from_bits(self.shadow_err[i].load(Ordering::Relaxed)),
                shadow_ref_sq: f64::from_bits(self.shadow_ref[i].load(Ordering::Relaxed)),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_is_disabled_and_empty() {
        assert!(!NoopSink.enabled());
        assert!(NoopSink.snapshot().is_empty());
    }

    #[test]
    fn block_obs_accumulates_per_flat_row() {
        let obs = BlockObs::new(2);
        let id = LayerId::new(1, LayerKind::Up);
        obs.record_proj(id, 64, 128, 1000, 500);
        obs.record_proj(id, 32, 128, 1000, 300);
        let rows = obs.snapshot();
        assert_eq!(rows.len(), 14);
        let row = rows.iter().find(|r| r.id == id).unwrap();
        assert_eq!(row.calls, 2);
        assert_eq!(row.kept_channels, 96);
        assert_eq!(row.dense_channels, 256);
        assert_eq!(row.ns, 800);
        // 1000*64/128 + 1000*32/128 = 500 + 250
        assert_eq!(row.bytes, 750);
        assert!((row.density() - 96.0 / 256.0).abs() < 1e-12);
        assert!((row.gb_per_s() - 750.0 / 800.0).abs() < 1e-12);
        // Untouched rows stay zeroed but present (one row per projection).
        assert!(rows.iter().filter(|r| r.calls == 0).count() == 13);
    }

    #[test]
    fn batch_record_charges_bytes_once_per_fused_call() {
        let obs = BlockObs::new(2);
        let id = LayerId::new(0, LayerKind::Gate);
        // 4 positions sharing one weight walk: union 80 of 128 channels
        // streamed, per-position kept counts summing to 200.
        obs.record_proj_batch(id, 4, 200, 80, 128, 1280, 900);
        let rows = obs.snapshot();
        let row = rows.iter().find(|r| r.id == id).unwrap();
        assert_eq!(row.calls, 4);
        assert_eq!(row.kept_channels, 200);
        assert_eq!(row.dense_channels, 4 * 128);
        assert_eq!(row.ns, 900);
        // bytes = resident * union / in_dim, once — NOT summed per position
        // (the per-position accounting would have charged 200/128 * 1280).
        assert_eq!(row.bytes, 1280 * 80 / 128);
        // The equivalent per-position recording over-reports bytes.
        let per_pos = BlockObs::new(2);
        for kept in [80usize, 40, 40, 40] {
            per_pos.record_proj(id, kept, 128, 1280, 225);
        }
        let pp = per_pos.snapshot();
        let pp_row = pp.iter().find(|r| r.id == id).unwrap();
        assert!(pp_row.bytes > row.bytes, "{} vs {}", pp_row.bytes, row.bytes);
        assert_eq!(pp_row.kept_channels, row.kept_channels);
        assert_eq!(pp_row.dense_channels, row.dense_channels);
    }

    #[test]
    fn batch_record_out_of_range_ignored() {
        let obs = BlockObs::new(1);
        obs.record_proj_batch(LayerId::new(5, LayerKind::Q), 2, 2, 2, 2, 2, 2);
        assert!(obs.snapshot().iter().all(|r| r.calls == 0));
    }

    #[test]
    fn reset_zeroes_all_rows() {
        let obs = BlockObs::new(1);
        obs.record_proj(LayerId::new(0, LayerKind::Q), 4, 8, 100, 50);
        obs.reset();
        assert!(obs
            .snapshot()
            .iter()
            .all(|r| r.calls == 0 && r.ns == 0 && r.bytes == 0 && r.dense_channels == 0));
    }

    #[test]
    fn shadow_samples_accumulate_relative_error() {
        let obs = BlockObs::new(2);
        let id = LayerId::new(1, LayerKind::Down);
        obs.record_shadow(id, 1.0, 100.0);
        obs.record_shadow(id, 3.0, 300.0);
        let rows = obs.snapshot();
        let row = rows.iter().find(|r| r.id == id).unwrap();
        assert_eq!(row.shadow_samples, 2);
        assert!((row.shadow_err_sq - 4.0).abs() < 1e-12);
        assert!((row.shadow_ref_sq - 400.0).abs() < 1e-12);
        assert!((row.shadow_rel_err() - (4.0f64 / 400.0).sqrt()).abs() < 1e-12);
        // Rows without shadow traffic report 0, not NaN.
        let other = rows.iter().find(|r| r.shadow_samples == 0).unwrap();
        assert_eq!(other.shadow_rel_err(), 0.0);
        // Out-of-range layers are ignored, and reset clears shadow sums.
        obs.record_shadow(LayerId::new(9, LayerKind::Q), 1.0, 1.0);
        obs.reset();
        assert!(obs
            .snapshot()
            .iter()
            .all(|r| r.shadow_samples == 0 && r.shadow_err_sq == 0.0 && r.shadow_ref_sq == 0.0));
    }

    #[test]
    fn out_of_range_layer_ignored() {
        let obs = BlockObs::new(1);
        obs.record_proj(LayerId::new(5, LayerKind::Q), 1, 1, 1, 1);
        assert!(obs.snapshot().iter().all(|r| r.calls == 0));
    }
}
