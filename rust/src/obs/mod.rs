//! Zero-dependency observability: in-process span tracing, forward-path
//! per-block telemetry behind the [`ObsSink`] trait, Prometheus text
//! exposition, native log-spaced histograms + sliding-window rates, and
//! STREAM-style roofline measurement for speed-of-light accounting.
//!
//! Everything here is allocation-free on the hot path: span records go into
//! a preallocated ring (per-slot locking only), sink counters are plain
//! atomics, and the no-op sink costs one virtual `enabled()` call per
//! projection.

pub mod hist;
pub mod prom;
pub mod quality;
pub mod roofline;
pub mod sink;
pub mod slo;
pub mod trace;

pub use hist::{Hist, RateWindow};
pub use prom::PromText;
pub use quality::{top2_margin, with_shadow_ctx, QualityObs, ShadowCtx};
pub use sink::{BlockObs, BlockStat, NoopSink, ObsSink};
pub use slo::{Alert, SloEngine, SloSpec};
pub use trace::{chrome_trace, is_truncated, tracer, Span, SpanGuard, TraceSummary, Tracer};
