//! Per-sequence page tables over the block pool, plus the serving-level
//! manager that ties pool + radix prefix cache together.

use crate::kv::pool::{BlockId, BlockPool, KvLayout};
use crate::kv::radix::RadixCache;
use crate::kv::KvSeq;
use crate::model::ModelConfig;
use crate::util::sync::{lock_ok, read_ok, write_ok};
use std::sync::{Arc, Mutex};

/// Paged-KV configuration (the `wisparse serve` knobs).
#[derive(Clone, Debug)]
pub struct KvCfg {
    /// Physical blocks in the global pool.
    pub pool_blocks: usize,
    /// Positions per block.
    pub block_size: usize,
    /// Enable the radix-tree prefix cache.
    pub prefix_cache: bool,
}

impl Default for KvCfg {
    fn default() -> Self {
        Self {
            pool_blocks: 256,
            block_size: 16,
            prefix_cache: true,
        }
    }
}

/// Prefix-cache hit accounting (served from shared blocks vs computed).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvStats {
    pub prefix_hit_tokens: u64,
    pub prefix_miss_tokens: u64,
}

/// A sequence's KV view: an ordered list of (possibly shared) physical
/// blocks. The tail block is made private before any write (copy-on-write),
/// so shared prefix blocks are immutable for their whole cached life.
pub struct PagedSeq {
    pool: Arc<BlockPool>,
    blocks: Vec<BlockId>,
    /// Positions stored so far.
    len: usize,
    /// Context-window capacity in tokens (the model's max_seq).
    capacity: usize,
    /// Leading tokens adopted from the prefix cache (never recomputed).
    prefix_len: usize,
}

impl PagedSeq {
    pub fn new(pool: Arc<BlockPool>, capacity_tokens: usize) -> Self {
        PagedSeq {
            pool,
            blocks: Vec::new(),
            len: 0,
            capacity: capacity_tokens,
            prefix_len: 0,
        }
    }

    /// Adopt already-populated full blocks as this sequence's prefix. The
    /// caller must have retained each block for this page table.
    pub fn adopt_prefix(&mut self, blocks: Vec<BlockId>) {
        assert_eq!(self.len, 0, "adopt_prefix on a non-empty sequence");
        let bs = self.pool.layout().block_size;
        self.len = blocks.len() * bs;
        self.prefix_len = self.len;
        self.blocks = blocks;
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Tokens served from the prefix cache at acquire time.
    pub fn prefix_len(&self) -> usize {
        self.prefix_len
    }

    /// Ensure backing blocks exist for the next `n` positions — allocating
    /// tail blocks ahead of time and copy-on-writing a shared tail first —
    /// without advancing `len`. Returns how many of those positions are now
    /// writable (less than `n` when the pool or the context window runs
    /// out). Speculative rounds reserve their whole draft-plus-verify
    /// footprint up front so mid-round allocation can never fail.
    pub fn reserve_ahead(&mut self, n: usize) -> usize {
        let n = n.min(self.capacity.saturating_sub(self.len));
        if n == 0 {
            return 0;
        }
        // COW/alloc for the block holding position `len` (only that block
        // can be shared; everything allocated beyond it is freshly owned).
        if !self.try_reserve() {
            return 0;
        }
        let bs = self.pool.layout().block_size;
        let need = self.pool.layout().blocks_for(self.len + n);
        while self.blocks.len() < need {
            match self.pool.try_alloc() {
                Some(b) => self.blocks.push(b),
                None => break,
            }
        }
        (self.blocks.len() * bs - self.len).min(n)
    }
}

impl Drop for PagedSeq {
    fn drop(&mut self) {
        for &b in &self.blocks {
            self.pool.release(b);
        }
    }
}

impl KvSeq for PagedSeq {
    fn seq_len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    /// Ensure position `len` is writable: allocate a fresh tail block at
    /// block boundaries, copy-on-write a shared tail otherwise. False when
    /// the pool is dry (callers evict or preempt and retry) or the context
    /// window is exhausted.
    ///
    /// Note: the serving flows share only *full* blocks (prefix matching
    /// and insertion are block-granular), so decode always appends into a
    /// privately-owned tail and the copy-on-write branch is a guard rail —
    /// it keeps any future sub-block sharing (fork/n>1 sampling, partial
    /// prefix adoption) correct and is unit-tested directly.
    fn try_reserve(&mut self) -> bool {
        if self.len >= self.capacity {
            return false;
        }
        let bs = self.pool.layout().block_size;
        let bi = self.len / bs;
        if bi >= self.blocks.len() {
            debug_assert_eq!(bi, self.blocks.len(), "page table has a hole");
            match self.pool.try_alloc() {
                Some(b) => {
                    self.blocks.push(b);
                    true
                }
                None => false,
            }
        } else {
            // The block already exists (partial tail, or pre-allocated by
            // `reserve_ahead` / retained across a `rewind`): make it private
            // before the write.
            let cur = self.blocks[bi];
            if self.pool.ref_count(cur) > 1 {
                let Some(fresh) = self.pool.try_alloc() else {
                    return false;
                };
                let filled = self.len - bi * bs;
                {
                    let src = read_ok(self.pool.block(cur));
                    let mut dst = write_ok(self.pool.block(fresh));
                    dst.copy_prefix_from(&src, filled);
                }
                self.blocks[bi] = fresh;
                self.pool.release(cur);
            }
            true
        }
    }

    fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        let bs = self.pool.layout().block_size;
        let b = self.blocks[pos / bs];
        debug_assert!(
            self.pool.ref_count(b) == 1,
            "store into shared kv block {b}"
        );
        write_ok(self.pool.block(b)).store(layer, pos % bs, k, v);
    }

    fn advance(&mut self) {
        self.len += 1;
    }

    /// Roll back to `new_len` positions and release every whole block the
    /// retained prefix no longer needs. A partially-covered tail block stays
    /// mapped; if it is shared, the next append copy-on-writes it, so
    /// sharers (prefix cache, forked sequences) keep reading valid data.
    fn truncate(&mut self, new_len: usize) {
        assert!(new_len <= self.len, "truncate beyond seq_len");
        let keep = self.pool.layout().blocks_for(new_len);
        while self.blocks.len() > keep {
            let b = self.blocks.pop().expect("block count checked");
            self.pool.release(b);
        }
        self.len = new_len;
        self.prefix_len = self.prefix_len.min(new_len);
    }

    /// Logical rollback that keeps the tail blocks mapped: the speculative
    /// verify pass rewrites the same positions immediately, so releasing
    /// and re-allocating them would only add pool churn (and a window for a
    /// concurrent sequence to starve this one mid-round).
    fn rewind(&mut self, new_len: usize) {
        assert!(new_len <= self.len, "rewind beyond seq_len");
        self.len = new_len;
        self.prefix_len = self.prefix_len.min(new_len);
    }

    fn with_k(&self, layer: usize, upto: usize, f: &mut dyn FnMut(usize, &[f32])) {
        let bs = self.pool.layout().block_size;
        let mut pos = 0usize;
        for &b in &self.blocks {
            if pos >= upto {
                break;
            }
            let n = (upto - pos).min(bs);
            let g = read_ok(self.pool.block(b));
            f(pos, g.k_rows(layer, n));
            pos += bs;
        }
    }

    fn with_v(&self, layer: usize, upto: usize, f: &mut dyn FnMut(usize, &[f32])) {
        let bs = self.pool.layout().block_size;
        let mut pos = 0usize;
        for &b in &self.blocks {
            if pos >= upto {
                break;
            }
            let n = (upto - pos).min(bs);
            let g = read_ok(self.pool.block(b));
            f(pos, g.v_rows(layer, n));
            pos += bs;
        }
    }
}

/// The serving-side owner of the pool and prefix cache. One per engine;
/// admission, prefix matching and eviction all go through here.
pub struct KvManager {
    pool: Arc<BlockPool>,
    radix: Mutex<RadixCache>,
    stats: Mutex<KvStats>,
    prefix_cache: bool,
    max_seq: usize,
}

impl KvManager {
    pub fn new(cfg: &ModelConfig, kv: &KvCfg) -> Arc<KvManager> {
        let layout = KvLayout {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            block_size: kv.block_size,
        };
        Arc::new(KvManager {
            pool: BlockPool::new(layout, kv.pool_blocks),
            radix: Mutex::new(RadixCache::new(kv.block_size)),
            stats: Mutex::new(KvStats::default()),
            prefix_cache: kv.prefix_cache,
            max_seq: cfg.max_seq,
        })
    }

    pub fn pool(&self) -> &Arc<BlockPool> {
        &self.pool
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_cache
    }

    pub fn blocks_total(&self) -> usize {
        self.pool.n_blocks()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.pool.blocks_in_use()
    }

    pub fn stats(&self) -> KvStats {
        *lock_ok(&self.stats)
    }

    /// Build a sequence's KV view for `prompt`, adopting cached prefix
    /// blocks. The match is capped below the full prompt so the last prompt
    /// token is always recomputed (its logits seed decoding). Returns the
    /// sequence and the number of prefix tokens served from cache.
    /// Schedule-free (matches only all-dense-tagged entries exactly like
    /// prior behaviour); prefill engines use
    /// [`KvManager::acquire_scheduled`] so hits stay bit-identical to
    /// misses under the mixed dense/sparse prefill split.
    pub fn acquire(&self, prompt: &[usize]) -> (PagedSeq, usize) {
        self.acquire_scheduled(prompt, usize::MAX)
    }

    /// [`KvManager::acquire`] with the consumer's prefill-schedule tag: the
    /// dense→sparse boundary (`dense_upto`) its own prefill would apply to
    /// this prompt. Only cached prefixes produced under an agreeing
    /// schedule are adopted.
    pub fn acquire_scheduled(&self, prompt: &[usize], dense_upto: usize) -> (PagedSeq, usize) {
        let mut seq = self.new_seq();
        let hit = self.adopt_cached_prefix(&mut seq, prompt, dense_upto);
        (seq, hit)
    }

    /// A fresh, empty sequence view over this manager's pool (no prefix
    /// matching — the chunked-prefill engine defers that to the first
    /// chunk via [`KvManager::adopt_cached_prefix`], so prompts admitted
    /// together still share prefixes their batch-mates publish first).
    pub fn new_seq(&self) -> PagedSeq {
        PagedSeq::new(Arc::clone(&self.pool), self.max_seq)
    }

    /// Match `prompt` against the prefix cache under the consumer's
    /// schedule tag and adopt the servable blocks into the (still empty)
    /// sequence. Returns the tokens served from cache; also records
    /// hit/miss stats for this prompt.
    pub fn adopt_cached_prefix(
        &self,
        seq: &mut PagedSeq,
        prompt: &[usize],
        dense_upto: usize,
    ) -> usize {
        let mut hit = 0usize;
        if self.prefix_cache && prompt.len() > 1 {
            let bs = self.pool.layout().block_size;
            let usable = (prompt.len() - 1) / bs * bs;
            if usable > 0 {
                // match_prefix retains the matched blocks for this page
                // table inside the radix lock, so a concurrent eviction can
                // never free them between match and adoption.
                let blocks = lock_ok(&self.radix).match_prefix_scheduled(
                    &prompt[..usable],
                    dense_upto,
                    &self.pool,
                );
                hit = blocks.len() * bs;
                if !blocks.is_empty() {
                    seq.adopt_prefix(blocks);
                }
            }
        }
        let mut s = lock_ok(&self.stats);
        s.prefix_hit_tokens += hit as u64;
        s.prefix_miss_tokens += (prompt.len() - hit) as u64;
        drop(s);
        hit
    }

    /// Publish a prefilled prompt's full blocks into the prefix cache so
    /// later sequences can share them (schedule-free tag; prefill engines
    /// use [`KvManager::insert_prefix_scheduled`]).
    pub fn insert_prefix(&self, prompt: &[usize], seq: &PagedSeq) {
        self.insert_prefix_scheduled(prompt, seq, usize::MAX);
    }

    /// Publish a prefilled prompt's full blocks tagged with the schedule
    /// (`dense_upto`) that produced their KV. Must only be called once the
    /// *entire* prompt has committed under the production prefill schedule
    /// — partially-prefilled or rolled-back KV never reaches the cache.
    pub fn insert_prefix_scheduled(&self, prompt: &[usize], seq: &PagedSeq, dense_upto: usize) {
        if !self.prefix_cache {
            return;
        }
        lock_ok(&self.radix)
            .insert_scheduled(prompt, seq.blocks(), dense_upto, &self.pool);
    }

    /// Room for one more token, evicting LRU cached prefixes while the pool
    /// is dry. False only when eviction can free nothing more.
    pub fn try_reserve(&self, seq: &mut PagedSeq) -> bool {
        loop {
            if seq.try_reserve() {
                return true;
            }
            if seq.seq_len() >= seq.capacity() {
                return false; // context window, not pool pressure
            }
            if lock_ok(&self.radix).evict(1, &self.pool) == 0 {
                return false;
            }
        }
    }

    /// Room for the next `n` tokens (blocks pre-allocated, `len` not
    /// advanced), evicting LRU cached prefixes while the pool is dry.
    /// Returns how many of the `n` positions are covered — speculative
    /// rounds shrink their draft chain to this.
    pub fn reserve_ahead(&self, seq: &mut PagedSeq, n: usize) -> usize {
        loop {
            let got = seq.reserve_ahead(n);
            if got >= n.min(seq.capacity().saturating_sub(seq.seq_len())) {
                return got;
            }
            if lock_ok(&self.radix).evict(1, &self.pool) == 0 {
                return got;
            }
        }
    }

    /// Roll a sequence back to `new_len` positions. Before the tail blocks
    /// are released, every prefix-cache entry referencing a block that
    /// covers a rolled-back position is invalidated (split before the
    /// block, subtree dropped), so a later prefix hit can never adopt
    /// rejected-token KV.
    pub fn rollback(&self, seq: &mut PagedSeq, new_len: usize) {
        if new_len >= seq.seq_len() {
            return;
        }
        if self.prefix_cache {
            let bs = self.pool.layout().block_size;
            let first_affected = new_len / bs;
            if first_affected < seq.blocks().len() {
                // Only shared blocks can be cached (the tree holds its own
                // ref); rolled-back blocks are almost always this round's
                // fresh rc==1 allocations, so the common case skips the
                // radix lock and tree scan entirely.
                let bad: Vec<BlockId> = seq.blocks()[first_affected..]
                    .iter()
                    .copied()
                    .filter(|&b| self.pool.ref_count(b) > 1)
                    .collect();
                if !bad.is_empty() {
                    lock_ok(&self.radix)
                        .invalidate_blocks(&bad, &self.pool);
                }
            }
        }
        seq.truncate(new_len);
    }

    /// Worst-case block demand of a request running `total_tokens`.
    pub fn worst_case_blocks(&self, total_tokens: usize) -> usize {
        self.pool
            .layout()
            .blocks_for(total_tokens.min(self.max_seq))
    }

    /// Admission headroom: free blocks plus everything eviction could
    /// release. Optimistic when cached blocks are also held by live
    /// sequences (evicting those frees no memory) — the scheduler's
    /// preempt-and-requeue path covers the shortfall.
    pub fn admissible_blocks(&self) -> usize {
        self.pool.blocks_free() + lock_ok(&self.radix).blocks_cached()
    }

    /// Blocks currently pinned by the prefix cache. The leak invariant
    /// after a drain with the prefix cache on is `blocks_in_use ==
    /// cached_blocks()` (the cache's own refs are the only legitimate
    /// holders once every sequence is gone); with it off, both are zero.
    pub fn cached_blocks(&self) -> usize {
        lock_ok(&self.radix).blocks_cached()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::preset("nano").unwrap()
    }

    fn kv_cfg(pool_blocks: usize, block_size: usize) -> KvCfg {
        KvCfg {
            pool_blocks,
            block_size,
            prefix_cache: true,
        }
    }

    #[test]
    fn append_grows_blocks_and_drop_releases() {
        let mgr = KvManager::new(&cfg(), &kv_cfg(8, 4));
        let (mut seq, hit) = mgr.acquire(&[1, 2, 3]);
        assert_eq!(hit, 0);
        let d = cfg().d_model;
        let k = vec![1.0; d];
        let v = vec![2.0; d];
        for pos in 0..6 {
            assert!(mgr.try_reserve(&mut seq));
            for layer in 0..cfg().n_layers {
                seq.store(layer, pos, &k, &v);
            }
            seq.advance();
        }
        assert_eq!(seq.blocks().len(), 2, "6 tokens at bs=4 -> 2 blocks");
        assert_eq!(mgr.blocks_in_use(), 2);
        drop(seq);
        assert_eq!(mgr.blocks_in_use(), 0, "drop releases the page table");
    }

    #[test]
    fn with_k_visits_positions_in_order() {
        let mgr = KvManager::new(&cfg(), &kv_cfg(8, 4));
        let (mut seq, _) = mgr.acquire(&[9]);
        let d = cfg().d_model;
        for pos in 0..7 {
            assert!(mgr.try_reserve(&mut seq));
            let k = vec![pos as f32; d];
            seq.store(0, pos, &k, &k);
            seq.advance();
        }
        let mut seen = Vec::new();
        seq.with_k(0, 6, &mut |start, rows| {
            for (r, row) in rows.chunks_exact(d).enumerate() {
                seen.push((start + r, row[0]));
            }
        });
        assert_eq!(seen.len(), 6);
        for (i, &(p, val)) in seen.iter().enumerate() {
            assert_eq!(p, i);
            assert_eq!(val, i as f32);
        }
    }

    #[test]
    fn cow_unshares_tail_block() {
        let mgr = KvManager::new(&cfg(), &kv_cfg(8, 4));
        let (mut seq, _) = mgr.acquire(&[1]);
        let d = cfg().d_model;
        for pos in 0..2 {
            assert!(mgr.try_reserve(&mut seq));
            let k = vec![10.0 + pos as f32; d];
            for layer in 0..cfg().n_layers {
                seq.store(layer, pos, &k, &k);
            }
            seq.advance();
        }
        let tail = seq.blocks()[0];
        // Simulate an external share of the (partial) tail block.
        mgr.pool().retain(tail);
        assert!(mgr.try_reserve(&mut seq), "reserve triggers copy-on-write");
        let fresh = seq.blocks()[0];
        assert_ne!(fresh, tail, "tail was replaced by a private copy");
        assert_eq!(mgr.pool().ref_count(tail), 1, "seq dropped its shared ref");
        // The private copy carries the already-stored positions.
        let src = mgr.pool().block(tail).read().unwrap();
        let dst = mgr.pool().block(fresh).read().unwrap();
        assert_eq!(src.k_rows(1, 2), dst.k_rows(1, 2));
        // Writing the private copy leaves the shared original untouched.
        drop(src);
        drop(dst);
        seq.store(0, 2, &vec![77.0; d], &vec![77.0; d]);
        seq.advance();
        let src = mgr.pool().block(tail).read().unwrap();
        assert_eq!(src.k_rows(0, 2)[0], 10.0);
        drop(src);
        drop(seq);
        mgr.pool().release(tail);
        assert_eq!(mgr.blocks_in_use(), 0);
    }

    #[test]
    fn reserve_evicts_cached_prefix_under_pressure() {
        let c = cfg();
        let mgr = KvManager::new(&c, &kv_cfg(2, 4));
        let d = c.d_model;
        let prompt: Vec<usize> = vec![1, 2, 3, 4];
        let (mut a, _) = mgr.acquire(&prompt);
        for pos in 0..4 {
            assert!(mgr.try_reserve(&mut a));
            for layer in 0..c.n_layers {
                a.store(layer, pos, &vec![0.5; d], &vec![0.5; d]);
            }
            a.advance();
        }
        mgr.insert_prefix(&prompt, &a);
        drop(a); // tree still caches 1 block; 1 block free
        assert_eq!(mgr.blocks_in_use(), 1);
        // A new unrelated sequence needs both blocks: the second reserve
        // must evict the cached prefix to make room.
        let (mut b, hit) = mgr.acquire(&[9, 9, 9, 9, 9]);
        assert_eq!(hit, 0);
        for pos in 0..8 {
            assert!(mgr.try_reserve(&mut b), "eviction frees the pool at pos {pos}");
            b.store(0, pos, &vec![0.1; d], &vec![0.1; d]);
            b.advance();
        }
        assert_eq!(b.blocks().len(), 2);
        drop(b);
        assert_eq!(mgr.blocks_in_use(), 0);
    }

    #[test]
    fn acquire_caps_match_below_full_prompt() {
        let c = cfg();
        let mgr = KvManager::new(&c, &kv_cfg(8, 4));
        let d = c.d_model;
        let prompt: Vec<usize> = (0..8).collect();
        let (mut a, hit0) = mgr.acquire(&prompt);
        assert_eq!(hit0, 0);
        for pos in 0..8 {
            assert!(mgr.try_reserve(&mut a));
            for layer in 0..c.n_layers {
                a.store(layer, pos, &vec![1.0; d], &vec![1.0; d]);
            }
            a.advance();
        }
        mgr.insert_prefix(&prompt, &a);
        // Identical prompt: only (8-1)/4*4 = 4 tokens may come from cache,
        // so the final prompt token always produces fresh logits.
        let (b, hit) = mgr.acquire(&prompt);
        assert_eq!(hit, 4);
        assert_eq!(b.seq_len(), 4);
        assert_eq!(b.blocks()[0], a.blocks()[0]);
        let s = mgr.stats();
        assert_eq!(s.prefix_hit_tokens, 4);
        assert_eq!(s.prefix_miss_tokens, 8 + 4);
    }
}
