//! The global ref-counted KV block pool.
//!
//! Physical memory for the paged KV cache: a fixed set of blocks, each
//! holding `block_size` positions of K and V for every layer. Blocks are
//! handed out by id, shared across sequences via refcounts (the prefix
//! cache and every page table referencing a block each hold one ref), and
//! recycled through a free list — total resident KV memory is
//! `n_blocks * 2 * n_layers * block_size * d_model` floats, fixed at
//! startup, instead of `O(max_batch * max_seq)`.
//!
//! Concurrency contract: block *metadata* (refcounts, free list) is behind
//! one mutex; block *data* sits behind a per-block RwLock. The write lock
//! is only ever taken by the sequence that uniquely owns the tail block
//! (copy-on-write guarantees rc == 1 before any store), so readers of
//! shared prefix blocks never contend with writers.

use crate::util::sync::lock_ok;
use std::sync::{Arc, Mutex, RwLock};

/// Index of a physical block in the pool.
pub type BlockId = u32;

/// Geometry of one block (shared by the pool and every page table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvLayout {
    pub n_layers: usize,
    pub d_model: usize,
    /// Positions per block.
    pub block_size: usize,
}

impl KvLayout {
    /// f32 count of one side (K or V) of one block.
    pub fn floats_per_side(&self) -> usize {
        self.n_layers * self.block_size * self.d_model
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }
}

/// One block's K/V storage: per layer a contiguous `[block_size, d_model]`
/// row-major slab, K and V separate.
pub struct KvBlockData {
    k: Vec<f32>,
    v: Vec<f32>,
    bs: usize,
    d: usize,
}

impl KvBlockData {
    fn new(layout: &KvLayout) -> Self {
        Self {
            k: vec![0.0; layout.floats_per_side()],
            v: vec![0.0; layout.floats_per_side()],
            bs: layout.block_size,
            d: layout.d_model,
        }
    }

    #[inline]
    fn layer_off(&self, layer: usize) -> usize {
        layer * self.bs * self.d
    }

    /// Write one position's K/V rows for a layer.
    pub fn store(&mut self, layer: usize, pos_in_block: usize, k: &[f32], v: &[f32]) {
        debug_assert!(pos_in_block < self.bs);
        let at = self.layer_off(layer) + pos_in_block * self.d;
        self.k[at..at + self.d].copy_from_slice(k);
        self.v[at..at + self.d].copy_from_slice(v);
    }

    /// The first `n` K rows of a layer, row-major `[n, d_model]`.
    pub fn k_rows(&self, layer: usize, n: usize) -> &[f32] {
        let off = self.layer_off(layer);
        &self.k[off..off + n * self.d]
    }

    /// The first `n` V rows of a layer, row-major `[n, d_model]`.
    pub fn v_rows(&self, layer: usize, n: usize) -> &[f32] {
        let off = self.layer_off(layer);
        &self.v[off..off + n * self.d]
    }

    /// Copy the first `n` positions of every layer from `src` (the
    /// copy-on-write path when a shared tail block must become private).
    pub fn copy_prefix_from(&mut self, src: &KvBlockData, n: usize) {
        debug_assert!(n <= self.bs && self.bs == src.bs && self.d == src.d);
        let n_layers = self.k.len() / (self.bs * self.d);
        for layer in 0..n_layers {
            let off = self.layer_off(layer);
            self.k[off..off + n * self.d].copy_from_slice(&src.k[off..off + n * self.d]);
            self.v[off..off + n * self.d].copy_from_slice(&src.v[off..off + n * self.d]);
        }
    }
}

struct PoolMeta {
    rc: Vec<u32>,
    free: Vec<BlockId>,
    allocs: u64,
    frees: u64,
}

/// The fixed-size block pool. Created once per server, shared via `Arc`.
pub struct BlockPool {
    layout: KvLayout,
    data: Vec<RwLock<KvBlockData>>,
    meta: Mutex<PoolMeta>,
}

impl BlockPool {
    pub fn new(layout: KvLayout, n_blocks: usize) -> Arc<BlockPool> {
        assert!(n_blocks > 0, "kv pool needs at least one block");
        assert!(
            n_blocks <= BlockId::MAX as usize,
            "kv pool too large for u32 block ids"
        );
        assert!(layout.block_size > 0 && layout.d_model > 0 && layout.n_layers > 0);
        Arc::new(BlockPool {
            layout,
            data: (0..n_blocks)
                .map(|_| RwLock::new(KvBlockData::new(&layout)))
                .collect(),
            meta: Mutex::new(PoolMeta {
                rc: vec![0; n_blocks],
                // Pop from the back: hand out low ids first.
                free: (0..n_blocks as BlockId).rev().collect(),
                allocs: 0,
                frees: 0,
            }),
        })
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    pub fn n_blocks(&self) -> usize {
        self.data.len()
    }

    /// Allocate a block with refcount 1. `None` when the pool is exhausted
    /// (callers evict cached prefixes or preempt, then retry).
    pub fn try_alloc(&self) -> Option<BlockId> {
        let mut m = lock_ok(&self.meta);
        let id = m.free.pop()?;
        debug_assert_eq!(m.rc[id as usize], 0);
        m.rc[id as usize] = 1;
        m.allocs += 1;
        Some(id)
    }

    /// Add a reference to a live block (page-table adoption, prefix-cache
    /// registration).
    pub fn retain(&self, id: BlockId) {
        let mut m = lock_ok(&self.meta);
        assert!(m.rc[id as usize] > 0, "retain of free kv block {id}");
        m.rc[id as usize] += 1;
    }

    /// Drop a reference; the block returns to the free list at rc 0.
    /// Returns true when this release actually freed the block (refcount
    /// reached zero) — eviction uses this to count reclaimed memory.
    /// Panics on double-free (releasing an already-free block).
    pub fn release(&self, id: BlockId) -> bool {
        let mut m = lock_ok(&self.meta);
        let rc = &mut m.rc[id as usize];
        assert!(*rc > 0, "double free of kv block {id}");
        *rc -= 1;
        if *rc == 0 {
            m.free.push(id);
            m.frees += 1;
            true
        } else {
            false
        }
    }

    pub fn ref_count(&self, id: BlockId) -> u32 {
        lock_ok(&self.meta).rc[id as usize]
    }

    pub fn blocks_free(&self) -> usize {
        lock_ok(&self.meta).free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks() - self.blocks_free()
    }

    /// Lifetime (allocs, frees) counters — the refcount-invariant check used
    /// by the property test: after all refs are dropped, allocs == frees and
    /// blocks_in_use == 0.
    pub fn counters(&self) -> (u64, u64) {
        let m = lock_ok(&self.meta);
        (m.allocs, m.frees)
    }

    /// Data access for a block id. Readers of shared blocks and the single
    /// writer of an owned tail block synchronize here.
    pub fn block(&self, id: BlockId) -> &RwLock<KvBlockData> {
        &self.data[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> KvLayout {
        KvLayout {
            n_layers: 2,
            d_model: 4,
            block_size: 3,
        }
    }

    #[test]
    fn alloc_release_cycle() {
        let pool = BlockPool::new(layout(), 4);
        assert_eq!(pool.blocks_free(), 4);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(pool.blocks_in_use(), 2);
        pool.retain(a);
        pool.release(a);
        assert_eq!(pool.ref_count(a), 1, "retained block survives one release");
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.blocks_in_use(), 0);
        let (allocs, frees) = pool.counters();
        assert_eq!(allocs, 2);
        assert_eq!(frees, 2);
    }

    #[test]
    fn exhaustion_returns_none() {
        let pool = BlockPool::new(layout(), 2);
        let a = pool.try_alloc().unwrap();
        let _b = pool.try_alloc().unwrap();
        assert!(pool.try_alloc().is_none());
        pool.release(a);
        assert!(pool.try_alloc().is_some());
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let pool = BlockPool::new(layout(), 2);
        let a = pool.try_alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn store_and_read_rows() {
        let l = layout();
        let pool = BlockPool::new(l, 1);
        let id = pool.try_alloc().unwrap();
        let k: Vec<f32> = (0..l.d_model).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..l.d_model).map(|i| -(i as f32)).collect();
        {
            let mut b = pool.block(id).write().unwrap();
            b.store(1, 2, &k, &v);
        }
        let b = pool.block(id).read().unwrap();
        let rows = b.k_rows(1, 3);
        assert_eq!(&rows[2 * l.d_model..3 * l.d_model], &k[..]);
        let rows = b.v_rows(1, 3);
        assert_eq!(&rows[2 * l.d_model..3 * l.d_model], &v[..]);
    }

    #[test]
    fn copy_prefix_copies_all_layers() {
        let l = layout();
        let pool = BlockPool::new(l, 2);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let k = vec![7.0; l.d_model];
        let v = vec![9.0; l.d_model];
        for layer in 0..l.n_layers {
            pool.block(a).write().unwrap().store(layer, 0, &k, &v);
            pool.block(a).write().unwrap().store(layer, 1, &v, &k);
        }
        {
            let src = pool.block(a).read().unwrap();
            let mut dst = pool.block(b).write().unwrap();
            dst.copy_prefix_from(&src, 2);
        }
        let src = pool.block(a).read().unwrap();
        let dst = pool.block(b).read().unwrap();
        for layer in 0..l.n_layers {
            assert_eq!(src.k_rows(layer, 2), dst.k_rows(layer, 2));
            assert_eq!(src.v_rows(layer, 2), dst.v_rows(layer, 2));
        }
    }
}
