//! Paged KV-cache subsystem: a global ref-counted block pool, per-sequence
//! page tables, and a radix-tree prefix cache with LRU eviction.
//!
//! Replaces the dense per-sequence `[max_seq, d_model]` K/V slabs on the
//! serving path: resident KV memory becomes `O(live tokens)` under a fixed
//! pool budget, sequences sharing a prompt prefix share physical blocks
//! (copy-on-write on first divergent append), and the scheduler gains
//! block-aware admission with preempt-and-requeue on pool exhaustion.

pub mod paged;
pub mod pool;
pub mod radix;

pub use paged::{KvCfg, KvManager, KvStats, PagedSeq};
pub use pool::{BlockId, BlockPool, KvBlockData, KvLayout};
pub use radix::RadixCache;

/// Per-sequence KV storage contract shared by the flat slab
/// ([`crate::model::kv_cache::KvCache`]) and the paged table
/// ([`PagedSeq`]). Attention visits K/V rows strictly in ascending position
/// order through `with_k`/`with_v`, performing the same arithmetic per row
/// regardless of how storage is chunked — which is what makes paged
/// attention bit-identical to the flat baseline.
pub trait KvSeq {
    /// Positions already stored (== the next token's position).
    fn seq_len(&self) -> usize;

    /// Context-window capacity in tokens.
    fn capacity(&self) -> usize;

    fn is_full(&self) -> bool {
        self.seq_len() >= self.capacity()
    }

    /// Ensure storage exists for position `seq_len()`, allocating or
    /// copy-on-writing as needed. Returns false when backing memory is
    /// exhausted (pool dry or context window full).
    fn try_reserve(&mut self) -> bool;

    /// Write one position's K/V rows for a layer. The position must have
    /// been reserved.
    fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);

    /// Commit the current position (`seq_len += 1`).
    fn advance(&mut self);

    /// Roll the sequence back to `new_len` positions (`new_len <=
    /// seq_len()`), returning backing storage the rolled-back tail no longer
    /// needs. Paged implementations release whole now-unused blocks to the
    /// pool; the data of retained positions is untouched. This is the KV
    /// primitive behind speculative-decode rejection.
    fn truncate(&mut self, new_len: usize);

    /// Roll back to `new_len` positions but *keep* the backing storage: the
    /// caller is about to rewrite the same positions (speculative verify
    /// re-running the draft chain at production sparsity). Defaults to
    /// [`KvSeq::truncate`]; paged implementations override it to avoid
    /// releasing blocks they will re-allocate within the same round.
    fn rewind(&mut self, new_len: usize) {
        self.truncate(new_len);
    }

    /// Visit K rows of `layer` covering positions `[0, upto)` in ascending
    /// order, as `(start_pos, rows)` chunks with `rows` row-major
    /// `[n, d_model]`.
    fn with_k(&self, layer: usize, upto: usize, f: &mut dyn FnMut(usize, &[f32]));

    /// Visit V rows of `layer` covering positions `[0, upto)`, as in
    /// [`KvSeq::with_k`].
    fn with_v(&self, layer: usize, upto: usize, f: &mut dyn FnMut(usize, &[f32]));
}
