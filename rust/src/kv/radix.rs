//! Radix-tree prefix cache over token sequences, block-granular.
//!
//! Nodes hold edge labels whose length is a whole number of KV blocks, so a
//! cached prefix maps 1:1 onto physical blocks in the [`BlockPool`]. Children
//! are keyed by their edge's first *block* of tokens (not the first token),
//! which lets two prompts that diverge inside their first block coexist —
//! sharing below block granularity is impossible anyway.
//!
//! The tree holds one pool reference per cached block. Eviction (LRU over
//! leaves that can actually free memory) releases only the tree's
//! reference: a full block is never written again, so sequences still
//! mapping it through their page tables keep reading valid data. No pin
//! counts are needed. `match_prefix` retains matched blocks for the caller
//! *inside* the tree walk, so a concurrent eviction can never free a block
//! between match and adoption.
//!
//! Not internally synchronized — the owner (`KvManager`) wraps it in a
//! mutex, and that mutex is load-bearing: matching/insertion run on the
//! scheduler side, but decode workers reach `evict` through
//! `KvManager::try_reserve` when the pool runs dry mid-step.

use crate::kv::pool::{BlockId, BlockPool};
use std::collections::HashMap;

struct Node {
    /// Edge label from the parent; a positive multiple of `block_size`
    /// tokens (empty only for the root).
    tokens: Vec<usize>,
    /// Physical blocks backing `tokens` (`tokens.len() / block_size` ids).
    blocks: Vec<BlockId>,
    /// Children keyed by the first `block_size` tokens of their edge.
    children: HashMap<Vec<usize>, usize>,
    parent: usize,
    /// Logical timestamp of the last match/insert touching this node.
    last_access: u64,
    in_use: bool,
    /// Prefill-schedule tag of the KV stored in this node's blocks: the
    /// producer's dense→sparse boundary position (`dense_upto`). Positions
    /// below it were computed dense, the rest sparse. `usize::MAX` means
    /// "all dense" / schedule-free (dense engines, direct pool users). A
    /// cached span is only served to a consumer whose own schedule agrees
    /// with the producer's over that span — see [`sched_agrees`].
    dense_upto: usize,
}

/// Do two prefill schedules (dense below `du_a` / `du_b`, sparse at or
/// above) execute positions `[start, end)` identically? True iff both
/// boundaries clamp to the same point inside the span — i.e. neither
/// schedule flips dense→sparse at a position where the other doesn't.
fn sched_agrees(du_a: usize, du_b: usize, start: usize, end: usize) -> bool {
    du_a.clamp(start, end) == du_b.clamp(start, end)
}

/// Longest block-aligned prefix of the span `[start, start + span)` the two
/// schedules execute identically, in tokens. The schedules disagree exactly
/// on `[min(du), max(du))`, so the usable prefix runs up to that interval
/// (or covers the whole span when it starts past it).
fn sched_prefix(du_a: usize, du_b: usize, start: usize, span: usize, bs: usize) -> usize {
    let end = start + span;
    let lo = du_a.min(du_b);
    let hi = du_a.max(du_b);
    let limit = if lo == hi || start >= hi {
        end
    } else {
        lo.clamp(start, end)
    };
    (limit - start) / bs * bs
}

/// The prefix cache. Node 0 is the root (empty edge).
pub struct RadixCache {
    block_size: usize,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    clock: u64,
    /// Blocks currently referenced by the tree (== sum of node block counts).
    blocks_cached: usize,
}

fn common_prefix_len(a: &[usize], b: &[usize]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl RadixCache {
    pub fn new(block_size: usize) -> Self {
        assert!(block_size > 0);
        RadixCache {
            block_size,
            nodes: vec![Node {
                tokens: Vec::new(),
                blocks: Vec::new(),
                children: HashMap::new(),
                parent: 0,
                last_access: 0,
                in_use: true,
                dense_upto: usize::MAX,
            }],
            free_nodes: Vec::new(),
            clock: 0,
            blocks_cached: 0,
        }
    }

    /// Number of blocks the tree currently references.
    pub fn blocks_cached(&self) -> usize {
        self.blocks_cached
    }

    fn new_node(&mut self, node: Node) -> usize {
        if let Some(i) = self.free_nodes.pop() {
            self.nodes[i] = node;
            i
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    /// Longest cached full-block prefix of `tokens`, as physical block ids.
    /// Each returned block is retained on behalf of the caller's page table
    /// before this returns (while the tree still holds its own reference),
    /// so the handoff is atomic under the owner's lock. Touches LRU clocks.
    /// Schedule-free (`usize::MAX` tag — matches anything a dense schedule
    /// produced); prefill consumers use [`RadixCache::match_prefix_scheduled`].
    pub fn match_prefix(&mut self, tokens: &[usize], pool: &BlockPool) -> Vec<BlockId> {
        self.match_prefix_scheduled(tokens, usize::MAX, pool)
    }

    /// [`RadixCache::match_prefix`] restricted to cached KV whose producer
    /// schedule agrees with the consumer's (`dense_upto`) on every matched
    /// position: the walk stops at the first node whose span the two
    /// schedules would execute differently, so a cache hit is always
    /// bit-identical to recomputing the prefix under the consumer's own
    /// half-dense/half-sparse prefill split.
    pub fn match_prefix_scheduled(
        &mut self,
        tokens: &[usize],
        dense_upto: usize,
        pool: &BlockPool,
    ) -> Vec<BlockId> {
        self.clock += 1;
        let clock = self.clock;
        let bs = self.block_size;
        let mut out = Vec::new();
        let mut node = 0usize;
        let mut rem = tokens;
        loop {
            self.nodes[node].last_access = clock;
            if rem.len() < bs {
                break;
            }
            let child = match self.nodes[node].children.get(&rem[..bs]) {
                Some(&c) => c,
                None => break,
            };
            let common = common_prefix_len(&self.nodes[child].tokens, rem);
            let common_blocks = common / bs * bs;
            debug_assert!(common_blocks >= bs, "child key matched but edge does not");
            // The child's edge covers positions [pos, pos + common_blocks);
            // only the leading part both schedules execute identically is
            // servable.
            let pos = tokens.len() - rem.len();
            let take = sched_prefix(
                self.nodes[child].dense_upto,
                dense_upto,
                pos,
                common_blocks,
                bs,
            );
            if take == 0 {
                break;
            }
            if take < self.nodes[child].tokens.len() {
                // Token divergence, query exhaustion or a schedule
                // disagreement inside this edge: split so the servable
                // full-block prefix is its own node, and take it.
                let head = self.split(child, take);
                self.nodes[head].last_access = clock;
                out.extend_from_slice(&self.nodes[head].blocks);
                break;
            }
            out.extend_from_slice(&self.nodes[child].blocks);
            rem = &rem[self.nodes[child].tokens.len()..];
            node = child;
        }
        for &b in &out {
            pool.retain(b);
        }
        out
    }

    /// Split `child`'s edge at `at` tokens (a positive multiple of
    /// block_size strictly inside the edge), inserting a new head node
    /// between parent and child. Returns the head's index; `child` keeps its
    /// index and the edge tail.
    fn split(&mut self, child: usize, at: usize) -> usize {
        let bs = self.block_size;
        debug_assert!(at > 0 && at % bs == 0 && at < self.nodes[child].tokens.len());
        let parent = self.nodes[child].parent;
        let head_tokens: Vec<usize> = self.nodes[child].tokens[..at].to_vec();
        let head_blocks: Vec<BlockId> = self.nodes[child].blocks[..at / bs].to_vec();
        let tail_tokens: Vec<usize> = self.nodes[child].tokens[at..].to_vec();
        let tail_blocks: Vec<BlockId> = self.nodes[child].blocks[at / bs..].to_vec();
        let last_access = self.nodes[child].last_access;
        let dense_upto = self.nodes[child].dense_upto;
        let mut head_children = HashMap::new();
        head_children.insert(tail_tokens[..bs].to_vec(), child);
        let head = self.new_node(Node {
            tokens: head_tokens,
            blocks: head_blocks,
            children: head_children,
            parent,
            last_access,
            in_use: true,
            // The tag is per-position, so both halves keep the producer's.
            dense_upto,
        });
        let head_key = self.nodes[head].tokens[..bs].to_vec();
        self.nodes[parent].children.insert(head_key, head);
        let c = &mut self.nodes[child];
        c.tokens = tail_tokens;
        c.blocks = tail_blocks;
        c.parent = head;
        head
    }

    /// Register the full-block prefix of `tokens` (backed by `blocks`, the
    /// sequence's page table) with the tree. Newly referenced blocks get a
    /// pool retain (the tree's own reference); already-cached spans are left
    /// untouched. Schedule-free tag (`usize::MAX`); prefill producers use
    /// [`RadixCache::insert_scheduled`].
    pub fn insert(&mut self, tokens: &[usize], blocks: &[BlockId], pool: &BlockPool) {
        self.insert_scheduled(tokens, blocks, usize::MAX, pool);
    }

    /// [`RadixCache::insert`] tagging new nodes with the producing
    /// prefill's `dense_upto` schedule. Descending through an existing node
    /// whose schedule *disagrees* with the producer's over its span aborts
    /// the insert: the producer's deeper KV attended to a differently-
    /// scheduled prefix, so grafting it below the cached (other-schedule)
    /// span would let a later match combine incompatible KV.
    pub fn insert_scheduled(
        &mut self,
        tokens: &[usize],
        blocks: &[BlockId],
        dense_upto: usize,
        pool: &BlockPool,
    ) {
        self.clock += 1;
        let clock = self.clock;
        let bs = self.block_size;
        let n_blocks = (tokens.len() / bs).min(blocks.len());
        let total = n_blocks * bs;
        let mut rem = &tokens[..total];
        let mut rem_blocks = &blocks[..n_blocks];
        let mut node = 0usize;
        loop {
            self.nodes[node].last_access = clock;
            if rem.is_empty() {
                return;
            }
            match self.nodes[node].children.get(&rem[..bs]).copied() {
                None => {
                    for &b in rem_blocks {
                        pool.retain(b);
                    }
                    self.blocks_cached += rem_blocks.len();
                    let leaf = self.new_node(Node {
                        tokens: rem.to_vec(),
                        blocks: rem_blocks.to_vec(),
                        children: HashMap::new(),
                        parent: node,
                        last_access: clock,
                        in_use: true,
                        dense_upto,
                    });
                    self.nodes[node].children.insert(rem[..bs].to_vec(), leaf);
                    return;
                }
                Some(child) => {
                    let common = common_prefix_len(&self.nodes[child].tokens, rem);
                    let cb = common / bs * bs;
                    debug_assert!(cb >= bs);
                    let pos = total - rem.len();
                    if !sched_agrees(self.nodes[child].dense_upto, dense_upto, pos, pos + cb) {
                        return;
                    }
                    let next = if cb < self.nodes[child].tokens.len() {
                        self.split(child, cb)
                    } else {
                        child
                    };
                    self.nodes[next].last_access = clock;
                    rem = &rem[cb..];
                    rem_blocks = &rem_blocks[cb / bs..];
                    node = next;
                }
            }
        }
    }

    /// Remove one leaf, releasing the tree's block references. Returns how
    /// many blocks actually went back to the free list (refcount hit 0).
    fn evict_leaf(&mut self, leaf: usize, pool: &BlockPool) -> usize {
        let bs = self.block_size;
        let mut freed = 0usize;
        for &b in &self.nodes[leaf].blocks {
            if pool.release(b) {
                freed += 1;
            }
        }
        self.blocks_cached -= self.nodes[leaf].blocks.len();
        let parent = self.nodes[leaf].parent;
        let key: Vec<usize> = self.nodes[leaf].tokens[..bs].to_vec();
        self.nodes[parent].children.remove(&key);
        let n = &mut self.nodes[leaf];
        n.in_use = false;
        n.tokens = Vec::new();
        n.blocks = Vec::new();
        self.free_nodes.push(leaf);
        freed
    }

    /// Drop an entire subtree hanging below `node` (the node itself is
    /// kept), releasing the tree's reference on every block it cached.
    /// Returns the number of tree block references released.
    fn drop_children(&mut self, node: usize, pool: &BlockPool) -> usize {
        let mut released = 0usize;
        let mut stack: Vec<usize> = self.nodes[node].children.values().copied().collect();
        self.nodes[node].children.clear();
        while let Some(i) = stack.pop() {
            stack.extend(self.nodes[i].children.values().copied());
            let blocks = std::mem::take(&mut self.nodes[i].blocks);
            self.blocks_cached -= blocks.len();
            released += blocks.len();
            for b in blocks {
                pool.release(b);
            }
            let n = &mut self.nodes[i];
            n.in_use = false;
            n.tokens = Vec::new();
            n.children = HashMap::new();
            n.parent = 0;
            self.free_nodes.push(i);
        }
        released
    }

    /// Invalidate every cached prefix that runs through one of `bad`'s
    /// blocks: the owning node's edge is truncated just before its first bad
    /// block and everything hanging below it is dropped, so a later
    /// `match_prefix` can never hand out a block whose positions were
    /// rolled back (speculative-decode rejection, KV truncation). Clean
    /// leading blocks of a split node stay cached. Returns the number of
    /// tree block references released.
    pub fn invalidate_blocks(&mut self, bad: &[BlockId], pool: &BlockPool) -> usize {
        if bad.is_empty() {
            return 0;
        }
        let bad: std::collections::HashSet<BlockId> = bad.iter().copied().collect();
        let hits: Vec<(usize, usize)> = self
            .nodes
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, n)| n.in_use)
            .filter_map(|(i, n)| {
                n.blocks.iter().position(|b| bad.contains(b)).map(|fb| (i, fb))
            })
            .collect();
        let mut released = 0usize;
        let bs = self.block_size;
        for (node, first_bad) in hits {
            if !self.nodes[node].in_use {
                continue; // already dropped as a descendant of an earlier hit
            }
            // Everything below this node extends through the bad block.
            released += self.drop_children(node, pool);
            let (tail, unlink_key) = {
                let n = &mut self.nodes[node];
                let key = n.tokens[..bs].to_vec();
                let tail = n.blocks.split_off(first_bad);
                n.tokens.truncate(first_bad * bs);
                (tail, if first_bad == 0 { Some(key) } else { None })
            };
            self.blocks_cached -= tail.len();
            released += tail.len();
            for &b in &tail {
                pool.release(b);
            }
            if let Some(key) = unlink_key {
                // Nothing clean remains: unlink from the parent and recycle.
                let parent = self.nodes[node].parent;
                self.nodes[parent].children.remove(&key);
                let n = &mut self.nodes[node];
                n.in_use = false;
                n.tokens = Vec::new();
                n.blocks = Vec::new();
                n.children = HashMap::new();
                n.parent = 0;
                self.free_nodes.push(node);
            }
        }
        released
    }

    /// Evict least-recently-used leaves until at least `want` blocks have
    /// actually returned to `pool`'s free list. Leaves whose blocks are all
    /// still mapped by live page tables are skipped — evicting them frees
    /// no memory and would only trash the cache under the very load where
    /// it matters most. Returns the number of blocks freed; 0 means nothing
    /// evictable can reclaim memory right now.
    pub fn evict(&mut self, want: usize, pool: &BlockPool) -> usize {
        let mut freed = 0usize;
        while freed < want {
            let mut best: Option<usize> = None;
            for i in 1..self.nodes.len() {
                let n = &self.nodes[i];
                if !n.in_use || !n.children.is_empty() {
                    continue;
                }
                // Only the tree's own reference left on some block?
                if !n.blocks.iter().any(|&b| pool.ref_count(b) == 1) {
                    continue;
                }
                match best {
                    None => best = Some(i),
                    Some(b) if n.last_access < self.nodes[b].last_access => best = Some(i),
                    _ => {}
                }
            }
            let Some(leaf) = best else { break };
            freed += self.evict_leaf(leaf, pool);
        }
        freed
    }

    /// Drop every cached prefix unconditionally (shutdown/tests) — unlike
    /// [`RadixCache::evict`], this also unwinds leaves whose blocks are
    /// still shared with live sequences.
    pub fn clear(&mut self, pool: &BlockPool) {
        loop {
            let leaf = (1..self.nodes.len())
                .find(|&i| self.nodes[i].in_use && self.nodes[i].children.is_empty());
            match leaf {
                Some(l) => {
                    self.evict_leaf(l, pool);
                }
                None => return,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::pool::KvLayout;
    use std::sync::Arc;

    fn pool(n: usize) -> Arc<BlockPool> {
        BlockPool::new(
            KvLayout {
                n_layers: 1,
                d_model: 2,
                block_size: 4,
            },
            n,
        )
    }

    /// Allocate `n` pool blocks to stand in for a prefilled page table.
    fn take(pool: &BlockPool, n: usize) -> Vec<BlockId> {
        (0..n).map(|_| pool.try_alloc().unwrap()).collect()
    }

    #[test]
    fn insert_then_match_roundtrip() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        let tokens: Vec<usize> = (0..12).collect();
        let blocks = take(&pool, 3);
        t.insert(&tokens, &blocks, &pool);
        assert_eq!(t.blocks_cached(), 3);
        // Tree holds its own refs on top of the page table's.
        assert!(blocks.iter().all(|&b| pool.ref_count(b) == 2));
        assert_eq!(t.match_prefix(&tokens, &pool), blocks);
        // A match retains each returned block for the caller.
        assert!(blocks.iter().all(|&b| pool.ref_count(b) == 3));
        // Longer query still matches the cached 3 blocks.
        let longer: Vec<usize> = (0..16).collect();
        assert_eq!(t.match_prefix(&longer, &pool), blocks);
        // Shorter query matches only whole blocks it covers.
        assert_eq!(t.match_prefix(&tokens[..7], &pool), &blocks[..1]);
    }

    #[test]
    fn diverging_prompts_split_shared_prefix() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        // a: blocks [0..2) over tokens 0..8; b shares block 0 then diverges.
        let a_tokens: Vec<usize> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let a_blocks = take(&pool, 2);
        t.insert(&a_tokens, &a_blocks, &pool);
        let b_tokens: Vec<usize> = vec![1, 2, 3, 4, 99, 98, 97, 96];
        let matched = t.match_prefix(&b_tokens, &pool);
        assert_eq!(matched, &a_blocks[..1], "shared first block matches");
        let b_blocks = take(&pool, 2);
        // b's page table: shared block 0 + its own block for tokens 4..8.
        let b_table = vec![a_blocks[0], b_blocks[0]];
        t.insert(&b_tokens, &b_table, &pool);
        // Shared block cached once: refs = a's table + b's table would be
        // managed by callers; here tree added exactly one ref for it.
        assert_eq!(t.blocks_cached(), 3);
        assert_eq!(t.match_prefix(&a_tokens, &pool), a_blocks);
        assert_eq!(t.match_prefix(&b_tokens, &pool), b_table);
    }

    #[test]
    fn no_sharing_below_block_granularity() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        let a_tokens: Vec<usize> = vec![1, 2, 3, 4];
        let a_blocks = take(&pool, 1);
        t.insert(&a_tokens, &a_blocks, &pool);
        // Diverges at token 2 — inside the first block: no match.
        let b_tokens: Vec<usize> = vec![1, 2, 9, 9];
        assert!(t.match_prefix(&b_tokens, &pool).is_empty());
        let b_blocks = take(&pool, 1);
        t.insert(&b_tokens, &b_blocks, &pool);
        assert_eq!(t.blocks_cached(), 2);
        assert_eq!(t.match_prefix(&a_tokens, &pool), a_blocks);
        assert_eq!(t.match_prefix(&b_tokens, &pool), b_blocks);
    }

    #[test]
    fn evict_lru_releases_refs() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        let old_tokens: Vec<usize> = (0..4).collect();
        let old_blocks = take(&pool, 1);
        t.insert(&old_tokens, &old_blocks, &pool);
        let new_tokens: Vec<usize> = (100..104).collect();
        let new_blocks = take(&pool, 1);
        t.insert(&new_tokens, &new_blocks, &pool);
        // Eviction skips leaves whose blocks live sequences still map —
        // releasing those frees no memory.
        assert_eq!(t.evict(1, &pool), 0, "all cached blocks still mapped");
        assert_eq!(t.blocks_cached(), 2);
        // Sequences complete: page tables drop their refs.
        pool.release(old_blocks[0]);
        pool.release(new_blocks[0]);
        // Touch the new prefix so the old one is LRU (drop the match ref).
        for b in t.match_prefix(&new_tokens, &pool) {
            pool.release(b);
        }
        let freed = t.evict(1, &pool);
        assert_eq!(freed, 1, "LRU leaf freed one real block");
        assert_eq!(t.blocks_cached(), 1);
        assert_eq!(pool.blocks_in_use(), 1, "only the hot cached block left");
        assert!(
            t.match_prefix(&old_tokens, &pool).is_empty(),
            "old prefix gone"
        );
        let hot = t.match_prefix(&new_tokens, &pool);
        assert_eq!(hot, new_blocks, "hot prefix kept");
        for &b in &hot {
            pool.release(b);
        }
        t.clear(&pool);
        assert_eq!(pool.blocks_in_use(), 0);
    }

    #[test]
    fn invalidate_blocks_splits_and_drops_subtrees() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        // Entry 0..8 (2 blocks) with an extension 8..12 (1 more block).
        let a: Vec<usize> = (0..8).collect();
        let ab = take(&pool, 2);
        t.insert(&a, &ab, &pool);
        let long: Vec<usize> = (0..12).collect();
        let b2 = take(&pool, 1)[0];
        t.insert(&long, &[ab[0], ab[1], b2], &pool);
        assert_eq!(t.blocks_cached(), 3);
        // Invalidate the middle block: the entry splits before it and the
        // extension (whose prefix runs through it) is dropped.
        let released = t.invalidate_blocks(&[ab[1]], &pool);
        assert_eq!(released, 2, "bad block + the extension beyond it");
        assert_eq!(t.blocks_cached(), 1);
        let m = t.match_prefix(&long, &pool);
        assert_eq!(m, &ab[..1], "clean leading block still matches");
        pool.release(ab[0]); // drop the match's caller ref
        // Invalidating the sole remaining block unlinks the entry entirely.
        assert_eq!(t.invalidate_blocks(&[ab[0]], &pool), 1);
        assert_eq!(t.blocks_cached(), 0);
        assert!(t.match_prefix(&a, &pool).is_empty());
        // Only the simulated page-table refs remain.
        assert_eq!(pool.ref_count(ab[0]), 1);
        assert_eq!(pool.ref_count(ab[1]), 1);
        assert_eq!(pool.ref_count(b2), 1);
        pool.release(ab[0]);
        pool.release(ab[1]);
        pool.release(b2);
        assert_eq!(pool.blocks_in_use(), 0);
    }

    #[test]
    fn schedule_tag_gates_matches() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        // Producer prefilled a 12-token prompt with dense_upto = 6: the
        // boundary falls inside the second block (positions 4..8).
        let tokens: Vec<usize> = (0..12).collect();
        let blocks = take(&pool, 3);
        t.insert_scheduled(&tokens, &blocks, 6, &pool);
        // Same schedule: full hit.
        let m = t.match_prefix_scheduled(&tokens, 6, &pool);
        assert_eq!(m, blocks);
        for &b in &m {
            pool.release(b);
        }
        // Boundary moved to 10 (a longer prompt's schedule): block 0 (0..4,
        // dense under both) still serves; block 1 (4..8) straddles the
        // disagreement (6 vs 10) and is refused, cutting the match there.
        let m = t.match_prefix_scheduled(&tokens, 10, &pool);
        assert_eq!(m, &blocks[..1], "only the schedule-consistent span matches");
        for &b in &m {
            pool.release(b);
        }
        // Boundary 5 clamps to 5 within block 1 either way it disagrees
        // with 6 — again only block 0.
        let m = t.match_prefix_scheduled(&tokens, 5, &pool);
        assert_eq!(m, &blocks[..1]);
        for &b in &m {
            pool.release(b);
        }
        // Deep spans where both schedules are already sparse stay shared:
        // producer du=2, consumer du=3 — blocks 1 and 2 (positions 4..12)
        // are sparse under both, but block 0 (0..4) straddles 2 vs 3, so
        // nothing matches from position 0.
        let mut t2 = RadixCache::new(4);
        let b2 = take(&pool, 3);
        t2.insert_scheduled(&tokens, &b2, 2, &pool);
        assert!(t2.match_prefix_scheduled(&tokens, 3, &pool).is_empty());
        // Identical boundary: everything matches again.
        let m = t2.match_prefix_scheduled(&tokens, 2, &pool);
        assert_eq!(m, b2);
        for &b in &m {
            pool.release(b);
        }
    }

    #[test]
    fn schedule_tag_gates_inserts() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        let tokens: Vec<usize> = (0..8).collect();
        let blocks = take(&pool, 2);
        t.insert_scheduled(&tokens, &blocks, 3, &pool);
        assert_eq!(t.blocks_cached(), 2);
        // A producer with a conflicting schedule over the cached span must
        // not graft its extension below it: its deeper KV attended to a
        // differently-scheduled prefix.
        let longer: Vec<usize> = (0..12).collect();
        let ext = take(&pool, 3);
        t.insert_scheduled(&longer, &ext, 9, &pool);
        assert_eq!(t.blocks_cached(), 2, "conflicting insert is refused");
        // An agreeing extension (same boundary) is grafted normally.
        t.insert_scheduled(&longer, &ext, 3, &pool);
        assert_eq!(t.blocks_cached(), 3);
        let m = t.match_prefix_scheduled(&longer, 3, &pool);
        assert_eq!(m.len(), 3);
        for &b in &m {
            pool.release(b);
        }
    }

    #[test]
    fn evict_unwinds_inner_nodes() {
        let pool = pool(8);
        let mut t = RadixCache::new(4);
        let a: Vec<usize> = (0..8).collect();
        let ab = take(&pool, 2);
        t.insert(&a, &ab, &pool);
        let b: Vec<usize> = (0..4).chain(50..54).collect();
        let bb = vec![ab[0], take(&pool, 1)[0]];
        t.insert(&b, &bb, &pool);
        // Three cached blocks across a split node and two leaves; full
        // eviction must unwind leaves then the inner node.
        assert_eq!(t.blocks_cached(), 3);
        t.clear(&pool);
        assert_eq!(t.blocks_cached(), 0);
        // Only page-table refs remain.
        assert_eq!(pool.ref_count(ab[0]), 1);
    }
}
