//! Binary weight interchange format shared with the Python trainer.
//!
//! Layout (all little-endian):
//! ```text
//!   magic    8 bytes  "WSPW0001"
//!   count    u32      number of tensors
//!   repeat count times:
//!     name_len u32, name bytes (utf-8)
//!     ndim     u32, dims ndim x u32
//!     data     prod(dims) x f32
//! ```
//! Tensor names follow the convention used by `python/compile/train.py`:
//! `embed.weight`, `blocks.{i}.attn_norm.weight`, `blocks.{i}.attn.wq.weight`,
//! ..., `final_norm.weight`, `lm_head.weight`.

use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"WSPW0001";

/// Named tensor store (order-preserving by name via BTreeMap).
#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing tensor `{name}`"))
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Weights> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }

    pub fn from_bytes(buf: &[u8]) -> anyhow::Result<Weights> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
            if *pos + n > buf.len() {
                anyhow::bail!("truncated weight file at byte {pos}");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 8)?;
        if magic != MAGIC {
            anyhow::bail!("bad magic {:?} (not a WSPW0001 weight file)", magic);
        }
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut w = Weights::default();
        for _ in 0..count {
            let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .map_err(|_| anyhow::anyhow!("non-utf8 tensor name"))?;
            let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            if ndim == 0 || ndim > 3 {
                anyhow::bail!("tensor `{name}`: bad ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize);
            }
            let numel: usize = shape.iter().product();
            let raw = take(&mut pos, numel * 4)?;
            let mut data = Vec::with_capacity(numel);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            w.tensors.insert(name, Tensor::from_vec(&shape, data));
        }
        if pos != buf.len() {
            anyhow::bail!("trailing bytes in weight file ({} unused)", buf.len() - pos);
        }
        Ok(w)
    }

    /// Tensor-name helpers matching the Python trainer's convention.
    pub fn attn_weight_name(block: usize, which: &str) -> String {
        format!("blocks.{block}.attn.w{which}.weight")
    }

    pub fn mlp_weight_name(block: usize, which: &str) -> String {
        format!("blocks.{block}.mlp.w_{which}.weight")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Pcg64::new(4);
        let mut w = Weights::default();
        w.insert("a.weight", Tensor::randn(&[3, 4], 1.0, &mut rng));
        w.insert("b", Tensor::randn(&[7], 0.5, &mut rng));
        w.insert("c3", Tensor::randn(&[2, 3, 4], 2.0, &mut rng));
        let dir = std::env::temp_dir().join("wisparse_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let w2 = Weights::load(&path).unwrap();
        assert_eq!(w.tensors.len(), w2.tensors.len());
        for (name, t) in &w.tensors {
            assert_eq!(t, w2.tensors.get(name).unwrap(), "{name}");
        }
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Weights::from_bytes(b"NOTMAGIC\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut w = Weights::default();
        w.insert("t", Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        let dir = std::env::temp_dir().join("wisparse_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(Weights::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing junk also rejected.
        let mut extended = bytes.clone();
        extended.extend_from_slice(&[0u8; 4]);
        assert!(Weights::from_bytes(&extended).is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let w = Weights::default();
        assert!(w.get("nope").is_err());
    }
}
