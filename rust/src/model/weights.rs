//! Binary weight interchange format shared with the Python trainer.
//!
//! Two container versions, distinguished by magic so an older reader can
//! never silently misparse a newer file:
//!
//! **v1** (`WSPW0001`, what `python/compile/train.py` writes — all
//! little-endian):
//! ```text
//!   magic    8 bytes  "WSPW0001"
//!   count    u32      number of tensors
//!   repeat count times:
//!     name_len u32, name bytes (utf-8)
//!     ndim     u32, dims ndim x u32
//!     data     prod(dims) x f32
//! ```
//!
//! **v2** (`WSPW0002`, written whenever a checkpoint carries quantized
//! weights or a manifest):
//! ```text
//!   magic        8 bytes  "WSPW0002"
//!   version      u32      (currently 2; readers reject anything newer)
//!   manifest_len u32, manifest bytes (utf-8 JSON, e.g.
//!                {"format":"quant","mode":"int8","group":64})
//!   count        u32, f32 tensor entries exactly as in v1
//!   qcount       u32
//!   repeat qcount times:
//!     name_len u32, name bytes (utf-8)
//!     mode     u32   (bits per weight: 8 or 4)
//!     m u32, n u32, group u32
//!     scales_len u32, scales scales_len x f32
//!     data_len   u32, data bytes (packed codes)
//! ```
//!
//! Dense-only stores keep writing byte-identical v1 files, so the Python
//! side and any pre-versioning reader are unaffected; legacy files load as
//! `version = 1`.
//!
//! Tensor names follow the convention used by `python/compile/train.py`:
//! `embed.weight`, `blocks.{i}.attn_norm.weight`, `blocks.{i}.attn.wq.weight`,
//! ..., `final_norm.weight`, `lm_head.weight`.

use crate::quant::{QuantMatrix, QuantMode};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC_V1: &[u8; 8] = b"WSPW0001";
const MAGIC_V2: &[u8; 8] = b"WSPW0002";

/// Highest container version this reader understands.
pub const FORMAT_VERSION: u32 = 2;

/// Named tensor store (order-preserving by name via BTreeMap), optionally
/// carrying group-quantized matrices alongside the f32 tensors.
#[derive(Clone, Debug)]
pub struct Weights {
    /// Container format version: 1 for legacy/dense files, 2 when quantized
    /// entries or a manifest are present.
    pub version: u32,
    /// Free-form JSON manifest (empty for v1/dense checkpoints).
    pub manifest: String,
    pub tensors: BTreeMap<String, Tensor>,
    /// Group-quantized matrices by the same naming convention.
    pub quants: BTreeMap<String, QuantMatrix>,
}

impl Default for Weights {
    fn default() -> Self {
        Self {
            version: 1,
            manifest: String::new(),
            tensors: BTreeMap::new(),
            quants: BTreeMap::new(),
        }
    }
}

impl Weights {
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn insert_quant(&mut self, name: &str, q: QuantMatrix) {
        self.quants.insert(name.to_string(), q);
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing tensor `{name}`"))
    }

    /// Whether this store needs the v2 container.
    fn needs_v2(&self) -> bool {
        self.version >= 2 || !self.quants.is_empty() || !self.manifest.is_empty()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        if self.needs_v2() {
            buf.extend_from_slice(MAGIC_V2);
            buf.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
            let mb = self.manifest.as_bytes();
            buf.extend_from_slice(&(mb.len() as u32).to_le_bytes());
            buf.extend_from_slice(mb);
        } else {
            buf.extend_from_slice(MAGIC_V1);
        }
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
            buf.extend_from_slice(nb);
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        if self.needs_v2() {
            buf.extend_from_slice(&(self.quants.len() as u32).to_le_bytes());
            for (name, q) in &self.quants {
                let nb = name.as_bytes();
                buf.extend_from_slice(&(nb.len() as u32).to_le_bytes());
                buf.extend_from_slice(nb);
                buf.extend_from_slice(&q.mode.tag().to_le_bytes());
                buf.extend_from_slice(&(q.m as u32).to_le_bytes());
                buf.extend_from_slice(&(q.n as u32).to_le_bytes());
                buf.extend_from_slice(&(q.group as u32).to_le_bytes());
                buf.extend_from_slice(&(q.scales.len() as u32).to_le_bytes());
                for &s in &q.scales {
                    buf.extend_from_slice(&s.to_le_bytes());
                }
                buf.extend_from_slice(&(q.data.len() as u32).to_le_bytes());
                buf.extend_from_slice(&q.data);
            }
        }
        buf
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let buf = self.to_bytes();
        let mut f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Weights> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }

    pub fn from_bytes(buf: &[u8]) -> anyhow::Result<Weights> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
            if *pos + n > buf.len() {
                anyhow::bail!("truncated weight file at byte {pos}");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let take_u32 = |pos: &mut usize| -> anyhow::Result<u32> {
            if *pos + 4 > buf.len() {
                anyhow::bail!("truncated weight file at byte {pos}");
            }
            let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
            *pos += 4;
            Ok(v)
        };
        let magic = take(&mut pos, 8)?;
        let mut w = Weights::default();
        if magic == MAGIC_V1 {
            w.version = 1; // legacy files predate the version field
        } else if magic == MAGIC_V2 {
            let version = take_u32(&mut pos)?;
            if version < 2 {
                anyhow::bail!("v2 container claims version {version}");
            }
            if version > FORMAT_VERSION {
                anyhow::bail!(
                    "weight file version {version} is newer than this reader \
                     (understands up to {FORMAT_VERSION})"
                );
            }
            w.version = version;
            let mlen = take_u32(&mut pos)? as usize;
            w.manifest = String::from_utf8(take(&mut pos, mlen)?.to_vec())
                .map_err(|_| anyhow::anyhow!("non-utf8 manifest"))?;
        } else {
            anyhow::bail!("bad magic {:?} (not a WSPW weight file)", magic);
        }
        let count = take_u32(&mut pos)? as usize;
        for _ in 0..count {
            let name_len = take_u32(&mut pos)? as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .map_err(|_| anyhow::anyhow!("non-utf8 tensor name"))?;
            let ndim = take_u32(&mut pos)? as usize;
            if ndim == 0 || ndim > 3 {
                anyhow::bail!("tensor `{name}`: bad ndim {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(take_u32(&mut pos)? as usize);
            }
            let numel: usize = shape.iter().product();
            let raw = take(&mut pos, numel * 4)?;
            let mut data = Vec::with_capacity(numel);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            w.tensors.insert(name, Tensor::from_vec(&shape, data));
        }
        if w.version >= 2 {
            let qcount = take_u32(&mut pos)? as usize;
            for _ in 0..qcount {
                let name_len = take_u32(&mut pos)? as usize;
                let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                    .map_err(|_| anyhow::anyhow!("non-utf8 quant tensor name"))?;
                let mode = QuantMode::from_tag(take_u32(&mut pos)?)
                    .ok_or_else(|| anyhow::anyhow!("quant `{name}`: unknown mode tag"))?;
                let m = take_u32(&mut pos)? as usize;
                let n = take_u32(&mut pos)? as usize;
                let group = take_u32(&mut pos)? as usize;
                if group == 0 {
                    anyhow::bail!("quant `{name}`: zero group size");
                }
                let gpc = m.div_ceil(group).max(1);
                let scales_len = take_u32(&mut pos)? as usize;
                if scales_len != n * gpc {
                    anyhow::bail!(
                        "quant `{name}`: {scales_len} scales for {n} cols x {gpc} groups"
                    );
                }
                let raw = take(&mut pos, scales_len * 4)?;
                let mut scales = Vec::with_capacity(scales_len);
                for c in raw.chunks_exact(4) {
                    scales.push(f32::from_le_bytes(c.try_into().unwrap()));
                }
                let data_len = take_u32(&mut pos)? as usize;
                let expect = match mode {
                    QuantMode::Int8 => n * m,
                    QuantMode::Int4 => n * m.div_ceil(2),
                };
                if data_len != expect {
                    anyhow::bail!(
                        "quant `{name}`: {data_len} code bytes, expected {expect}"
                    );
                }
                let data = take(&mut pos, data_len)?.to_vec();
                w.quants.insert(
                    name,
                    QuantMatrix {
                        m,
                        n,
                        mode,
                        group,
                        scales,
                        data,
                    },
                );
            }
        }
        if pos != buf.len() {
            anyhow::bail!("trailing bytes in weight file ({} unused)", buf.len() - pos);
        }
        Ok(w)
    }

    /// Tensor-name helpers matching the Python trainer's convention.
    pub fn attn_weight_name(block: usize, which: &str) -> String {
        format!("blocks.{block}.attn.w{which}.weight")
    }

    pub fn mlp_weight_name(block: usize, which: &str) -> String {
        format!("blocks.{block}.mlp.w_{which}.weight")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse_kernel::ColMajorMatrix;
    use crate::util::rng::Pcg64;

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Pcg64::new(4);
        let mut w = Weights::default();
        w.insert("a.weight", Tensor::randn(&[3, 4], 1.0, &mut rng));
        w.insert("b", Tensor::randn(&[7], 0.5, &mut rng));
        w.insert("c3", Tensor::randn(&[2, 3, 4], 2.0, &mut rng));
        let dir = std::env::temp_dir().join("wisparse_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let w2 = Weights::load(&path).unwrap();
        assert_eq!(w.tensors.len(), w2.tensors.len());
        assert_eq!(w2.version, 1, "dense store stays a v1 file");
        for (name, t) in &w.tensors {
            assert_eq!(t, w2.tensors.get(name).unwrap(), "{name}");
        }
    }

    #[test]
    fn dense_store_writes_legacy_v1_bytes() {
        let mut w = Weights::default();
        w.insert("t", Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        let bytes = w.to_bytes();
        assert_eq!(&bytes[..8], MAGIC_V1, "python interop must stay intact");
    }

    #[test]
    fn quantized_store_roundtrips_as_v2() {
        let mut rng = Pcg64::new(12);
        let dense = ColMajorMatrix::from_row_major(&Tensor::randn(&[10, 6], 1.0, &mut rng));
        let q = QuantMatrix::quantize(&dense, QuantMode::Int4, 4);
        let mut w = Weights::default();
        w.insert("norm.weight", Tensor::randn(&[10], 1.0, &mut rng));
        w.insert_quant("layer.weight", q.clone());
        w.manifest = r#"{"format":"quant","mode":"int4","group":4}"#.to_string();
        let bytes = w.to_bytes();
        assert_eq!(&bytes[..8], MAGIC_V2);
        let w2 = Weights::from_bytes(&bytes).unwrap();
        assert_eq!(w2.version, 2);
        assert_eq!(w2.manifest, w.manifest);
        assert_eq!(w2.quants.get("layer.weight").unwrap(), &q);
        assert_eq!(w2.tensors.len(), 1);
    }

    #[test]
    fn rejects_bad_magic_and_newer_versions() {
        assert!(Weights::from_bytes(b"NOTMAGIC\x00\x00\x00\x00").is_err());
        // A v2 file stamped with a future version must be refused, not
        // misread: that is the point of the version field.
        let mut w = Weights::default();
        w.manifest = "{}".to_string();
        let mut bytes = w.to_bytes();
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = Weights::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("newer"), "{err}");
    }

    #[test]
    fn rejects_truncation() {
        let mut w = Weights::default();
        w.insert("t", Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        let dir = std::env::temp_dir().join("wisparse_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(Weights::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing junk also rejected.
        let mut extended = bytes.clone();
        extended.extend_from_slice(&[0u8; 4]);
        assert!(Weights::from_bytes(&extended).is_err());
        // v2 truncation inside the quant section too.
        let mut w2 = Weights::default();
        let dense = ColMajorMatrix::from_row_major(&Tensor::from_vec(
            &[2, 2],
            vec![1., 2., 3., 4.],
        ));
        w2.insert_quant("q", QuantMatrix::quantize(&dense, QuantMode::Int8, 2));
        let b2 = w2.to_bytes();
        assert!(Weights::from_bytes(&b2[..b2.len() - 1]).is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let w = Weights::default();
        assert!(w.get("nope").is_err());
    }
}
