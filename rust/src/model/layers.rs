//! Layer addressing: the paper allocates sparsity and tunes exponents per
//! *linear layer* within each transformer *block*, so every projection gets a
//! stable identifier used by sparsity plans, calibration captures and
//! reports.

use crate::model::ModelConfig;

/// The seven sparsifiable linear projections in one block (paper Sec 5.1:
/// "all linear layers in the transformer blocks").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LayerKind {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
}

impl LayerKind {
    pub const ALL: [LayerKind; 7] = [
        LayerKind::Q,
        LayerKind::K,
        LayerKind::V,
        LayerKind::O,
        LayerKind::Gate,
        LayerKind::Up,
        LayerKind::Down,
    ];

    pub fn name(self) -> &'static str {
        match self {
            LayerKind::Q => "q_proj",
            LayerKind::K => "k_proj",
            LayerKind::V => "v_proj",
            LayerKind::O => "o_proj",
            LayerKind::Gate => "gate_proj",
            LayerKind::Up => "up_proj",
            LayerKind::Down => "down_proj",
        }
    }

    pub fn from_name(s: &str) -> Option<LayerKind> {
        LayerKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    pub fn index(self) -> usize {
        LayerKind::ALL.iter().position(|&k| k == self).unwrap()
    }

    /// Whether this projection belongs to the attention module (for the
    /// per-module breakdown of Fig 5).
    pub fn is_attn(self) -> bool {
        matches!(self, LayerKind::Q | LayerKind::K | LayerKind::V | LayerKind::O)
    }

    /// (out_dim, in_dim) of the projection's weight for a given config.
    pub fn dims(self, cfg: &ModelConfig) -> (usize, usize) {
        let d = cfg.d_model;
        let f = cfg.ffn_dim;
        match self {
            LayerKind::Q | LayerKind::K | LayerKind::V | LayerKind::O => (d, d),
            LayerKind::Gate | LayerKind::Up => (f, d),
            LayerKind::Down => (d, f),
        }
    }
}

/// Address of one linear layer in the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LayerId {
    pub block: usize,
    pub kind: LayerKind,
}

impl LayerId {
    pub fn new(block: usize, kind: LayerKind) -> Self {
        Self { block, kind }
    }

    /// Flat index over the model's `n_layers * 7` linear layers.
    pub fn flat(self) -> usize {
        self.block * 7 + self.kind.index()
    }

    pub fn from_flat(flat: usize) -> LayerId {
        LayerId {
            block: flat / 7,
            kind: LayerKind::ALL[flat % 7],
        }
    }

    /// Stable string form used in JSON plans: `"3.up_proj"`.
    pub fn key(self) -> String {
        format!("{}.{}", self.block, self.kind.name())
    }

    pub fn from_key(s: &str) -> Option<LayerId> {
        let (b, k) = s.split_once('.')?;
        Some(LayerId {
            block: b.parse().ok()?,
            kind: LayerKind::from_name(k)?,
        })
    }
}

/// Iterate every linear layer id in a model, block-major.
pub fn all_layers(cfg: &ModelConfig) -> Vec<LayerId> {
    (0..cfg.n_layers)
        .flat_map(|b| LayerKind::ALL.iter().map(move |&k| LayerId::new(b, k)))
        .collect()
}

/// Per-layer FLOP weight (2*m*n multiply-adds) used when averaging layer
/// sparsities into an *effective* block/model sparsity: skipping a channel in
/// a big projection saves more compute than in a small one.
pub fn layer_flops(cfg: &ModelConfig, kind: LayerKind) -> f64 {
    let (m, n) = kind.dims(cfg);
    2.0 * m as f64 * n as f64
}

/// FLOP-weighted effective sparsity of a block given per-kind sparsities.
pub fn block_effective_sparsity(cfg: &ModelConfig, per_kind: &[f64; 7]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, &k) in LayerKind::ALL.iter().enumerate() {
        let w = layer_flops(cfg, k);
        num += w * per_kind[i];
        den += w;
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_roundtrip() {
        for b in 0..4 {
            for &k in &LayerKind::ALL {
                let id = LayerId::new(b, k);
                assert_eq!(LayerId::from_key(&id.key()), Some(id));
                assert_eq!(LayerId::from_flat(id.flat()), id);
            }
        }
        assert_eq!(LayerId::from_key("junk"), None);
        assert_eq!(LayerId::from_key("1.nope"), None);
    }

    #[test]
    fn dims_consistent() {
        let cfg = ModelConfig::preset("llama-micro").unwrap();
        assert_eq!(LayerKind::Q.dims(&cfg), (128, 128));
        assert_eq!(LayerKind::Up.dims(&cfg), (352, 128));
        assert_eq!(LayerKind::Down.dims(&cfg), (128, 352));
    }

    #[test]
    fn all_layers_count() {
        let cfg = ModelConfig::preset("qwen-micro").unwrap();
        assert_eq!(all_layers(&cfg).len(), cfg.n_layers * 7);
    }

    #[test]
    fn effective_sparsity_weighted() {
        let cfg = ModelConfig::preset("llama-micro").unwrap();
        // All layers at 0.5 -> effective 0.5 regardless of weights.
        assert!((block_effective_sparsity(&cfg, &[0.5; 7]) - 0.5).abs() < 1e-12);
        // Sparsity only on the largest layers > only on the smallest.
        let mut big = [0.0; 7];
        big[LayerKind::Up.index()] = 1.0;
        big[LayerKind::Down.index()] = 1.0;
        let mut small = [0.0; 7];
        small[LayerKind::K.index()] = 1.0;
        assert!(
            block_effective_sparsity(&cfg, &big) > block_effective_sparsity(&cfg, &small)
        );
    }

    #[test]
    fn attn_split() {
        assert!(LayerKind::O.is_attn());
        assert!(!LayerKind::Gate.is_attn());
    }
}
