//! Model architecture configuration, serialized as JSON next to the weight
//! binary (written by `python/compile/train.py`, read here).

use crate::util::json::Json;
use std::path::Path;

/// Llama-style decoder config. Field names match the JSON emitted by the
/// Python trainer.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn_dim: usize,
    pub max_seq: usize,
    pub rope_base: f32,
    pub rmsnorm_eps: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// The three micro-model profiles mirroring the paper's Llama-3.1-8B /
    /// Mistral-7B / Qwen-2.5-7B trio (distinct depth/width so the
    /// sensitivity landscapes differ, as in Fig 5).
    pub fn preset(name: &str) -> anyhow::Result<ModelConfig> {
        let (d_model, n_layers, n_heads, ffn_dim) = match name {
            "llama-micro" => (128, 8, 4, 352),
            "mistral-micro" => (160, 6, 4, 432),
            "qwen-micro" => (96, 10, 4, 256),
            "nano" => (32, 2, 2, 64), // test-only profile
            _ => anyhow::bail!(
                "unknown model preset `{name}` (expected llama-micro|mistral-micro|qwen-micro|nano)"
            ),
        };
        Ok(ModelConfig {
            name: name.to_string(),
            vocab_size: 256,
            d_model,
            n_layers,
            n_heads,
            ffn_dim,
            max_seq: 256,
            rope_base: 10000.0,
            rmsnorm_eps: 1e-5,
        })
    }

    pub fn all_presets() -> [&'static str; 3] {
        ["llama-micro", "mistral-micro", "qwen-micro"]
    }

    /// Parameter count (embeddings + blocks + head).
    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let f = self.ffn_dim;
        let per_block = 4 * d * d + 3 * d * f + 2 * d; // attn + mlp + 2 norms
        self.vocab_size * d * 2 + self.n_layers * per_block + d
    }

    /// FLOPs (multiply-adds x2) of the *linear projections* for one decoded
    /// token at density 1.0. This is the quantity the paper's Fig 4 scales
    /// with sparsity; attention score/value FLOPs are excluded, matching the
    /// "skipped activation channels in linear projections" accounting.
    pub fn linear_flops_per_token(&self) -> u64 {
        let d = self.d_model as u64;
        let f = self.ffn_dim as u64;
        let per_block = 2 * (4 * d * d + 3 * d * f);
        per_block * self.n_layers as u64 + 2 * d * self.vocab_size as u64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("vocab_size", Json::Num(self.vocab_size as f64)),
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("ffn_dim", Json::Num(self.ffn_dim as f64)),
            ("max_seq", Json::Num(self.max_seq as f64)),
            ("rope_base", Json::Num(self.rope_base as f64)),
            ("rmsnorm_eps", Json::Num(self.rmsnorm_eps as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            vocab_size: j.req_usize("vocab_size")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            ffn_dim: j.req_usize("ffn_dim")?,
            max_seq: j.req_usize("max_seq")?,
            rope_base: j.req_f64("rope_base")? as f32,
            rmsnorm_eps: j.req_f64("rmsnorm_eps")? as f32,
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<ModelConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_valid() {
        for name in ModelConfig::all_presets() {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.d_model % c.n_heads, 0, "{name}");
            assert!(c.n_params() > 100_000, "{name}");
        }
        assert!(ModelConfig::preset("bogus").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::preset("llama-micro").unwrap();
        let j = c.to_json();
        let c2 = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn flops_scale_with_depth() {
        let a = ModelConfig::preset("llama-micro").unwrap();
        let b = ModelConfig::preset("qwen-micro").unwrap();
        assert!(a.linear_flops_per_token() > 0);
        assert_ne!(a.linear_flops_per_token(), b.linear_flops_per_token());
    }
}
