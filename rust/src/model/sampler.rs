//! Token sampling policies for the serving path.

use crate::tensor::ops::{argmax, softmax_inplace};
use crate::util::rng::Pcg64;

/// Sampling configuration attached to a generation request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    /// Temperature sampling (t > 0); t -> 0 approaches greedy.
    Temperature(f32),
}

impl Sampling {
    pub fn sample(&self, logits: &[f32], rng: &mut Pcg64) -> usize {
        match *self {
            Sampling::Greedy => argmax(logits),
            Sampling::Temperature(t) => {
                let t = t.max(1e-4);
                let mut probs: Vec<f32> = logits.iter().map(|&l| l / t).collect();
                softmax_inplace(&mut probs);
                let u = rng.next_f32();
                let mut acc = 0.0f32;
                for (i, &p) in probs.iter().enumerate() {
                    acc += p;
                    if u < acc {
                        return i;
                    }
                }
                probs.len() - 1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.0f32, 3.0, -1.0];
        let mut rng = Pcg64::new(1);
        assert_eq!(Sampling::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = vec![0.0f32, 5.0, 1.0];
        let mut rng = Pcg64::new(2);
        for _ in 0..50 {
            assert_eq!(Sampling::Temperature(0.01).sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_explores() {
        let logits = vec![0.0f32, 1.0, 0.5];
        let mut rng = Pcg64::new(3);
        let mut seen = [false; 3];
        for _ in 0..500 {
            seen[Sampling::Temperature(10.0).sample(&logits, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "high temp should reach all tokens");
    }

    #[test]
    fn sample_always_in_range() {
        let logits = vec![-100.0f32; 16];
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            assert!(Sampling::Temperature(1.0).sample(&logits, &mut rng) < 16);
        }
    }
}
