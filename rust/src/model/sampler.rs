//! Token sampling policies for the serving path.

use crate::tensor::ops::{argmax, softmax_inplace};
use crate::util::rng::Pcg64;

/// Sampling configuration attached to a generation request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    Greedy,
    /// Temperature sampling (t > 0); t -> 0 approaches greedy.
    Temperature(f32),
}

impl Sampling {
    pub fn sample(&self, logits: &[f32], rng: &mut Pcg64) -> usize {
        match *self {
            Sampling::Greedy => argmax(logits),
            Sampling::Temperature(t) => {
                let t = t.max(1e-4);
                let mut probs: Vec<f32> = logits.iter().map(|&l| l / t).collect();
                softmax_inplace(&mut probs);
                sample_from(&probs, rng)
            }
        }
    }

    /// The full next-token distribution this policy samples from, written
    /// into `probs` (cleared and refilled; no allocation once warm). Greedy
    /// is the argmax point mass. Speculative decoding needs the explicit
    /// distributions for its accept/residual arithmetic.
    pub fn probs_into(&self, logits: &[f32], probs: &mut Vec<f32>) {
        probs.clear();
        match *self {
            Sampling::Greedy => {
                probs.resize(logits.len(), 0.0);
                probs[argmax(logits)] = 1.0;
            }
            Sampling::Temperature(t) => {
                let t = t.max(1e-4);
                probs.extend(logits.iter().map(|&l| l / t));
                softmax_inplace(probs);
            }
        }
    }
}

/// Draw from an explicit probability vector (non-negative, sums to ~1).
pub fn sample_from(probs: &[f32], rng: &mut Pcg64) -> usize {
    let u = rng.next_f32();
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i;
        }
    }
    probs.len() - 1
}

/// Speculative rejection sampling (the standard accept rule): a draft token
/// `d` drawn from the draft distribution `q` is accepted against the target
/// distribution `p` with probability `min(1, p[d]/q[d])`. On rejection the
/// caller must draw the correction from [`residual_sample`]; the combined
/// procedure emits tokens distributed exactly as `p`.
pub fn spec_accept(p: &[f32], q: &[f32], d: usize, rng: &mut Pcg64) -> bool {
    let ratio = (p[d] / q[d].max(1e-12)).min(1.0);
    rng.next_f32() < ratio
}

/// Sample from the normalized residual `max(p - q, 0)` — the rejection
/// branch of speculative sampling. Falls back to `p` itself when the
/// residual has no mass (p == q).
pub fn residual_sample(p: &[f32], q: &[f32], rng: &mut Pcg64) -> usize {
    debug_assert_eq!(p.len(), q.len());
    let mut total = 0.0f32;
    for i in 0..p.len() {
        total += (p[i] - q[i]).max(0.0);
    }
    if total <= 0.0 {
        return sample_from(p, rng);
    }
    let u = rng.next_f32() * total;
    let mut acc = 0.0f32;
    let mut last = 0usize;
    for i in 0..p.len() {
        let r = (p[i] - q[i]).max(0.0);
        if r > 0.0 {
            last = i;
            acc += r;
            if u < acc {
                return i;
            }
        }
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.0f32, 3.0, -1.0];
        let mut rng = Pcg64::new(1);
        assert_eq!(Sampling::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = vec![0.0f32, 5.0, 1.0];
        let mut rng = Pcg64::new(2);
        for _ in 0..50 {
            assert_eq!(Sampling::Temperature(0.01).sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn high_temperature_explores() {
        let logits = vec![0.0f32, 1.0, 0.5];
        let mut rng = Pcg64::new(3);
        let mut seen = [false; 3];
        for _ in 0..500 {
            seen[Sampling::Temperature(10.0).sample(&logits, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s), "high temp should reach all tokens");
    }

    #[test]
    fn probs_into_greedy_is_point_mass() {
        let logits = vec![0.0f32, 3.0, -1.0];
        let mut probs = Vec::new();
        Sampling::Greedy.probs_into(&logits, &mut probs);
        assert_eq!(probs, vec![0.0, 1.0, 0.0]);
        Sampling::Temperature(1.0).probs_into(&logits, &mut probs);
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(probs[1] > probs[0] && probs[0] > probs[2]);
    }

    #[test]
    fn spec_accept_is_certain_when_target_dominates() {
        let p = vec![0.25f32, 0.75];
        let q = vec![0.5f32, 0.5];
        let mut rng = Pcg64::new(5);
        for _ in 0..50 {
            assert!(spec_accept(&p, &q, 1, &mut rng), "p[1] > q[1] always accepts");
        }
    }

    #[test]
    fn residual_only_emits_underdrawn_tokens() {
        let p = vec![0.1f32, 0.6, 0.3];
        let q = vec![0.5f32, 0.2, 0.3];
        let mut rng = Pcg64::new(6);
        for _ in 0..200 {
            let c = residual_sample(&p, &q, &mut rng);
            assert!(p[c] > q[c], "residual token {c} has no excess mass");
        }
        // p == q: falls back to p itself, stays in range.
        for _ in 0..50 {
            assert!(residual_sample(&p, &p, &mut rng) < 3);
        }
    }

    #[test]
    fn sample_always_in_range() {
        let logits = vec![-100.0f32; 16];
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            assert!(Sampling::Temperature(1.0).sample(&logits, &mut rng) < 16);
        }
    }
}
