//! The Llama-style decoder, generic over a [`Sparsifier`].
//!
//! One code path serves dense and every sparse method: each of the seven
//! linear projections per block calls `sparsifier.project`, which realizes
//! Eq. 2's `y = (x ⊙ m) W^T`. The numeric conventions (RMSNorm, half-split
//! RoPE, SwiGLU, 1/sqrt(hd) attention scaling) mirror
//! `python/compile/model.py` so PJRT cross-validation can assert agreement.

use crate::kv::KvSeq;
use crate::model::kv_cache::KvCache;
use crate::model::layers::{LayerId, LayerKind};
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::obs::{NoopSink, ObsSink};
use crate::quant::{QuantMode, WeightMat, WeightRepr};
use crate::sparse_kernel::ColMajorMatrix;
use crate::sparsity::Sparsifier;
use crate::tensor::ops::{rmsnorm, rope_inplace, silu, softmax_inplace};
use crate::tensor::Tensor;
use crate::util::threadpool::intra_op_threads;
use std::path::Path;
use std::sync::Arc;

/// One transformer block's weights in kernel layout — dense-f32 columns or
/// group-quantized codes, behind one [`WeightRepr`] contract either way.
pub struct BlockWeights {
    pub attn_norm: Vec<f32>,
    pub wq: WeightMat,
    pub wk: WeightMat,
    pub wv: WeightMat,
    pub wo: WeightMat,
    pub mlp_norm: Vec<f32>,
    pub w_gate: WeightMat,
    pub w_up: WeightMat,
    pub w_down: WeightMat,
}

impl BlockWeights {
    pub fn w(&self, kind: LayerKind) -> &WeightMat {
        match kind {
            LayerKind::Q => &self.wq,
            LayerKind::K => &self.wk,
            LayerKind::V => &self.wv,
            LayerKind::O => &self.wo,
            LayerKind::Gate => &self.w_gate,
            LayerKind::Up => &self.w_up,
            LayerKind::Down => &self.w_down,
        }
    }

    pub fn w_mut(&mut self, kind: LayerKind) -> &mut WeightMat {
        match kind {
            LayerKind::Q => &mut self.wq,
            LayerKind::K => &mut self.wk,
            LayerKind::V => &mut self.wv,
            LayerKind::O => &mut self.wo,
            LayerKind::Gate => &mut self.w_gate,
            LayerKind::Up => &mut self.w_up,
            LayerKind::Down => &mut self.w_down,
        }
    }
}

/// Checkpoint tensor name for one linear layer (trainer convention).
fn weight_name(block: usize, kind: LayerKind) -> String {
    match kind {
        LayerKind::Q => Weights::attn_weight_name(block, "q"),
        LayerKind::K => Weights::attn_weight_name(block, "k"),
        LayerKind::V => Weights::attn_weight_name(block, "v"),
        LayerKind::O => Weights::attn_weight_name(block, "o"),
        LayerKind::Gate => Weights::mlp_weight_name(block, "gate"),
        LayerKind::Up => Weights::mlp_weight_name(block, "up"),
        LayerKind::Down => Weights::mlp_weight_name(block, "down"),
    }
}

/// FLOP accounting collected during forward passes (Fig 4's metric).
#[derive(Clone, Copy, Debug, Default)]
pub struct ForwardStats {
    /// Multiply-adds actually spent in block linear projections.
    pub macs_kept: u64,
    /// Multiply-adds a dense pass would have spent in the same projections.
    pub macs_dense: u64,
    /// Extra MACs from method side-paths (e.g. R-Sparse low-rank).
    pub macs_extra: u64,
    /// Tokens processed.
    pub tokens: u64,
}

impl ForwardStats {
    pub fn add(&mut self, other: &ForwardStats) {
        self.macs_kept += other.macs_kept;
        self.macs_dense += other.macs_dense;
        self.macs_extra += other.macs_extra;
        self.tokens += other.tokens;
    }

    /// Achieved density of the linear projections (1.0 = dense).
    pub fn density(&self) -> f64 {
        if self.macs_dense == 0 {
            return 1.0;
        }
        (self.macs_kept + self.macs_extra) as f64 / self.macs_dense as f64
    }

    /// FLOPs (2 * MACs) per token actually spent.
    pub fn flops_per_token(&self) -> f64 {
        if self.tokens == 0 {
            return 0.0;
        }
        2.0 * (self.macs_kept + self.macs_extra) as f64 / self.tokens as f64
    }
}

/// Reusable per-sequence scratch buffers (kept out of the hot loop's
/// allocator traffic). Together with the caller-owned logits buffer this
/// covers every vector the decode path touches: steady-state
/// `forward_token` performs zero heap allocations as long as projections
/// stay below the intra-GEMV row-split threshold (asserted by
/// `rust/tests/alloc_steady_state.rs`; the split path forks scoped
/// threads, which allocate).
pub struct Scratch {
    /// Residual stream for the token being decoded.
    resid: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    o: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    hbuf: Vec<f32>,
    down: Vec<f32>,
    scores: Vec<f32>,
    /// Residual streams for multi-token chunk passes (`[m, d_model]`, grown
    /// to the widest chunk seen and then reused).
    chunk: Vec<f32>,
}

impl Scratch {
    pub fn new(cfg: &ModelConfig) -> Self {
        let d = cfg.d_model;
        let f = cfg.ffn_dim;
        Self {
            resid: vec![0.0; d],
            normed: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn_out: vec![0.0; d],
            o: vec![0.0; d],
            gate: vec![0.0; f],
            up: vec![0.0; f],
            hbuf: vec![0.0; f],
            down: vec![0.0; d],
            scores: vec![0.0; cfg.max_seq],
            chunk: Vec::new(),
        }
    }
}

/// Which logits a chunk forward pass materializes. Prefill chunks only need
/// the final prompt token's logits (they seed decoding) — skipping the
/// `[vocab, d]` lm_head GEMV for every interior position is a large share of
/// the chunked-prefill win on small models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkLogits {
    /// Per-position logits, row-major `[m, vocab]` (speculative verify).
    PerToken,
    /// Only the last position's logits, `[vocab]` (a prompt's final chunk).
    LastOnly,
    /// No logits at all (interior prefill chunks). The buffer is untouched.
    Skip,
}

/// Mutable access to the per-sequence state a batch-fused decode step
/// needs: the tokens each sequence contributes, its KV store, its FLOP
/// stats and its logits destination. Implemented by the engine's batch
/// adapters; object-safe so the model stays ignorant of engine types.
pub trait FusedSeqAccess {
    fn n_seqs(&self) -> usize;
    /// Tokens sequence `i` contributes this step (plain decode: one;
    /// speculative verify: the draft chain). Must be non-empty.
    fn tokens(&self, i: usize) -> &[usize];
    fn want(&self, i: usize) -> ChunkLogits;
    fn cache(&mut self, i: usize) -> &mut dyn KvSeq;
    fn stats(&mut self, i: usize) -> &mut ForwardStats;
    fn logits(&mut self, i: usize) -> &mut Vec<f32>;
}

/// Reusable buffers for [`Model::forward_fused`]: stacked `[P, dim]`
/// activations for the whole batch, grown once to the widest step seen and
/// then reused (the steady-state fused decode step allocates nothing).
#[derive(Default)]
pub struct FusedScratch {
    /// Residual streams, `[P, d]` row-major.
    xs: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    o: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    hbuf: Vec<f32>,
    down: Vec<f32>,
    scores: Vec<f32>,
    /// Per-position kept-channel counts from the last fused projection.
    kept: Vec<usize>,
    /// Final-normed rows that need logits, `[R, d]`.
    head: Vec<f32>,
    head_logits: Vec<f32>,
    /// Row-range prefix: sequence `i` owns rows `row0[i]..row0[i+1]`
    /// (`n + 1` entries, last = total row count).
    row0: Vec<usize>,
    /// Absolute KV position of sequence `i`'s first row this step.
    pos0: Vec<usize>,
}

impl FusedScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, cfg: &ModelConfig, p_total: usize) {
        let d = cfg.d_model;
        let f = cfg.ffn_dim;
        let grow = |v: &mut Vec<f32>, len: usize| {
            if v.len() < len {
                v.resize(len, 0.0);
            }
        };
        for buf in [
            &mut self.xs,
            &mut self.normed,
            &mut self.q,
            &mut self.k,
            &mut self.v,
            &mut self.attn,
            &mut self.o,
            &mut self.down,
            &mut self.head,
        ] {
            grow(buf, p_total * d);
        }
        for buf in [&mut self.gate, &mut self.up, &mut self.hbuf] {
            grow(buf, p_total * f);
        }
        grow(&mut self.scores, cfg.max_seq);
        grow(&mut self.head_logits, p_total * cfg.vocab_size);
        if self.kept.len() < p_total {
            self.kept.resize(p_total, 0);
        }
    }
}

/// The model: weights in kernel layout plus precomputed per-layer column
/// norms (`g` of Eq. 4, always computed from the *deployed* representation
/// so quantized checkpoints calibrate against the weights they execute).
pub struct Model {
    pub cfg: ModelConfig,
    pub embed: Tensor,
    pub blocks: Vec<BlockWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: WeightMat,
    /// `g` vectors indexed by `LayerId::flat()`.
    pub col_norms: Vec<Vec<f32>>,
    /// Forward-path telemetry sink. The default no-op sink costs one
    /// virtual `enabled()` call per projection; install a recording sink
    /// with [`Model::set_obs_sink`] before sharing the model.
    pub obs: Arc<dyn ObsSink>,
}

impl Model {
    /// Assemble from a named-tensor store (the trainer's output, or a
    /// quantized v2 checkpoint — each weight is taken from the quantized
    /// entries when present, the f32 tensors otherwise).
    pub fn from_weights(cfg: ModelConfig, w: &Weights) -> anyhow::Result<Model> {
        let expect2 = |name: &str, m: usize, n: usize| -> anyhow::Result<WeightMat> {
            if let Some(q) = w.quants.get(name) {
                if (q.m, q.n) != (m, n) {
                    anyhow::bail!(
                        "quant tensor `{name}`: expected [{m}, {n}], got [{}, {}]",
                        q.m,
                        q.n
                    );
                }
                return Ok(WeightMat::Quant(q.clone()));
            }
            let t = w.get(name)?;
            let (tm, tn) = t.dims2();
            if (tm, tn) != (m, n) {
                anyhow::bail!("tensor `{name}`: expected [{m}, {n}], got {:?}", t.shape);
            }
            Ok(WeightMat::Dense(ColMajorMatrix::from_row_major(t)))
        };
        let expect1 = |name: &str, n: usize| -> anyhow::Result<Vec<f32>> {
            let t = w.get(name)?;
            if t.shape != vec![n] {
                anyhow::bail!("tensor `{name}`: expected [{n}], got {:?}", t.shape);
            }
            Ok(t.data.clone())
        };
        let d = cfg.d_model;
        let f = cfg.ffn_dim;
        let embed = w.get("embed.weight")?.clone();
        if embed.shape != vec![cfg.vocab_size, d] {
            anyhow::bail!("embed.weight shape {:?}", embed.shape);
        }
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for b in 0..cfg.n_layers {
            blocks.push(BlockWeights {
                attn_norm: expect1(&format!("blocks.{b}.attn_norm.weight"), d)?,
                wq: expect2(&Weights::attn_weight_name(b, "q"), d, d)?,
                wk: expect2(&Weights::attn_weight_name(b, "k"), d, d)?,
                wv: expect2(&Weights::attn_weight_name(b, "v"), d, d)?,
                wo: expect2(&Weights::attn_weight_name(b, "o"), d, d)?,
                mlp_norm: expect1(&format!("blocks.{b}.mlp_norm.weight"), d)?,
                w_gate: expect2(&Weights::mlp_weight_name(b, "gate"), f, d)?,
                w_up: expect2(&Weights::mlp_weight_name(b, "up"), f, d)?,
                w_down: expect2(&Weights::mlp_weight_name(b, "down"), d, f)?,
            });
        }
        let final_norm = expect1("final_norm.weight", d)?;
        let lm_head = expect2("lm_head.weight", cfg.vocab_size, d)?;
        let col_norms = Self::compute_col_norms(&cfg, &blocks);
        Ok(Model {
            cfg,
            embed,
            blocks,
            final_norm,
            lm_head,
            col_norms,
            obs: Arc::new(NoopSink),
        })
    }

    /// Install a telemetry sink (e.g. [`crate::obs::BlockObs`]). Call before
    /// the model is shared; the engine reads it lock-free on every
    /// projection.
    pub fn set_obs_sink(&mut self, sink: Arc<dyn ObsSink>) {
        self.obs = sink;
    }

    fn compute_col_norms(cfg: &ModelConfig, blocks: &[BlockWeights]) -> Vec<Vec<f32>> {
        let mut col_norms = Vec::with_capacity(cfg.n_layers * 7);
        for block in blocks {
            for &kind in &LayerKind::ALL {
                col_norms.push(block.w(kind).col_l2_norms());
            }
        }
        col_norms
    }

    /// Load `config.json` + `weights.bin` from a model directory.
    pub fn load_dir(dir: &Path) -> anyhow::Result<Model> {
        let cfg = ModelConfig::load(&dir.join("config.json"))?;
        let w = Weights::load(&dir.join("weights.bin"))?;
        Self::from_weights(cfg, &w)
    }

    pub fn w(&self, id: LayerId) -> &WeightMat {
        self.blocks[id.block].w(id.kind)
    }

    /// Precomputed `g_i = ||W[:,i]||_2` for a layer.
    pub fn g(&self, id: LayerId) -> &[f32] {
        &self.col_norms[id.flat()]
    }

    /// Group-quantize every linear projection (the seven per block plus the
    /// lm_head) in place, then recompute the `g` norms from the quantized
    /// groups so downstream calibration and tau selection match the weights
    /// the kernels will actually multiply. Embeddings and norm vectors stay
    /// f32. Idempotent on already-quantized weights.
    pub fn quantize(&mut self, mode: QuantMode, group: usize) {
        for block in self.blocks.iter_mut() {
            for &kind in &LayerKind::ALL {
                let w = block.w_mut(kind);
                let q = w.quantized(mode, group);
                *w = q;
            }
        }
        self.lm_head = self.lm_head.quantized(mode, group);
        self.col_norms = Self::compute_col_norms(&self.cfg, &self.blocks);
    }

    /// Representation label of the deployed weights: `f32`, `int8`, `int4`.
    pub fn weight_repr_name(&self) -> &'static str {
        self.lm_head.repr_name()
    }

    /// Bytes of weight memory actually resident (embeddings and norms are
    /// always f32; projections and lm_head follow their representation).
    pub fn weight_bytes_resident(&self) -> usize {
        let mut bytes = (self.embed.numel() + self.final_norm.len()) * 4;
        for block in &self.blocks {
            bytes += (block.attn_norm.len() + block.mlp_norm.len()) * 4;
            for &kind in &LayerKind::ALL {
                bytes += block.w(kind).resident_bytes();
            }
        }
        bytes + self.lm_head.resident_bytes()
    }

    /// Bytes the same model occupies with dense-f32 weights (the
    /// compression-ratio denominator).
    pub fn weight_bytes_dense(&self) -> usize {
        let mut bytes = (self.embed.numel() + self.final_norm.len()) * 4;
        for block in &self.blocks {
            bytes += (block.attn_norm.len() + block.mlp_norm.len()) * 4;
            for &kind in &LayerKind::ALL {
                bytes += block.w(kind).dense_equiv_bytes();
            }
        }
        bytes + self.lm_head.dense_equiv_bytes()
    }

    /// Serialize back to the checkpoint container: dense layers as f32
    /// tensors (a byte-identical v1 file when nothing is quantized),
    /// quantized layers as v2 quant entries with a manifest describing the
    /// deployed representation.
    pub fn export_weights(&self) -> Weights {
        let mut w = Weights::default();
        let d = self.cfg.d_model;
        w.insert("embed.weight", self.embed.clone());
        // Advisory manifest info: models quantized via `Model::quantize`
        // are uniform, so the first quant layer describes them all.
        let mut quant_info: Option<(QuantMode, usize)> = None;
        let mut put = |w: &mut Weights, name: &str, mat: &WeightMat| match mat {
            WeightMat::Dense(dm) => w.insert(name, dm.to_row_major()),
            WeightMat::Quant(q) => {
                quant_info.get_or_insert((q.mode, q.group));
                w.insert_quant(name, q.clone());
            }
        };
        for (b, block) in self.blocks.iter().enumerate() {
            w.insert(
                &format!("blocks.{b}.attn_norm.weight"),
                Tensor::from_vec(&[d], block.attn_norm.clone()),
            );
            w.insert(
                &format!("blocks.{b}.mlp_norm.weight"),
                Tensor::from_vec(&[d], block.mlp_norm.clone()),
            );
            for &kind in &LayerKind::ALL {
                put(&mut w, &weight_name(b, kind), block.w(kind));
            }
        }
        w.insert(
            "final_norm.weight",
            Tensor::from_vec(&[d], self.final_norm.clone()),
        );
        put(&mut w, "lm_head.weight", &self.lm_head);
        if let Some((mode, group)) = quant_info {
            w.version = 2;
            w.manifest = crate::util::json::Json::obj(vec![
                ("format", crate::util::json::Json::Str("quant".into())),
                (
                    "mode",
                    crate::util::json::Json::Str(mode.name().to_string()),
                ),
                ("group", crate::util::json::Json::Num(group as f64)),
                (
                    "source",
                    crate::util::json::Json::Str(self.cfg.name.clone()),
                ),
            ])
            .to_string_compact();
        }
        w
    }

    /// Run one token through one block in place. `x` is the residual stream.
    /// `cache_layer` is the KV store's layer index (== `b` except for the
    /// single-layer cache `block_forward_seq` uses). The KV store may be the
    /// flat slab or a paged page table — attention visits rows through
    /// [`KvSeq::with_k`]/[`KvSeq::with_v`] in ascending position order, so
    /// both produce bit-identical outputs.
    #[allow(clippy::too_many_arguments)]
    fn block_step(
        &self,
        b: usize,
        cache_layer: usize,
        x: &mut [f32],
        pos: usize,
        cache: &mut dyn KvSeq,
        sp: &dyn Sparsifier,
        scratch: &mut Scratch,
        stats: &mut ForwardStats,
    ) {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let block = &self.blocks[b];
        // One virtual call when the no-op sink is installed; the timing
        // branch only exists for recording sinks, so the hot path stays
        // allocation- and syscall-free (pinned by the kernel bench A/B and
        // the obs differential test).
        let obs = &*self.obs;
        let obs_on = obs.enabled();
        let proj = |kind: LayerKind,
                        input: &[f32],
                        out: &mut [f32],
                        stats: &mut ForwardStats| {
            let id = LayerId::new(b, kind);
            let w = block.w(kind);
            let kept = if obs_on {
                let t0 = std::time::Instant::now();
                let kept = sp.project(id, input, w, out);
                obs.record_proj(
                    id,
                    kept,
                    w.in_dim(),
                    w.resident_bytes(),
                    t0.elapsed().as_nanos() as u64,
                );
                kept
            } else {
                sp.project(id, input, w, out)
            };
            stats.macs_kept += (kept * w.out_dim()) as u64;
            stats.macs_dense += (w.in_dim() * w.out_dim()) as u64;
            stats.macs_extra += sp.extra_macs(id, w);
        };

        // --- attention ---
        rmsnorm(x, &block.attn_norm, cfg.rmsnorm_eps, &mut scratch.normed);
        proj(LayerKind::Q, &scratch.normed, &mut scratch.q, stats);
        proj(LayerKind::K, &scratch.normed, &mut scratch.k, stats);
        proj(LayerKind::V, &scratch.normed, &mut scratch.v, stats);
        for h in 0..cfg.n_heads {
            rope_inplace(&mut scratch.q[h * hd..(h + 1) * hd], pos, cfg.rope_base);
            rope_inplace(&mut scratch.k[h * hd..(h + 1) * hd], pos, cfg.rope_base);
        }
        cache.store(cache_layer, pos, &scratch.k, &scratch.v);
        let scale = 1.0 / (hd as f32).sqrt();
        for h in 0..cfg.n_heads {
            let qh = &scratch.q[h * hd..(h + 1) * hd];
            let scores = &mut scratch.scores[..=pos];
            cache.with_k(cache_layer, pos + 1, &mut |start, rows| {
                for (r, kr) in rows.chunks_exact(d).enumerate() {
                    let kh = &kr[h * hd..(h + 1) * hd];
                    let mut acc = 0.0f32;
                    for i in 0..hd {
                        acc += qh[i] * kh[i];
                    }
                    scores[start + r] = acc * scale;
                }
            });
            softmax_inplace(scores);
            let out_h = &mut scratch.attn_out[h * hd..(h + 1) * hd];
            out_h.fill(0.0);
            let scores: &[f32] = scores;
            cache.with_v(cache_layer, pos + 1, &mut |start, rows| {
                for (r, vr) in rows.chunks_exact(d).enumerate() {
                    let sc = scores[start + r];
                    let vh = &vr[h * hd..(h + 1) * hd];
                    for i in 0..hd {
                        out_h[i] += sc * vh[i];
                    }
                }
            });
        }
        proj(LayerKind::O, &scratch.attn_out, &mut scratch.o, stats);
        for i in 0..d {
            x[i] += scratch.o[i];
        }

        // --- MLP (SwiGLU) ---
        rmsnorm(x, &block.mlp_norm, cfg.rmsnorm_eps, &mut scratch.normed);
        proj(LayerKind::Gate, &scratch.normed, &mut scratch.gate, stats);
        proj(LayerKind::Up, &scratch.normed, &mut scratch.up, stats);
        for i in 0..cfg.ffn_dim {
            scratch.hbuf[i] = silu(scratch.gate[i]) * scratch.up[i];
        }
        proj(LayerKind::Down, &scratch.hbuf, &mut scratch.down, stats);
        for i in 0..d {
            x[i] += scratch.down[i];
        }
    }

    /// Decode one token, writing the next position's logits into `logits`
    /// (resized on first use, then reused — the steady state allocates
    /// nothing). `cache.seq_len()` is the current position; it is advanced.
    /// The caller must have reserved room (serving does, via the KV
    /// manager's evict-then-preempt path); the internal reserve here is the
    /// flat-cache path plus a backstop assert for paged stores.
    pub fn forward_token(
        &self,
        token: usize,
        cache: &mut dyn KvSeq,
        sp: &dyn Sparsifier,
        scratch: &mut Scratch,
        stats: &mut ForwardStats,
        logits: &mut Vec<f32>,
    ) {
        assert!(token < self.cfg.vocab_size, "token {token} out of vocab");
        let pos = cache.seq_len();
        assert!(
            cache.try_reserve(),
            "KV reserve failed at pos {pos} (capacity {})",
            cache.capacity()
        );
        // The residual stream lives in scratch; it is taken out for the
        // duration of the block loop so `scratch`'s other buffers stay
        // borrowable, and put back afterwards.
        let mut x = std::mem::take(&mut scratch.resid);
        x.copy_from_slice(self.embed.row(token));
        for b in 0..self.cfg.n_layers {
            self.block_step(b, b, &mut x, pos, cache, sp, scratch, stats);
        }
        cache.advance();
        stats.tokens += 1;
        rmsnorm(&x, &self.final_norm, self.cfg.rmsnorm_eps, &mut scratch.normed);
        scratch.resid = x;
        logits.resize(self.cfg.vocab_size, 0.0);
        self.lm_head
            .gemv_dense(&scratch.normed, logits, intra_op_threads());
    }

    /// Shadow-dense replay of the most recently committed decode step, for
    /// the online quality monitor: re-run `token` (the token whose forward
    /// produced position `seq_len() - 1`) with every projection dense,
    /// against the *same* KV history the served step saw, writing the dense
    /// logits into `logits` — without mutating the cache, the sequence's
    /// RNG, its stats, or anything else the served path reads.
    ///
    /// Non-perturbation is structural: the cache is taken by `&dyn KvSeq`
    /// (shared reference — `store`/`advance`/`truncate` are uncallable),
    /// and every `Scratch` buffer is fully overwritten by the next served
    /// forward, so reusing the sequence's scratch here cannot leak state
    /// (pinned bit-for-bit by `rust/tests/quality_shadow.rs`).
    ///
    /// The committed rows `[0, pos)` hold the *served* (sparse-path) K/V —
    /// exactly what the served step attended over. The cache row at `pos`
    /// holds the served step's own sparse K/V and must not be read: the
    /// shadow's dense K/V for `pos` stays in scratch and is folded into the
    /// scores and weighted-V sums manually.
    ///
    /// When a recording [`ObsSink`] is installed, each projection also runs
    /// the served sparsifier on the shadow's input and records the
    /// per-(block, projection) output-L2 reconstruction error via
    /// [`ObsSink::record_shadow`] — `record_proj` is deliberately not
    /// called, so density/bandwidth telemetry stays pure production
    /// traffic.
    pub fn forward_shadow(
        &self,
        token: usize,
        cache: &dyn KvSeq,
        sparse_sp: &dyn Sparsifier,
        scratch: &mut Scratch,
        recon_tmp: &mut Vec<f32>,
        logits: &mut Vec<f32>,
    ) {
        assert!(token < self.cfg.vocab_size, "token {token} out of vocab");
        assert!(cache.seq_len() >= 1, "no committed step to shadow");
        let pos = cache.seq_len() - 1;
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let hd = cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();
        let obs = &*self.obs;
        let obs_on = obs.enabled();
        recon_tmp.resize(d.max(cfg.ffn_dim), 0.0);

        let mut x = std::mem::take(&mut scratch.resid);
        x.copy_from_slice(self.embed.row(token));
        for b in 0..cfg.n_layers {
            let block = &self.blocks[b];
            let proj = |kind: LayerKind, input: &[f32], out: &mut [f32], tmp: &mut [f32]| {
                let w = block.w(kind);
                w.gemv_dense(input, out, intra_op_threads());
                if obs_on {
                    let id = LayerId::new(b, kind);
                    let tmp = &mut tmp[..out.len()];
                    sparse_sp.project(id, input, w, tmp);
                    let (mut err_sq, mut ref_sq) = (0.0f64, 0.0f64);
                    for (dv, sv) in out.iter().zip(tmp.iter()) {
                        let e = (*dv - *sv) as f64;
                        err_sq += e * e;
                        ref_sq += *dv as f64 * *dv as f64;
                    }
                    obs.record_shadow(id, err_sq, ref_sq);
                }
            };

            // --- attention (dense replay of `block_step`) ---
            rmsnorm(&x, &block.attn_norm, cfg.rmsnorm_eps, &mut scratch.normed);
            proj(LayerKind::Q, &scratch.normed, &mut scratch.q, recon_tmp);
            proj(LayerKind::K, &scratch.normed, &mut scratch.k, recon_tmp);
            proj(LayerKind::V, &scratch.normed, &mut scratch.v, recon_tmp);
            for h in 0..cfg.n_heads {
                rope_inplace(&mut scratch.q[h * hd..(h + 1) * hd], pos, cfg.rope_base);
                rope_inplace(&mut scratch.k[h * hd..(h + 1) * hd], pos, cfg.rope_base);
            }
            for h in 0..cfg.n_heads {
                let qh = &scratch.q[h * hd..(h + 1) * hd];
                let scores = &mut scratch.scores[..=pos];
                // Committed history only: `[0, pos)` through the cache, the
                // shadow's own row folded in from scratch.
                cache.with_k(b, pos, &mut |start, rows| {
                    for (r, kr) in rows.chunks_exact(d).enumerate() {
                        let kh = &kr[h * hd..(h + 1) * hd];
                        let mut acc = 0.0f32;
                        for i in 0..hd {
                            acc += qh[i] * kh[i];
                        }
                        scores[start + r] = acc * scale;
                    }
                });
                let kh = &scratch.k[h * hd..(h + 1) * hd];
                let mut acc = 0.0f32;
                for i in 0..hd {
                    acc += qh[i] * kh[i];
                }
                scores[pos] = acc * scale;
                softmax_inplace(scores);
                let out_h = &mut scratch.attn_out[h * hd..(h + 1) * hd];
                out_h.fill(0.0);
                let scores: &[f32] = scores;
                cache.with_v(b, pos, &mut |start, rows| {
                    for (r, vr) in rows.chunks_exact(d).enumerate() {
                        let sc = scores[start + r];
                        let vh = &vr[h * hd..(h + 1) * hd];
                        for i in 0..hd {
                            out_h[i] += sc * vh[i];
                        }
                    }
                });
                let sc = scores[pos];
                let vh = &scratch.v[h * hd..(h + 1) * hd];
                for i in 0..hd {
                    out_h[i] += sc * vh[i];
                }
            }
            proj(LayerKind::O, &scratch.attn_out, &mut scratch.o, recon_tmp);
            for i in 0..d {
                x[i] += scratch.o[i];
            }

            // --- MLP (SwiGLU) ---
            rmsnorm(&x, &block.mlp_norm, cfg.rmsnorm_eps, &mut scratch.normed);
            proj(LayerKind::Gate, &scratch.normed, &mut scratch.gate, recon_tmp);
            proj(LayerKind::Up, &scratch.normed, &mut scratch.up, recon_tmp);
            for i in 0..cfg.ffn_dim {
                scratch.hbuf[i] = silu(scratch.gate[i]) * scratch.up[i];
            }
            proj(LayerKind::Down, &scratch.hbuf, &mut scratch.down, recon_tmp);
            for i in 0..d {
                x[i] += scratch.down[i];
            }
        }
        rmsnorm(&x, &self.final_norm, cfg.rmsnorm_eps, &mut scratch.normed);
        scratch.resid = x;
        logits.resize(cfg.vocab_size, 0.0);
        self.lm_head
            .gemv_dense(&scratch.normed, logits, intra_op_threads());
    }

    /// Decode a chunk of `m` already-known tokens in one layer-major pass,
    /// writing per-position logits into `logits` (`[m, vocab]`, row-major,
    /// resized on first use). This is the speculative-decode verify pass:
    /// the draft chain's tokens are all known up front, so instead of
    /// streaming every layer's weights once per token (token-major
    /// [`Model::forward_token`]), the block loop is interchanged — each
    /// layer's weights are visited once per *chunk* and stay cache-hot
    /// across the `m` tokens, which is where the verify pass beats `m`
    /// sequential decode steps on memory-bound models.
    ///
    /// Per-token arithmetic is exactly [`Model::forward_token`]'s — same
    /// ops in the same order, with causal attention inside the chunk
    /// reading K/V rows stored earlier in the same layer iteration — so the
    /// resulting logits are bit-identical to `m` sequential calls. The
    /// differential-equivalence suite (`rust/tests/spec_decode.rs`) pins
    /// this down; it is what makes greedy speculative decoding
    /// token-identical to the baseline.
    ///
    /// The caller must have made room for all `m` positions (see
    /// `reserve_ahead` on the KV manager); reservation failure here panics
    /// like [`Model::forward_token`]'s.
    pub fn forward_chunk(
        &self,
        tokens: &[usize],
        cache: &mut dyn KvSeq,
        sp: &dyn Sparsifier,
        scratch: &mut Scratch,
        stats: &mut ForwardStats,
        logits: &mut Vec<f32>,
    ) {
        self.forward_chunk_mixed(
            tokens,
            cache,
            sp,
            sp,
            0,
            ChunkLogits::PerToken,
            scratch,
            stats,
            logits,
        );
    }

    /// [`Model::forward_chunk`] with a per-position sparsifier split and a
    /// logits policy — the chunked-prefill workhorse. Positions strictly
    /// below the *absolute* position `sparse_from` run through `dense_sp`,
    /// positions at or beyond it through `sparse_sp`, so the paper's
    /// `prefill_sparse_fraction` dense→sparse boundary may fall anywhere
    /// inside the chunk (a chunk wholly on one side simply never consults
    /// the other sparsifier). Per-token arithmetic remains exactly
    /// [`Model::forward_token`]'s under the same per-position sparsifier
    /// choice, so chunked prefill is bit-identical to the token-by-token
    /// schedule; `want` controls which lm_head projections run at all.
    #[allow(clippy::too_many_arguments)]
    pub fn forward_chunk_mixed(
        &self,
        tokens: &[usize],
        cache: &mut dyn KvSeq,
        dense_sp: &dyn Sparsifier,
        sparse_sp: &dyn Sparsifier,
        sparse_from: usize,
        want: ChunkLogits,
        scratch: &mut Scratch,
        stats: &mut ForwardStats,
        logits: &mut Vec<f32>,
    ) {
        let m = tokens.len();
        assert!(m > 0, "empty chunk");
        let d = self.cfg.d_model;
        let vocab = self.cfg.vocab_size;
        let pos0 = cache.seq_len();
        for (j, &t) in tokens.iter().enumerate() {
            assert!(t < vocab, "token {t} out of vocab");
            assert!(
                cache.try_reserve(),
                "KV reserve failed at pos {} (capacity {})",
                pos0 + j,
                cache.capacity()
            );
            cache.advance();
        }
        let mut xs = std::mem::take(&mut scratch.chunk);
        xs.resize(m * d, 0.0);
        for (j, &t) in tokens.iter().enumerate() {
            xs[j * d..(j + 1) * d].copy_from_slice(self.embed.row(t));
        }
        for b in 0..self.cfg.n_layers {
            for j in 0..m {
                let sp = if pos0 + j < sparse_from {
                    dense_sp
                } else {
                    sparse_sp
                };
                let x = &mut xs[j * d..(j + 1) * d];
                self.block_step(b, b, x, pos0 + j, cache, sp, scratch, stats);
            }
        }
        stats.tokens += m as u64;
        match want {
            ChunkLogits::PerToken => {
                logits.resize(m * vocab, 0.0);
                for j in 0..m {
                    rmsnorm(
                        &xs[j * d..(j + 1) * d],
                        &self.final_norm,
                        self.cfg.rmsnorm_eps,
                        &mut scratch.normed,
                    );
                    self.lm_head.gemv_dense(
                        &scratch.normed,
                        &mut logits[j * vocab..(j + 1) * vocab],
                        intra_op_threads(),
                    );
                }
            }
            ChunkLogits::LastOnly => {
                logits.resize(vocab, 0.0);
                rmsnorm(
                    &xs[(m - 1) * d..m * d],
                    &self.final_norm,
                    self.cfg.rmsnorm_eps,
                    &mut scratch.normed,
                );
                self.lm_head
                    .gemv_dense(&scratch.normed, &mut logits[..], intra_op_threads());
            }
            ChunkLogits::Skip => {}
        }
        scratch.chunk = xs;
    }

    /// One batch-fused projection: a single weight walk covering every row
    /// of the step, with per-sequence FLOP attribution and one telemetry
    /// record for the whole call (weight bytes charged once, not per row).
    #[allow(clippy::too_many_arguments)]
    fn fused_proj(
        &self,
        b: usize,
        kind: LayerKind,
        sp: &dyn Sparsifier,
        ins: &[f32],
        outs: &mut [f32],
        kept: &mut [usize],
        row0: &[usize],
        batch: &mut dyn FusedSeqAccess,
    ) {
        let id = LayerId::new(b, kind);
        let w = self.blocks[b].w(kind);
        let n_pos = *row0.last().expect("row0 has a sentinel");
        let (ind, outd) = (w.in_dim(), w.out_dim());
        let obs = &*self.obs;
        if obs.enabled() {
            let t0 = std::time::Instant::now();
            let streamed = sp.project_batch(id, ins, ind, w, outs, outd, n_pos, kept);
            obs.record_proj_batch(
                id,
                n_pos,
                kept[..n_pos].iter().sum(),
                streamed,
                ind,
                w.resident_bytes(),
                t0.elapsed().as_nanos() as u64,
            );
        } else {
            sp.project_batch(id, ins, ind, w, outs, outd, n_pos, kept);
        }
        let extra = sp.extra_macs(id, w);
        for i in 0..row0.len() - 1 {
            let (r0, r1) = (row0[i], row0[i + 1]);
            let ksum: usize = kept[r0..r1].iter().sum();
            let st = batch.stats(i);
            st.macs_kept += (ksum * outd) as u64;
            st.macs_dense += ((r1 - r0) * ind * outd) as u64;
            st.macs_extra += (r1 - r0) as u64 * extra;
        }
    }

    /// Batch-fused decode step: every sequence's pending tokens run through
    /// the model together, with each linear projection streaming its weight
    /// columns **once** for the whole batch (the union of the batch's
    /// dynamic masks) instead of once per sequence.
    ///
    /// Per-row arithmetic is exactly [`Model::forward_token`]'s /
    /// [`Model::forward_chunk`]'s — same ops in the same order per row, with
    /// each sequence's rows visited in ascending position order against its
    /// own KV store — so every sequence's logits are bit-identical to
    /// running it alone (pinned by `rust/tests/fused_batch.rs` across KV
    /// layouts, weight representations and batch sizes).
    pub fn forward_fused(
        &self,
        batch: &mut dyn FusedSeqAccess,
        sp: &dyn Sparsifier,
        scratch: &mut FusedScratch,
    ) {
        let n = batch.n_seqs();
        assert!(n > 0, "empty fused batch");
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let f = cfg.ffn_dim;
        let hd = cfg.head_dim();
        let vocab = cfg.vocab_size;
        // Row layout: sequence i owns rows row0[i]..row0[i+1].
        scratch.row0.clear();
        scratch.pos0.clear();
        let mut p_total = 0usize;
        for i in 0..n {
            scratch.row0.push(p_total);
            let m = batch.tokens(i).len();
            assert!(m > 0, "fused member {i} contributes no tokens");
            p_total += m;
        }
        scratch.row0.push(p_total);
        // Reserve + advance every position up front, exactly as
        // `forward_chunk_mixed` does per sequence.
        for i in 0..n {
            let m = batch.tokens(i).len();
            let mut first = 0usize;
            for j in 0..m {
                let t = batch.tokens(i)[j];
                assert!(t < vocab, "token {t} out of vocab");
                let cache = batch.cache(i);
                if j == 0 {
                    first = cache.seq_len();
                }
                assert!(
                    cache.try_reserve(),
                    "KV reserve failed at pos {} (capacity {})",
                    cache.seq_len(),
                    cache.capacity()
                );
                cache.advance();
            }
            scratch.pos0.push(first);
        }
        scratch.ensure(cfg, p_total);
        let FusedScratch {
            xs,
            normed,
            q,
            k,
            v,
            attn,
            o,
            gate,
            up,
            hbuf,
            down,
            scores,
            kept,
            head,
            head_logits,
            row0,
            pos0,
        } = scratch;
        for i in 0..n {
            let toks = batch.tokens(i);
            for (j, &t) in toks.iter().enumerate() {
                let p = row0[i] + j;
                xs[p * d..(p + 1) * d].copy_from_slice(self.embed.row(t));
            }
        }
        let scale = 1.0 / (hd as f32).sqrt();
        for b in 0..cfg.n_layers {
            let block = &self.blocks[b];
            // --- attention ---
            for p in 0..p_total {
                rmsnorm(
                    &xs[p * d..(p + 1) * d],
                    &block.attn_norm,
                    cfg.rmsnorm_eps,
                    &mut normed[p * d..(p + 1) * d],
                );
            }
            self.fused_proj(b, LayerKind::Q, sp, normed, q, kept, row0, batch);
            self.fused_proj(b, LayerKind::K, sp, normed, k, kept, row0, batch);
            self.fused_proj(b, LayerKind::V, sp, normed, v, kept, row0, batch);
            for i in 0..n {
                let m = row0[i + 1] - row0[i];
                let cache = batch.cache(i);
                for j in 0..m {
                    let p = row0[i] + j;
                    let pos = pos0[i] + j;
                    for h in 0..cfg.n_heads {
                        rope_inplace(
                            &mut q[p * d + h * hd..p * d + (h + 1) * hd],
                            pos,
                            cfg.rope_base,
                        );
                        rope_inplace(
                            &mut k[p * d + h * hd..p * d + (h + 1) * hd],
                            pos,
                            cfg.rope_base,
                        );
                    }
                    cache.store(b, pos, &k[p * d..(p + 1) * d], &v[p * d..(p + 1) * d]);
                    for h in 0..cfg.n_heads {
                        let qh = &q[p * d + h * hd..p * d + (h + 1) * hd];
                        let sc = &mut scores[..=pos];
                        cache.with_k(b, pos + 1, &mut |start, rows| {
                            for (r, kr) in rows.chunks_exact(d).enumerate() {
                                let kh = &kr[h * hd..(h + 1) * hd];
                                let mut acc = 0.0f32;
                                for t in 0..hd {
                                    acc += qh[t] * kh[t];
                                }
                                sc[start + r] = acc * scale;
                            }
                        });
                        softmax_inplace(sc);
                        let out_h = &mut attn[p * d + h * hd..p * d + (h + 1) * hd];
                        out_h.fill(0.0);
                        let sc: &[f32] = sc;
                        cache.with_v(b, pos + 1, &mut |start, rows| {
                            for (r, vr) in rows.chunks_exact(d).enumerate() {
                                let s = sc[start + r];
                                let vh = &vr[h * hd..(h + 1) * hd];
                                for t in 0..hd {
                                    out_h[t] += s * vh[t];
                                }
                            }
                        });
                    }
                }
            }
            self.fused_proj(b, LayerKind::O, sp, attn, o, kept, row0, batch);
            for p in 0..p_total {
                for t in 0..d {
                    xs[p * d + t] += o[p * d + t];
                }
            }
            // --- MLP (SwiGLU) ---
            for p in 0..p_total {
                rmsnorm(
                    &xs[p * d..(p + 1) * d],
                    &block.mlp_norm,
                    cfg.rmsnorm_eps,
                    &mut normed[p * d..(p + 1) * d],
                );
            }
            self.fused_proj(b, LayerKind::Gate, sp, normed, gate, kept, row0, batch);
            self.fused_proj(b, LayerKind::Up, sp, normed, up, kept, row0, batch);
            for p in 0..p_total {
                for t in 0..f {
                    hbuf[p * f + t] = silu(gate[p * f + t]) * up[p * f + t];
                }
            }
            self.fused_proj(b, LayerKind::Down, sp, hbuf, down, kept, row0, batch);
            for p in 0..p_total {
                for t in 0..d {
                    xs[p * d + t] += down[p * d + t];
                }
            }
        }
        for i in 0..n {
            let m = (row0[i + 1] - row0[i]) as u64;
            batch.stats(i).tokens += m;
        }
        // Gather the rows that need logits, final-norm them, run one fused
        // lm_head pass, then scatter rows back to each sequence's buffer
        // (copies preserve bits).
        let mut nrows = 0usize;
        for i in 0..n {
            let (r0, r1) = (row0[i], row0[i + 1]);
            let sel = match batch.want(i) {
                ChunkLogits::PerToken => r0..r1,
                ChunkLogits::LastOnly => (r1 - 1)..r1,
                ChunkLogits::Skip => r0..r0,
            };
            for p in sel {
                rmsnorm(
                    &xs[p * d..(p + 1) * d],
                    &self.final_norm,
                    cfg.rmsnorm_eps,
                    &mut head[nrows * d..(nrows + 1) * d],
                );
                nrows += 1;
            }
        }
        if nrows > 0 {
            self.lm_head.gemv_dense_batch(
                &head[..nrows * d],
                d,
                &mut head_logits[..nrows * vocab],
                vocab,
                nrows,
                intra_op_threads(),
            );
        }
        let mut r = 0usize;
        for i in 0..n {
            let m = row0[i + 1] - row0[i];
            match batch.want(i) {
                ChunkLogits::PerToken => {
                    let lg = batch.logits(i);
                    lg.resize(m * vocab, 0.0);
                    lg.copy_from_slice(&head_logits[r * vocab..(r + m) * vocab]);
                    r += m;
                }
                ChunkLogits::LastOnly => {
                    let lg = batch.logits(i);
                    lg.resize(vocab, 0.0);
                    lg.copy_from_slice(&head_logits[r * vocab..(r + 1) * vocab]);
                    r += 1;
                }
                ChunkLogits::Skip => {}
            }
        }
    }

    /// Full-sequence forward. Returns `[T, vocab]` logits. If `block_taps`
    /// is provided it receives, per block, the `[T, d]` inputs to that block
    /// (the calibration capture for Alg. 2-4).
    pub fn forward_seq(
        &self,
        tokens: &[usize],
        sp: &dyn Sparsifier,
        stats: &mut ForwardStats,
        mut block_taps: Option<&mut Vec<Tensor>>,
    ) -> Tensor {
        assert!(!tokens.is_empty());
        assert!(tokens.len() <= self.cfg.max_seq, "sequence too long");
        let d = self.cfg.d_model;
        if let Some(taps) = block_taps.as_deref_mut() {
            taps.clear();
            for _ in 0..self.cfg.n_layers {
                taps.push(Tensor::zeros(&[tokens.len(), d]));
            }
        }
        let mut cache = KvCache::new(&self.cfg);
        let mut scratch = Scratch::new(&self.cfg);
        let mut logits = Tensor::zeros(&[tokens.len(), self.cfg.vocab_size]);
        let mut x = std::mem::take(&mut scratch.resid);
        for (t, &tok) in tokens.iter().enumerate() {
            let pos = cache.len;
            x.copy_from_slice(self.embed.row(tok));
            for b in 0..self.cfg.n_layers {
                if let Some(taps) = block_taps.as_deref_mut() {
                    taps[b].row_mut(t).copy_from_slice(&x);
                }
                self.block_step(b, b, &mut x, pos, &mut cache, sp, &mut scratch, stats);
            }
            cache.len = pos + 1;
            stats.tokens += 1;
            rmsnorm(&x, &self.final_norm, self.cfg.rmsnorm_eps, &mut scratch.normed);
            self.lm_head
                .gemv_dense(&scratch.normed, logits.row_mut(t), intra_op_threads());
        }
        scratch.resid = x;
        logits
    }

    /// Run captured block inputs `xs: [T, d]` through block `b` alone
    /// (fresh local KV cache), returning the block outputs `[T, d]`.
    /// This is `F_B(x_B)` / `F_B^sparse(x_B; alpha, tau)` from Eq. 6.
    pub fn block_forward_seq(
        &self,
        b: usize,
        xs: &Tensor,
        sp: &dyn Sparsifier,
        stats: &mut ForwardStats,
    ) -> Tensor {
        let (t_len, d) = xs.dims2();
        assert_eq!(d, self.cfg.d_model);
        let mut cache = KvCache::single_block(&self.cfg);
        let mut scratch = Scratch::new(&self.cfg);
        let mut out = Tensor::zeros(&[t_len, d]);
        for t in 0..t_len {
            let mut x = xs.row(t).to_vec();
            self.block_step(b, 0, &mut x, t, &mut cache, sp, &mut scratch, stats);
            out.row_mut(t).copy_from_slice(&x);
        }
        out
    }

    /// Greedy-decode `n_new` tokens after a prompt. Returns generated ids.
    pub fn generate_greedy(
        &self,
        prompt: &[usize],
        n_new: usize,
        sp: &dyn Sparsifier,
        stats: &mut ForwardStats,
    ) -> Vec<usize> {
        let mut cache = KvCache::new(&self.cfg);
        let mut scratch = Scratch::new(&self.cfg);
        let mut logits: Vec<f32> = Vec::new();
        for &t in prompt {
            self.forward_token(t, &mut cache, sp, &mut scratch, stats, &mut logits);
        }
        let mut out = Vec::with_capacity(n_new);
        for _ in 0..n_new {
            if cache.is_full() {
                break;
            }
            let next = crate::tensor::ops::argmax(&logits);
            out.push(next);
            self.forward_token(next, &mut cache, sp, &mut scratch, stats, &mut logits);
        }
        out
    }

    /// Synthetic randomly-initialized model (tests only; real weights come
    /// from the Python trainer).
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> Model {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(seed);
        let d = cfg.d_model;
        let f = cfg.ffn_dim;
        let std = 0.7 / (d as f32).sqrt();
        let mut w = Weights::default();
        w.insert("embed.weight", Tensor::randn(&[cfg.vocab_size, d], 0.02, &mut rng));
        for b in 0..cfg.n_layers {
            w.insert(
                &format!("blocks.{b}.attn_norm.weight"),
                Tensor::full(&[d], 1.0),
            );
            for which in ["q", "k", "v", "o"] {
                w.insert(
                    &Weights::attn_weight_name(b, which),
                    Tensor::randn(&[d, d], std, &mut rng),
                );
            }
            w.insert(
                &format!("blocks.{b}.mlp_norm.weight"),
                Tensor::full(&[d], 1.0),
            );
            w.insert(
                &Weights::mlp_weight_name(b, "gate"),
                Tensor::randn(&[f, d], std, &mut rng),
            );
            w.insert(
                &Weights::mlp_weight_name(b, "up"),
                Tensor::randn(&[f, d], std, &mut rng),
            );
            w.insert(
                &Weights::mlp_weight_name(b, "down"),
                Tensor::randn(&[d, f], std, &mut rng),
            );
        }
        w.insert("final_norm.weight", Tensor::full(&[d], 1.0));
        w.insert("lm_head.weight", Tensor::randn(&[cfg.vocab_size, d], 0.02, &mut rng));
        Model::from_weights(cfg, &w).expect("synthetic weights are well-formed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::Dense;

    fn nano() -> Model {
        Model::synthetic(ModelConfig::preset("nano").unwrap(), 42)
    }

    #[test]
    fn forward_shapes() {
        let m = nano();
        let mut stats = ForwardStats::default();
        let logits = m.forward_seq(&[1, 2, 3, 4], &Dense, &mut stats, None);
        assert_eq!(logits.shape, vec![4, m.cfg.vocab_size]);
        assert_eq!(stats.tokens, 4);
        assert!(stats.macs_dense > 0);
        assert_eq!(stats.macs_kept, stats.macs_dense); // dense keeps all
        assert!((stats.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn incremental_matches_seq() {
        let m = nano();
        let tokens = [5usize, 9, 200, 3, 77];
        let mut stats = ForwardStats::default();
        let seq_logits = m.forward_seq(&tokens, &Dense, &mut stats, None);
        // Incremental decode must produce identical logits per position.
        let mut cache = KvCache::new(&m.cfg);
        let mut scratch = Scratch::new(&m.cfg);
        let mut l: Vec<f32> = Vec::new();
        for (t, &tok) in tokens.iter().enumerate() {
            m.forward_token(tok, &mut cache, &Dense, &mut scratch, &mut stats, &mut l);
            for v in 0..m.cfg.vocab_size {
                assert!(
                    (l[v] - seq_logits.at2(t, v)).abs() < 1e-4,
                    "pos {t} vocab {v}: {} vs {}",
                    l[v],
                    seq_logits.at2(t, v)
                );
            }
        }
    }

    #[test]
    fn logits_depend_on_context() {
        let m = nano();
        let mut stats = ForwardStats::default();
        let a = m.forward_seq(&[1, 2, 3], &Dense, &mut stats, None);
        let b = m.forward_seq(&[7, 2, 3], &Dense, &mut stats, None);
        // Same last token, different context -> different last logits.
        let diff: f32 = (0..m.cfg.vocab_size)
            .map(|v| (a.at2(2, v) - b.at2(2, v)).abs())
            .sum();
        assert!(diff > 1e-4, "attention ignored context");
    }

    #[test]
    fn causality() {
        // Changing a FUTURE token must not change past logits.
        let m = nano();
        let mut stats = ForwardStats::default();
        let a = m.forward_seq(&[1, 2, 3, 4], &Dense, &mut stats, None);
        let b = m.forward_seq(&[1, 2, 3, 200], &Dense, &mut stats, None);
        for t in 0..3 {
            for v in 0..m.cfg.vocab_size {
                assert!(
                    (a.at2(t, v) - b.at2(t, v)).abs() < 1e-6,
                    "future token leaked into position {t}"
                );
            }
        }
    }

    #[test]
    fn block_taps_capture_inputs() {
        let m = nano();
        let mut stats = ForwardStats::default();
        let mut taps = Vec::new();
        let _ = m.forward_seq(&[1, 2, 3], &Dense, &mut stats, Some(&mut taps));
        assert_eq!(taps.len(), m.cfg.n_layers);
        assert_eq!(taps[0].shape, vec![3, m.cfg.d_model]);
        // Block 0 inputs are the embeddings.
        for t in 0..3 {
            let tok = [1usize, 2, 3][t];
            for i in 0..m.cfg.d_model {
                assert!((taps[0].at2(t, i) - m.embed.at2(tok, i)).abs() < 1e-6);
            }
        }
        // Deeper blocks see transformed inputs.
        assert!(taps[1].max_abs_diff(&taps[0]) > 1e-6);
    }

    #[test]
    fn block_forward_consistent_with_taps() {
        // Running block b on its captured inputs reproduces block b+1 inputs.
        let m = nano();
        let mut stats = ForwardStats::default();
        let mut taps = Vec::new();
        let _ = m.forward_seq(&[10, 20, 30], &Dense, &mut stats, Some(&mut taps));
        let out0 = m.block_forward_seq(0, &taps[0], &Dense, &mut stats);
        assert!(
            out0.max_abs_diff(&taps[1]) < 1e-4,
            "block_forward_seq diverges from in-model block output"
        );
    }

    #[test]
    fn chunk_forward_bit_identical_to_sequential() {
        // The speculative verify pass (layer-major chunk) must reproduce
        // token-major decode bit-for-bit at every position.
        let m = nano();
        let tokens = [5usize, 9, 200, 3, 77, 13, 1];
        let mut stats = ForwardStats::default();
        let mut seq_cache = KvCache::new(&m.cfg);
        let mut seq_scratch = Scratch::new(&m.cfg);
        let mut seq_logits: Vec<f32> = Vec::new();
        let mut expect: Vec<Vec<f32>> = Vec::new();
        for &t in &tokens {
            m.forward_token(
                t,
                &mut seq_cache,
                &Dense,
                &mut seq_scratch,
                &mut stats,
                &mut seq_logits,
            );
            expect.push(seq_logits.clone());
        }
        // One warm-up token decoded normally, then the rest as a chunk —
        // exercises a non-zero chunk start position.
        let mut cache = KvCache::new(&m.cfg);
        let mut scratch = Scratch::new(&m.cfg);
        let mut logits: Vec<f32> = Vec::new();
        m.forward_token(tokens[0], &mut cache, &Dense, &mut scratch, &mut stats, &mut logits);
        let mut chunk_logits: Vec<f32> = Vec::new();
        m.forward_chunk(
            &tokens[1..],
            &mut cache,
            &Dense,
            &mut scratch,
            &mut stats,
            &mut chunk_logits,
        );
        assert_eq!(cache.len, tokens.len());
        for (j, exp) in expect.iter().enumerate().skip(1) {
            let row = &chunk_logits[(j - 1) * m.cfg.vocab_size..j * m.cfg.vocab_size];
            for v in 0..m.cfg.vocab_size {
                assert_eq!(
                    row[v].to_bits(),
                    exp[v].to_bits(),
                    "chunk diverged at pos {j} vocab {v}: {} vs {}",
                    row[v],
                    exp[v]
                );
            }
        }
    }

    #[test]
    fn mixed_chunk_matches_per_token_schedule() {
        // A dense→sparse boundary falling *inside* the chunk must reproduce
        // the token-by-token mixed schedule bit-for-bit, and LastOnly must
        // equal the PerToken pass's final row.
        use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};
        let m = nano();
        let sparse = ScoredSparsifier::new(
            "teal",
            (0..m.cfg.n_layers * 7)
                .map(|_| ScoredLayer { ga: None, tau: 0.5 })
                .collect(),
        );
        let tokens = [5usize, 9, 200, 3, 77, 13, 1, 42];
        let sparse_from = 3usize; // inside the chunk below
        let mut stats = ForwardStats::default();
        // Reference: token-major decode under the same per-position choice.
        let mut cache = KvCache::new(&m.cfg);
        let mut scratch = Scratch::new(&m.cfg);
        let mut l: Vec<f32> = Vec::new();
        let mut expect: Vec<Vec<f32>> = Vec::new();
        for (i, &t) in tokens.iter().enumerate() {
            let sp: &dyn Sparsifier = if i < sparse_from { &Dense } else { &sparse };
            m.forward_token(t, &mut cache, sp, &mut scratch, &mut stats, &mut l);
            expect.push(l.clone());
        }
        // One warm-up token, then the rest as a single mixed chunk.
        let mut cache = KvCache::new(&m.cfg);
        let mut scratch = Scratch::new(&m.cfg);
        m.forward_token(tokens[0], &mut cache, &Dense, &mut scratch, &mut stats, &mut l);
        let mut chunk_logits: Vec<f32> = Vec::new();
        m.forward_chunk_mixed(
            &tokens[1..],
            &mut cache,
            &Dense,
            &sparse,
            sparse_from,
            ChunkLogits::PerToken,
            &mut scratch,
            &mut stats,
            &mut chunk_logits,
        );
        let vocab = m.cfg.vocab_size;
        for (j, exp) in expect.iter().enumerate().skip(1) {
            let row = &chunk_logits[(j - 1) * vocab..j * vocab];
            for v in 0..vocab {
                assert_eq!(
                    row[v].to_bits(),
                    exp[v].to_bits(),
                    "mixed chunk diverged at pos {j} vocab {v}"
                );
            }
        }
        // LastOnly: same KV trajectory, only the final row materialized.
        let mut cache = KvCache::new(&m.cfg);
        let mut scratch = Scratch::new(&m.cfg);
        let mut last: Vec<f32> = Vec::new();
        m.forward_chunk_mixed(
            &tokens,
            &mut cache,
            &Dense,
            &sparse,
            sparse_from,
            ChunkLogits::LastOnly,
            &mut scratch,
            &mut stats,
            &mut last,
        );
        assert_eq!(last.len(), vocab);
        let exp = expect.last().unwrap();
        for v in 0..vocab {
            assert_eq!(last[v].to_bits(), exp[v].to_bits(), "LastOnly row differs");
        }
        // Skip: logits untouched, KV still advanced.
        let mut cache = KvCache::new(&m.cfg);
        let mut scratch = Scratch::new(&m.cfg);
        let mut untouched: Vec<f32> = vec![7.0; 3];
        m.forward_chunk_mixed(
            &tokens,
            &mut cache,
            &Dense,
            &sparse,
            sparse_from,
            ChunkLogits::Skip,
            &mut scratch,
            &mut stats,
            &mut untouched,
        );
        assert_eq!(untouched, vec![7.0; 3], "Skip must not touch the buffer");
        assert_eq!(cache.len, tokens.len());
    }

    #[test]
    fn fused_batch_bit_identical_to_per_sequence() {
        // Three sequences of different lengths step together through one
        // fused pass — one plain decode (LastOnly), one multi-token chunk
        // (PerToken, the speculative-verify shape), one logits-free chunk
        // (Skip) — and every logit must match the per-sequence paths
        // bit-for-bit, as must the FLOP accounting.
        use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};
        struct TestBatch {
            seqs: Vec<(Vec<usize>, KvCache, ForwardStats, Vec<f32>, ChunkLogits)>,
        }
        impl FusedSeqAccess for TestBatch {
            fn n_seqs(&self) -> usize {
                self.seqs.len()
            }
            fn tokens(&self, i: usize) -> &[usize] {
                &self.seqs[i].0
            }
            fn want(&self, i: usize) -> ChunkLogits {
                self.seqs[i].4
            }
            fn cache(&mut self, i: usize) -> &mut dyn KvSeq {
                &mut self.seqs[i].1
            }
            fn stats(&mut self, i: usize) -> &mut ForwardStats {
                &mut self.seqs[i].2
            }
            fn logits(&mut self, i: usize) -> &mut Vec<f32> {
                &mut self.seqs[i].3
            }
        }
        let m = nano();
        let sp = ScoredSparsifier::new(
            "teal",
            (0..m.cfg.n_layers * 7)
                .map(|_| ScoredLayer { ga: None, tau: 0.3 })
                .collect(),
        );
        let prompts: [&[usize]; 3] = [&[1, 2, 3], &[7, 9], &[5, 5, 5, 5]];
        let steps: [&[usize]; 3] = [&[11], &[42, 13, 8], &[99]];
        let wants = [ChunkLogits::LastOnly, ChunkLogits::PerToken, ChunkLogits::Skip];
        // Reference: each sequence alone through the per-sequence paths.
        let mut expect: Vec<Vec<f32>> = Vec::new();
        let mut expect_stats: Vec<ForwardStats> = Vec::new();
        for i in 0..3 {
            let mut cache = KvCache::new(&m.cfg);
            let mut scratch = Scratch::new(&m.cfg);
            let mut stats = ForwardStats::default();
            let mut logits: Vec<f32> = Vec::new();
            for &t in prompts[i] {
                m.forward_token(t, &mut cache, &sp, &mut scratch, &mut stats, &mut logits);
            }
            m.forward_chunk_mixed(
                steps[i],
                &mut cache,
                &sp,
                &sp,
                0,
                wants[i],
                &mut scratch,
                &mut stats,
                &mut logits,
            );
            expect.push(logits);
            expect_stats.push(stats);
        }
        // Fused: same prefills, one batched step.
        let mut batch = TestBatch { seqs: Vec::new() };
        for i in 0..3 {
            let mut cache = KvCache::new(&m.cfg);
            let mut scratch = Scratch::new(&m.cfg);
            let mut stats = ForwardStats::default();
            let mut logits: Vec<f32> = Vec::new();
            for &t in prompts[i] {
                m.forward_token(t, &mut cache, &sp, &mut scratch, &mut stats, &mut logits);
            }
            batch
                .seqs
                .push((steps[i].to_vec(), cache, stats, logits, wants[i]));
        }
        let mut fs = FusedScratch::new();
        m.forward_fused(&mut batch, &sp, &mut fs);
        for i in 0..3 {
            let got = &batch.seqs[i].3;
            assert_eq!(got.len(), expect[i].len(), "seq {i} logits len");
            for (a, b) in got.iter().zip(&expect[i]) {
                assert_eq!(a.to_bits(), b.to_bits(), "seq {i} first-step logits");
            }
            assert_eq!(
                batch.seqs[i].1.len,
                prompts[i].len() + steps[i].len(),
                "seq {i} cache advanced"
            );
            let gs = &batch.seqs[i].2;
            let es = &expect_stats[i];
            assert_eq!(gs.tokens, es.tokens, "seq {i} tokens");
            assert_eq!(gs.macs_kept, es.macs_kept, "seq {i} macs_kept");
            assert_eq!(gs.macs_dense, es.macs_dense, "seq {i} macs_dense");
            assert_eq!(gs.macs_extra, es.macs_extra, "seq {i} macs_extra");
        }
        // A second fused step over fresh single-token chains checks scratch
        // reuse across steps with a different batch shape.
        for (i, s) in batch.seqs.iter_mut().enumerate() {
            s.0 = vec![3 + i];
            s.4 = ChunkLogits::LastOnly;
        }
        m.forward_fused(&mut batch, &sp, &mut fs);
        for i in 0..3 {
            let mut cache = KvCache::new(&m.cfg);
            let mut scratch = Scratch::new(&m.cfg);
            let mut stats = ForwardStats::default();
            let mut logits: Vec<f32> = Vec::new();
            for &t in prompts[i] {
                m.forward_token(t, &mut cache, &sp, &mut scratch, &mut stats, &mut logits);
            }
            m.forward_chunk_mixed(
                steps[i],
                &mut cache,
                &sp,
                &sp,
                0,
                wants[i],
                &mut scratch,
                &mut stats,
                &mut logits,
            );
            m.forward_token(3 + i, &mut cache, &sp, &mut scratch, &mut stats, &mut logits);
            let got = &batch.seqs[i].3;
            assert_eq!(got.len(), logits.len(), "seq {i} second-step logits len");
            for (a, b) in got.iter().zip(&logits) {
                assert_eq!(a.to_bits(), b.to_bits(), "seq {i} second-step logits");
            }
            assert_eq!(batch.seqs[i].1.len, cache.len, "seq {i} second-step cache");
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let m = nano();
        let mut s1 = ForwardStats::default();
        let mut s2 = ForwardStats::default();
        let a = m.generate_greedy(&[1, 2], 8, &Dense, &mut s1);
        let b = m.generate_greedy(&[1, 2], 8, &Dense, &mut s2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
    }

    #[test]
    fn g_vectors_present_for_all_layers() {
        let m = nano();
        for id in crate::model::layers::all_layers(&m.cfg) {
            let g = m.g(id);
            assert_eq!(g.len(), id.kind.dims(&m.cfg).1);
            assert!(g.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn quantized_model_decodes_and_tracks_norms() {
        let mut m = nano();
        let f32_bytes = m.weight_bytes_resident();
        assert_eq!(m.weight_repr_name(), "f32");
        m.quantize(QuantMode::Int8, 8);
        assert_eq!(m.weight_repr_name(), "int8");
        assert!(m.weight_bytes_resident() < f32_bytes);
        assert_eq!(m.weight_bytes_dense(), f32_bytes);
        // Norms were recomputed from the quantized groups.
        for id in crate::model::layers::all_layers(&m.cfg) {
            let g = m.g(id);
            let deployed = m.w(id).col_l2_norms();
            for (a, b) in g.iter().zip(&deployed) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        // Decode still runs and is deterministic.
        let mut s = ForwardStats::default();
        let a = m.generate_greedy(&[1, 2], 8, &Dense, &mut s);
        let b = m.generate_greedy(&[1, 2], 8, &Dense, &mut s);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        // Quantizing again is a no-op on the codes.
        let before = m.weight_bytes_resident();
        m.quantize(QuantMode::Int4, 8);
        assert_eq!(m.weight_repr_name(), "int8");
        assert_eq!(m.weight_bytes_resident(), before);
    }

    #[test]
    fn export_import_roundtrips_quantized_checkpoint() {
        let mut m = nano();
        m.quantize(QuantMode::Int4, 4);
        let w = m.export_weights();
        assert_eq!(w.version, 2);
        assert!(w.manifest.contains("int4"), "{}", w.manifest);
        assert_eq!(w.quants.len(), m.cfg.n_layers * 7 + 1);
        let m2 = Model::from_weights(m.cfg.clone(), &w).unwrap();
        assert_eq!(m2.weight_repr_name(), "int4");
        // Logit-identical: the codes round-trip exactly.
        let mut s1 = ForwardStats::default();
        let mut s2 = ForwardStats::default();
        let a = m.forward_seq(&[3, 1, 4], &Dense, &mut s1, None);
        let b = m2.forward_seq(&[3, 1, 4], &Dense, &mut s2, None);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // A dense model still exports a v1 container.
        let dense = nano().export_weights();
        assert_eq!(dense.version, 1);
        assert!(dense.quants.is_empty());
    }
}
