//! Per-sequence KV cache for incremental decoding.

use crate::model::ModelConfig;

/// One block's cached keys/values, row-major `[pos, d_model]` (heads are
/// interleaved inside d_model exactly as the projections emit them).
#[derive(Clone, Debug)]
pub struct BlockKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    d: usize,
}

impl BlockKv {
    fn new(max_seq: usize, d: usize) -> Self {
        Self {
            k: vec![0.0; max_seq * d],
            v: vec![0.0; max_seq * d],
            d,
        }
    }

    #[inline]
    pub fn k_at(&self, pos: usize) -> &[f32] {
        &self.k[pos * self.d..(pos + 1) * self.d]
    }

    #[inline]
    pub fn v_at(&self, pos: usize) -> &[f32] {
        &self.v[pos * self.d..(pos + 1) * self.d]
    }

    pub fn store(&mut self, pos: usize, k: &[f32], v: &[f32]) {
        self.k[pos * self.d..(pos + 1) * self.d].copy_from_slice(k);
        self.v[pos * self.d..(pos + 1) * self.d].copy_from_slice(v);
    }

    /// The first `upto` K rows, row-major `[upto, d_model]`.
    #[inline]
    pub fn k_rows(&self, upto: usize) -> &[f32] {
        &self.k[..upto * self.d]
    }

    /// The first `upto` V rows, row-major `[upto, d_model]`.
    #[inline]
    pub fn v_rows(&self, upto: usize) -> &[f32] {
        &self.v[..upto * self.d]
    }
}

/// Full-model KV cache; `len` is the number of positions already decoded.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub blocks: Vec<BlockKv>,
    pub len: usize,
    pub max_seq: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        Self {
            blocks: (0..cfg.n_layers)
                .map(|_| BlockKv::new(cfg.max_seq, cfg.d_model))
                .collect(),
            len: 0,
            max_seq: cfg.max_seq,
        }
    }

    /// Single-block cache (used by `block_forward_seq` during calibration).
    pub fn single_block(cfg: &ModelConfig) -> Self {
        Self {
            blocks: vec![BlockKv::new(cfg.max_seq, cfg.d_model)],
            len: 0,
            max_seq: cfg.max_seq,
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.max_seq
    }
}

/// The flat slab as a [`KvSeq`]: one contiguous chunk per layer, visited in
/// a single callback. This is the baseline the paged implementation must
/// match bit-for-bit.
impl crate::kv::KvSeq for KvCache {
    fn seq_len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.max_seq
    }

    fn try_reserve(&mut self) -> bool {
        self.len < self.max_seq
    }

    fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.blocks[layer].store(pos, k, v);
    }

    fn advance(&mut self) {
        self.len += 1;
    }

    /// Rolling back the flat slab is purely logical: rows beyond `new_len`
    /// become stale and are overwritten by the next stores before any
    /// attention pass can read them (`with_k`/`with_v` never visit past
    /// `seq_len`).
    fn truncate(&mut self, new_len: usize) {
        assert!(new_len <= self.len, "truncate beyond seq_len");
        self.len = new_len;
    }

    fn with_k(&self, layer: usize, upto: usize, f: &mut dyn FnMut(usize, &[f32])) {
        f(0, self.blocks[layer].k_rows(upto));
    }

    fn with_v(&self, layer: usize, upto: usize, f: &mut dyn FnMut(usize, &[f32])) {
        f(0, self.blocks[layer].v_rows(upto));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_and_read() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut c = KvCache::new(&cfg);
        assert_eq!(c.blocks.len(), cfg.n_layers);
        let k: Vec<f32> = (0..cfg.d_model).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..cfg.d_model).map(|i| -(i as f32)).collect();
        c.blocks[0].store(3, &k, &v);
        assert_eq!(c.blocks[0].k_at(3), &k[..]);
        assert_eq!(c.blocks[0].v_at(3), &v[..]);
        c.len = cfg.max_seq;
        assert!(c.is_full());
        c.reset();
        assert_eq!(c.len, 0);
    }
}
