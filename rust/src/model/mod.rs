//! Native transformer inference engine (the serving substrate).
//!
//! A Llama-architecture decoder (RMSNorm, RoPE, MHA, SwiGLU) sized down to
//! "micro" models trained at build time by `python/compile/train.py`. The
//! engine runs every linear projection through a [`crate::sparsity::Sparsifier`]
//! so dense, TEAL, R-Sparse, WINA and WiSparse execution share one code path.

pub mod config;
pub mod weights;
pub mod layers;
pub mod kv_cache;
pub mod transformer;
pub mod sampler;

pub use config::ModelConfig;
pub use layers::{LayerId, LayerKind};
pub use transformer::Model;
