//! Calibration data handling and activation capture.
//!
//! The searches of Sec 4.2/4.3 need, per block: the block's input hidden
//! states (`x_B`), the dense block outputs (`F_B(x_B)`), and the inputs to
//! every linear layer inside the block (to pool score distributions for
//! Eq. 7 thresholds). One dense pass over the calibration set collects all
//! of it.

pub mod dataset;
pub mod collector;

pub use collector::{BlockCalib, Capturing, ModelCalib};
pub use dataset::CalibSet;
