//! Activation capture: one dense pass over the calibration set collects
//! everything the searches need.

use crate::calib::dataset::CalibSet;
use crate::model::layers::{LayerId, LayerKind};
use crate::model::transformer::{ForwardStats, Model};
use crate::quant::WeightRepr;
use crate::sparsity::{Dense, Sparsifier};
use crate::tensor::Tensor;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Wrapper that records the input activation of every projection it routes,
/// then delegates to the inner sparsifier. Calibration-only (the Mutex makes
/// it unsuitable for the serving hot path by design).
pub struct Capturing<'a> {
    inner: &'a dyn Sparsifier,
    store: Mutex<BTreeMap<LayerId, Vec<f32>>>,
}

impl<'a> Capturing<'a> {
    pub fn new(inner: &'a dyn Sparsifier) -> Self {
        Self {
            inner,
            store: Mutex::new(BTreeMap::new()),
        }
    }

    /// Flat captured rows per layer (row length = layer input dim).
    pub fn into_store(self) -> BTreeMap<LayerId, Vec<f32>> {
        self.store.into_inner().unwrap()
    }
}

impl Sparsifier for Capturing<'_> {
    fn name(&self) -> &'static str {
        "capturing"
    }

    fn project(&self, layer: LayerId, x: &[f32], w: &dyn WeightRepr, out: &mut [f32]) -> usize {
        self.store
            .lock()
            .unwrap()
            .entry(layer)
            .or_default()
            .extend_from_slice(x);
        self.inner.project(layer, x, w, out)
    }
}

/// Calibration captures for one block.
pub struct BlockCalib {
    /// `[T, d]` inputs to the block over the whole calibration set
    /// (concatenated sequences; boundaries in `seq_lens`).
    pub inputs: Tensor,
    /// `[T, d]` dense outputs of the block on those inputs.
    pub dense_out: Tensor,
    /// Per projection kind: flat `[rows * in_dim]` input activations.
    pub layer_inputs: BTreeMap<LayerKind, Vec<f32>>,
    /// Length of each calibration sequence inside `inputs` (attention must
    /// never cross these boundaries).
    pub seq_lens: Vec<usize>,
}

impl BlockCalib {
    /// Rows captured for a projection kind together with its input dim.
    pub fn rows_of(&self, kind: LayerKind, cfg: &crate::model::ModelConfig) -> (&[f32], usize) {
        let dim = kind.dims(cfg).1;
        (&self.layer_inputs[&kind], dim)
    }

    /// Run the block on the captured inputs under a sparsifier, respecting
    /// sequence boundaries (fresh KV state per sequence). This is the
    /// `F_B^sparse(x_B)` evaluator used by Algs. 2 and 4.
    pub fn forward_with(
        &self,
        model: &Model,
        block: usize,
        sp: &dyn Sparsifier,
        stats: &mut ForwardStats,
    ) -> Tensor {
        let (total, d) = self.inputs.dims2();
        let mut out = Tensor::zeros(&[total, d]);
        let mut row0 = 0usize;
        for &t in &self.seq_lens {
            let xs = Tensor::from_vec(
                &[t, d],
                self.inputs.data[row0 * d..(row0 + t) * d].to_vec(),
            );
            let o = model.block_forward_seq(block, &xs, sp, stats);
            out.data[row0 * d..(row0 + t) * d].copy_from_slice(&o.data);
            row0 += t;
        }
        debug_assert_eq!(row0, total);
        out
    }
}

/// Full-model calibration captures plus the dense logits (for Eq. 8's KL).
pub struct ModelCalib {
    pub blocks: Vec<BlockCalib>,
    /// Dense logits per sequence: `[T, vocab]` each.
    pub dense_logits: Vec<Tensor>,
    /// The token sequences (kept for sparse re-evaluation).
    pub seqs: Vec<Vec<usize>>,
}

impl ModelCalib {
    /// One dense pass per sequence, capturing block inputs; then one
    /// instrumented block pass per block to capture per-layer inputs and
    /// dense block outputs.
    pub fn collect(model: &Model, calib: &CalibSet) -> ModelCalib {
        let n_blocks = model.cfg.n_layers;
        let d = model.cfg.d_model;
        let mut stats = ForwardStats::default();
        // Per-block concatenated inputs across sequences.
        let mut inputs_flat: Vec<Vec<f32>> = vec![Vec::new(); n_blocks];
        let mut dense_logits = Vec::with_capacity(calib.seqs.len());
        for seq in &calib.seqs {
            let mut taps = Vec::new();
            let logits = model.forward_seq(seq, &Dense, &mut stats, Some(&mut taps));
            dense_logits.push(logits);
            for (b, tap) in taps.into_iter().enumerate() {
                inputs_flat[b].extend_from_slice(&tap.data);
            }
        }
        let total_rows: usize = calib.seqs.iter().map(|s| s.len()).sum();
        let mut blocks = Vec::with_capacity(n_blocks);
        for (b, flat) in inputs_flat.into_iter().enumerate() {
            let inputs = Tensor::from_vec(&[total_rows, d], flat);
            // Dense block outputs + per-layer inputs, per sequence to keep
            // positions/causality right.
            let capturing = Capturing::new(&Dense);
            let mut dense_out = Tensor::zeros(&[total_rows, d]);
            let mut row0 = 0usize;
            for seq in &calib.seqs {
                let t = seq.len();
                let xs = Tensor::from_vec(
                    &[t, d],
                    inputs.data[row0 * d..(row0 + t) * d].to_vec(),
                );
                let out = model.block_forward_seq(b, &xs, &capturing, &mut stats);
                dense_out.data[row0 * d..(row0 + t) * d].copy_from_slice(&out.data);
                row0 += t;
            }
            let store = capturing.into_store();
            let mut layer_inputs = BTreeMap::new();
            for &kind in &LayerKind::ALL {
                let rows = store
                    .get(&LayerId::new(b, kind))
                    .cloned()
                    .unwrap_or_default();
                layer_inputs.insert(kind, rows);
            }
            blocks.push(BlockCalib {
                inputs,
                dense_out,
                layer_inputs,
                seq_lens: calib.seqs.iter().map(|s| s.len()).collect(),
            });
        }
        ModelCalib {
            blocks,
            dense_logits,
            seqs: calib.seqs.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn setup() -> (Model, ModelCalib) {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 3);
        let calib = CalibSet::synthetic(2, 12, m.cfg.vocab_size, 5);
        let mc = ModelCalib::collect(&m, &calib);
        (m, mc)
    }

    #[test]
    fn capture_shapes() {
        let (m, mc) = setup();
        assert_eq!(mc.blocks.len(), m.cfg.n_layers);
        assert_eq!(mc.dense_logits.len(), 2);
        let total = 24;
        for bc in &mc.blocks {
            assert_eq!(bc.inputs.shape, vec![total, m.cfg.d_model]);
            assert_eq!(bc.dense_out.shape, vec![total, m.cfg.d_model]);
            // Q/K/V/O/Gate/Up see d_model-dim inputs, Down sees ffn_dim.
            let (rows, dim) = bc.rows_of(LayerKind::Down, &m.cfg);
            assert_eq!(dim, m.cfg.ffn_dim);
            assert_eq!(rows.len(), total * m.cfg.ffn_dim);
            let (rows_q, dim_q) = bc.rows_of(LayerKind::Q, &m.cfg);
            assert_eq!(dim_q, m.cfg.d_model);
            assert_eq!(rows_q.len(), total * m.cfg.d_model);
        }
    }

    #[test]
    fn block_outputs_chain_to_next_inputs() {
        let (_, mc) = setup();
        // dense_out of block b == inputs of block b+1.
        for b in 0..mc.blocks.len() - 1 {
            let d = mc.blocks[b].dense_out.max_abs_diff(&mc.blocks[b + 1].inputs);
            assert!(d < 1e-4, "block {b} chain break: {d}");
        }
    }

    #[test]
    fn qkv_inputs_identical() {
        // Q, K, V all receive the same normed input.
        let (m, mc) = setup();
        let (q, _) = mc.blocks[0].rows_of(LayerKind::Q, &m.cfg);
        let (k, _) = mc.blocks[0].rows_of(LayerKind::K, &m.cfg);
        assert_eq!(q, k);
    }
}
