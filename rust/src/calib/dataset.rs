//! Calibration sequences.
//!
//! The paper calibrates on a pile-val + CodeAlpaca + MetaMathQA mix so that
//! "math and code tasks can also be calibrated". Our substitute is held-out
//! slices of the synthetic corpus covering the same three pattern families
//! (prose-like, code-like, math-like) — written by `python/compile/data.py`
//! to `artifacts/data/<model>/calib.json` as arrays of byte-token ids. A
//! Rust-side generator provides equivalent sequences for tests.

use crate::util::json::Json;
use crate::util::rng::Pcg64;
use std::path::Path;

/// A set of token sequences used for calibration.
#[derive(Clone, Debug)]
pub struct CalibSet {
    pub seqs: Vec<Vec<usize>>,
}

impl CalibSet {
    /// Load from the JSON written by the Python data generator:
    /// `{"seqs": [[t, t, ...], ...]}`.
    pub fn load(path: &Path) -> anyhow::Result<CalibSet> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        let seqs = j
            .req_arr("seqs")?
            .iter()
            .map(|s| {
                s.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("calib seq must be an array"))
                    .map(|a| a.iter().filter_map(|t| t.as_usize()).collect::<Vec<_>>())
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        if seqs.is_empty() || seqs.iter().any(|s| s.is_empty()) {
            anyhow::bail!("empty calibration set at {}", path.display());
        }
        Ok(CalibSet { seqs })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let seqs = Json::Arr(
            self.seqs
                .iter()
                .map(|s| Json::Arr(s.iter().map(|&t| Json::Num(t as f64)).collect()))
                .collect(),
        );
        std::fs::write(path, Json::obj(vec![("seqs", seqs)]).to_string_compact())?;
        Ok(())
    }

    /// Total number of tokens.
    pub fn n_tokens(&self) -> usize {
        self.seqs.iter().map(|s| s.len()).sum()
    }

    /// Truncate to at most `n` sequences of at most `max_len` tokens (search
    /// speed knob; the searches use a slice, final thresholds use more).
    pub fn subset(&self, n: usize, max_len: usize) -> CalibSet {
        CalibSet {
            seqs: self
                .seqs
                .iter()
                .take(n.max(1))
                .map(|s| s[..s.len().min(max_len.max(1))].to_vec())
                .collect(),
        }
    }

    /// Synthetic byte-token calibration set mirroring the mixed corpus
    /// (prose / math / code lines). Used by tests and by the quickstart when
    /// no artifacts are present.
    pub fn synthetic(n_seqs: usize, seq_len: usize, vocab: usize, seed: u64) -> CalibSet {
        let mut rng = Pcg64::new(seed);
        let mut seqs = Vec::with_capacity(n_seqs);
        for _ in 0..n_seqs {
            let mut s = Vec::with_capacity(seq_len);
            while s.len() < seq_len {
                match rng.below(3) {
                    0 => {
                        // prose-like: lowercase words
                        let wlen = 2 + rng.below(6);
                        for _ in 0..wlen {
                            s.push((b'a' + rng.below(26) as u8) as usize % vocab);
                        }
                        s.push(b' ' as usize % vocab);
                    }
                    1 => {
                        // math-like: "12+34=46."
                        for _ in 0..2 {
                            s.push((b'0' + rng.below(10) as u8) as usize % vocab);
                        }
                        s.push(b'+' as usize % vocab);
                        for _ in 0..2 {
                            s.push((b'0' + rng.below(10) as u8) as usize % vocab);
                        }
                        s.push(b'=' as usize % vocab);
                    }
                    _ => {
                        // code-like: brackets and symbols
                        for _ in 0..4 {
                            let syms = b"(){}[];=.";
                            s.push(syms[rng.below(syms.len())] as usize % vocab);
                        }
                    }
                }
            }
            s.truncate(seq_len);
            seqs.push(s);
        }
        CalibSet { seqs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_shape() {
        let c = CalibSet::synthetic(4, 32, 256, 1);
        assert_eq!(c.seqs.len(), 4);
        assert!(c.seqs.iter().all(|s| s.len() == 32));
        assert!(c.seqs.iter().flatten().all(|&t| t < 256));
        assert_eq!(c.n_tokens(), 128);
    }

    #[test]
    fn save_load_roundtrip() {
        let c = CalibSet::synthetic(3, 16, 256, 2);
        let path = std::env::temp_dir().join("wisparse_calib_test.json");
        c.save(&path).unwrap();
        let c2 = CalibSet::load(&path).unwrap();
        assert_eq!(c.seqs, c2.seqs);
    }

    #[test]
    fn subset_truncates() {
        let c = CalibSet::synthetic(8, 64, 256, 3);
        let s = c.subset(2, 10);
        assert_eq!(s.seqs.len(), 2);
        assert!(s.seqs.iter().all(|q| q.len() == 10));
    }

    #[test]
    fn deterministic() {
        let a = CalibSet::synthetic(2, 20, 256, 9);
        let b = CalibSet::synthetic(2, 20, 256, 9);
        assert_eq!(a.seqs, b.seqs);
    }
}
