//! AOT parameter manifest: the contract between `python/compile/aot.py`
//! (which lowers the JAX model with a fixed parameter order) and the Rust
//! runtime (which must feed literals in exactly that order).
//!
//! ```json
//! {
//!   "model": "llama-micro",
//!   "variant": "dense" | "wisparse",
//!   "seq_len": 64,
//!   "vocab_size": 256,
//!   "params": [{"name": "embed.weight", "shape": [256, 128]}, ...]
//! }
//! ```
//! `params` excludes the token input (always parameter 0 on the HLO side).
//! For the "wisparse" variant, extra parameters named `sparse.<layer>.ga`
//! (shape `[in_dim]`) and `sparse.<layer>.tau` (shape `[1]`) follow the
//! weights; the Rust side materializes them from a calibrated plan.

use crate::util::json::Json;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub model: String,
    pub variant: String,
    pub seq_len: usize,
    pub vocab_size: usize,
    pub params: Vec<ParamSpec>,
}

impl Manifest {
    pub fn from_json(j: &Json) -> anyhow::Result<Manifest> {
        let params = j
            .req_arr("params")?
            .iter()
            .map(|p| {
                let name = p.req_str("name")?.to_string();
                let shape = p
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("param `{name}`: missing shape"))?
                    .iter()
                    .map(|d| {
                        d.as_usize()
                            .ok_or_else(|| anyhow::anyhow!("param `{name}`: bad dim"))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?;
                Ok(ParamSpec { name, shape })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest {
            model: j.req_str("model")?.to_string(),
            variant: j.req_str("variant")?.to_string(),
            seq_len: j.req_usize("seq_len")?,
            vocab_size: j.req_usize("vocab_size")?,
            params,
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("variant", Json::Str(self.variant.clone())),
            ("seq_len", Json::Num(self.seq_len as f64)),
            ("vocab_size", Json::Num(self.vocab_size as f64)),
            (
                "params",
                Json::Arr(
                    self.params
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("name", Json::Str(p.name.clone())),
                                (
                                    "shape",
                                    Json::Arr(
                                        p.shape
                                            .iter()
                                            .map(|&d| Json::Num(d as f64))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let m = Manifest {
            model: "llama-micro".into(),
            variant: "dense".into(),
            seq_len: 64,
            vocab_size: 256,
            params: vec![
                ParamSpec {
                    name: "embed.weight".into(),
                    shape: vec![256, 128],
                },
                ParamSpec {
                    name: "final_norm.weight".into(),
                    shape: vec![128],
                },
            ],
        };
        let m2 = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn rejects_missing_fields() {
        let j = Json::parse(r#"{"model": "x"}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }
}
