//! PJRT runtime: load AOT-compiled HLO (text) produced by
//! `python/compile/aot.py`, compile it on the PJRT CPU client, and execute
//! it with weight literals — the Rust side of the L2/L3 bridge. Python is
//! build-time only; at runtime this module and the native engine are the
//! only execution paths.
//!
//! The PJRT execution path needs the vendored `xla` crate, which is not
//! part of the default offline build: it is gated behind the `pjrt` cargo
//! feature (`cargo build --features pjrt`). The manifest schema is always
//! available so plans/manifests can be read and validated without XLA.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub mod validate;

pub use manifest::Manifest;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtModel;
