//! PJRT runtime: load AOT-compiled HLO (text) produced by
//! `python/compile/aot.py`, compile it on the PJRT CPU client, and execute
//! it with weight literals — the Rust side of the L2/L3 bridge. Python is
//! build-time only; at runtime this module and the native engine are the
//! only execution paths.

pub mod manifest;
pub mod pjrt;
pub mod validate;

pub use manifest::Manifest;
pub use pjrt::PjrtModel;
