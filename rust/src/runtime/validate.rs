//! Native-vs-PJRT cross-validation: the two execution backends must agree
//! on logits for the same weights, both dense and under a WiSparse plan.
//! This is the proof that the three layers (Pallas kernel -> JAX model ->
//! Rust engine) compute the same function.

use crate::model::transformer::{ForwardStats, Model};
use crate::model::weights::Weights;
use crate::runtime::pjrt::PjrtModel;
use crate::sparsity::methods::ScoredSparsifier;
use crate::sparsity::plan::SparsityPlan;
use crate::sparsity::Dense;
use std::path::Path;

/// Result of one cross-validation run.
#[derive(Debug)]
pub struct ValidationReport {
    pub variant: String,
    pub seq_len: usize,
    pub max_abs_diff: f32,
    pub mean_abs_diff: f64,
    pub pass: bool,
}

impl ValidationReport {
    pub fn line(&self) -> String {
        format!(
            "{:<10} seq {:>4}  max|Δ| {:.3e}  mean|Δ| {:.3e}  {}",
            self.variant,
            self.seq_len,
            self.max_abs_diff,
            self.mean_abs_diff,
            if self.pass { "PASS" } else { "FAIL" }
        )
    }
}

/// Compare the native engine against the compiled HLO on one token
/// sequence. `tol` is in absolute logits (f32 accumulation-order noise).
pub fn cross_validate(
    model_dir: &Path,
    variant: &str,
    tokens: &[usize],
    plan: Option<&SparsityPlan>,
    tol: f32,
) -> anyhow::Result<ValidationReport> {
    let model = Model::load_dir(model_dir)?;
    let weights = Weights::load(&model_dir.join("weights.bin"))?;
    let pjrt = PjrtModel::load(model_dir, variant)?;
    let t_len = pjrt.manifest.seq_len.min(tokens.len());
    let tokens = &tokens[..t_len];

    // Native logits.
    let mut stats = ForwardStats::default();
    let native = match (variant, plan) {
        ("dense", _) => model.forward_seq(tokens, &Dense, &mut stats, None),
        ("wisparse", Some(p)) => {
            let sp = ScoredSparsifier::from_plan("wisparse", &model, p);
            model.forward_seq(tokens, &sp, &mut stats, None)
        }
        _ => anyhow::bail!("variant `{variant}` needs a plan iff sparse"),
    };

    // PJRT logits (fixed seq_len; compare the first t_len rows).
    let pjrt_logits = pjrt.forward(tokens, &weights, plan)?;
    let vocab = model.cfg.vocab_size;
    let mut max_diff = 0.0f32;
    let mut sum_diff = 0.0f64;
    for t in 0..t_len {
        for v in 0..vocab {
            let d = (native.at2(t, v) - pjrt_logits.at2(t, v)).abs();
            max_diff = max_diff.max(d);
            sum_diff += d as f64;
        }
    }
    let mean = sum_diff / (t_len * vocab) as f64;
    Ok(ValidationReport {
        variant: variant.to_string(),
        seq_len: t_len,
        max_abs_diff: max_diff,
        mean_abs_diff: mean,
        pass: max_diff <= tol,
    })
}
