//! PJRT CPU execution of AOT-lowered HLO text.
//!
//! Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use crate::model::layers::LayerId;
use crate::model::weights::Weights;
use crate::runtime::manifest::Manifest;
use crate::sparsity::plan::SparsityPlan;
use crate::sparsity::score::pow_clamped;
use crate::tensor::Tensor;
use std::path::Path;

/// A compiled HLO model ready to execute.
pub struct PjrtModel {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

impl PjrtModel {
    /// Load `<dir>/<variant>.hlo.txt` + `<dir>/<variant>.manifest.json`,
    /// compile on a fresh CPU client.
    pub fn load(dir: &Path, variant: &str) -> anyhow::Result<PjrtModel> {
        let manifest = Manifest::load(&dir.join(format!("{variant}.manifest.json")))?;
        let hlo_path = dir.join(format!("{variant}.hlo.txt"));
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", hlo_path.display()))?;
        Ok(PjrtModel { exe, manifest })
    }

    /// Build the literal list for the weight parameters (in manifest
    /// order); sparse params are resolved from `plan` (required iff the
    /// variant is "wisparse").
    fn param_literals(
        &self,
        weights: &Weights,
        plan: Option<&SparsityPlan>,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(self.manifest.params.len());
        for spec in &self.manifest.params {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let data: Vec<f32> = if let Some(rest) = spec.name.strip_prefix("sparse.") {
                let plan = plan.ok_or_else(|| {
                    anyhow::anyhow!("variant `{}` needs a sparsity plan", self.manifest.variant)
                })?;
                let (layer_key, field) = rest
                    .rsplit_once('.')
                    .ok_or_else(|| anyhow::anyhow!("bad sparse param `{}`", spec.name))?;
                let id = LayerId::from_key(layer_key)
                    .ok_or_else(|| anyhow::anyhow!("bad layer key `{layer_key}`"))?;
                let lp = plan.layer(id);
                match field {
                    "ga" => {
                        // g must come from the weights we are executing with.
                        let wname = weight_name_for(id);
                        let w = weights.get(&wname)?;
                        pow_clamped(&w.col_l2_norms(), lp.alpha)
                    }
                    "tau" => vec![lp.tau],
                    _ => anyhow::bail!("unknown sparse field `{field}`"),
                }
            } else {
                let t = weights.get(&spec.name)?;
                if t.shape != spec.shape {
                    anyhow::bail!(
                        "param `{}`: manifest shape {:?} != weight shape {:?}",
                        spec.name,
                        spec.shape,
                        t.shape
                    );
                }
                t.data.clone()
            };
            let expected: usize = spec.shape.iter().product();
            if data.len() != expected {
                anyhow::bail!("param `{}`: built {} values, need {expected}", spec.name, data.len());
            }
            let lit = xla::Literal::vec1(&data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshaping `{}`: {e:?}", spec.name))?;
            lits.push(lit);
        }
        Ok(lits)
    }

    /// Execute the model on a token sequence (padded/truncated to the
    /// manifest's fixed seq_len). Returns `[seq_len, vocab]` logits.
    pub fn forward(
        &self,
        tokens: &[usize],
        weights: &Weights,
        plan: Option<&SparsityPlan>,
    ) -> anyhow::Result<Tensor> {
        let t_len = self.manifest.seq_len;
        let mut toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        toks.resize(t_len, 0);
        let tok_lit = xla::Literal::vec1(&toks)
            .reshape(&[t_len as i64])
            .map_err(|e| anyhow::anyhow!("token literal: {e:?}"))?;
        let mut args = vec![tok_lit];
        args.extend(self.param_literals(weights, plan)?);
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let values: Vec<f32> = out
            .to_vec()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        let vocab = self.manifest.vocab_size;
        if values.len() != t_len * vocab {
            anyhow::bail!(
                "unexpected output size {} (want {} x {})",
                values.len(),
                t_len,
                vocab
            );
        }
        Ok(Tensor::from_vec(&[t_len, vocab], values))
    }
}

/// Map a LayerId to its weight tensor name (trainer convention).
pub fn weight_name_for(id: LayerId) -> String {
    use crate::model::layers::LayerKind::*;
    match id.kind {
        Q => Weights::attn_weight_name(id.block, "q"),
        K => Weights::attn_weight_name(id.block, "k"),
        V => Weights::attn_weight_name(id.block, "v"),
        O => Weights::attn_weight_name(id.block, "o"),
        Gate => Weights::mlp_weight_name(id.block, "gate"),
        Up => Weights::mlp_weight_name(id.block, "up"),
        Down => Weights::mlp_weight_name(id.block, "down"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layers::LayerKind;

    #[test]
    fn weight_names_match_convention() {
        assert_eq!(
            weight_name_for(LayerId::new(2, LayerKind::Q)),
            "blocks.2.attn.wq.weight"
        );
        assert_eq!(
            weight_name_for(LayerId::new(0, LayerKind::Down)),
            "blocks.0.mlp.w_down.weight"
        );
    }
}
