//! Alg. 4: intra-block greedy layer-level allocation (the fine stage).
//!
//! With the block budget fixed by the coarse search, sparsity is added in
//! small increments, each time to whichever layer raises the block's output
//! reconstruction error least (following TEAL's greedy allocation, Liu et
//! al. 2025). Effective block sparsity is FLOP-weighted: adding `delta` to
//! `up_proj` buys more compute savings than adding it to `k_proj`.

use crate::calib::collector::BlockCalib;
use crate::model::layers::{block_effective_sparsity, LayerId, LayerKind};
use crate::model::transformer::{ForwardStats, Model};
use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use crate::sparsity::score::{pow_clamped, tau_from_rows};
use crate::util::threadpool::parallel_map;

/// Fine-search configuration.
#[derive(Clone, Debug)]
pub struct GreedyCfg {
    /// Sparsity increment per step (delta in Alg. 4).
    pub step: f64,
    /// Score exponent used while searching (alpha search runs later).
    pub search_alpha: f64,
    pub max_layer_sparsity: f64,
    pub threads: usize,
}

impl Default for GreedyCfg {
    fn default() -> Self {
        Self {
            step: 0.05,
            search_alpha: 1.0,
            max_layer_sparsity: 0.95,
            threads: crate::util::threadpool::num_threads(),
        }
    }
}

fn block_sparsifier(
    model: &Model,
    block: usize,
    bc: &BlockCalib,
    sparsities: &[f64; 7],
    alpha: f64,
) -> ScoredSparsifier {
    let mut sp = ScoredSparsifier::identity("greedy-candidate", model.cfg.n_layers * 7);
    for (i, &kind) in LayerKind::ALL.iter().enumerate() {
        let id = LayerId::new(block, kind);
        let keep = (1.0 - sparsities[i]).clamp(0.0, 1.0);
        let (rows, dim) = bc.rows_of(kind, &model.cfg);
        let ga = pow_clamped(model.g(id), alpha);
        let tau = if rows.is_empty() || keep >= 1.0 {
            0.0
        } else {
            tau_from_rows(rows, dim, &ga, keep)
        };
        *sp.layer_mut(id) = ScoredLayer { ga: Some(ga), tau };
    }
    sp
}

fn block_error(model: &Model, block: usize, bc: &BlockCalib, sparsities: &[f64; 7], alpha: f64) -> f64 {
    let sp = block_sparsifier(model, block, bc, sparsities, alpha);
    let mut stats = ForwardStats::default();
    let out = bc.forward_with(model, block, &sp, &mut stats);
    out.mse(&bc.dense_out)
}

/// Greedy allocation for one block (Alg. 4): returns per-kind sparsities
/// whose FLOP-weighted average reaches `target_block_sparsity`.
pub fn greedy_layer_allocation(
    model: &Model,
    block: usize,
    bc: &BlockCalib,
    target_block_sparsity: f64,
    cfg: &GreedyCfg,
) -> [f64; 7] {
    let mut sparsities = [0.0f64; 7];
    let mut guard = 0usize;
    while block_effective_sparsity(&model.cfg, &sparsities) < target_block_sparsity
        && guard < 10_000
    {
        guard += 1;
        // Evaluate the 7 candidate increments in parallel.
        let errors = parallel_map(7, cfg.threads.min(7), |li| {
            if sparsities[li] + cfg.step > cfg.max_layer_sparsity {
                return f64::INFINITY;
            }
            let mut cand = sparsities;
            cand[li] += cfg.step;
            block_error(model, block, bc, &cand, cfg.search_alpha)
        });
        let (best_li, &best_err) = errors
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if best_err.is_infinite() {
            break; // every layer saturated
        }
        sparsities[best_li] += cfg.step;
    }
    sparsities
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{CalibSet, ModelCalib};
    use crate::model::{Model, ModelConfig};

    fn setup() -> (Model, ModelCalib) {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 41);
        let calib = CalibSet::synthetic(2, 8, m.cfg.vocab_size, 43);
        let mc = ModelCalib::collect(&m, &calib);
        (m, mc)
    }

    #[test]
    fn reaches_target_budget() {
        let (m, mc) = setup();
        let cfg = GreedyCfg {
            step: 0.1,
            threads: 2,
            ..GreedyCfg::default()
        };
        let s = greedy_layer_allocation(&m, 0, &mc.blocks[0], 0.4, &cfg);
        let eff = block_effective_sparsity(&m.cfg, &s);
        assert!(eff >= 0.4, "effective {eff}");
        assert!(eff < 0.4 + 0.11, "overshoot: {eff}");
        assert!(s.iter().all(|&p| (0.0..=0.95).contains(&p)));
    }

    #[test]
    fn zero_target_stays_dense() {
        let (m, mc) = setup();
        let cfg = GreedyCfg {
            step: 0.1,
            threads: 1,
            ..GreedyCfg::default()
        };
        let s = greedy_layer_allocation(&m, 0, &mc.blocks[0], 0.0, &cfg);
        assert_eq!(s, [0.0; 7]);
    }

    #[test]
    fn allocation_is_heterogeneous_under_pressure() {
        // At a mid budget, the greedy allocator should not pick a perfectly
        // uniform split unless the block is pathologically symmetric.
        let (m, mc) = setup();
        let cfg = GreedyCfg {
            step: 0.1,
            threads: 2,
            ..GreedyCfg::default()
        };
        let s = greedy_layer_allocation(&m, 1, &mc.blocks[1], 0.5, &cfg);
        let min = s.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = s.iter().cloned().fold(0.0f64, f64::max);
        assert!(max - min > 1e-9, "suspiciously uniform allocation {s:?}");
    }

    #[test]
    fn greedy_not_worse_than_uniform() {
        let (m, mc) = setup();
        let cfg = GreedyCfg {
            step: 0.1,
            threads: 2,
            ..GreedyCfg::default()
        };
        let s = greedy_layer_allocation(&m, 0, &mc.blocks[0], 0.5, &cfg);
        let greedy_err = block_error(&m, 0, &mc.blocks[0], &s, 1.0);
        let uniform_err = block_error(&m, 0, &mc.blocks[0], &[0.5; 7], 1.0);
        // The greedy result has effective sparsity >= 0.5; it should still
        // reconstruct no worse than ~the uniform 0.5 allocation.
        assert!(
            greedy_err <= uniform_err * 1.25,
            "greedy {greedy_err} vs uniform {uniform_err}"
        );
    }
}
