//! Alg. 2: lightweight block-wise grid search for the weight exponents.
//!
//! For each block, each layer's exponent `alpha_l` is swept over a grid on
//! [0, 1.5] (paper: step 0.05, i.e. 30 points) minimizing the MSE between
//! dense and sparse block outputs on calibration data (Eq. 6). Candidate
//! thresholds are recomputed per alpha via Eq. 7 so every candidate hits the
//! layer's target keep ratio. Layers are optimized coordinate-wise, which
//! is what lets Fig 6 show distinct alphas per projection.

use crate::calib::collector::BlockCalib;
use crate::model::layers::{LayerId, LayerKind};
use crate::model::transformer::{ForwardStats, Model};
use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use crate::sparsity::plan::SparsityPlan;
use crate::sparsity::score::{pow_clamped, tau_from_rows};
use crate::util::threadpool::parallel_map;

/// Grid-search configuration.
#[derive(Clone, Debug)]
pub struct AlphaSearchCfg {
    /// Number of grid points over [0, alpha_max] (paper: 30).
    pub n_grid: usize,
    /// Upper end of the grid (paper: 1.5).
    pub alpha_max: f64,
    /// Coordinate-descent passes over the block's layers.
    pub passes: usize,
    pub threads: usize,
}

impl Default for AlphaSearchCfg {
    fn default() -> Self {
        Self {
            n_grid: 30,
            alpha_max: 1.5,
            passes: 1,
            threads: crate::util::threadpool::num_threads(),
        }
    }
}

/// Sparse-block-output MSE for a candidate per-kind (alpha -> ga, tau)
/// assignment. `sp` must already carry the candidate parameters for this
/// block's seven layers.
fn block_mse(model: &Model, block: usize, bc: &BlockCalib, sp: &ScoredSparsifier) -> f64 {
    let mut stats = ForwardStats::default();
    let out = bc.forward_with(model, block, sp, &mut stats);
    out.mse(&bc.dense_out)
}

/// Build a `ScoredSparsifier` whose entries for `block` follow the given
/// per-kind alphas and keep ratios (thresholds via Eq. 7 on the captured
/// layer inputs). Other blocks are identity (the block forward never
/// touches them).
fn sparsifier_for_block(
    model: &Model,
    block: usize,
    bc: &BlockCalib,
    alphas: &[f64; 7],
    keep_ratios: &[f64; 7],
) -> ScoredSparsifier {
    let mut sp = ScoredSparsifier::identity("wisparse", model.cfg.n_layers * 7);
    for (i, &kind) in LayerKind::ALL.iter().enumerate() {
        let id = LayerId::new(block, kind);
        let (rows, dim) = bc.rows_of(kind, &model.cfg);
        let ga = pow_clamped(model.g(id), alphas[i]);
        let tau = if rows.is_empty() {
            0.0
        } else {
            tau_from_rows(rows, dim, &ga, keep_ratios[i])
        };
        *sp.layer_mut(id) = ScoredLayer { ga: Some(ga), tau };
    }
    sp
}

/// Result of the per-block search.
#[derive(Clone, Debug)]
pub struct BlockAlphas {
    pub alphas: [f64; 7],
    pub mse: f64,
}

/// Coordinate-wise grid search for one block (Alg. 2). `keep_ratios` are
/// the per-kind keep ratios fixed by the earlier allocation stages
/// (r = 1 - sparsity).
pub fn search_block_alphas(
    model: &Model,
    block: usize,
    bc: &BlockCalib,
    keep_ratios: &[f64; 7],
    cfg: &AlphaSearchCfg,
) -> BlockAlphas {
    // Start from alpha = 1 (the WINA operating point) — a good prior.
    let mut alphas = [1.0f64; 7];
    let grid: Vec<f64> = (0..cfg.n_grid)
        .map(|i| i as f64 * cfg.alpha_max / cfg.n_grid as f64)
        .collect();
    let mut best_mse = {
        let sp = sparsifier_for_block(model, block, bc, &alphas, keep_ratios);
        block_mse(model, block, bc, &sp)
    };
    for _pass in 0..cfg.passes.max(1) {
        for li in 0..7 {
            // Evaluate the whole grid for this coordinate in parallel.
            let losses = parallel_map(grid.len(), cfg.threads, |gi| {
                let mut cand = alphas;
                cand[li] = grid[gi];
                let sp = sparsifier_for_block(model, block, bc, &cand, keep_ratios);
                block_mse(model, block, bc, &sp)
            });
            let (gi_best, &loss_best) = losses
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            if loss_best < best_mse {
                best_mse = loss_best;
                alphas[li] = grid[gi_best];
            }
        }
    }
    BlockAlphas {
        alphas,
        mse: best_mse,
    }
}

/// Run Alg. 2 over all blocks, writing alphas and final Eq. 7 thresholds
/// into the plan (keep ratios come from the plan's per-layer sparsities).
pub fn search_alphas_into_plan(
    model: &Model,
    calib_blocks: &[BlockCalib],
    plan: &mut SparsityPlan,
    cfg: &AlphaSearchCfg,
) {
    for b in 0..model.cfg.n_layers {
        let mut keep = [0.0f64; 7];
        for (i, &kind) in LayerKind::ALL.iter().enumerate() {
            keep[i] = 1.0 - plan.layer(LayerId::new(b, kind)).sparsity;
        }
        let result = search_block_alphas(model, b, &calib_blocks[b], &keep, cfg);
        for (i, &kind) in LayerKind::ALL.iter().enumerate() {
            let id = LayerId::new(b, kind);
            plan.layer_mut(id).alpha = result.alphas[i];
        }
        crate::debug!(
            "block {b}: alphas {:?} mse {:.3e}",
            result.alphas,
            result.mse
        );
    }
    finalize_taus(model, calib_blocks, plan);
}

/// Compute the fixed per-layer inference thresholds (Eq. 7) for whatever
/// (alpha, sparsity) the plan currently holds.
pub fn finalize_taus(model: &Model, calib_blocks: &[BlockCalib], plan: &mut SparsityPlan) {
    for b in 0..model.cfg.n_layers {
        for &kind in &LayerKind::ALL {
            let id = LayerId::new(b, kind);
            let lp = *plan.layer(id);
            let keep = 1.0 - lp.sparsity;
            let (rows, dim) = calib_blocks[b].rows_of(kind, &model.cfg);
            let tau = if rows.is_empty() || keep >= 1.0 {
                0.0
            } else {
                let ga = pow_clamped(model.g(id), lp.alpha);
                tau_from_rows(rows, dim, &ga, keep)
            };
            plan.layer_mut(id).tau = tau;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{CalibSet, ModelCalib};
    use crate::model::{Model, ModelConfig};

    fn setup() -> (Model, ModelCalib) {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 13);
        let calib = CalibSet::synthetic(2, 10, m.cfg.vocab_size, 17);
        let mc = ModelCalib::collect(&m, &calib);
        (m, mc)
    }

    #[test]
    fn search_returns_grid_values() {
        let (m, mc) = setup();
        let cfg = AlphaSearchCfg {
            n_grid: 6,
            alpha_max: 1.5,
            passes: 1,
            threads: 2,
        };
        let r = search_block_alphas(&m, 0, &mc.blocks[0], &[0.5; 7], &cfg);
        for a in r.alphas {
            // Either the 1.0 prior or a grid point.
            let on_grid = (0..6).any(|i| (a - i as f64 * 0.25).abs() < 1e-9);
            assert!(on_grid || (a - 1.0).abs() < 1e-9, "alpha {a}");
        }
        assert!(r.mse.is_finite());
    }

    #[test]
    fn weight_aware_beats_activation_only_on_block_mse() {
        // The searched alphas must do at least as well as alpha = 0
        // (activation-only) — Observation 1's fix.
        let (m, mc) = setup();
        let keep = [0.5f64; 7];
        let sp0 = sparsifier_for_block(&m, 0, &mc.blocks[0], &[0.0; 7], &keep);
        let mse0 = block_mse(&m, 0, &mc.blocks[0], &sp0);
        let cfg = AlphaSearchCfg {
            n_grid: 10,
            alpha_max: 1.5,
            passes: 1,
            threads: 2,
        };
        let r = search_block_alphas(&m, 0, &mc.blocks[0], &keep, &cfg);
        assert!(
            r.mse <= mse0 + 1e-12,
            "searched mse {} worse than alpha=0 mse {}",
            r.mse,
            mse0
        );
    }

    #[test]
    fn finalize_taus_hits_keep_ratio() {
        let (m, mc) = setup();
        let mut plan = SparsityPlan::uniform(&m.cfg, "wisparse", 0.4);
        for lp in plan.layers.iter_mut() {
            lp.alpha = 1.0;
        }
        finalize_taus(&m, &mc.blocks, &mut plan);
        // Check realized keep fraction on the calibration pool for a layer.
        let id = LayerId::new(0, LayerKind::Up);
        let (rows, dim) = mc.blocks[0].rows_of(LayerKind::Up, &m.cfg);
        let ga = pow_clamped(m.g(id), 1.0);
        let realized =
            crate::sparsity::score::realized_keep_fraction(rows, dim, &ga, plan.layer(id).tau);
        assert!((realized - 0.6).abs() < 0.05, "realized {realized}");
    }

    #[test]
    fn zero_sparsity_gives_zero_tau() {
        let (m, mc) = setup();
        let mut plan = SparsityPlan::uniform(&m.cfg, "wisparse", 0.0);
        finalize_taus(&m, &mc.blocks, &mut plan);
        assert!(plan.layers.iter().all(|lp| lp.tau == 0.0));
    }
}
