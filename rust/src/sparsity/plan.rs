//! Sparsity plans: the serializable output of the calibration pipeline
//! (Alg. 1) consumed by the serving engine at startup.

use crate::model::layers::{all_layers, LayerId};
use crate::model::ModelConfig;
use crate::util::json::Json;
use std::path::Path;

/// Per-linear-layer calibrated parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerPlan {
    /// Target sparsity (fraction of channels pruned) for this layer.
    pub sparsity: f64,
    /// Weight exponent `alpha_l` (Eq. 4).
    pub alpha: f64,
    /// Fixed inference threshold `tau_l` (Eq. 7).
    pub tau: f32,
}

impl Default for LayerPlan {
    fn default() -> Self {
        Self {
            sparsity: 0.0,
            alpha: 0.0,
            tau: 0.0,
        }
    }
}

/// Calibrated sparsity configuration for one model + method + target.
#[derive(Clone, Debug, PartialEq)]
pub struct SparsityPlan {
    pub model: String,
    pub method: String,
    pub target_sparsity: f64,
    /// Block-level allocation found by the coarse search (len = n_layers).
    pub block_sparsity: Vec<f64>,
    /// Per linear layer, indexed by `LayerId::flat()`.
    pub layers: Vec<LayerPlan>,
}

impl SparsityPlan {
    /// Uniform plan: every layer at `target`, alpha 0 everywhere, taus unset.
    pub fn uniform(cfg: &ModelConfig, method: &str, target: f64) -> Self {
        Self {
            model: cfg.name.clone(),
            method: method.to_string(),
            target_sparsity: target,
            block_sparsity: vec![target; cfg.n_layers],
            layers: vec![
                LayerPlan {
                    sparsity: target,
                    alpha: 0.0,
                    tau: 0.0,
                };
                cfg.n_layers * 7
            ],
        }
    }

    pub fn layer(&self, id: LayerId) -> &LayerPlan {
        &self.layers[id.flat()]
    }

    pub fn layer_mut(&mut self, id: LayerId) -> &mut LayerPlan {
        &mut self.layers[id.flat()]
    }

    /// FLOP-weighted model-level sparsity implied by the per-layer values.
    pub fn effective_sparsity(&self, cfg: &ModelConfig) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for id in all_layers(cfg) {
            let w = crate::model::layers::layer_flops(cfg, id.kind);
            num += w * self.layers[id.flat()].sparsity;
            den += w;
        }
        num / den
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .enumerate()
            .map(|(flat, lp)| {
                Json::obj(vec![
                    ("layer", Json::Str(LayerId::from_flat(flat).key())),
                    ("sparsity", Json::Num(lp.sparsity)),
                    ("alpha", Json::Num(lp.alpha)),
                    ("tau", Json::Num(lp.tau as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("method", Json::Str(self.method.clone())),
            ("target_sparsity", Json::Num(self.target_sparsity)),
            ("block_sparsity", Json::arr_f64(&self.block_sparsity)),
            ("layers", Json::Arr(layers)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SparsityPlan> {
        let block_sparsity = j
            .get("block_sparsity")
            .f64_vec()
            .ok_or_else(|| anyhow::anyhow!("missing block_sparsity"))?;
        let raw_layers = j.req_arr("layers")?;
        let mut layers = vec![LayerPlan::default(); raw_layers.len()];
        for lj in raw_layers {
            let key = lj.req_str("layer")?;
            let id = LayerId::from_key(key)
                .ok_or_else(|| anyhow::anyhow!("bad layer key `{key}`"))?;
            if id.flat() >= layers.len() {
                anyhow::bail!("layer `{key}` out of range");
            }
            layers[id.flat()] = LayerPlan {
                sparsity: lj.req_f64("sparsity")?,
                alpha: lj.req_f64("alpha")?,
                tau: lj.req_f64("tau")? as f32,
            };
        }
        Ok(SparsityPlan {
            model: j.req_str("model")?.to_string(),
            method: j.req_str("method")?.to_string(),
            target_sparsity: j.req_f64("target_sparsity")?,
            block_sparsity,
            layers,
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<SparsityPlan> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Conventional on-disk location for a calibrated plan.
    pub fn default_path(artifacts: &Path, model: &str, method: &str, target: f64) -> std::path::PathBuf {
        artifacts
            .join("plans")
            .join(format!("{model}_{method}_{}.json", (target * 100.0).round() as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerKind;

    #[test]
    fn uniform_plan_shape() {
        let cfg = ModelConfig::preset("llama-micro").unwrap();
        let p = SparsityPlan::uniform(&cfg, "test", 0.5);
        assert_eq!(p.layers.len(), cfg.n_layers * 7);
        assert!((p.effective_sparsity(&cfg) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let mut p = SparsityPlan::uniform(&cfg, "wisparse", 0.4);
        p.layer_mut(LayerId::new(1, LayerKind::Up)).alpha = 0.65;
        p.layer_mut(LayerId::new(0, LayerKind::Q)).tau = 0.123;
        p.block_sparsity = vec![0.3, 0.5];
        let j = p.to_json();
        let p2 = SparsityPlan::from_json(&j).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn save_load() {
        let cfg = ModelConfig::preset("nano").unwrap();
        let p = SparsityPlan::uniform(&cfg, "teal", 0.3);
        let dir = std::env::temp_dir().join("wisparse_plan_test");
        let path = dir.join("plan.json");
        p.save(&path).unwrap();
        assert_eq!(SparsityPlan::load(&path).unwrap(), p);
    }

    #[test]
    fn default_path_encodes_target() {
        let p = SparsityPlan::default_path(Path::new("artifacts"), "llama-micro", "wisparse", 0.5);
        assert!(p.to_string_lossy().contains("llama-micro_wisparse_50.json"));
    }
}
