//! Alg. 1: the full WiSparse calibration pipeline, plus the baseline
//! calibration recipes (how TEAL / R-Sparse / WINA / activation-only derive
//! their plans), so Table 1/2 compare like for like.

use crate::calib::collector::ModelCalib;
use crate::model::layers::{LayerId, LayerKind};
use crate::model::transformer::Model;
use crate::sparsity::alpha_search::{finalize_taus, search_alphas_into_plan, AlphaSearchCfg};
use crate::sparsity::evo::{evolutionary_block_allocation, EvoCfg};
use crate::sparsity::greedy::{greedy_layer_allocation, GreedyCfg};
use crate::sparsity::plan::SparsityPlan;

/// Which components of the pipeline to run — the ablation axis of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineStages {
    /// Weight-aware score (alpha search). Off = activation-only (alpha 0).
    pub weight_aware: bool,
    /// Coarse evolutionary block allocation. Off = uniform blocks.
    pub coarse: bool,
    /// Fine greedy intra-block allocation. Off = uniform within block.
    pub fine: bool,
}

impl PipelineStages {
    pub const FULL: PipelineStages = PipelineStages {
        weight_aware: true,
        coarse: true,
        fine: true,
    };

    /// Table 2 ablation ladder, in paper order.
    pub fn ablation_ladder() -> [(&'static str, PipelineStages); 4] {
        [
            (
                "activation-only",
                PipelineStages {
                    weight_aware: false,
                    coarse: false,
                    fine: false,
                },
            ),
            (
                "+weight-importance",
                PipelineStages {
                    weight_aware: true,
                    coarse: false,
                    fine: false,
                },
            ),
            (
                "+coarse-search",
                PipelineStages {
                    weight_aware: true,
                    coarse: true,
                    fine: false,
                },
            ),
            ("+fine-search", PipelineStages::FULL),
        ]
    }
}

/// Tuning knobs for the full pipeline.
#[derive(Clone, Debug, Default)]
pub struct WiSparseCfg {
    pub evo: EvoCfg,
    pub greedy: GreedyCfg,
    pub alpha: AlphaSearchCfg,
}

/// Alg. 1: coarse block allocation -> fine layer allocation -> alpha search
/// -> final thresholds. Returns a fully-calibrated plan.
pub fn calibrate_wisparse(
    model: &Model,
    calib: &ModelCalib,
    target: f64,
    cfg: &WiSparseCfg,
    stages: PipelineStages,
) -> SparsityPlan {
    let method = if stages == PipelineStages::FULL {
        "wisparse"
    } else {
        "wisparse-ablation"
    };
    let mut plan = SparsityPlan::uniform(&model.cfg, method, target);

    // Stage 1 (coarse): block-level allocation via evolutionary search.
    if stages.coarse {
        let (block_sparsity, trace) =
            evolutionary_block_allocation(model, calib, target, &cfg.evo);
        crate::info!(
            "coarse search: loss {:.4e} -> {:.4e} over {} generations",
            trace.first().map(|t| t.best_loss).unwrap_or(0.0),
            trace.last().map(|t| t.best_loss).unwrap_or(0.0),
            trace.len() - 1
        );
        plan.block_sparsity = block_sparsity;
    }

    // Stage 2 (fine): distribute each block's budget across its layers.
    for b in 0..model.cfg.n_layers {
        let pb = plan.block_sparsity[b];
        if stages.fine {
            let per_kind = greedy_layer_allocation(model, b, &calib.blocks[b], pb, &cfg.greedy);
            for (i, &kind) in LayerKind::ALL.iter().enumerate() {
                plan.layer_mut(LayerId::new(b, kind)).sparsity = per_kind[i];
            }
        } else {
            for &kind in &LayerKind::ALL {
                plan.layer_mut(LayerId::new(b, kind)).sparsity = pb;
            }
        }
    }

    // Stage 3: weight exponents (Alg. 2) + final Eq. 7 thresholds.
    if stages.weight_aware {
        search_alphas_into_plan(model, &calib.blocks, &mut plan, &cfg.alpha);
    } else {
        for lp in plan.layers.iter_mut() {
            lp.alpha = 0.0;
        }
        finalize_taus(model, &calib.blocks, &mut plan);
    }
    plan
}

/// TEAL baseline: activation-magnitude score (alpha = 0), uniform block
/// allocation, greedy intra-block allocation (their recipe), thresholds via
/// quantile calibration.
pub fn calibrate_teal(
    model: &Model,
    calib: &ModelCalib,
    target: f64,
    greedy_cfg: &GreedyCfg,
) -> SparsityPlan {
    let mut plan = SparsityPlan::uniform(&model.cfg, "teal", target);
    let cfg = GreedyCfg {
        search_alpha: 0.0,
        ..greedy_cfg.clone()
    };
    for b in 0..model.cfg.n_layers {
        let per_kind = greedy_layer_allocation(model, b, &calib.blocks[b], target, &cfg);
        for (i, &kind) in LayerKind::ALL.iter().enumerate() {
            plan.layer_mut(LayerId::new(b, kind)).sparsity = per_kind[i];
        }
    }
    for lp in plan.layers.iter_mut() {
        lp.alpha = 0.0;
    }
    finalize_taus(model, &calib.blocks, &mut plan);
    plan
}

/// R-Sparse baseline plan: uniform allocation, magnitude thresholds; the
/// low-rank side path is attached by `RSparse::from_plan`.
pub fn calibrate_rsparse(model: &Model, calib: &ModelCalib, target: f64) -> SparsityPlan {
    let mut plan = SparsityPlan::uniform(&model.cfg, "rsparse", target);
    for lp in plan.layers.iter_mut() {
        lp.alpha = 0.0;
    }
    finalize_taus(model, &calib.blocks, &mut plan);
    plan
}

/// WINA baseline: `|x| * ||W:,i||` score (alpha = 1 fixed), uniform
/// allocation — the paper's description of Chen et al. 2025.
pub fn calibrate_wina(model: &Model, calib: &ModelCalib, target: f64) -> SparsityPlan {
    let mut plan = SparsityPlan::uniform(&model.cfg, "wina", target);
    for lp in plan.layers.iter_mut() {
        lp.alpha = 1.0;
    }
    finalize_taus(model, &calib.blocks, &mut plan);
    plan
}

/// Activation-only baseline: |x| score, uniform allocation (Table 2 row 1).
pub fn calibrate_activation_only(model: &Model, calib: &ModelCalib, target: f64) -> SparsityPlan {
    let mut plan = SparsityPlan::uniform(&model.cfg, "activation-only", target);
    for lp in plan.layers.iter_mut() {
        lp.alpha = 0.0;
    }
    finalize_taus(model, &calib.blocks, &mut plan);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::CalibSet;
    use crate::model::ModelConfig;
    use crate::sparsity::alpha_search::AlphaSearchCfg;
    use crate::sparsity::evo::EvoCfg;

    fn quick_cfg() -> WiSparseCfg {
        WiSparseCfg {
            evo: EvoCfg {
                generations: 2,
                offspring: 3,
                eps: 0.05,
                threads: 2,
                ..EvoCfg::default()
            },
            greedy: GreedyCfg {
                step: 0.1,
                threads: 2,
                ..GreedyCfg::default()
            },
            alpha: AlphaSearchCfg {
                n_grid: 4,
                passes: 1,
                threads: 2,
                ..AlphaSearchCfg::default()
            },
        }
    }

    fn setup() -> (Model, ModelCalib) {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 53);
        let calib = CalibSet::synthetic(2, 8, m.cfg.vocab_size, 59);
        let mc = ModelCalib::collect(&m, &calib);
        (m, mc)
    }

    #[test]
    fn full_pipeline_produces_valid_plan() {
        let (m, mc) = setup();
        let plan = calibrate_wisparse(&m, &mc, 0.5, &quick_cfg(), PipelineStages::FULL);
        // Budget respected (block average <= target + step slack).
        let mean: f64 =
            plan.block_sparsity.iter().sum::<f64>() / plan.block_sparsity.len() as f64;
        assert!(mean <= 0.5 + 1e-9);
        // Effective layer-level sparsity near target.
        let eff = plan.effective_sparsity(&m.cfg);
        assert!(eff > 0.3 && eff < 0.7, "effective {eff}");
        // Alphas on the search grid, thresholds finite.
        assert!(plan.layers.iter().all(|lp| lp.alpha >= 0.0 && lp.alpha <= 1.5));
        assert!(plan.layers.iter().all(|lp| lp.tau.is_finite()));
        assert_eq!(plan.method, "wisparse");
    }

    #[test]
    fn ablation_stages_differ() {
        let (m, mc) = setup();
        let ladder = PipelineStages::ablation_ladder();
        let p0 = calibrate_wisparse(&m, &mc, 0.5, &quick_cfg(), ladder[0].1);
        let p1 = calibrate_wisparse(&m, &mc, 0.5, &quick_cfg(), ladder[1].1);
        // Stage 0 has alpha = 0 everywhere; stage 1 must have searched some.
        assert!(p0.layers.iter().all(|lp| lp.alpha == 0.0));
        assert!(p1.layers.iter().any(|lp| lp.alpha != 0.0));
        // Stage 0/1 keep uniform blocks.
        assert!(p0
            .block_sparsity
            .iter()
            .all(|&p| (p - 0.5).abs() < 1e-12));
    }

    #[test]
    fn baselines_have_expected_signatures() {
        let (m, mc) = setup();
        let teal = calibrate_teal(&m, &mc, 0.4, &quick_cfg().greedy);
        assert!(teal.layers.iter().all(|lp| lp.alpha == 0.0));
        assert_eq!(teal.method, "teal");
        let wina = calibrate_wina(&m, &mc, 0.4);
        assert!(wina.layers.iter().all(|lp| lp.alpha == 1.0));
        assert!(wina.layers.iter().all(|lp| (lp.sparsity - 0.4).abs() < 1e-12));
        let rs = calibrate_rsparse(&m, &mc, 0.4);
        assert_eq!(rs.method, "rsparse");
        let act = calibrate_activation_only(&m, &mc, 0.4);
        assert!(act.layers.iter().all(|lp| lp.alpha == 0.0 && (lp.sparsity - 0.4).abs() < 1e-12));
    }
}
