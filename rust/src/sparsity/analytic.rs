//! Analytical threshold calibration — the paper's future-work item
//! ("developing more efficient or analytical methods for this step would
//! enhance the framework's practicality").
//!
//! Instead of pooling every calibration score and taking an empirical
//! quantile (Eq. 7, O(T·n) memory and a quickselect per layer), model each
//! channel's activation as zero-mean Gaussian with per-channel std
//! `sigma_c` estimated from calibration in one streaming pass. The score
//! `s_c = |x_c| * ga_c` is then half-normal with scale `sigma_c * ga_c`,
//! and the keep ratio at threshold tau is
//!
//!   keep(tau) = (1/n) * sum_c erfc( tau / (sqrt(2) * sigma_c * ga_c) )
//!
//! which is continuous and strictly decreasing in tau, so the tau hitting a
//! target keep ratio is found by bisection. Memory drops from O(T·n) to
//! O(n); accuracy depends on how Gaussian the activations are (tested
//! against the empirical calibrator below, and ablatable via
//! `--tau-mode analytic` on the calibrate command).

/// Complementary error function, Abramowitz & Stegun 7.1.26 rational
/// approximation (|error| <= 1.5e-7 — far below calibration noise).
pub fn erfc(x: f64) -> f64 {
    let sign_negative = x < 0.0;
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let erfc_pos = poly * (-x * x).exp();
    if sign_negative {
        2.0 - erfc_pos
    } else {
        erfc_pos
    }
}

/// Per-channel std estimates from flat calibration rows (`[n_rows * dim]`),
/// one streaming pass, zero-mean model (activations feeding linear layers
/// are post-norm and approximately centered).
pub fn channel_stds(rows: &[f32], dim: usize) -> Vec<f64> {
    assert!(dim > 0 && rows.len() % dim == 0);
    let n_rows = (rows.len() / dim).max(1);
    let mut sumsq = vec![0.0f64; dim];
    for row in rows.chunks_exact(dim) {
        for (c, &v) in row.iter().enumerate() {
            sumsq[c] += (v as f64) * (v as f64);
        }
    }
    sumsq
        .into_iter()
        .map(|s| (s / n_rows as f64).sqrt().max(1e-12))
        .collect()
}

/// Expected keep fraction at threshold `tau` under the half-normal model.
pub fn expected_keep(tau: f64, sigmas: &[f64], ga: &[f32]) -> f64 {
    assert_eq!(sigmas.len(), ga.len());
    let n = sigmas.len().max(1);
    let mut acc = 0.0;
    for (s, &g) in sigmas.iter().zip(ga) {
        let scale = s * (g as f64).max(1e-12);
        acc += erfc(tau / (std::f64::consts::SQRT_2 * scale));
    }
    acc / n as f64
}

/// Analytical tau for a target keep ratio: bisection on the monotone
/// `expected_keep`. Returns 0.0 / +inf at the extremes like the empirical
/// calibrator.
pub fn tau_analytic(rows: &[f32], dim: usize, ga: &[f32], keep_ratio: f64) -> f32 {
    assert_eq!(ga.len(), dim);
    if keep_ratio >= 1.0 {
        return 0.0;
    }
    if keep_ratio <= 0.0 {
        return f32::INFINITY;
    }
    let sigmas = channel_stds(rows, dim);
    // Bracket: tau=0 keeps everything; grow hi until keep < target.
    let max_scale = sigmas
        .iter()
        .zip(ga)
        .map(|(s, &g)| s * g as f64)
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let mut lo = 0.0f64;
    let mut hi = 8.0 * max_scale;
    let mut guard = 0;
    while expected_keep(hi, &sigmas, ga) > keep_ratio && guard < 60 {
        hi *= 2.0;
        guard += 1;
    }
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if expected_keep(mid, &sigmas, ga) > keep_ratio {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (0.5 * (lo + hi)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::score::{realized_keep_fraction, tau_from_rows};
    use crate::util::rng::Pcg64;

    #[test]
    fn erfc_reference_values() {
        // erfc(0) = 1, erfc(inf) -> 0, erfc(-x) = 2 - erfc(x).
        assert!((erfc(0.0) - 1.0).abs() < 1e-7);
        assert!(erfc(4.0) < 2e-8);
        assert!((erfc(-1.0) - (2.0 - erfc(1.0))).abs() < 1e-12);
        // erfc(1) = 0.157299...
        assert!((erfc(1.0) - 0.1572992).abs() < 1e-6);
        // erfc(0.5) = 0.4795001...
        assert!((erfc(0.5) - 0.4795001).abs() < 1e-6);
    }

    fn gaussian_rows(dim: usize, n_rows: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        // Heterogeneous channel scales, like real activations.
        let scales: Vec<f32> = (0..dim).map(|_| 0.2 + 1.8 * rng.next_f32()).collect();
        let mut rows = Vec::with_capacity(dim * n_rows);
        for _ in 0..n_rows {
            for s in &scales {
                rows.push(rng.normal() as f32 * s);
            }
        }
        let ga: Vec<f32> = (0..dim).map(|_| rng.next_f32() + 0.1).collect();
        (rows, ga)
    }

    #[test]
    fn channel_stds_recover_scales() {
        let mut rng = Pcg64::new(3);
        let dim = 8;
        let scales: Vec<f64> = (0..dim).map(|_| 0.5 + rng.next_f64()).collect();
        let mut rows = Vec::new();
        for _ in 0..4000 {
            for s in &scales {
                rows.push((rng.normal() * s) as f32);
            }
        }
        let est = channel_stds(&rows, dim);
        for (e, s) in est.iter().zip(&scales) {
            assert!((e / s - 1.0).abs() < 0.06, "est {e} true {s}");
        }
    }

    #[test]
    fn analytic_matches_empirical_on_gaussian_data() {
        // The future-work estimator must agree with Eq. 7's empirical
        // quantile when the Gaussian assumption holds.
        let (rows, ga) = gaussian_rows(32, 400, 7);
        for keep in [0.3, 0.5, 0.7, 0.9] {
            let tau_a = tau_analytic(&rows, 32, &ga, keep);
            let tau_e = tau_from_rows(&rows, 32, &ga, keep);
            let realized_a = realized_keep_fraction(&rows, 32, &ga, tau_a);
            assert!(
                (realized_a - keep).abs() < 0.03,
                "keep {keep}: analytic realizes {realized_a}"
            );
            // Thresholds should be in the same ballpark.
            assert!(
                (tau_a / tau_e - 1.0).abs() < 0.2,
                "keep {keep}: tau_a {tau_a} vs tau_e {tau_e}"
            );
        }
    }

    #[test]
    fn monotone_in_keep_ratio() {
        let (rows, ga) = gaussian_rows(16, 200, 11);
        let t30 = tau_analytic(&rows, 16, &ga, 0.3);
        let t60 = tau_analytic(&rows, 16, &ga, 0.6);
        let t90 = tau_analytic(&rows, 16, &ga, 0.9);
        assert!(t30 > t60 && t60 > t90);
    }

    #[test]
    fn extremes() {
        let (rows, ga) = gaussian_rows(8, 50, 13);
        assert_eq!(tau_analytic(&rows, 8, &ga, 1.0), 0.0);
        assert_eq!(tau_analytic(&rows, 8, &ga, 0.0), f32::INFINITY);
    }

    #[test]
    fn expected_keep_monotone_decreasing() {
        let sigmas = vec![1.0f64; 10];
        let ga = vec![1.0f32; 10];
        let mut prev = 1.1;
        for i in 0..20 {
            let k = expected_keep(i as f64 * 0.3, &sigmas, &ga);
            assert!(k <= prev + 1e-12);
            prev = k;
        }
    }
}
