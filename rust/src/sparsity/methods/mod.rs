//! Sparsifier implementations: WiSparse and the baselines it is compared
//! against in Table 1/2 (TEAL, R-Sparse, WINA, activation-only).
//!
//! All scored methods share [`ScoredSparsifier`] — the only differences
//! between WiSparse, WINA, TEAL and activation-only are *how the per-layer
//! `(ga, tau)` parameters are calibrated*, which happens in
//! `sparsity::allocator`. R-Sparse additionally carries a low-rank side
//! path per layer.

mod scored;
mod rsparse;

pub use rsparse::{RSparse, RSparseLayer};
pub use scored::{ScoredLayer, ScoredSparsifier};
