//! R-Sparse baseline (Zhang et al., ICLR 2025): magnitude-thresholded sparse
//! path for high-|x| channels plus a precomputed rank-r low-rank path for the
//! pruned remainder, so no input information is fully discarded.

use crate::model::layers::LayerId;
use crate::model::transformer::Model;
use crate::quant::WeightRepr;
use crate::sparsity::plan::SparsityPlan;
use crate::sparsity::Sparsifier;
use crate::tensor::linalg::{truncated_svd, TruncatedSvd};
use std::cell::RefCell;

/// Per-layer R-Sparse parameters.
pub struct RSparseLayer {
    /// Magnitude threshold for the exact path.
    pub tau: f32,
    /// Low-rank approximation of the layer's weight for the pruned channels.
    pub svd: TruncatedSvd,
    pub rank: usize,
}

/// The R-Sparse sparsifier.
pub struct RSparse {
    layers: Vec<RSparseLayer>,
    ones: Vec<Vec<f32>>, // per-layer all-ones ga (score = |x|), cached
}

thread_local! {
    static SCRATCH: RefCell<(Vec<usize>, Vec<f32>, Vec<bool>)> =
        const { RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

impl RSparse {
    /// Build from a plan: thresholds come from the plan (magnitude-score
    /// calibrated), the low-rank factors are computed here from the model
    /// weights. `rank` follows the R-Sparse recipe of a small fixed rank
    /// relative to the layer width.
    pub fn from_plan(model: &Model, plan: &SparsityPlan, rank: usize) -> Self {
        let mut layers = Vec::with_capacity(plan.layers.len());
        let mut ones = Vec::with_capacity(plan.layers.len());
        for (flat, lp) in plan.layers.iter().enumerate() {
            let id = LayerId::from_flat(flat);
            let w = model.w(id).to_row_major();
            let r = rank.min(w.shape[0] / 2).min(w.shape[1] / 2).max(1);
            layers.push(RSparseLayer {
                tau: lp.tau,
                svd: truncated_svd(&w, r, 10, 0x5EED ^ flat as u64),
                rank: r,
            });
            ones.push(vec![1.0f32; w.shape[1]]);
        }
        Self { layers, ones }
    }

    pub fn layer(&self, id: LayerId) -> &RSparseLayer {
        &self.layers[id.flat()]
    }
}

impl Sparsifier for RSparse {
    fn name(&self) -> &'static str {
        "rsparse"
    }

    fn project(&self, layer: LayerId, x: &[f32], w: &dyn WeightRepr, out: &mut [f32]) -> usize {
        let lp = &self.layers[layer.flat()];
        let (m, n) = (w.out_dim(), w.in_dim());
        SCRATCH.with(|cell| {
            let (kept, lowrank_out, is_kept) = &mut *cell.borrow_mut();
            lowrank_out.resize(m, 0.0);
            is_kept.resize(n, false);
            // Exact path over high-magnitude channels.
            let n_kept =
                w.gemv_masked_collect(x, &self.ones[layer.flat()], lp.tau, out, kept);
            // Low-rank path over the complement.
            is_kept.iter_mut().for_each(|b| *b = false);
            for &c in kept.iter() {
                is_kept[c] = true;
            }
            let complement: Vec<usize> = (0..n).filter(|&c| !is_kept[c]).collect();
            lp.svd.matvec_subset(x, &complement, lowrank_out);
            for i in 0..m {
                out[i] += lowrank_out[i];
            }
            n_kept
        })
    }

    fn extra_macs(&self, layer: LayerId, w: &dyn WeightRepr) -> u64 {
        // diag(s) V^T x over ~all channels + U t: (n + m) * r.
        let r = self.layers[layer.flat()].rank as u64;
        (w.in_dim() as u64 + w.out_dim() as u64) * r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layers::all_layers;
    use crate::model::transformer::ForwardStats;
    use crate::model::{Model, ModelConfig};
    use crate::sparsity::Dense;

    fn nano() -> Model {
        Model::synthetic(ModelConfig::preset("nano").unwrap(), 11)
    }

    #[test]
    fn zero_threshold_matches_dense() {
        // tau = 0 keeps every channel exactly; the low-rank path sees an
        // empty complement, so R-Sparse must equal dense.
        let m = nano();
        let plan = SparsityPlan::uniform(&m.cfg, "rsparse", 0.0);
        let sp = RSparse::from_plan(&m, &plan, 4);
        let mut s1 = ForwardStats::default();
        let mut s2 = ForwardStats::default();
        let a = m.forward_seq(&[2, 7, 1], &Dense, &mut s1, None);
        let b = m.forward_seq(&[2, 7, 1], &sp, &mut s2, None);
        assert!(a.max_abs_diff(&b) < 1e-4);
    }

    #[test]
    fn lowrank_path_reduces_error_vs_plain_pruning() {
        use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};
        let m = nano();
        // Calibrate a fairly aggressive magnitude threshold on both methods.
        let mut plan = SparsityPlan::uniform(&m.cfg, "rsparse", 0.6);
        for lp in plan.layers.iter_mut() {
            lp.tau = 0.6;
        }
        let rsp = RSparse::from_plan(&m, &plan, 8);
        let teal = ScoredSparsifier::new(
            "teal",
            (0..m.cfg.n_layers * 7)
                .map(|_| ScoredLayer { ga: None, tau: 0.6 })
                .collect(),
        );
        let mut s = ForwardStats::default();
        let dense = m.forward_seq(&[5, 9, 2, 8], &Dense, &mut s, None);
        let with_lr = m.forward_seq(&[5, 9, 2, 8], &rsp, &mut s, None);
        let without = m.forward_seq(&[5, 9, 2, 8], &teal, &mut s, None);
        let err_lr = dense.mse(&with_lr);
        let err_plain = dense.mse(&without);
        assert!(
            err_lr < err_plain,
            "low-rank residual should reduce error: {err_lr} vs {err_plain}"
        );
    }

    #[test]
    fn extra_macs_accounted() {
        let m = nano();
        let plan = SparsityPlan::uniform(&m.cfg, "rsparse", 0.5);
        let sp = RSparse::from_plan(&m, &plan, 4);
        for id in all_layers(&m.cfg) {
            let w = m.w(id);
            let extra = sp.extra_macs(id, w);
            assert!(extra > 0);
            assert!(
                extra < (w.out_dim() * w.in_dim()) as u64,
                "low-rank must be cheaper than dense"
            );
        }
    }
}
