//! Threshold sparsifiers driven by the score `s_i = |x_i| * ga_i` (Eq. 4-5).
//!
//! `ga = g^alpha` (clamped) makes this WiSparse/WINA; `ga = None` (implicit
//! ones) makes it TEAL/activation-only magnitude thresholding — the kernel
//! then skips the multiply entirely.

use crate::model::layers::LayerId;
use crate::model::transformer::Model;
use crate::quant::WeightRepr;
use crate::sparse_kernel::gemv::sparse_gemv_scored_x4;
use crate::sparse_kernel::sparse_gemv_threshold;
use crate::sparsity::plan::SparsityPlan;
use crate::sparsity::score::pow_clamped;
use crate::sparsity::Sparsifier;
use std::cell::RefCell;

thread_local! {
    /// Reusable kept-index scratch for the two-pass fused kernel: one buffer
    /// per worker thread, grown once to the widest layer and never freed, so
    /// steady-state projections allocate nothing.
    static KEPT_IDX: RefCell<Vec<u32>> = const { RefCell::new(Vec::new()) };
}

/// Per-layer scored-mask parameters.
#[derive(Clone, Debug, Default)]
pub struct ScoredLayer {
    /// Precomputed `g^alpha` (None = activation-only magnitude score).
    pub ga: Option<Vec<f32>>,
    /// Fixed inference threshold; 0.0 keeps everything.
    pub tau: f32,
}

/// A fully-calibrated scored sparsifier covering every linear layer.
pub struct ScoredSparsifier {
    method: &'static str,
    layers: Vec<ScoredLayer>,
    /// Plan target keep-fraction per flat layer (empty when hand-built);
    /// telemetry reports achieved-vs-planned drift against it.
    planned: Vec<f64>,
    /// Thread budget for intra-GEMV row parallelism on large-output layers
    /// (`gate`/`up`-sized and beyond; small layers never split).
    intra_threads: usize,
    /// Route through the pre-SIMD kernels (auto-vectorized x4 fused for the
    /// scored path, single-pass scalar for the threshold path) instead of
    /// the dispatched fused path — the honest "before this backend existed"
    /// A/B baseline in `benches/e2e_decode.rs`.
    force_scalar: bool,
}

impl ScoredSparsifier {
    pub fn new(method: &'static str, layers: Vec<ScoredLayer>) -> Self {
        Self {
            method,
            layers,
            planned: Vec::new(),
            intra_threads: crate::util::threadpool::num_threads_cached(),
            force_scalar: false,
        }
    }

    /// All-pass instance (tau = 0 everywhere): behaves exactly like dense.
    pub fn identity(method: &'static str, n_layers_flat: usize) -> Self {
        Self::new(method, vec![ScoredLayer::default(); n_layers_flat])
    }

    /// Force the pre-SIMD kernels (the exact projection path this codebase
    /// used before the dispatched backend), selectable per-sparsifier for
    /// A/B benchmarking.
    pub fn force_scalar(mut self, on: bool) -> Self {
        self.force_scalar = on;
        self
    }

    /// Cap the intra-GEMV thread budget (1 disables row splitting).
    pub fn with_intra_threads(mut self, threads: usize) -> Self {
        self.intra_threads = threads.max(1);
        self
    }

    /// Build from a calibrated plan: `ga = g^alpha` per layer, thresholds
    /// straight from the plan (they were computed against calibration
    /// activations by the allocator).
    pub fn from_plan(method: &'static str, model: &Model, plan: &SparsityPlan) -> Self {
        assert_eq!(plan.layers.len(), model.cfg.n_layers * 7, "plan/model mismatch");
        let layers = plan
            .layers
            .iter()
            .enumerate()
            .map(|(flat, lp)| {
                let ga = if lp.alpha == 0.0 {
                    None // score reduces to |x|; use the cheaper kernel
                } else {
                    Some(pow_clamped(
                        model.g(LayerId::from_flat(flat)),
                        lp.alpha,
                    ))
                };
                ScoredLayer { ga, tau: lp.tau }
            })
            .collect();
        let mut sp = Self::new(method, layers);
        sp.planned = plan.layers.iter().map(|lp| 1.0 - lp.sparsity).collect();
        sp
    }

    pub fn layer(&self, id: LayerId) -> &ScoredLayer {
        &self.layers[id.flat()]
    }

    pub fn layer_mut(&mut self, id: LayerId) -> &mut ScoredLayer {
        &mut self.layers[id.flat()]
    }

    pub fn n_layers_flat(&self) -> usize {
        self.layers.len()
    }
}

impl Sparsifier for ScoredSparsifier {
    fn name(&self) -> &'static str {
        self.method
    }

    fn project(&self, layer: LayerId, x: &[f32], w: &dyn WeightRepr, out: &mut [f32]) -> usize {
        let lp = &self.layers[layer.flat()];
        if self.force_scalar {
            // The pre-SIMD production path, kept verbatim for A/B runs. It
            // only ever existed for f32 columns; quantized weights fall
            // through to the fused path below.
            if let Some(dense) = w.as_dense() {
                return match &lp.ga {
                    Some(ga) => sparse_gemv_scored_x4(dense, x, ga, lp.tau, out),
                    None => sparse_gemv_threshold(dense, x, lp.tau, out),
                };
            }
        }
        // Two-pass fused SIMD kernel for both the WiSparse/WINA (`ga`) and
        // the TEAL (`ga = None`) score; the kept-index scratch is per-thread
        // and reused across layers and tokens. Quantized weights take the
        // same path through the fused dequant kernels.
        // The builder cap and the current thread's scoped budget (see
        // `with_intra_op_threads`) both bound the row split, so batched
        // decode never multiplies to threads^2.
        let threads = self
            .intra_threads
            .min(crate::util::threadpool::intra_op_threads());
        KEPT_IDX.with(|cell| {
            let kept_idx = &mut *cell.borrow_mut();
            w.gemv_masked(x, lp.ga.as_deref(), lp.tau, out, kept_idx, threads)
        })
    }

    fn planned_density(&self, layer: LayerId) -> Option<f64> {
        self.planned.get(layer.flat()).copied()
    }

    fn project_batch(
        &self,
        layer: LayerId,
        xs: &[f32],
        in_stride: usize,
        w: &dyn WeightRepr,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        kept_out: &mut [usize],
    ) -> usize {
        if self.force_scalar && w.as_dense().is_some() {
            // The pre-SIMD kernels have no batched form; keep the A/B
            // baseline honest by running them per position.
            let mut streamed = 0usize;
            for p in 0..n_pos {
                let x = &xs[p * in_stride..p * in_stride + w.in_dim()];
                let out = &mut outs[p * out_stride..p * out_stride + w.out_dim()];
                kept_out[p] = self.project(layer, x, w, out);
                streamed += kept_out[p];
            }
            return streamed;
        }
        let lp = &self.layers[layer.flat()];
        let threads = self
            .intra_threads
            .min(crate::util::threadpool::intra_op_threads());
        w.gemv_masked_batch(
            xs,
            in_stride,
            lp.ga.as_deref(),
            lp.tau,
            outs,
            out_stride,
            n_pos,
            kept_out,
            threads,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layers::{all_layers, LayerKind};
    use crate::model::transformer::ForwardStats;
    use crate::model::{Model, ModelConfig};
    use crate::sparsity::Dense;

    fn nano() -> Model {
        Model::synthetic(ModelConfig::preset("nano").unwrap(), 7)
    }

    #[test]
    fn identity_matches_dense_forward() {
        let m = nano();
        let sp = ScoredSparsifier::identity("wisparse", m.cfg.n_layers * 7);
        let mut s1 = ForwardStats::default();
        let mut s2 = ForwardStats::default();
        let a = m.forward_seq(&[3, 1, 4, 1, 5], &Dense, &mut s1, None);
        let b = m.forward_seq(&[3, 1, 4, 1, 5], &sp, &mut s2, None);
        assert!(a.max_abs_diff(&b) < 1e-5);
        assert_eq!(s1.macs_kept, s2.macs_kept);
    }

    #[test]
    fn thresholds_reduce_density() {
        let m = nano();
        let mut sp = ScoredSparsifier::identity("teal", m.cfg.n_layers * 7);
        for id in all_layers(&m.cfg) {
            sp.layer_mut(id).tau = 0.5; // aggressive magnitude cut
        }
        let mut stats = ForwardStats::default();
        let _ = m.forward_seq(&[3, 1, 4, 1, 5], &sp, &mut stats, None);
        assert!(stats.density() < 1.0, "density {}", stats.density());
        assert!(stats.macs_kept < stats.macs_dense);
    }

    #[test]
    fn from_plan_uses_alpha() {
        let m = nano();
        let mut plan = SparsityPlan::uniform(&m.cfg, "wisparse", 0.5);
        let id = crate::model::LayerId::new(0, LayerKind::Up);
        plan.layer_mut(id).alpha = 1.0;
        let sp = ScoredSparsifier::from_plan("wisparse", &m, &plan);
        let lp = sp.layer(id);
        let ga = lp.ga.as_ref().expect("alpha=1 -> explicit ga");
        // ga must equal the weight column norms (alpha = 1).
        for (a, b) in ga.iter().zip(m.g(id)) {
            assert!((a - b.max(1e-4)).abs() < 1e-6);
        }
        // alpha = 0 layers use the implicit-ones fast path.
        assert!(sp
            .layer(crate::model::LayerId::new(0, LayerKind::Q))
            .ga
            .is_none());
    }
}
