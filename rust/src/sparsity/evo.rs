//! Alg. 3: evolutionary block-level sparsity allocation (the coarse stage).
//!
//! Candidates are block-sparsity vectors constrained to average to the
//! global target. Offspring are produced by localized mutation (raise a
//! random ~10% of blocks by `eps`, then lower random blocks until the
//! constraint holds), and selected by the token-averaged KL divergence
//! between dense and sparse logits (Eq. 8) on the calibration set.

use crate::calib::collector::ModelCalib;
use crate::eval::kl::mean_token_kl;
use crate::model::layers::{LayerId, LayerKind};
use crate::model::transformer::{ForwardStats, Model};
use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};
use crate::sparsity::score::{pow_clamped, tau_from_rows};
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_map;

/// Evolutionary-search configuration. Paper defaults: 400 generations,
/// 64 offspring, eps 0.5%, 10% of blocks mutated. The defaults here are
/// scaled to the micro models; the paper values are reachable via CLI
/// flags.
#[derive(Clone, Debug)]
pub struct EvoCfg {
    pub generations: usize,
    pub offspring: usize,
    /// Mutation step size (paper: 0.005).
    pub eps: f64,
    /// Fraction of blocks mutated per offspring (paper: 0.1).
    pub mutate_frac: f64,
    /// Sparsity clamp range per block.
    pub min_sparsity: f64,
    pub max_sparsity: f64,
    pub seed: u64,
    pub threads: usize,
    /// The alpha used to score channels during the search (the exponent
    /// search runs later in the pipeline; 1.0 = WINA operating point).
    pub search_alpha: f64,
}

impl Default for EvoCfg {
    fn default() -> Self {
        Self {
            generations: 40,
            offspring: 16,
            eps: 0.02,
            mutate_frac: 0.1,
            min_sparsity: 0.0,
            max_sparsity: 0.95,
            seed: 0xE0_5EED,
            threads: crate::util::threadpool::num_threads(),
            search_alpha: 1.0,
        }
    }
}

/// Mutate a parent allocation per Alg. 3: raise a random subset, then lower
/// random blocks until the mean returns to the target.
pub fn mutate(parent: &[f64], target: f64, cfg: &EvoCfg, rng: &mut Pcg64) -> Vec<f64> {
    let n = parent.len();
    let mut child = parent.to_vec();
    let num_flips = ((n as f64 * cfg.mutate_frac).floor() as usize).max(1);
    for _ in 0..num_flips {
        let b = rng.below(n);
        child[b] = (child[b] + cfg.eps).min(cfg.max_sparsity);
    }
    // Constraint enforcement: average back down to the target.
    let mut guard = 0usize;
    while mean(&child) > target + 1e-12 && guard < 10_000 {
        let b = rng.below(n);
        if child[b] > cfg.min_sparsity {
            child[b] = (child[b] - cfg.eps).max(cfg.min_sparsity);
        }
        guard += 1;
    }
    child
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Build a uniform-within-block sparsifier for a candidate block allocation:
/// every layer in block `b` runs at keep ratio `1 - p[b]`, thresholds from
/// Eq. 7 over the captured layer inputs, score exponent `search_alpha`.
pub fn sparsifier_for_allocation(
    model: &Model,
    calib: &ModelCalib,
    p: &[f64],
    alpha: f64,
) -> ScoredSparsifier {
    let mut sp = ScoredSparsifier::identity("evo-candidate", model.cfg.n_layers * 7);
    for (b, &pb) in p.iter().enumerate() {
        let keep = (1.0 - pb).clamp(0.0, 1.0);
        for &kind in &LayerKind::ALL {
            let id = LayerId::new(b, kind);
            let (rows, dim) = calib.blocks[b].rows_of(kind, &model.cfg);
            let ga = pow_clamped(model.g(id), alpha);
            let tau = if rows.is_empty() || keep >= 1.0 {
                0.0
            } else {
                tau_from_rows(rows, dim, &ga, keep)
            };
            *sp.layer_mut(id) = ScoredLayer { ga: Some(ga), tau };
        }
    }
    sp
}

/// Eq. 8: mean token-level KL(dense || sparse) over the calibration set for
/// a candidate allocation.
pub fn allocation_loss(model: &Model, calib: &ModelCalib, p: &[f64], alpha: f64) -> f64 {
    let sp = sparsifier_for_allocation(model, calib, p, alpha);
    let mut stats = ForwardStats::default();
    let mut total = 0.0f64;
    for (seq, dense_logits) in calib.seqs.iter().zip(&calib.dense_logits) {
        let sparse_logits = model.forward_seq(seq, &sp, &mut stats, None);
        total += mean_token_kl(dense_logits, &sparse_logits);
    }
    total / calib.seqs.len() as f64
}

/// Search trace entry (per generation) for reporting/diagnostics.
#[derive(Clone, Debug)]
pub struct EvoTrace {
    pub generation: usize,
    pub best_loss: f64,
}

/// Run Alg. 3. Returns the best block allocation and the per-generation
/// loss trace.
pub fn evolutionary_block_allocation(
    model: &Model,
    calib: &ModelCalib,
    target: f64,
    cfg: &EvoCfg,
) -> (Vec<f64>, Vec<EvoTrace>) {
    let n = model.cfg.n_layers;
    let mut parent = vec![target; n];
    let mut parent_loss = allocation_loss(model, calib, &parent, cfg.search_alpha);
    let mut trace = vec![EvoTrace {
        generation: 0,
        best_loss: parent_loss,
    }];
    let mut rng = Pcg64::new(cfg.seed);
    for generation in 1..=cfg.generations {
        // Generate offspring serially (cheap), evaluate in parallel
        // (expensive: one sparse forward over the calibration set each).
        let offspring: Vec<Vec<f64>> = (0..cfg.offspring)
            .map(|_| mutate(&parent, target, cfg, &mut rng))
            .collect();
        let losses = parallel_map(offspring.len(), cfg.threads, |i| {
            allocation_loss(model, calib, &offspring[i], cfg.search_alpha)
        });
        let (best_i, &best_loss) = losses
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if best_loss < parent_loss {
            parent = offspring[best_i].clone();
            parent_loss = best_loss;
        }
        trace.push(EvoTrace {
            generation,
            best_loss: parent_loss,
        });
    }
    (parent, trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::{CalibSet, ModelCalib};
    use crate::model::{Model, ModelConfig};

    fn setup() -> (Model, ModelCalib) {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 23);
        let calib = CalibSet::synthetic(2, 8, m.cfg.vocab_size, 29);
        let mc = ModelCalib::collect(&m, &calib);
        (m, mc)
    }

    fn quick_cfg() -> EvoCfg {
        EvoCfg {
            generations: 3,
            offspring: 4,
            eps: 0.05,
            threads: 2,
            ..EvoCfg::default()
        }
    }

    #[test]
    fn mutation_preserves_constraint() {
        let cfg = quick_cfg();
        let mut rng = Pcg64::new(1);
        let parent = vec![0.5; 8];
        for _ in 0..50 {
            let child = mutate(&parent, 0.5, &cfg, &mut rng);
            assert!(mean(&child) <= 0.5 + 1e-9, "mean {}", mean(&child));
            assert!(child
                .iter()
                .all(|&p| (cfg.min_sparsity..=cfg.max_sparsity).contains(&p)));
        }
    }

    #[test]
    fn mutation_changes_something() {
        let cfg = quick_cfg();
        let mut rng = Pcg64::new(2);
        let parent = vec![0.5; 8];
        let child = mutate(&parent, 0.5, &cfg, &mut rng);
        assert!(child.iter().zip(&parent).any(|(a, b)| (a - b).abs() > 1e-12));
    }

    #[test]
    fn zero_allocation_has_zero_loss() {
        let (m, mc) = setup();
        let loss = allocation_loss(&m, &mc, &vec![0.0; m.cfg.n_layers], 1.0);
        assert!(loss.abs() < 1e-6, "dense candidate must have ~0 KL, got {loss}");
    }

    #[test]
    fn higher_sparsity_higher_loss() {
        let (m, mc) = setup();
        let lo = allocation_loss(&m, &mc, &vec![0.2; m.cfg.n_layers], 1.0);
        let hi = allocation_loss(&m, &mc, &vec![0.8; m.cfg.n_layers], 1.0);
        assert!(hi > lo, "hi {hi} <= lo {lo}");
    }

    #[test]
    fn search_never_worse_than_uniform() {
        let (m, mc) = setup();
        let cfg = quick_cfg();
        let uniform_loss = allocation_loss(&m, &mc, &vec![0.5; m.cfg.n_layers], 1.0);
        let (best, trace) = evolutionary_block_allocation(&m, &mc, 0.5, &cfg);
        let best_loss = trace.last().unwrap().best_loss;
        assert!(best_loss <= uniform_loss + 1e-12);
        assert!(mean(&best) <= 0.5 + 1e-9);
        assert_eq!(trace.len(), cfg.generations + 1);
        // Trace is monotone non-increasing.
        for w in trace.windows(2) {
            assert!(w[1].best_loss <= w[0].best_loss + 1e-12);
        }
    }
}
