//! The weight-aware importance score (Eq. 4-5, 7) and threshold calibration.
//!
//! `s_i = |x_i| * g_i^{alpha_l}` with `g_i = ||W[:,i]||_2` precomputed. At
//! inference `g^alpha` is a single fixed vector per layer, so scoring costs
//! one abs + one multiply + one compare per channel.

use crate::util::stats::select_kth_f32;

/// `g_i^alpha`, clamped below at 1e-4 exactly as Alg. 2's
/// `scales <- score^alpha.clamp(min=1e-4)` does: a dead column (g = 0) must
/// not force the score to zero for every token.
pub fn pow_clamped(g: &[f32], alpha: f64) -> Vec<f32> {
    g.iter()
        .map(|&gi| (gi as f64).powf(alpha).max(1e-4) as f32)
        .collect()
}

/// Scores for one activation vector.
pub fn scores(x: &[f32], ga: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), ga.len());
    x.iter().zip(ga).map(|(&xv, &g)| xv.abs() * g).collect()
}

/// Threshold achieving a target keep ratio over pooled calibration scores
/// (Eq. 7): `tau = Quantile_{1-r}({s_i})`. Keep ratio `r` in [0, 1];
/// sparsity = 1 - r. Implemented with quickselect, O(N).
///
/// With `tau` set to the (1-r)-quantile and the mask keeping `s_i >= tau`,
/// the realized keep fraction over the calibration pool is ~r (exact up to
/// ties and the discreteness of the pool).
pub fn tau_for_keep_ratio(pooled_scores: &[f32], keep_ratio: f64) -> f32 {
    assert!(!pooled_scores.is_empty(), "empty score pool");
    assert!((0.0..=1.0).contains(&keep_ratio));
    if keep_ratio >= 1.0 {
        return 0.0; // keep everything
    }
    if keep_ratio <= 0.0 {
        return f32::INFINITY; // drop everything
    }
    let n = pooled_scores.len();
    // Index of the first kept score in ascending order: drop floor((1-r)*n).
    let drop = (((1.0 - keep_ratio) * n as f64).floor() as usize).min(n - 1);
    let mut work = pooled_scores.to_vec();
    select_kth_f32(&mut work, drop)
}

/// Pool scores over many activation rows, then compute tau (convenience for
/// calibration: `rows` is a flat `[n_rows * dim]` buffer).
pub fn tau_from_rows(rows: &[f32], dim: usize, ga: &[f32], keep_ratio: f64) -> f32 {
    assert_eq!(rows.len() % dim, 0);
    assert_eq!(ga.len(), dim);
    let mut pool = Vec::with_capacity(rows.len());
    for row in rows.chunks_exact(dim) {
        for (c, &xv) in row.iter().enumerate() {
            pool.push(xv.abs() * ga[c]);
        }
    }
    tau_for_keep_ratio(&pool, keep_ratio)
}

/// Realized keep fraction of a (ga, tau) pair over calibration rows —
/// used by tests and by the plan validator to confirm Eq. 7 calibration.
pub fn realized_keep_fraction(rows: &[f32], dim: usize, ga: &[f32], tau: f32) -> f64 {
    let mut kept = 0usize;
    let mut total = 0usize;
    for row in rows.chunks_exact(dim) {
        for (c, &xv) in row.iter().enumerate() {
            if xv.abs() * ga[c] >= tau {
                kept += 1;
            }
            total += 1;
        }
    }
    kept as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn pow_clamped_basics() {
        let g = vec![0.0f32, 1.0, 4.0];
        let ga = pow_clamped(&g, 0.5);
        assert_eq!(ga[0], 1e-4); // clamped
        assert!((ga[1] - 1.0).abs() < 1e-6);
        assert!((ga[2] - 2.0).abs() < 1e-5);
        // alpha = 0 -> all ones (weight term disabled).
        let ga0 = pow_clamped(&g, 0.0);
        assert!(ga0.iter().all(|&v| (v - 1.0).abs() < 1e-6 || v == 1.0));
    }

    #[test]
    fn tau_hits_keep_ratio() {
        let mut rng = Pcg64::new(5);
        let dim = 64;
        let rows: Vec<f32> = (0..200 * dim).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..dim).map(|_| rng.next_f32() + 0.1).collect();
        for r in [0.3, 0.5, 0.7] {
            let tau = tau_from_rows(&rows, dim, &ga, r);
            let realized = realized_keep_fraction(&rows, dim, &ga, tau);
            assert!(
                (realized - r).abs() < 0.01,
                "keep {r}: realized {realized}"
            );
        }
    }

    #[test]
    fn tau_extremes() {
        let scores = vec![1.0f32, 2.0, 3.0];
        assert_eq!(tau_for_keep_ratio(&scores, 1.0), 0.0);
        assert_eq!(tau_for_keep_ratio(&scores, 0.0), f32::INFINITY);
    }

    #[test]
    fn tau_monotone_in_keep_ratio() {
        let mut rng = Pcg64::new(6);
        let scores: Vec<f32> = (0..1000).map(|_| rng.next_f32()).collect();
        let t30 = tau_for_keep_ratio(&scores, 0.3);
        let t50 = tau_for_keep_ratio(&scores, 0.5);
        let t70 = tau_for_keep_ratio(&scores, 0.7);
        assert!(t30 >= t50 && t50 >= t70);
    }

    #[test]
    fn scores_weight_interaction() {
        // The motivating example (Fig 2): small activation, huge weight norm.
        let x = vec![0.1f32, 1.0];
        let g = vec![50.0f32, 1.0];
        let s1 = scores(&x, &pow_clamped(&g, 1.0));
        assert!(s1[0] > s1[1], "weight-aware score must rescue channel 0");
        let s0 = scores(&x, &pow_clamped(&g, 0.0));
        assert!(s0[0] < s0[1], "activation-only score misses channel 0");
    }
}
