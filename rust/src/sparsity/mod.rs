//! WiSparse core: the weight-aware importance score (Sec 4.2), the
//! mixed-granularity allocation searches (Sec 4.3, Algs 1-4) and the
//! baseline sparsifiers (TEAL, R-Sparse, WINA, activation-only).
//!
//! Everything runs through the [`Sparsifier`] trait so the transformer
//! engine has exactly one execution path for all methods.

pub mod score;
pub mod analytic;
pub mod plan;
pub mod methods;
pub mod alpha_search;
pub mod evo;
pub mod greedy;
pub mod allocator;

pub use plan::{LayerPlan, SparsityPlan};
pub use score::{pow_clamped, tau_for_keep_ratio};

use crate::model::LayerId;
use crate::quant::WeightRepr;

/// A sparsification policy for linear projections.
///
/// `project` computes `out = (x ⊙ m) W^T` for the layer's dynamic mask `m`
/// and returns the number of kept channels, so the engine can account the
/// FLOPs actually spent (Fig 4's x-axis). The weight arrives as a
/// [`WeightRepr`], so every method runs unchanged on dense-f32 and
/// group-quantized checkpoints. Implementations must be `Sync`: the
/// serving coordinator shares one sparsifier across worker threads.
pub trait Sparsifier: Sync + Send {
    fn name(&self) -> &'static str;

    fn project(&self, layer: LayerId, x: &[f32], w: &dyn WeightRepr, out: &mut [f32]) -> usize;

    /// Extra multiply-accumulates this method spends *outside* the kept
    /// channels (e.g. R-Sparse's low-rank path). Default zero.
    fn extra_macs(&self, _layer: LayerId, _w: &dyn WeightRepr) -> u64 {
        0
    }

    /// The calibrated target keep-fraction for this layer, when the method
    /// was built from a plan. Telemetry compares it against the achieved
    /// density to report tau-vs-plan drift; `None` means "no plan target"
    /// (hand-built sparsifiers, uniform-tau baselines).
    fn planned_density(&self, _layer: LayerId) -> Option<f64> {
        None
    }

    /// Batch-fused projection: position `p` reads `xs[p*in_stride..][..n]`
    /// and writes `outs[p*out_stride..][..m]`, each under its *own* dynamic
    /// mask, with the weight columns walked once per fused call (the union
    /// of the batch's masks) instead of once per position. `kept_out[p]`
    /// receives position `p`'s kept count; the return value is the number
    /// of columns streamed. Output must be bit-identical to `n_pos`
    /// [`Sparsifier::project`] calls — the default simply makes them.
    #[allow(clippy::too_many_arguments)]
    fn project_batch(
        &self,
        layer: LayerId,
        xs: &[f32],
        in_stride: usize,
        w: &dyn WeightRepr,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        kept_out: &mut [usize],
    ) -> usize {
        let mut streamed = 0usize;
        for p in 0..n_pos {
            let x = &xs[p * in_stride..p * in_stride + w.in_dim()];
            let out = &mut outs[p * out_stride..p * out_stride + w.out_dim()];
            kept_out[p] = self.project(layer, x, w, out);
            streamed += kept_out[p];
        }
        streamed
    }
}

/// Dense execution (the 0%-sparsity baseline).
pub struct Dense;

impl Sparsifier for Dense {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn project(&self, _layer: LayerId, x: &[f32], w: &dyn WeightRepr, out: &mut [f32]) -> usize {
        w.gemv_dense(x, out, crate::util::threadpool::intra_op_threads())
    }

    fn planned_density(&self, _layer: LayerId) -> Option<f64> {
        Some(1.0)
    }

    fn project_batch(
        &self,
        _layer: LayerId,
        xs: &[f32],
        in_stride: usize,
        w: &dyn WeightRepr,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        kept_out: &mut [usize],
    ) -> usize {
        w.gemv_dense_batch(
            xs,
            in_stride,
            outs,
            out_stride,
            n_pos,
            crate::util::threadpool::intra_op_threads(),
        );
        kept_out[..n_pos].fill(w.in_dim());
        w.in_dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerKind;
    use crate::quant::QuantMode;
    use crate::sparse_kernel::ColMajorMatrix;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    #[test]
    fn dense_projects() {
        let mut rng = Pcg64::new(1);
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[4, 6], 1.0, &mut rng));
        let x: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; 4];
        let kept = Dense.project(LayerId::new(0, LayerKind::Q), &x, &w, &mut out);
        assert_eq!(kept, 6);
        assert_eq!(Dense.extra_macs(LayerId::new(0, LayerKind::Q), &w), 0);
    }

    #[test]
    fn dense_projects_quantized_weights() {
        let mut rng = Pcg64::new(2);
        let t = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let w = crate::quant::WeightMat::dense(&t).quantized(QuantMode::Int8, 4);
        let x: Vec<f32> = (0..6).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; 4];
        let kept = Dense.project(LayerId::new(0, LayerKind::Q), &x, &w, &mut out);
        assert_eq!(kept, 6);
        assert!(out.iter().any(|&v| v != 0.0));
    }
}
