//! WiSparse: weight-aware mixed-granularity training-free activation sparsity.
//!
//! Reproduction of "WiSparse: Boosting LLM Inference Efficiency with
//! Weight-Aware Mixed Activation Sparsity" (CS.LG 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 1** (`python/compile/kernels/`): Pallas kernel implementing the
//!   weight-aware scored sparse matmul, validated against a pure-jnp oracle.
//! - **Layer 2** (`python/compile/model.py`): JAX transformer forward pass
//!   calling the kernel, AOT-lowered to HLO text at build time.
//! - **Layer 3** (this crate): the serving coordinator, the native sparse
//!   inference engine, and the calibration search algorithms (Algs. 1-4 of
//!   the paper). Python is never on the request path.

pub mod util;
pub mod data;
pub mod tensor;
pub mod model;
pub mod kv;
pub mod sparsity;
pub mod sparse_kernel;
pub mod quant;
pub mod calib;
pub mod eval;
pub mod obs;
pub mod server;
pub mod runtime;
pub mod report;
