//! Reporting: CSV emission and terminal-friendly charts for regenerating
//! the paper's tables and figures.

pub mod csv;
pub mod chart;

pub use csv::write_csv;
pub use chart::ascii_chart;
