//! CSV output for figures (each bench target writes `results/*.csv` so the
//! series can be re-plotted outside the terminal).

use std::path::Path;

/// Quote a CSV field if needed.
fn field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Write rows of stringified cells with a header.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    out.push_str(&header.iter().map(|h| field(h)).collect::<Vec<_>>().join(","));
    out.push('\n');
    for row in rows {
        if row.len() != header.len() {
            anyhow::bail!("row width {} != header width {}", row.len(), header.len());
        }
        out.push_str(&row.iter().map(|c| field(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Convenience: format an f64 for CSV.
pub fn f(x: f64) -> String {
    format!("{x:.6}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let path = std::env::temp_dir().join("wisparse_csv_test.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[
                vec!["1".into(), "x,y".into()],
                vec!["2".into(), "plain".into()],
            ],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"x,y\""));
        assert_eq!(text.lines().count(), 3);
    }

    #[test]
    fn rejects_ragged_rows() {
        let path = std::env::temp_dir().join("wisparse_csv_test2.csv");
        assert!(write_csv(&path, &["a", "b"], &[vec!["1".into()]]).is_err());
    }
}
