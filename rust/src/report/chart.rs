//! ASCII charts: enough to eyeball the shape of every figure in a terminal.

/// Render labeled series as a simple scaled bar/line chart.
/// `series`: (label, points). All series share the y-scale.
pub fn ascii_chart(title: &str, series: &[(&str, Vec<(f64, f64)>)], height: usize) -> String {
    let mut out = format!("── {title} ──\n");
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().map(|&(_, y)| y))
        .collect();
    if all.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let ymin = all.iter().cloned().fold(f64::INFINITY, f64::min);
    let ymax = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (ymax - ymin).max(1e-12);
    let height = height.max(3);
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    // One row of columns per series point, rasterized to a grid.
    let width: usize = series.iter().map(|(_, p)| p.len()).max().unwrap_or(0);
    let mut grid = vec![vec![' '; width * 2]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        for (xi, &(_, y)) in pts.iter().enumerate() {
            let row = ((y - ymin) / span * (height - 1) as f64).round() as usize;
            let r = height - 1 - row.min(height - 1);
            grid[r][xi * 2] = marks[si % marks.len()];
        }
    }
    for (r, row) in grid.iter().enumerate() {
        let yval = ymax - span * r as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:>10.3} |"));
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width * 2)));
    // X labels: first and last x of the longest series.
    if let Some((_, pts)) = series.iter().max_by_key(|(_, p)| p.len()) {
        if let (Some(first), Some(last)) = (pts.first(), pts.last()) {
            out.push_str(&format!(
                "{:>11}{:<width$.3}{:>8.3}\n",
                "",
                first.0,
                last.0,
                width = (width * 2).saturating_sub(8).max(1)
            ));
        }
    }
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {label}\n", marks[si % marks.len()]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_basic_chart() {
        let s = ascii_chart(
            "test",
            &[
                ("up", vec![(0.0, 1.0), (1.0, 2.0), (2.0, 3.0)]),
                ("down", vec![(0.0, 3.0), (1.0, 2.0), (2.0, 1.0)]),
            ],
            5,
        );
        assert!(s.contains("── test ──"));
        assert!(s.contains('*'));
        assert!(s.contains('o'));
        assert!(s.contains("up"));
    }

    #[test]
    fn empty_series_ok() {
        let s = ascii_chart("empty", &[], 5);
        assert!(s.contains("(no data)"));
    }

    #[test]
    fn constant_series_no_panic() {
        let s = ascii_chart("flat", &[("c", vec![(0.0, 5.0), (1.0, 5.0)])], 4);
        assert!(s.contains('*'));
    }
}
