//! Perplexity on held-out sequences — the metric behind the block-wise
//! sensitivity study (Fig 3).

use crate::model::transformer::{ForwardStats, Model};
use crate::sparsity::Sparsifier;
use crate::tensor::ops::log_softmax;

/// Mean negative log-likelihood (nats/token) of next-token prediction over
/// the sequences; positions predict the *next* token, so a length-T sequence
/// contributes T-1 terms.
pub fn mean_nll(model: &Model, seqs: &[Vec<usize>], sp: &dyn Sparsifier) -> f64 {
    let mut stats = ForwardStats::default();
    let mut total = 0.0f64;
    let mut count = 0usize;
    for seq in seqs {
        if seq.len() < 2 {
            continue;
        }
        let logits = model.forward_seq(seq, sp, &mut stats, None);
        for t in 0..seq.len() - 1 {
            let ls = log_softmax(logits.row(t));
            total -= ls[seq[t + 1]] as f64;
            count += 1;
        }
    }
    assert!(count > 0, "no scored positions");
    total / count as f64
}

/// Perplexity = exp(mean NLL).
pub fn perplexity(model: &Model, seqs: &[Vec<usize>], sp: &dyn Sparsifier) -> f64 {
    mean_nll(model, seqs, sp).exp()
}

/// Relative perplexity change vs the dense model, in percent — Fig 3's
/// y-axis (ΔPPL %).
pub fn delta_ppl_percent(dense_ppl: f64, sparse_ppl: f64) -> f64 {
    (sparse_ppl - dense_ppl) / dense_ppl * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::sparsity::Dense;

    #[test]
    fn ppl_positive_and_bounded_by_vocab() {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 31);
        let seqs = vec![vec![1usize, 5, 9, 13, 2], vec![3usize, 3, 3, 3]];
        let ppl = perplexity(&m, &seqs, &Dense);
        assert!(ppl > 1.0);
        // A random model's ppl is near vocab size; must not exceed it by much.
        assert!(ppl < m.cfg.vocab_size as f64 * 2.0, "ppl {ppl}");
    }

    #[test]
    fn delta_ppl_sign() {
        assert!(delta_ppl_percent(10.0, 11.0) > 0.0);
        assert!(delta_ppl_percent(10.0, 9.0) < 0.0);
        assert!((delta_ppl_percent(10.0, 10.0)).abs() < 1e-12);
    }

    #[test]
    fn deterministic() {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 37);
        let seqs = vec![vec![7usize, 8, 9, 10]];
        assert_eq!(mean_nll(&m, &seqs, &Dense), mean_nll(&m, &seqs, &Dense));
    }
}
