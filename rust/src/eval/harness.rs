//! Suite-level evaluation: accuracy per task + average, the row format of
//! Table 1 / Table 2.

use crate::data::tasks::{full_suite, Task};
use crate::eval::tasks::task_accuracy;
use crate::model::transformer::Model;
use crate::sparsity::Sparsifier;

/// One method's row: per-task accuracies in paper column order + average.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub method: String,
    pub sparsity: f64,
    /// (task name, paper analogue, accuracy %).
    pub per_task: Vec<(String, String, f64)>,
    pub average: f64,
}

impl EvalReport {
    pub fn header() -> String {
        format!(
            "{:<22} {:>6} {:>7} {:>7} {:>7} {:>9} {:>7} {:>7} {:>8}",
            "method", "sparse", "SIQA", "GSM8K", "WiC", "HumanEval", "MMLU", "CSQA", "Average"
        )
    }

    pub fn row(&self) -> String {
        let mut s = format!("{:<22} {:>5.0}% ", self.method, self.sparsity * 100.0);
        for (i, (_, _, acc)) in self.per_task.iter().enumerate() {
            let w = if i == 3 { 9 } else { 7 };
            s.push_str(&format!("{acc:>w$.2} "));
        }
        s.push_str(&format!("{:>8.2}", self.average));
        s
    }
}

/// Evaluate a (model, sparsifier) pair over a task suite.
pub fn evaluate_suite(
    model: &Model,
    suite: &[Task],
    sp: &dyn Sparsifier,
    method: &str,
    sparsity: f64,
    threads: usize,
) -> EvalReport {
    let mut per_task = Vec::with_capacity(suite.len());
    let mut sum = 0.0;
    for t in suite {
        let acc = task_accuracy(model, t, sp, threads);
        per_task.push((t.name.to_string(), t.paper_analogue.to_string(), acc));
        sum += acc;
    }
    EvalReport {
        method: method.to_string(),
        sparsity,
        average: sum / suite.len().max(1) as f64,
        per_task,
    }
}

/// Evaluate with the default suite size.
pub fn evaluate_all(
    model: &Model,
    sp: &dyn Sparsifier,
    method: &str,
    sparsity: f64,
    n_per_task: usize,
    seed: u64,
    threads: usize,
) -> EvalReport {
    let suite = full_suite(n_per_task, seed);
    evaluate_suite(model, &suite, sp, method, sparsity, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::sparsity::Dense;

    #[test]
    fn report_covers_six_tasks() {
        let m = Model::synthetic(ModelConfig::preset("nano").unwrap(), 71);
        let r = evaluate_all(&m, &Dense, "dense", 0.0, 4, 1, 2);
        assert_eq!(r.per_task.len(), 6);
        assert!(r.average >= 0.0 && r.average <= 100.0);
        let row = r.row();
        assert!(row.contains("dense"));
        assert!(!EvalReport::header().is_empty());
    }
}
