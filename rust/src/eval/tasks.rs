//! Task scoring: choice-by-logprob and exact-match generation, the two
//! scoring modes OpenCompass uses for the paper's six benchmarks.

use crate::data::corpus::tokenize;
use crate::data::tasks::{Task, TaskItem, TaskKind};
use crate::model::transformer::{ForwardStats, Model};
use crate::sparsity::Sparsifier;
use crate::tensor::ops::log_softmax;
use crate::util::threadpool::parallel_map;

/// Sum logprob of `continuation` tokens given `prompt` (teacher-forced).
pub fn continuation_logprob(
    model: &Model,
    prompt: &[usize],
    continuation: &[usize],
    sp: &dyn Sparsifier,
) -> f64 {
    assert!(!prompt.is_empty() && !continuation.is_empty());
    let mut seq = prompt.to_vec();
    seq.extend_from_slice(continuation);
    let mut stats = ForwardStats::default();
    let logits = model.forward_seq(&seq, sp, &mut stats, None);
    let mut lp = 0.0f64;
    for (k, &tok) in continuation.iter().enumerate() {
        let pos = prompt.len() + k - 1; // logits at pos predict token pos+1
        let ls = log_softmax(logits.row(pos));
        lp += ls[tok] as f64;
    }
    lp
}

/// Score one choice item: 1 if the correct choice has the highest
/// length-normalized logprob.
pub fn score_choice(model: &Model, item: &TaskItem, sp: &dyn Sparsifier) -> bool {
    let prompt = tokenize(&item.prompt);
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, choice) in item.choices.iter().enumerate() {
        let cont = tokenize(choice);
        let lp = continuation_logprob(model, &prompt, &cont, sp) / cont.len() as f64;
        if lp > best.0 {
            best = (lp, ci);
        }
    }
    best.1 == item.answer
}

/// Score one generation item: greedy-decode `answer_text.len()` tokens and
/// require an exact match.
pub fn score_generate(model: &Model, item: &TaskItem, sp: &dyn Sparsifier) -> bool {
    let prompt = tokenize(&item.prompt);
    let expect = tokenize(&item.answer_text);
    let mut stats = ForwardStats::default();
    let out = model.generate_greedy(&prompt, expect.len(), sp, &mut stats);
    out == expect
}

/// Accuracy (%) of a task under a sparsifier. Items are scored in parallel.
pub fn task_accuracy(model: &Model, task: &Task, sp: &dyn Sparsifier, threads: usize) -> f64 {
    let correct = parallel_map(task.items.len(), threads, |i| {
        let item = &task.items[i];
        match task.kind {
            TaskKind::Choice => score_choice(model, item, sp),
            TaskKind::Generate => score_generate(model, item, sp),
        }
    });
    100.0 * correct.iter().filter(|&&c| c).count() as f64 / task.items.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::{facts_task, full_suite};
    use crate::model::ModelConfig;
    use crate::model::transformer::Model as M;
    use crate::sparsity::Dense;

    fn nano() -> M {
        M::synthetic(ModelConfig::preset("nano").unwrap(), 61)
    }

    #[test]
    fn logprob_is_negative_and_additive() {
        let m = nano();
        let p = tokenize("ab");
        let c = tokenize("cd");
        let lp = continuation_logprob(&m, &p, &c, &Dense);
        assert!(lp < 0.0);
        // Longer continuation -> lower total logprob for a ~uniform model.
        let c2 = tokenize("cdef");
        assert!(continuation_logprob(&m, &p, &c2, &Dense) < lp);
    }

    #[test]
    fn untrained_model_scores_near_chance() {
        // A random model should be near 25% on a 4-way choice task —
        // sanity-checks that scoring isn't leaking the answer.
        let m = nano();
        let t = facts_task(40, 7);
        let acc = task_accuracy(&m, &t, &Dense, 4);
        assert!(acc <= 60.0, "suspicious accuracy {acc}");
    }

    #[test]
    fn accuracy_deterministic_and_parallel_safe() {
        let m = nano();
        let suite = full_suite(6, 11);
        for t in &suite {
            let a1 = task_accuracy(&m, t, &Dense, 1);
            let a4 = task_accuracy(&m, t, &Dense, 4);
            assert_eq!(a1, a4, "{}", t.name);
        }
    }
}
