//! Evaluation: perplexity, KL divergence (Eq. 8), and the six synthetic
//! task families mirroring the paper's benchmark suite (SIQA, GSM8K, WiC,
//! HumanEval, MMLU, CSQA).

pub mod kl;
pub mod ppl;
pub mod tasks;
pub mod harness;

pub use harness::{evaluate_all, EvalReport};
