//! Token-level KL divergence between dense and sparse logits — the
//! objective of the coarse evolutionary search (Eq. 8).

use crate::tensor::ops::log_softmax;
use crate::tensor::Tensor;

/// KL(p || q) between two categorical distributions given their logits.
pub fn kl_from_logits(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    debug_assert_eq!(p_logits.len(), q_logits.len());
    let lp = log_softmax(p_logits);
    let lq = log_softmax(q_logits);
    let mut kl = 0.0f64;
    for (a, b) in lp.iter().zip(&lq) {
        let pa = (*a as f64).exp();
        if pa > 0.0 {
            kl += pa * ((*a - *b) as f64);
        }
    }
    kl.max(0.0) // numeric floors
}

/// Mean over positions of KL(dense_t || sparse_t); logits are `[T, vocab]`.
pub fn mean_token_kl(dense: &Tensor, sparse: &Tensor) -> f64 {
    assert_eq!(dense.shape, sparse.shape);
    let (t_len, _) = dense.dims2();
    let mut total = 0.0;
    for t in 0..t_len {
        total += kl_from_logits(dense.row(t), sparse.row(t));
    }
    total / t_len as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_of_identical_is_zero() {
        let l = vec![0.5f32, -1.0, 2.0];
        assert!(kl_from_logits(&l, &l) < 1e-9);
    }

    #[test]
    fn kl_nonnegative_and_asymmetric() {
        let p = vec![2.0f32, 0.0, 0.0];
        let q = vec![0.0f32, 0.0, 2.0];
        let ab = kl_from_logits(&p, &q);
        let ba = kl_from_logits(&q, &p);
        assert!(ab > 0.0);
        // Symmetric here by construction; use an asymmetric pair:
        let r = vec![1.0f32, 1.0, -5.0];
        assert!((kl_from_logits(&p, &r) - kl_from_logits(&r, &p)).abs() > 1e-6);
        assert!(ba > 0.0);
    }

    #[test]
    fn kl_grows_with_divergence() {
        let p = vec![3.0f32, 0.0, 0.0];
        let near = vec![2.5f32, 0.0, 0.0];
        let far = vec![-3.0f32, 0.0, 0.0];
        assert!(kl_from_logits(&p, &far) > kl_from_logits(&p, &near));
    }

    #[test]
    fn mean_token_kl_averages() {
        let dense = Tensor::from_vec(&[2, 3], vec![1., 0., 0., 0., 1., 0.]);
        let same = dense.clone();
        assert!(mean_token_kl(&dense, &same) < 1e-9);
        let off = Tensor::from_vec(&[2, 3], vec![0., 1., 0., 0., 1., 0.]);
        let kl = mean_token_kl(&dense, &off);
        // Only position 0 diverges; mean halves the single-position KL.
        let single = kl_from_logits(&[1., 0., 0.], &[0., 1., 0.]);
        assert!((kl - single / 2.0).abs() < 1e-9);
    }
}
