//! Synthetic corpus + benchmark substrate.
//!
//! The paper evaluates on OpenCompass (SIQA, GSM8K, WiC, HumanEval, MMLU,
//! CSQA) with HF-pretrained 7-8B models. Neither is available here, so this
//! module is the substitution (DESIGN.md §2): a deterministic generator of
//! a mixed structured corpus that the micro models are trained on at build
//! time, plus six task families probing the same six skill axes, scored the
//! same two ways the originals are (choice-by-logprob, exact-match
//! generation). Rust is the single source of truth: `wisparse gen-data`
//! writes the corpus for the Python trainer and the calibration sets.

pub mod corpus;
pub mod tasks;

pub use corpus::CorpusGen;
pub use tasks::{Task, TaskItem, TaskKind};
