//! The synthetic training corpus: six structured pattern families, one per
//! benchmark skill axis. Byte-level tokens (vocab 256), deterministic given
//! a seed, with a train/eval split at the *instance* level so eval items
//! never appear verbatim in training data (except the closed-world fact and
//! category tables, which are memorization tasks by design, like MMLU
//! factual recall).

use crate::util::rng::Pcg64;

/// Closed world of entities shared by the generators and the task builders.
pub mod world {
    /// Synthetic "country -> capital" fact table (mmlu-like memorization).
    pub const COUNTRIES: [&str; 16] = [
        "avaria", "belorn", "cindor", "draken", "elvane", "fornost", "galdor",
        "hivern", "isgard", "jorvik", "kalora", "lindon", "mordia", "nerath",
        "ostia", "pelagir",
    ];
    pub const CAPITALS: [&str; 16] = [
        "avapol", "belcity", "cinport", "drakby", "elvtown", "fornham",
        "galbury", "hivopol", "isfort", "jorton", "kalbury", "linford",
        "morport", "nerham", "ostgate", "pelham",
    ];

    /// "noun is-a category" table (csqa-like association).
    pub const NOUNS: [&str; 16] = [
        "dog", "cat", "wolf", "crow", "dove", "carp", "pike", "oak", "fir",
        "rose", "iris", "iron", "gold", "clay", "sand", "mint",
    ];
    pub const CATEGORIES: [&str; 16] = [
        "animal", "animal", "animal", "bird", "bird", "fish", "fish", "tree",
        "tree", "flower", "flower", "metal", "metal", "soil", "soil", "herb",
    ];

    /// Actors for social (siqa-like) templates.
    pub const ACTORS: [&str; 8] = [
        "tom", "mary", "sam", "lily", "john", "emma", "alex", "ruth",
    ];

    /// (verb phrase, felt emotion) pairs for social inference.
    pub const SOCIAL: [(&str, &str); 6] = [
        ("gives a gift to", "happy"),
        ("sings a song for", "happy"),
        ("helps", "glad"),
        ("shouts at", "angry"),
        ("ignores", "sad"),
        ("lies to", "upset"),
    ];
    pub const EMOTIONS: [&str; 5] = ["happy", "glad", "angry", "sad", "upset"];

    /// Singular/plural subject pool for the agreement (wic-like) family.
    pub const AGREE_NOUNS: [&str; 8] = [
        "cat", "dog", "bird", "fish", "fox", "cow", "hen", "owl",
    ];
    pub const AGREE_VERBS: [(&str, &str); 6] = [
        ("runs", "run"),
        ("jumps", "jump"),
        ("sleeps", "sleep"),
        ("eats", "eat"),
        ("sings", "sing"),
        ("hides", "hide"),
    ];
}

/// Deterministic corpus generator.
pub struct CorpusGen {
    rng: Pcg64,
}

impl CorpusGen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg64::new(seed),
        }
    }

    /// One arithmetic line: "12+34=46." (gsm8k-like). `eval_holdout`
    /// selects the instance subspace reserved for eval: pairs where
    /// (a*100+b) % 7 == 0 never appear in training.
    pub fn arith_line(&mut self, train: bool) -> String {
        loop {
            let a = self.rng.below(90) + 10;
            let b = self.rng.below(90) + 10;
            let held_out = (a * 100 + b) % 7 == 0;
            if held_out != train {
                return format!("{a}+{b}={}.", a + b);
            }
        }
    }

    /// Reserved arithmetic instance for eval.
    pub fn arith_eval(&mut self) -> (String, String) {
        loop {
            let a = self.rng.below(90) + 10;
            let b = self.rng.below(90) + 10;
            if (a * 100 + b) % 7 == 0 {
                return (format!("{a}+{b}="), format!("{}", a + b));
            }
        }
    }

    /// Fact line: "the capital of avaria is avapol."
    pub fn fact_line(&mut self) -> String {
        let i = self.rng.below(world::COUNTRIES.len());
        format!(
            "the capital of {} is {}.",
            world::COUNTRIES[i],
            world::CAPITALS[i]
        )
    }

    /// Category line: "a dog is an animal."
    pub fn category_line(&mut self) -> String {
        let i = self.rng.below(world::NOUNS.len());
        format!("a {} is an {}.", world::NOUNS[i], world::CATEGORIES[i])
    }

    /// Social template: "tom gives a gift to mary. mary feels happy."
    pub fn social_line(&mut self) -> String {
        let a = world::ACTORS[self.rng.below(world::ACTORS.len())];
        let mut b = world::ACTORS[self.rng.below(world::ACTORS.len())];
        while b == a {
            b = world::ACTORS[self.rng.below(world::ACTORS.len())];
        }
        let (verb, emotion) = world::SOCIAL[self.rng.below(world::SOCIAL.len())];
        format!("{a} {verb} {b}. {b} feels {emotion}.")
    }

    /// Agreement line: "one cat runs." / "two cats run." (wic-like binary
    /// usage-in-context). Training uses counts one/two; "six"/"ten" are
    /// held out for eval prompts.
    pub fn agree_line(&mut self, train: bool) -> String {
        let noun = world::AGREE_NOUNS[self.rng.below(world::AGREE_NOUNS.len())];
        let (sing, plur) = world::AGREE_VERBS[self.rng.below(world::AGREE_VERBS.len())];
        let plural = self.rng.below(2) == 1;
        let count = if train {
            if plural { "two" } else { "one" }
        } else if plural {
            "ten"
        } else {
            "six"
        };
        // "six" is singularly-numbered in our toy grammar? No: any count >1
        // is plural; "six"/"ten" both plural. For the singular eval case we
        // keep "one" (it also appears in training, but with other nouns).
        if plural {
            format!("{count} {noun}s {plur}.")
        } else {
            format!("one {noun} {sing}.")
        }
    }

    /// Code line: "rev(abc)=cba." (humaneval-like exact-match generation).
    /// Training strings avoid the letter 'z'; eval strings contain it.
    pub fn code_line(&mut self, train: bool) -> String {
        let len = 3;
        let mut s = String::new();
        for pos in 0..len {
            let c = if !train && pos == self.rng.below(len) {
                'z'
            } else {
                (b'a' + self.rng.below(25) as u8) as char // a..y
            };
            s.push(c);
        }
        if !train && !s.contains('z') {
            s.replace_range(0..1, "z");
        }
        let rev: String = s.chars().rev().collect();
        format!("rev({s})={rev}.")
    }

    /// Filler prose (keeps the model honest about general text).
    pub fn prose_line(&mut self) -> String {
        let words = [
            "the", "sun", "rises", "over", "hills", "and", "rivers", "flow",
            "to", "sea", "wind", "moves", "trees", "birds", "fly", "home",
        ];
        let n = 4 + self.rng.below(6);
        let mut line = String::new();
        for i in 0..n {
            if i > 0 {
                line.push(' ');
            }
            line.push_str(words[self.rng.below(words.len())]);
        }
        line.push('.');
        line
    }

    /// A full training line from a random family (weights tuned so every
    /// family gets enough signal).
    pub fn train_line(&mut self) -> String {
        match self.rng.below(10) {
            0 | 1 => self.arith_line(true),
            2 => self.fact_line(),
            3 => self.category_line(),
            4 | 5 => self.social_line(),
            6 => self.agree_line(true),
            7 | 8 => self.code_line(true),
            _ => self.prose_line(),
        }
    }

    /// Generate the training corpus as one newline-joined string of about
    /// `approx_bytes` bytes.
    pub fn training_corpus(&mut self, approx_bytes: usize) -> String {
        let mut out = String::with_capacity(approx_bytes + 64);
        while out.len() < approx_bytes {
            out.push_str(&self.train_line());
            out.push('\n');
        }
        out
    }

    /// Calibration sequences: held-out corpus slices covering all families
    /// (the paper's pile-val + code + math mix).
    pub fn calib_sequences(&mut self, n_seqs: usize, seq_len: usize) -> Vec<Vec<usize>> {
        (0..n_seqs)
            .map(|_| {
                let mut bytes = Vec::with_capacity(seq_len);
                while bytes.len() < seq_len {
                    let line = self.train_line();
                    bytes.extend(line.bytes().map(|b| b as usize));
                    bytes.push(b'\n' as usize);
                }
                bytes.truncate(seq_len);
                bytes
            })
            .collect()
    }
}

/// Byte-level tokenization helpers.
pub fn tokenize(s: &str) -> Vec<usize> {
    s.bytes().map(|b| b as usize).collect()
}

/// Byte tokens back to text. Non-printable / non-ASCII bytes render as `?`
/// so the output stays one byte per token (the corpus itself is pure ASCII).
pub fn detokenize(tokens: &[usize]) -> String {
    tokens
        .iter()
        .map(|&t| {
            let b = t.min(255) as u8;
            if b == b'\n' || (0x20..0x7f).contains(&b) {
                b as char
            } else {
                '?'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = CorpusGen::new(7);
        let mut b = CorpusGen::new(7);
        for _ in 0..50 {
            assert_eq!(a.train_line(), b.train_line());
        }
    }

    #[test]
    fn arith_split_is_disjoint() {
        let mut g = CorpusGen::new(1);
        for _ in 0..200 {
            let line = g.arith_line(true);
            let (ab, _) = line.split_once('=').unwrap();
            let (a, b) = ab.split_once('+').unwrap();
            let key: usize = a.parse::<usize>().unwrap() * 100 + b.parse::<usize>().unwrap();
            assert_ne!(key % 7, 0, "eval instance leaked into training: {line}");
        }
        for _ in 0..50 {
            let (prompt, ans) = g.arith_eval();
            let nums: Vec<usize> = prompt
                .trim_end_matches('=')
                .split('+')
                .map(|x| x.parse().unwrap())
                .collect();
            assert_eq!((nums[0] * 100 + nums[1]) % 7, 0);
            assert_eq!(ans.parse::<usize>().unwrap(), nums[0] + nums[1]);
        }
    }

    #[test]
    fn arith_correctness() {
        let mut g = CorpusGen::new(2);
        for _ in 0..100 {
            let line = g.arith_line(true);
            let body = line.trim_end_matches('.');
            let (lhs, rhs) = body.split_once('=').unwrap();
            let (a, b) = lhs.split_once('+').unwrap();
            assert_eq!(
                a.parse::<usize>().unwrap() + b.parse::<usize>().unwrap(),
                rhs.parse::<usize>().unwrap()
            );
        }
    }

    #[test]
    fn code_split_by_letter_z() {
        let mut g = CorpusGen::new(3);
        for _ in 0..100 {
            assert!(!g.code_line(true).contains('z'));
            assert!(g.code_line(false).contains('z'));
        }
    }

    #[test]
    fn code_reversal_correct() {
        let mut g = CorpusGen::new(4);
        for train in [true, false] {
            for _ in 0..50 {
                let line = g.code_line(train);
                let inner = line
                    .strip_prefix("rev(")
                    .unwrap()
                    .strip_suffix('.')
                    .unwrap();
                let (s, rev) = inner.split_once(")=").unwrap();
                let expect: String = s.chars().rev().collect();
                assert_eq!(rev, expect);
            }
        }
    }

    #[test]
    fn corpus_is_ascii_and_sized() {
        let mut g = CorpusGen::new(5);
        let c = g.training_corpus(10_000);
        assert!(c.len() >= 10_000);
        assert!(c.is_ascii());
        assert!(c.lines().count() > 100);
    }

    #[test]
    fn calib_sequences_byte_range() {
        let mut g = CorpusGen::new(6);
        let seqs = g.calib_sequences(3, 64);
        assert_eq!(seqs.len(), 3);
        for s in &seqs {
            assert_eq!(s.len(), 64);
            assert!(s.iter().all(|&t| t < 256));
        }
    }

    #[test]
    fn tokenize_roundtrip() {
        let s = "rev(abc)=cba.";
        assert_eq!(detokenize(&tokenize(s)), s);
    }

    #[test]
    fn world_tables_consistent() {
        assert_eq!(world::COUNTRIES.len(), world::CAPITALS.len());
        assert_eq!(world::NOUNS.len(), world::CATEGORIES.len());
    }
}
