//! The six task families probing the paper's six benchmark axes.
//!
//! | Paper benchmark | Family here  | Skill probed            | Scoring    |
//! |-----------------|--------------|-------------------------|------------|
//! | SIQA            | `social`     | social-emotion inference| choice     |
//! | GSM8K           | `arith`      | 2-digit addition        | exact gen  |
//! | WiC             | `agree`      | usage-in-context        | choice (2) |
//! | HumanEval       | `strrev`     | string transformation   | exact gen  |
//! | MMLU            | `facts`      | factual recall          | choice (4) |
//! | CSQA            | `category`   | concept association     | choice (4) |

use crate::data::corpus::{world, CorpusGen};
use crate::util::rng::Pcg64;

/// Scoring mode of a task family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Rank `choices` by sequence logprob after `prompt`; correct iff the
    /// `answer` index wins (how OpenCompass scores MC benchmarks).
    Choice,
    /// Greedy-decode after `prompt`; correct iff the decode starts with
    /// `answer_text` (how exact-match generation benchmarks score).
    Generate,
}

/// One evaluation item.
#[derive(Clone, Debug)]
pub struct TaskItem {
    pub prompt: String,
    /// For Choice tasks: candidate continuations (index `answer` correct).
    pub choices: Vec<String>,
    pub answer: usize,
    /// For Generate tasks: the expected continuation text.
    pub answer_text: String,
}

/// A named task with its items.
#[derive(Clone, Debug)]
pub struct Task {
    pub name: &'static str,
    pub paper_analogue: &'static str,
    pub kind: TaskKind,
    pub items: Vec<TaskItem>,
}

impl Task {
    pub fn n(&self) -> usize {
        self.items.len()
    }
}

fn choice_item(prompt: String, choices: Vec<String>, answer: usize) -> TaskItem {
    TaskItem {
        prompt,
        choices,
        answer,
        answer_text: String::new(),
    }
}

fn gen_item(prompt: String, answer_text: String) -> TaskItem {
    TaskItem {
        prompt,
        choices: Vec::new(),
        answer: 0,
        answer_text,
    }
}

/// GSM8K-like: held-out 2-digit additions, exact-match generation.
pub fn arith_task(n: usize, seed: u64) -> Task {
    let mut g = CorpusGen::new(seed);
    let items = (0..n)
        .map(|_| {
            let (prompt, ans) = g.arith_eval();
            gen_item(prompt, format!("{ans}."))
        })
        .collect();
    Task {
        name: "arith",
        paper_analogue: "GSM8K",
        kind: TaskKind::Generate,
        items,
    }
}

/// HumanEval-like: string reversal on held-out strings (contain 'z').
pub fn strrev_task(n: usize, seed: u64) -> Task {
    let mut g = CorpusGen::new(seed);
    let items = (0..n)
        .map(|_| {
            let line = g.code_line(false);
            let inner = line.strip_prefix("rev(").unwrap().strip_suffix('.').unwrap();
            let (s, rev) = inner.split_once(")=").unwrap();
            gen_item(format!("rev({s})="), format!("{rev}."))
        })
        .collect();
    Task {
        name: "strrev",
        paper_analogue: "HumanEval",
        kind: TaskKind::Generate,
        items,
    }
}

/// MMLU-like: capital-of recall, 4-way choice.
pub fn facts_task(n: usize, seed: u64) -> Task {
    let mut rng = Pcg64::new(seed);
    let k = world::COUNTRIES.len();
    let items = (0..n)
        .map(|_| {
            let i = rng.below(k);
            let mut distractors: Vec<usize> = (0..k).filter(|&j| j != i).collect();
            rng.shuffle(&mut distractors);
            let mut choice_idx = vec![i, distractors[0], distractors[1], distractors[2]];
            rng.shuffle(&mut choice_idx);
            let answer = choice_idx.iter().position(|&c| c == i).unwrap();
            choice_item(
                format!("the capital of {} is ", world::COUNTRIES[i]),
                choice_idx
                    .iter()
                    .map(|&c| format!("{}.", world::CAPITALS[c]))
                    .collect(),
                answer,
            )
        })
        .collect();
    Task {
        name: "facts",
        paper_analogue: "MMLU",
        kind: TaskKind::Choice,
        items,
    }
}

/// CSQA-like: category association, 4-way choice.
pub fn category_task(n: usize, seed: u64) -> Task {
    let mut rng = Pcg64::new(seed);
    let k = world::NOUNS.len();
    let uniq_cats: Vec<&str> = {
        let mut v: Vec<&str> = world::CATEGORIES.to_vec();
        v.sort_unstable();
        v.dedup();
        v
    };
    let items = (0..n)
        .map(|_| {
            let i = rng.below(k);
            let correct = world::CATEGORIES[i];
            let mut wrong: Vec<&str> = uniq_cats
                .iter()
                .copied()
                .filter(|&c| c != correct)
                .collect();
            rng.shuffle(&mut wrong);
            let mut cands = vec![correct, wrong[0], wrong[1], wrong[2]];
            rng.shuffle(&mut cands);
            let answer = cands.iter().position(|&c| c == correct).unwrap();
            choice_item(
                format!("a {} is an ", world::NOUNS[i]),
                cands.iter().map(|c| format!("{c}.")).collect(),
                answer,
            )
        })
        .collect();
    Task {
        name: "category",
        paper_analogue: "CSQA",
        kind: TaskKind::Choice,
        items,
    }
}

/// SIQA-like: emotion inference from a social template, choice over the
/// emotion vocabulary.
pub fn social_task(n: usize, seed: u64) -> Task {
    let mut rng = Pcg64::new(seed);
    let items = (0..n)
        .map(|_| {
            let a = world::ACTORS[rng.below(world::ACTORS.len())];
            let mut b = world::ACTORS[rng.below(world::ACTORS.len())];
            while b == a {
                b = world::ACTORS[rng.below(world::ACTORS.len())];
            }
            let (verb, emotion) = world::SOCIAL[rng.below(world::SOCIAL.len())];
            let mut cands: Vec<&str> = world::EMOTIONS
                .iter()
                .copied()
                .filter(|&e| e != emotion)
                .collect();
            rng.shuffle(&mut cands);
            let mut choices = vec![emotion, cands[0], cands[1]];
            rng.shuffle(&mut choices);
            let answer = choices.iter().position(|&e| e == emotion).unwrap();
            choice_item(
                format!("{a} {verb} {b}. {b} feels "),
                choices.iter().map(|e| format!("{e}.")).collect(),
                answer,
            )
        })
        .collect();
    Task {
        name: "social",
        paper_analogue: "SIQA",
        kind: TaskKind::Choice,
        items,
    }
}

/// WiC-like: number agreement in context, binary choice between the
/// singular and plural verb forms after held-out count words.
pub fn agree_task(n: usize, seed: u64) -> Task {
    let mut rng = Pcg64::new(seed);
    let items = (0..n)
        .map(|_| {
            let noun = world::AGREE_NOUNS[rng.below(world::AGREE_NOUNS.len())];
            let (sing, plur) = world::AGREE_VERBS[rng.below(world::AGREE_VERBS.len())];
            let plural = rng.below(2) == 1;
            let (prompt, correct, wrong) = if plural {
                (format!("ten {noun}s "), plur, sing)
            } else {
                (format!("one {noun} "), sing, plur)
            };
            let flip = rng.below(2) == 1;
            let (choices, answer) = if flip {
                (vec![format!("{wrong}."), format!("{correct}.")], 1)
            } else {
                (vec![format!("{correct}."), format!("{wrong}.")], 0)
            };
            choice_item(prompt, choices, answer)
        })
        .collect();
    Task {
        name: "agree",
        paper_analogue: "WiC",
        kind: TaskKind::Choice,
        items,
    }
}

/// The full suite in the paper's column order
/// (SIQA, GSM8K, WiC, HumanEval, MMLU, CSQA).
pub fn full_suite(n_per_task: usize, seed: u64) -> Vec<Task> {
    vec![
        social_task(n_per_task, seed ^ 0x51),
        arith_task(n_per_task, seed ^ 0x52),
        agree_task(n_per_task, seed ^ 0x53),
        strrev_task(n_per_task, seed ^ 0x54),
        facts_task(n_per_task, seed ^ 0x55),
        category_task(n_per_task, seed ^ 0x56),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_six_tasks_in_paper_order() {
        let suite = full_suite(5, 1);
        let names: Vec<&str> = suite.iter().map(|t| t.paper_analogue).collect();
        assert_eq!(
            names,
            vec!["SIQA", "GSM8K", "WiC", "HumanEval", "MMLU", "CSQA"]
        );
        assert!(suite.iter().all(|t| t.n() == 5));
    }

    #[test]
    fn choice_answers_in_range() {
        for t in full_suite(30, 2) {
            if t.kind == TaskKind::Choice {
                for item in &t.items {
                    assert!(item.answer < item.choices.len(), "{}", t.name);
                    // Answer text is one of the choices, all distinct.
                    let mut c = item.choices.clone();
                    c.sort();
                    c.dedup();
                    assert_eq!(c.len(), item.choices.len(), "{} dup choices", t.name);
                }
            }
        }
    }

    #[test]
    fn generate_answers_nonempty() {
        for t in full_suite(20, 3) {
            if t.kind == TaskKind::Generate {
                for item in &t.items {
                    assert!(!item.answer_text.is_empty());
                    assert!(item.answer_text.ends_with('.'));
                }
            }
        }
    }

    #[test]
    fn answer_position_unbiased() {
        // Over many items, the correct index must not always be 0 — that
        // would let a degenerate model score 100%.
        let t = facts_task(100, 4);
        let zero_frac = t.items.iter().filter(|i| i.answer == 0).count();
        assert!(zero_frac < 50, "answer index biased: {zero_frac}/100 at 0");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = arith_task(10, 9);
        let b = arith_task(10, 9);
        for (x, y) in a.items.iter().zip(&b.items) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer_text, y.answer_text);
        }
    }

    #[test]
    fn facts_correct_choice_matches_table() {
        let t = facts_task(50, 5);
        for item in &t.items {
            let country = item
                .prompt
                .strip_prefix("the capital of ")
                .unwrap()
                .strip_suffix(" is ")
                .unwrap();
            let i = world::COUNTRIES.iter().position(|&c| c == country).unwrap();
            assert_eq!(item.choices[item.answer], format!("{}.", world::CAPITALS[i]));
        }
    }
}
