//! Group-quantized weight storage: symmetric per-group int8 and packed int4
//! codes over the columns of a [`ColMajorMatrix`].
//!
//! Groups run *along* each column (the `m` output dimension), so a fused
//! GEMV that walks one kept column dequantizes group-by-group with one
//! scale broadcast per group — the scale stream is tiny (`m / group` floats
//! per column) and the code stream is 1 byte (int8) or half a byte (int4)
//! per element instead of 4. Decode is memory-bandwidth-bound, so the
//! 4x/8x weight-traffic reduction is the whole point; the extra multiply
//! per element is compute the memory system was waiting on anyway.
//!
//! The dequantized value of a code `q` in group `g` is exactly
//! `scales[g] * (q as f32)` — one IEEE multiply, identical on every SIMD
//! backend, which is what lets the fused kernels promise bit-identical
//! results against the dequantize-then-f32-GEMV reference.

use crate::sparse_kernel::simd;
use crate::sparse_kernel::ColMajorMatrix;

/// Quantization mode: symmetric int8 (codes in `[-127, 127]`) or packed
/// int4 (codes in `[-7, 7]`, two per byte).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    Int8,
    Int4,
}

impl QuantMode {
    /// Largest code magnitude: the symmetric range is `[-levels, levels]`.
    pub fn levels(self) -> i32 {
        match self {
            QuantMode::Int8 => 127,
            QuantMode::Int4 => 7,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QuantMode::Int8 => "int8",
            QuantMode::Int4 => "int4",
        }
    }

    /// Bits-per-weight tag used by the v2 checkpoint encoding.
    pub fn tag(self) -> u32 {
        match self {
            QuantMode::Int8 => 8,
            QuantMode::Int4 => 4,
        }
    }

    pub fn from_tag(tag: u32) -> Option<QuantMode> {
        match tag {
            8 => Some(QuantMode::Int8),
            4 => Some(QuantMode::Int4),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`int8`/`int4`, case-insensitive).
    pub fn parse(s: &str) -> Option<QuantMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "int8" | "i8" | "8" => Some(QuantMode::Int8),
            "int4" | "i4" | "4" => Some(QuantMode::Int4),
            _ => None,
        }
    }

    /// Directory-name convention for a quantized checkpoint of `base` —
    /// the single definition shared by `wisparse quantize` (writer) and
    /// `serve --quant` / `bench-decode` (readers).
    pub fn checkpoint_name(self, base: &str) -> String {
        format!("{base}-{}", self.name())
    }
}

/// A group-quantized column-major weight matrix (see module docs for the
/// layout). `scales` holds `n * groups_per_col()` entries, column-major by
/// group; `data` holds the codes — `n * m` bytes for int8, `n * ceil(m/2)`
/// for int4 (row `2k` in the low nibble, row `2k+1` in the high nibble,
/// nibbles biased by +8).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantMatrix {
    /// Output dimension m.
    pub m: usize,
    /// Input dimension n (channels).
    pub n: usize,
    pub mode: QuantMode,
    /// Rows per scale group within a column (>= 1; may exceed m).
    pub group: usize,
    pub scales: Vec<f32>,
    pub data: Vec<u8>,
}

impl QuantMatrix {
    /// Symmetric per-group quantization of `w`'s columns. A group's scale is
    /// `max|v| / levels`; codes are `round(v / scale)` clamped to the
    /// symmetric range (all-zero groups get scale 0 and codes 0).
    pub fn quantize(w: &ColMajorMatrix, mode: QuantMode, group: usize) -> QuantMatrix {
        assert!(group >= 1, "group size must be >= 1");
        let (m, n) = (w.m, w.n);
        let gpc = m.div_ceil(group).max(1);
        let levels = mode.levels();
        let mut scales = vec![0.0f32; n * gpc];
        let mut data = match mode {
            QuantMode::Int8 => vec![0u8; n * m],
            QuantMode::Int4 => vec![0u8; n * m.div_ceil(2)],
        };
        let stride4 = m.div_ceil(2);
        for c in 0..n {
            let col = w.col(c);
            for g in 0..gpc {
                let lo = g * group;
                let hi = (lo + group).min(m);
                let max_abs = col[lo..hi].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let scale = if max_abs > 0.0 {
                    max_abs / levels as f32
                } else {
                    0.0
                };
                scales[c * gpc + g] = scale;
                for (r, &v) in col.iter().enumerate().take(hi).skip(lo) {
                    let code: i32 = if scale > 0.0 {
                        ((v / scale).round() as i32).clamp(-levels, levels)
                    } else {
                        0
                    };
                    match mode {
                        QuantMode::Int8 => data[c * m + r] = code as i8 as u8,
                        QuantMode::Int4 => {
                            let idx = c * stride4 + r / 2;
                            let nib = (code + 8) as u8 & 0x0F;
                            if r % 2 == 0 {
                                data[idx] = (data[idx] & 0xF0) | nib;
                            } else {
                                data[idx] = (data[idx] & 0x0F) | (nib << 4);
                            }
                        }
                    }
                }
            }
        }
        QuantMatrix {
            m,
            n,
            mode,
            group,
            scales,
            data,
        }
    }

    /// Scale groups per column.
    pub fn groups_per_col(&self) -> usize {
        self.m.div_ceil(self.group).max(1)
    }

    /// Bytes per column of code storage.
    pub fn col_stride(&self) -> usize {
        match self.mode {
            QuantMode::Int8 => self.m,
            QuantMode::Int4 => self.m.div_ceil(2),
        }
    }

    /// Resident bytes of the quantized payload (codes + scales).
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// Dequantize rows `[row0, row0 + out.len())` of column `c` into `out`
    /// — the inline-dequant primitive of the fused kernels. Every element
    /// is exactly `scale * (code as f32)`.
    pub fn dequant_col_range(&self, c: usize, row0: usize, out: &mut [f32]) {
        debug_assert!(c < self.n);
        debug_assert!(row0 + out.len() <= self.m);
        let gpc = self.groups_per_col();
        let scales = &self.scales[c * gpc..(c + 1) * gpc];
        match self.mode {
            QuantMode::Int8 => {
                let col = &self.data[c * self.m..(c + 1) * self.m];
                // Group-stepped: one scale broadcast per group segment.
                let mut i = 0usize;
                while i < out.len() {
                    let r = row0 + i;
                    let g = r / self.group;
                    let gend = ((g + 1) * self.group).min(self.m);
                    let take = (gend - r).min(out.len() - i);
                    simd::dequant_i8(scales[g], &col[r..r + take], &mut out[i..i + take]);
                    i += take;
                }
            }
            QuantMode::Int4 => {
                let stride = self.col_stride();
                let col = &self.data[c * stride..(c + 1) * stride];
                // Group-stepped like the int8 arm: the scale lookup and the
                // group division are hoisted out of the per-element loop.
                let mut i = 0usize;
                while i < out.len() {
                    let r = row0 + i;
                    let g = r / self.group;
                    let gend = ((g + 1) * self.group).min(self.m);
                    let take = (gend - r).min(out.len() - i);
                    let s = scales[g];
                    for k in 0..take {
                        let rr = r + k;
                        let byte = col[rr / 2];
                        let nib = if rr % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                        out[i + k] = s * (nib as i32 - 8) as f32;
                    }
                    i += take;
                }
            }
        }
    }

    /// Full dequantization back to f32 columns (tests, calibration-time
    /// references, R-Sparse factorization).
    pub fn dequantize(&self) -> ColMajorMatrix {
        let mut data = vec![0.0f32; self.m * self.n];
        for c in 0..self.n {
            self.dequant_col_range(c, 0, &mut data[c * self.m..(c + 1) * self.m]);
        }
        ColMajorMatrix {
            m: self.m,
            n: self.n,
            data,
        }
    }

    /// Column L2 norms of the *deployed* (dequantized) values — the `g` of
    /// Eq. 4 must be computed from what the kernels actually multiply, so
    /// calibration, tau selection and execution agree.
    pub fn col_l2_norms(&self) -> Vec<f32> {
        let mut tmp = vec![0.0f32; self.m];
        (0..self.n)
            .map(|c| {
                self.dequant_col_range(c, 0, &mut tmp);
                tmp.iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum::<f64>()
                    .sqrt() as f32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn random_cm(m: usize, n: usize, seed: u64) -> ColMajorMatrix {
        let mut rng = Pcg64::new(seed);
        ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 1.0, &mut rng))
    }

    #[test]
    fn roundtrip_error_within_analytic_bound() {
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            for group in [1usize, 3, 8, 64, 1000] {
                let w = random_cm(37, 11, 5 + group as u64);
                let q = QuantMatrix::quantize(&w, mode, group);
                let dq = q.dequantize();
                let gpc = q.groups_per_col();
                for c in 0..w.n {
                    let col = w.col(c);
                    for r in 0..w.m {
                        let scale = q.scales[c * gpc + r / group];
                        let err = (col[r] - dq.col(c)[r]).abs();
                        // Half a quantization step per group, plus fp slack.
                        let bound = scale * 0.5 * (1.0 + 1e-4) + 1e-9;
                        assert!(
                            err <= bound,
                            "{} group {group} c={c} r={r}: err {err} > bound {bound}",
                            mode.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_group_quantizes_to_zero() {
        let w = ColMajorMatrix::from_row_major(&Tensor::zeros(&[6, 3]));
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let q = QuantMatrix::quantize(&w, mode, 4);
            assert!(q.scales.iter().all(|&s| s == 0.0));
            let dq = q.dequantize();
            assert!(dq.data.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn extreme_values_hit_full_range() {
        // The group max must map to exactly +/- levels and back to itself.
        let t = Tensor::from_vec(&[4, 1], vec![2.0, -2.0, 1.0, 0.5]);
        let w = ColMajorMatrix::from_row_major(&t);
        let q = QuantMatrix::quantize(&w, QuantMode::Int8, 4);
        let dq = q.dequantize();
        assert!((dq.col(0)[0] - 2.0).abs() < 1e-6);
        assert!((dq.col(0)[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn int4_packing_roundtrips_odd_m() {
        let w = random_cm(7, 5, 9);
        let q = QuantMatrix::quantize(&w, QuantMode::Int4, 3);
        assert_eq!(q.col_stride(), 4);
        assert_eq!(q.data.len(), 5 * 4);
        let dq = q.dequantize();
        // Ranged dequant agrees with the full dequant on every window.
        let mut buf = vec![0.0f32; 3];
        for c in 0..5 {
            for row0 in [0usize, 1, 2, 4] {
                q.dequant_col_range(c, row0, &mut buf);
                for i in 0..3 {
                    assert_eq!(buf[i].to_bits(), dq.col(c)[row0 + i].to_bits());
                }
            }
        }
    }

    #[test]
    fn col_norms_match_dequantized_reference() {
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let w = random_cm(23, 9, 31);
            let q = QuantMatrix::quantize(&w, mode, 8);
            let a = q.col_l2_norms();
            let b = q.dequantize().col_l2_norms();
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6, "{}", mode.name());
            }
        }
    }

    #[test]
    fn compression_ratio_is_real() {
        let w = random_cm(128, 64, 1);
        let f32_bytes = w.bytes();
        let q8 = QuantMatrix::quantize(&w, QuantMode::Int8, 64);
        let q4 = QuantMatrix::quantize(&w, QuantMode::Int4, 64);
        assert!(f32_bytes as f64 / q8.bytes() as f64 > 3.5);
        assert!(f32_bytes as f64 / q4.bytes() as f64 > 7.0);
    }

    #[test]
    fn mode_parse_and_tags() {
        assert_eq!(QuantMode::parse("int8"), Some(QuantMode::Int8));
        assert_eq!(QuantMode::parse(" INT4 "), Some(QuantMode::Int4));
        assert_eq!(QuantMode::parse("fp16"), None);
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            assert_eq!(QuantMode::from_tag(mode.tag()), Some(mode));
        }
        assert_eq!(QuantMode::from_tag(16), None);
    }
}
