//! Fused dequant×sparse GEMV kernels over [`QuantMatrix`] weights.
//!
//! Same two-pass structure as the f32 fused kernels in
//! `sparse_kernel/gemv.rs`: pass 1 scans the mask predicate into a reusable
//! index buffer (the *identical* SIMD scans — quantization never changes
//! which channels are kept), pass 2 walks only the kept columns. Each kept
//! column is dequantized inline — group-by-group, one scale broadcast per
//! group, through the dispatched [`simd::dequant_i8`] primitive — into a
//! thread-local eight-column window that stays L1/L2-resident, then
//! accumulated with the same fused `axpy8` pass the f32 path uses. DRAM
//! sees only the 1-byte (int8) or half-byte (int4) code stream plus the
//! tiny scale stream; the f32 image of a column never exists outside the
//! reused window.
//!
//! Because dequantization is a single IEEE multiply per element and the
//! accumulate pass is byte-for-byte the f32 kernel's, every kernel here is
//! **bit-identical** to "dequantize the whole matrix, then run the f32
//! fused kernel on the same backend" — pinned down by
//! `rust/tests/quant_subsystem.rs` across backends, odd shapes, group
//! sizes and tau regimes.

use crate::quant::matrix::QuantMatrix;
use crate::sparse_kernel::gemv::{merge_walk_groups, union_count, with_scanned_batch, PAR_MIN_MACS};
use crate::sparse_kernel::simd::{self, Backend};
use crate::util::threadpool::{parallel_row_windows, parallel_slices_aligned, SendPtr};
use std::cell::RefCell;

thread_local! {
    /// Per-thread dequant window: eight column slices of the widest layer
    /// seen, grown once and reused (steady-state decode allocates nothing).
    static DEQ_WIN: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Kept-index scratch for the collect entry point.
    static COLLECT_IDX: RefCell<Vec<u32>> = const { RefCell::new(Vec::new()) };
}

/// Fused scored/threshold projection on the process-wide SIMD backend.
/// `ga = None` is the TEAL/magnitude path. `kept_idx` is caller-owned
/// scratch. Returns the kept-channel count.
pub fn quant_gemv_fused(
    w: &QuantMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
) -> usize {
    quant_gemv_fused_with(simd::active(), w, x, ga, tau, out, kept_idx)
}

/// Fused projection on an explicit backend (tests / bench sweeps).
pub fn quant_gemv_fused_with(
    backend: Backend,
    w: &QuantMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    match ga {
        Some(ga) => {
            debug_assert_eq!(ga.len(), w.n);
            simd::scan_scored_with(backend, x, ga, tau, kept_idx);
        }
        None => simd::scan_threshold_with(backend, x, tau, kept_idx),
    }
    out.fill(0.0);
    accum_rows_quant(backend, w, x, kept_idx, 0, out);
    kept_idx.len()
}

/// Fused projection with intra-GEMV row parallelism (window boundaries
/// aligned to the SIMD group width, so the split is bit-identical to the
/// serial kernel at any thread count — exactly as the f32 path).
pub fn quant_gemv_fused_parallel(
    w: &QuantMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
    threads: usize,
) -> usize {
    quant_gemv_fused_parallel_with(
        simd::active(),
        w,
        x,
        ga,
        tau,
        out,
        kept_idx,
        threads,
        PAR_MIN_MACS,
    )
}

/// As [`quant_gemv_fused_parallel`] with explicit backend and split
/// threshold (tests force `min_macs = 0` to exercise the split on small
/// shapes).
#[allow(clippy::too_many_arguments)]
pub fn quant_gemv_fused_parallel_with(
    backend: Backend,
    w: &QuantMatrix,
    x: &[f32],
    ga: Option<&[f32]>,
    tau: f32,
    out: &mut [f32],
    kept_idx: &mut Vec<u32>,
    threads: usize,
    min_macs: usize,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    match ga {
        Some(ga) => {
            debug_assert_eq!(ga.len(), w.n);
            simd::scan_scored_with(backend, x, ga, tau, kept_idx);
        }
        None => simd::scan_threshold_with(backend, x, tau, kept_idx),
    }
    let kept = kept_idx.len();
    if threads <= 1 || w.m.saturating_mul(kept) < min_macs.max(1) {
        out.fill(0.0);
        accum_rows_quant(backend, w, x, kept_idx, 0, out);
        return kept;
    }
    let idx: &[u32] = kept_idx.as_slice();
    parallel_slices_aligned(out, threads, 8, |_, row0, rows| {
        rows.fill(0.0);
        accum_rows_quant(backend, w, x, idx, row0, rows);
    });
    kept
}

/// Dense projection (all channels kept) on an explicit backend.
pub fn quant_gemv_dense_with(
    backend: Backend,
    w: &QuantMatrix,
    x: &[f32],
    out: &mut [f32],
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    out.fill(0.0);
    dense_rows_quant(backend, w, x, 0, out);
    w.n
}

/// Dense projection with intra-GEMV row parallelism — the quantized
/// `lm_head` path of single-sequence decode.
pub fn quant_gemv_dense_parallel(
    w: &QuantMatrix,
    x: &[f32],
    out: &mut [f32],
    threads: usize,
) -> usize {
    debug_assert_eq!(x.len(), w.n);
    debug_assert_eq!(out.len(), w.m);
    let backend = simd::active();
    if threads <= 1 || w.m.saturating_mul(w.n) < PAR_MIN_MACS {
        out.fill(0.0);
        dense_rows_quant(backend, w, x, 0, out);
        return w.n;
    }
    parallel_slices_aligned(out, threads, 8, |_, row0, rows| {
        rows.fill(0.0);
        dense_rows_quant(backend, w, x, row0, rows);
    });
    w.n
}

/// Scored projection that also reports the kept-channel indices (R-Sparse's
/// exact path over quantized weights).
pub fn quant_gemv_scored_collect(
    w: &QuantMatrix,
    x: &[f32],
    ga: &[f32],
    tau: f32,
    out: &mut [f32],
    kept_buf: &mut Vec<usize>,
) -> usize {
    COLLECT_IDX.with(|cell| {
        let idx = &mut *cell.borrow_mut();
        let kept = quant_gemv_fused(w, x, Some(ga), tau, out, idx);
        kept_buf.clear();
        kept_buf.extend(idx.iter().map(|&c| c as usize));
        kept
    })
}

/// rows += sum over kept channels of `x[c] * Wq[row0..row0+rows.len(), c]`,
/// eight columns fused per accumulator pass. Each batch of eight kept
/// columns is dequantized inline into the thread-local window, then fed to
/// the same dispatched `axpy8` the f32 kernels use — identical values,
/// identical op order, bit-identical output.
fn accum_rows_quant(
    backend: Backend,
    w: &QuantMatrix,
    x: &[f32],
    idx: &[u32],
    row0: usize,
    rows: &mut [f32],
) {
    let mlen = rows.len();
    debug_assert!(row0 + mlen <= w.m);
    if mlen == 0 {
        return;
    }
    DEQ_WIN.with(|cell| {
        let deq = &mut *cell.borrow_mut();
        if deq.len() < 8 * mlen {
            deq.resize(8 * mlen, 0.0);
        }
        let mut coeffs = [0.0f32; 8];
        let mut offs = [0usize; 8];
        let groups = idx.chunks_exact(8);
        let rem = groups.remainder();
        for group in groups {
            for (j, &c) in group.iter().enumerate() {
                let c = c as usize;
                coeffs[j] = x[c];
                offs[j] = j * mlen;
                w.dequant_col_range(c, row0, &mut deq[j * mlen..(j + 1) * mlen]);
            }
            simd::axpy8_with(backend, &coeffs, &offs, &deq[..8 * mlen], rows);
        }
        for &c in rem {
            let c = c as usize;
            w.dequant_col_range(c, row0, &mut deq[..mlen]);
            simd::axpy_with(backend, x[c], &deq[..mlen], rows);
        }
    });
}

/// Dense counterpart of [`accum_rows_quant`]: every channel, eight at a
/// time, mirroring the f32 `dense_rows` geometry exactly.
fn dense_rows_quant(backend: Backend, w: &QuantMatrix, x: &[f32], row0: usize, rows: &mut [f32]) {
    let mlen = rows.len();
    let n = w.n;
    debug_assert!(row0 + mlen <= w.m);
    if mlen == 0 {
        return;
    }
    DEQ_WIN.with(|cell| {
        let deq = &mut *cell.borrow_mut();
        if deq.len() < 8 * mlen {
            deq.resize(8 * mlen, 0.0);
        }
        let mut coeffs = [0.0f32; 8];
        let mut offs = [0usize; 8];
        let mut c = 0usize;
        while c + 8 <= n {
            for j in 0..8 {
                coeffs[j] = x[c + j];
                offs[j] = j * mlen;
                w.dequant_col_range(c + j, row0, &mut deq[j * mlen..(j + 1) * mlen]);
            }
            simd::axpy8_with(backend, &coeffs, &offs, &deq[..8 * mlen], rows);
            c += 8;
        }
        while c < n {
            w.dequant_col_range(c, row0, &mut deq[..mlen]);
            simd::axpy_with(backend, x[c], &deq[..mlen], rows);
            c += 1;
        }
    });
}

// ---------------------------------------------------------------------------
// Batch-fused kernels (§Tentpole, PR 8): the quant counterparts of
// `sparse_gemv_masked_batch` / `dense_gemv_batch`. The masked path streams
// each kept column's *code* bytes once per group flush — the codes stay
// cache-hot across positions sharing a column, so DRAM sees the union
// stream — while the dense path dequantizes each eight-column group exactly
// once into the shared window and replays it across every position (the
// fused `lm_head` win). Both are bit-identical per position to the
// per-sequence quant kernels: same scans, same dequant values, same flush
// grouping.
// ---------------------------------------------------------------------------

/// Union merge-walk over one row window. Each flush dequantizes the
/// position's pending columns into the thread-local window before the same
/// `axpy8` pass `accum_rows_quant` uses.
///
/// # Safety
/// Same disjoint-window contract as the f32 `walk_rows_batch`: the windows
/// `out_base[p*out_stride + row0 .. + rows]` must be valid for writes and
/// disjoint from every other live reference.
#[allow(clippy::too_many_arguments)]
unsafe fn walk_rows_quant_batch(
    backend: Backend,
    w: &QuantMatrix,
    xs: &[f32],
    in_stride: usize,
    idx: &[Vec<u32>],
    out_base: *mut f32,
    out_stride: usize,
    row0: usize,
    rows: usize,
) {
    let window = |p: usize| unsafe {
        std::slice::from_raw_parts_mut(out_base.add(p * out_stride + row0), rows)
    };
    for p in 0..idx.len() {
        window(p).fill(0.0);
    }
    if rows == 0 {
        return;
    }
    DEQ_WIN.with(|cell| {
        let deq = &mut *cell.borrow_mut();
        if deq.len() < 8 * rows {
            deq.resize(8 * rows, 0.0);
        }
        let mut coeffs = [0.0f32; 8];
        let mut offs = [0usize; 8];
        merge_walk_groups(
            idx,
            |p, cols| {
                let x = &xs[p * in_stride..];
                for (j, &c) in cols.iter().enumerate() {
                    let c = c as usize;
                    coeffs[j] = x[c];
                    offs[j] = j * rows;
                    w.dequant_col_range(c, row0, &mut deq[j * rows..(j + 1) * rows]);
                }
                simd::axpy8_with(backend, &coeffs, &offs, &deq[..8 * rows], window(p));
            },
            |p, c| {
                let c = c as usize;
                w.dequant_col_range(c, row0, &mut deq[..rows]);
                simd::axpy_with(backend, xs[p * in_stride + c], &deq[..rows], window(p));
            },
        );
    });
}

/// Batch-fused scored/threshold projection over quantized weights on the
/// process-wide backend. Writes each position's kept count into `kept_out`;
/// returns the union (distinct streamed) column count.
#[allow(clippy::too_many_arguments)]
pub fn quant_gemv_masked_batch(
    w: &QuantMatrix,
    xs: &[f32],
    in_stride: usize,
    ga: Option<&[f32]>,
    tau: f32,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    kept_out: &mut [usize],
    threads: usize,
) -> usize {
    quant_gemv_masked_batch_with(
        simd::active(),
        w,
        xs,
        in_stride,
        ga,
        tau,
        outs,
        out_stride,
        n_pos,
        kept_out,
        threads,
        PAR_MIN_MACS,
    )
}

/// As [`quant_gemv_masked_batch`] with explicit backend and split threshold.
#[allow(clippy::too_many_arguments)]
pub fn quant_gemv_masked_batch_with(
    backend: Backend,
    w: &QuantMatrix,
    xs: &[f32],
    in_stride: usize,
    ga: Option<&[f32]>,
    tau: f32,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    kept_out: &mut [usize],
    threads: usize,
    min_macs: usize,
) -> usize {
    debug_assert!(n_pos >= 1);
    debug_assert!(in_stride >= w.n && out_stride >= w.m);
    debug_assert!(xs.len() >= (n_pos - 1) * in_stride + w.n);
    debug_assert!(outs.len() >= (n_pos - 1) * out_stride + w.m);
    debug_assert!(kept_out.len() >= n_pos);
    with_scanned_batch(
        n_pos,
        w.n,
        |p, l| {
            let x = &xs[p * in_stride..p * in_stride + w.n];
            match ga {
                Some(ga) => {
                    debug_assert_eq!(ga.len(), w.n);
                    simd::scan_scored_with(backend, x, ga, tau, l);
                }
                None => simd::scan_threshold_with(backend, x, tau, l),
            }
            kept_out[p] = l.len();
        },
        |idx| {
            let union = union_count(idx);
            let base = SendPtr(outs.as_mut_ptr());
            if threads <= 1 || w.m.saturating_mul(union) < min_macs.max(1) {
                // Safety: `outs` is exclusively borrowed; serial walk only
                // writer.
                unsafe {
                    walk_rows_quant_batch(backend, w, xs, in_stride, idx, base.0, out_stride, 0, w.m)
                };
                return union;
            }
            parallel_row_windows(w.m, threads, 8, |row0, rows| {
                let b = base;
                // Safety: disjoint row windows per worker, disjoint strided
                // rows per position.
                unsafe {
                    walk_rows_quant_batch(
                        backend, w, xs, in_stride, idx, b.0, out_stride, row0, rows,
                    )
                };
            });
            union
        },
    )
}

/// Dense batch row-window accumulation: each eight-column group is
/// dequantized *once* into the shared window, then replayed across every
/// position — shared dequant work, identical window contents to
/// `dense_rows_quant`, so per-position output is bit-identical.
///
/// # Safety
/// Same disjoint-window contract as [`walk_rows_quant_batch`].
unsafe fn dense_rows_quant_batch(
    backend: Backend,
    w: &QuantMatrix,
    xs: &[f32],
    in_stride: usize,
    n_pos: usize,
    out_base: *mut f32,
    out_stride: usize,
    row0: usize,
    rows: usize,
) {
    let n = w.n;
    let window = |p: usize| unsafe {
        std::slice::from_raw_parts_mut(out_base.add(p * out_stride + row0), rows)
    };
    for p in 0..n_pos {
        window(p).fill(0.0);
    }
    if rows == 0 {
        return;
    }
    DEQ_WIN.with(|cell| {
        let deq = &mut *cell.borrow_mut();
        if deq.len() < 8 * rows {
            deq.resize(8 * rows, 0.0);
        }
        let mut coeffs = [0.0f32; 8];
        let mut offs = [0usize; 8];
        let mut c = 0usize;
        while c + 8 <= n {
            for (j, off) in offs.iter_mut().enumerate() {
                *off = j * rows;
                w.dequant_col_range(c + j, row0, &mut deq[j * rows..(j + 1) * rows]);
            }
            for p in 0..n_pos {
                let x = &xs[p * in_stride..];
                for (j, coeff) in coeffs.iter_mut().enumerate() {
                    *coeff = x[c + j];
                }
                simd::axpy8_with(backend, &coeffs, &offs, &deq[..8 * rows], window(p));
            }
            c += 8;
        }
        while c < n {
            w.dequant_col_range(c, row0, &mut deq[..rows]);
            for p in 0..n_pos {
                simd::axpy_with(backend, xs[p * in_stride + c], &deq[..rows], window(p));
            }
            c += 1;
        }
    });
}

/// Dense batch projection over quantized weights (the fused quant `lm_head`
/// path). Returns `w.n`.
pub fn quant_gemv_dense_batch(
    w: &QuantMatrix,
    xs: &[f32],
    in_stride: usize,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    threads: usize,
) -> usize {
    quant_gemv_dense_batch_with(
        simd::active(),
        w,
        xs,
        in_stride,
        outs,
        out_stride,
        n_pos,
        threads,
        PAR_MIN_MACS,
    )
}

/// As [`quant_gemv_dense_batch`] with explicit backend and split threshold.
#[allow(clippy::too_many_arguments)]
pub fn quant_gemv_dense_batch_with(
    backend: Backend,
    w: &QuantMatrix,
    xs: &[f32],
    in_stride: usize,
    outs: &mut [f32],
    out_stride: usize,
    n_pos: usize,
    threads: usize,
    min_macs: usize,
) -> usize {
    debug_assert!(n_pos >= 1);
    debug_assert!(in_stride >= w.n && out_stride >= w.m);
    debug_assert!(xs.len() >= (n_pos - 1) * in_stride + w.n);
    debug_assert!(outs.len() >= (n_pos - 1) * out_stride + w.m);
    let base = SendPtr(outs.as_mut_ptr());
    if threads <= 1 || w.m.saturating_mul(w.n) < min_macs.max(1) {
        // Safety: `outs` is exclusively borrowed; serial walk only writer.
        unsafe {
            dense_rows_quant_batch(backend, w, xs, in_stride, n_pos, base.0, out_stride, 0, w.m)
        };
        return w.n;
    }
    parallel_row_windows(w.m, threads, 8, |row0, rows| {
        let b = base;
        // Safety: disjoint row windows per worker, disjoint strided rows per
        // position.
        unsafe {
            dense_rows_quant_batch(backend, w, xs, in_stride, n_pos, b.0, out_stride, row0, rows)
        };
    });
    w.n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::matrix::QuantMode;
    use crate::sparse_kernel::gemv::{dense_gemv_simd_with, sparse_gemv_fused_with};
    use crate::sparse_kernel::ColMajorMatrix;
    use crate::tensor::Tensor;
    use crate::util::rng::Pcg64;

    fn setup(m: usize, n: usize, seed: u64) -> (ColMajorMatrix, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let w = ColMajorMatrix::from_row_major(&Tensor::randn(&[m, n], 1.0, &mut rng));
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ga: Vec<f32> = (0..n).map(|_| rng.next_f32() + 0.05).collect();
        (w, x, ga)
    }

    #[test]
    fn fused_bit_identical_to_dequant_reference() {
        for backend in crate::sparse_kernel::simd::available_backends() {
            for mode in [QuantMode::Int8, QuantMode::Int4] {
                for group in [3usize, 16, 64] {
                    let (w, x, ga) = setup(29, 41, 7 + group as u64);
                    let q = QuantMatrix::quantize(&w, mode, group);
                    let dq = q.dequantize();
                    let mut idx_a = Vec::new();
                    let mut idx_b = Vec::new();
                    for tau in [0.0f32, 0.3, 0.9, f32::INFINITY] {
                        for ga_opt in [Some(ga.as_slice()), None] {
                            let mut a = vec![0.0f32; 29];
                            let mut b = vec![0.0f32; 29];
                            let ka = sparse_gemv_fused_with(
                                backend, &dq, &x, ga_opt, tau, &mut a, &mut idx_a,
                            );
                            let kb = quant_gemv_fused_with(
                                backend, &q, &x, ga_opt, tau, &mut b, &mut idx_b,
                            );
                            assert_eq!(ka, kb, "{} {} tau {tau}", backend.name(), mode.name());
                            for i in 0..29 {
                                assert_eq!(
                                    a[i].to_bits(),
                                    b[i].to_bits(),
                                    "{} {} group {group} tau {tau} row {i}: {} vs {}",
                                    backend.name(),
                                    mode.name(),
                                    a[i],
                                    b[i]
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dense_bit_identical_to_dequant_reference() {
        for backend in crate::sparse_kernel::simd::available_backends() {
            for mode in [QuantMode::Int8, QuantMode::Int4] {
                let (w, x, _) = setup(27, 19, 83);
                let q = QuantMatrix::quantize(&w, mode, 8);
                let dq = q.dequantize();
                let mut a = vec![0.0f32; 27];
                let mut b = vec![0.0f32; 27];
                assert_eq!(dense_gemv_simd_with(backend, &dq, &x, &mut a), 19);
                assert_eq!(quant_gemv_dense_with(backend, &q, &x, &mut b), 19);
                for i in 0..27 {
                    assert_eq!(a[i].to_bits(), b[i].to_bits(), "{} row {i}", backend.name());
                }
            }
        }
    }

    #[test]
    fn parallel_split_bit_identical_to_serial() {
        let (w, x, ga) = setup(53, 31, 71);
        let q = QuantMatrix::quantize(&w, QuantMode::Int8, 16);
        let mut idx = Vec::new();
        let mut serial = vec![0.0f32; 53];
        let backend = crate::sparse_kernel::simd::active();
        let ks = quant_gemv_fused_with(backend, &q, &x, Some(&ga), 0.4, &mut serial, &mut idx);
        for threads in [2usize, 3, 8] {
            let mut par = vec![0.0f32; 53];
            let kp = quant_gemv_fused_parallel_with(
                backend,
                &q,
                &x,
                Some(&ga),
                0.4,
                &mut par,
                &mut idx,
                threads,
                0, // force the row split on this tiny shape
            );
            assert_eq!(ks, kp);
            for i in 0..53 {
                assert_eq!(serial[i].to_bits(), par[i].to_bits(), "threads {threads} row {i}");
            }
        }
    }

    #[test]
    fn collect_reports_kept_channels() {
        let (w, x, ga) = setup(9, 14, 17);
        let q = QuantMatrix::quantize(&w, QuantMode::Int8, 4);
        let mut out = vec![0.0f32; 9];
        let mut kept = Vec::new();
        let k = quant_gemv_scored_collect(&q, &x, &ga, 0.4, &mut out, &mut kept);
        assert_eq!(k, kept.len());
        for &c in &kept {
            assert!(x[c].abs() * ga[c] >= 0.4);
        }
        for c in 0..14 {
            if !kept.contains(&c) {
                assert!(x[c].abs() * ga[c] < 0.4);
            }
        }
    }

    #[test]
    fn infinite_tau_keeps_nothing() {
        let (w, x, ga) = setup(5, 8, 23);
        let q = QuantMatrix::quantize(&w, QuantMode::Int4, 2);
        let mut out = vec![1.0f32; 5];
        let mut idx = Vec::new();
        let kept = quant_gemv_fused(&q, &x, Some(&ga), f32::INFINITY, &mut out, &mut idx);
        assert_eq!(kept, 0);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn masked_batch_bit_identical_to_per_position() {
        let (m, n, n_pos) = (29usize, 41usize, 5usize);
        let backend = crate::sparse_kernel::simd::active();
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let (w, _, ga) = setup(m, n, 7);
            let q = QuantMatrix::quantize(&w, mode, 16);
            let mut rng = Pcg64::new(0x5EED);
            let in_stride = n + 3;
            let mut xs = vec![f32::NAN; n_pos * in_stride];
            for p in 0..n_pos {
                for c in 0..n {
                    xs[p * in_stride + c] = rng.normal() as f32;
                }
            }
            for ga_opt in [Some(ga.as_slice()), None] {
                for tau in [0.0f32, 0.4, f32::INFINITY] {
                    let mut refs = vec![0.0f32; n_pos * m];
                    let mut kept_ref = vec![0usize; n_pos];
                    let mut idx = Vec::new();
                    for p in 0..n_pos {
                        kept_ref[p] = quant_gemv_fused_with(
                            backend,
                            &q,
                            &xs[p * in_stride..p * in_stride + n],
                            ga_opt,
                            tau,
                            &mut refs[p * m..(p + 1) * m],
                            &mut idx,
                        );
                    }
                    for threads in [1usize, 3] {
                        let mut outs = vec![f32::NAN; n_pos * m];
                        let mut kept = vec![0usize; n_pos];
                        let union = quant_gemv_masked_batch_with(
                            backend, &q, &xs, in_stride, ga_opt, tau, &mut outs, m, n_pos,
                            &mut kept, threads, 0,
                        );
                        assert_eq!(kept, kept_ref, "{} tau {tau}", mode.name());
                        assert!(union >= kept.iter().copied().max().unwrap_or(0));
                        for i in 0..n_pos * m {
                            assert_eq!(
                                outs[i].to_bits(),
                                refs[i].to_bits(),
                                "{} tau {tau} threads {threads} idx {i}",
                                mode.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn dense_batch_bit_identical_to_per_position() {
        let (m, n, n_pos) = (27usize, 19usize, 4usize);
        let backend = crate::sparse_kernel::simd::active();
        for mode in [QuantMode::Int8, QuantMode::Int4] {
            let (w, _, _) = setup(m, n, 83);
            let q = QuantMatrix::quantize(&w, mode, 8);
            let mut rng = Pcg64::new(0xBA7C);
            let mut xs = vec![0.0f32; n_pos * n];
            for v in xs.iter_mut() {
                *v = rng.normal() as f32;
            }
            let mut refs = vec![0.0f32; n_pos * m];
            for p in 0..n_pos {
                quant_gemv_dense_with(
                    backend,
                    &q,
                    &xs[p * n..(p + 1) * n],
                    &mut refs[p * m..(p + 1) * m],
                );
            }
            for threads in [1usize, 4] {
                let mut outs = vec![f32::NAN; n_pos * m];
                let streamed = quant_gemv_dense_batch_with(
                    backend, &q, &xs, n, &mut outs, m, n_pos, threads, 0,
                );
                assert_eq!(streamed, n);
                for i in 0..n_pos * m {
                    assert_eq!(
                        outs[i].to_bits(),
                        refs[i].to_bits(),
                        "{} threads {threads} idx {i}",
                        mode.name()
                    );
                }
            }
        }
    }
}
