//! Group-quantized weight subsystem.
//!
//! Three pieces:
//!
//! - [`QuantMatrix`] (`matrix.rs`): symmetric per-group int8 / packed int4
//!   codes over column-major weight columns, with per-group scales.
//! - fused dequant×sparse GEMV kernels (`gemv.rs`): walk only the kept
//!   columns, dequantize inline through the dispatched SIMD primitives,
//!   bit-identical to the dequantize-then-f32 reference on every backend.
//! - the [`WeightRepr`] trait + [`WeightMat`] enum: one projection contract
//!   for dense-f32 and quantized weights, so the transformer, every
//!   sparsifier, the lm_head, and flat/paged/speculative decode all run
//!   unchanged on either representation.
//!
//! Weight-aware scores (`g_i = ||W[:,i]||_2`, Eq. 4) come from
//! [`WeightRepr::col_l2_norms`], which quantized reprs compute from the
//! *deployed* (dequantized) values — calibration, tau selection and the
//! kernels always agree on the same weights.

pub mod gemv;
pub mod matrix;

pub use gemv::{
    quant_gemv_dense_batch, quant_gemv_dense_parallel, quant_gemv_dense_with, quant_gemv_fused,
    quant_gemv_fused_parallel, quant_gemv_fused_with, quant_gemv_masked_batch,
    quant_gemv_scored_collect,
};
pub use matrix::{QuantMatrix, QuantMode};

use crate::sparse_kernel::gemv::{
    dense_gemv_batch, dense_gemv_parallel, sparse_gemv_fused_parallel, sparse_gemv_masked_batch,
    sparse_gemv_scored_collect,
};
use crate::sparse_kernel::ColMajorMatrix;
use crate::tensor::Tensor;

/// One linear layer's deployed weight representation. Everything the engine
/// needs from a weight matrix goes through this trait, so dense-f32 and
/// group-quantized checkpoints share a single execution path.
pub trait WeightRepr: Send + Sync {
    /// Output dimension m of `y = x W^T`.
    fn out_dim(&self) -> usize;

    /// Input (channel) dimension n.
    fn in_dim(&self) -> usize;

    /// Bytes resident for the weight payload (codes + scales for quant).
    fn resident_bytes(&self) -> usize;

    /// `g_i = ||W[:,i]||_2` of the representation as deployed (dequantized
    /// values for quantized reprs).
    fn col_l2_norms(&self) -> Vec<f32>;

    /// Row-major f32 view (dequantized for quantized reprs) — calibration
    /// references and R-Sparse's low-rank factorization.
    fn to_row_major(&self) -> Tensor;

    /// The raw f32 columns when this repr is dense (the pre-SIMD
    /// `force_scalar` A/B paths need them; quantized reprs return None).
    fn as_dense(&self) -> Option<&ColMajorMatrix>;

    /// Dense projection `out = x W^T` (all channels kept). Returns n.
    fn gemv_dense(&self, x: &[f32], out: &mut [f32], threads: usize) -> usize;

    /// Masked fused projection: keep channel c iff `|x_c| * ga_c >= tau`
    /// (`ga = None` = pure magnitude). `kept_idx` is caller-owned scratch.
    /// Returns the kept count.
    fn gemv_masked(
        &self,
        x: &[f32],
        ga: Option<&[f32]>,
        tau: f32,
        out: &mut [f32],
        kept_idx: &mut Vec<u32>,
        threads: usize,
    ) -> usize;

    /// Masked projection that also reports the kept-channel indices
    /// (R-Sparse routes the complement through its low-rank path).
    fn gemv_masked_collect(
        &self,
        x: &[f32],
        ga: &[f32],
        tau: f32,
        out: &mut [f32],
        kept_buf: &mut Vec<usize>,
    ) -> usize;

    /// Batched dense projection: position `p` reads `xs[p*in_stride..][..n]`
    /// and writes `outs[p*out_stride..][..m]`. Returns the number of
    /// channels streamed (n). The fallback streams the weights once per
    /// position; batch-aware reprs stream them once for the whole batch.
    fn gemv_dense_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        threads: usize,
    ) -> usize {
        for p in 0..n_pos {
            let x = &xs[p * in_stride..p * in_stride + self.in_dim()];
            let out = &mut outs[p * out_stride..p * out_stride + self.out_dim()];
            self.gemv_dense(x, out, threads);
        }
        self.in_dim()
    }

    /// Batched masked projection over the same strided layout as
    /// [`WeightRepr::gemv_dense_batch`], one mask per position (shared
    /// `ga`/`tau`). `kept_out[p]` gets position `p`'s kept count; the return
    /// value is the number of weight columns streamed — the *union* of the
    /// batch's masks for batch-fused reprs, the sum for the per-position
    /// fallback.
    fn gemv_masked_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        ga: Option<&[f32]>,
        tau: f32,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        kept_out: &mut [usize],
        threads: usize,
    ) -> usize {
        BATCH_FALLBACK_IDX.with(|cell| {
            let idx = &mut *cell.borrow_mut();
            let mut streamed = 0usize;
            for p in 0..n_pos {
                let x = &xs[p * in_stride..p * in_stride + self.in_dim()];
                let out = &mut outs[p * out_stride..p * out_stride + self.out_dim()];
                kept_out[p] = self.gemv_masked(x, ga, tau, out, idx, threads);
                streamed += kept_out[p];
            }
            streamed
        })
    }
}

thread_local! {
    /// Kept-index scratch for the default (per-position) batch fallbacks.
    static BATCH_FALLBACK_IDX: std::cell::RefCell<Vec<u32>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

impl WeightRepr for ColMajorMatrix {
    fn out_dim(&self) -> usize {
        self.m
    }

    fn in_dim(&self) -> usize {
        self.n
    }

    fn resident_bytes(&self) -> usize {
        self.bytes()
    }

    fn col_l2_norms(&self) -> Vec<f32> {
        ColMajorMatrix::col_l2_norms(self)
    }

    fn to_row_major(&self) -> Tensor {
        ColMajorMatrix::to_row_major(self)
    }

    fn as_dense(&self) -> Option<&ColMajorMatrix> {
        Some(self)
    }

    fn gemv_dense(&self, x: &[f32], out: &mut [f32], threads: usize) -> usize {
        dense_gemv_parallel(self, x, out, threads)
    }

    fn gemv_masked(
        &self,
        x: &[f32],
        ga: Option<&[f32]>,
        tau: f32,
        out: &mut [f32],
        kept_idx: &mut Vec<u32>,
        threads: usize,
    ) -> usize {
        sparse_gemv_fused_parallel(self, x, ga, tau, out, kept_idx, threads)
    }

    fn gemv_masked_collect(
        &self,
        x: &[f32],
        ga: &[f32],
        tau: f32,
        out: &mut [f32],
        kept_buf: &mut Vec<usize>,
    ) -> usize {
        sparse_gemv_scored_collect(self, x, ga, tau, out, kept_buf)
    }

    fn gemv_dense_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        threads: usize,
    ) -> usize {
        dense_gemv_batch(self, xs, in_stride, outs, out_stride, n_pos, threads)
    }

    fn gemv_masked_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        ga: Option<&[f32]>,
        tau: f32,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        kept_out: &mut [usize],
        threads: usize,
    ) -> usize {
        sparse_gemv_masked_batch(
            self, xs, in_stride, ga, tau, outs, out_stride, n_pos, kept_out, threads,
        )
    }
}

impl WeightRepr for QuantMatrix {
    fn out_dim(&self) -> usize {
        self.m
    }

    fn in_dim(&self) -> usize {
        self.n
    }

    fn resident_bytes(&self) -> usize {
        self.bytes()
    }

    fn col_l2_norms(&self) -> Vec<f32> {
        QuantMatrix::col_l2_norms(self)
    }

    fn to_row_major(&self) -> Tensor {
        self.dequantize().to_row_major()
    }

    fn as_dense(&self) -> Option<&ColMajorMatrix> {
        None
    }

    fn gemv_dense(&self, x: &[f32], out: &mut [f32], threads: usize) -> usize {
        quant_gemv_dense_parallel(self, x, out, threads)
    }

    fn gemv_masked(
        &self,
        x: &[f32],
        ga: Option<&[f32]>,
        tau: f32,
        out: &mut [f32],
        kept_idx: &mut Vec<u32>,
        threads: usize,
    ) -> usize {
        quant_gemv_fused_parallel(self, x, ga, tau, out, kept_idx, threads)
    }

    fn gemv_masked_collect(
        &self,
        x: &[f32],
        ga: &[f32],
        tau: f32,
        out: &mut [f32],
        kept_buf: &mut Vec<usize>,
    ) -> usize {
        quant_gemv_scored_collect(self, x, ga, tau, out, kept_buf)
    }

    fn gemv_dense_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        threads: usize,
    ) -> usize {
        quant_gemv_dense_batch(self, xs, in_stride, outs, out_stride, n_pos, threads)
    }

    fn gemv_masked_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        ga: Option<&[f32]>,
        tau: f32,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        kept_out: &mut [usize],
        threads: usize,
    ) -> usize {
        quant_gemv_masked_batch(
            self, xs, in_stride, ga, tau, outs, out_stride, n_pos, kept_out, threads,
        )
    }
}

/// A weight matrix in whichever representation the checkpoint deployed.
#[derive(Clone, Debug)]
pub enum WeightMat {
    Dense(ColMajorMatrix),
    Quant(QuantMatrix),
}

impl WeightMat {
    /// Build the dense representation from a row-major tensor.
    pub fn dense(t: &Tensor) -> WeightMat {
        WeightMat::Dense(ColMajorMatrix::from_row_major(t))
    }

    /// Group-quantized copy of this matrix (idempotent on already-quantized
    /// weights — re-quantizing lossy codes would silently change them).
    pub fn quantized(&self, mode: QuantMode, group: usize) -> WeightMat {
        match self {
            WeightMat::Dense(d) => WeightMat::Quant(QuantMatrix::quantize(d, mode, group)),
            WeightMat::Quant(q) => WeightMat::Quant(q.clone()),
        }
    }

    /// Representation label for metrics/reports: `f32`, `int8` or `int4`.
    pub fn repr_name(&self) -> &'static str {
        match self {
            WeightMat::Dense(_) => "f32",
            WeightMat::Quant(q) => q.mode.name(),
        }
    }

    /// Bytes a dense-f32 copy of this matrix would occupy.
    pub fn dense_equiv_bytes(&self) -> usize {
        self.out_dim() * self.in_dim() * std::mem::size_of::<f32>()
    }
}

impl WeightRepr for WeightMat {
    fn out_dim(&self) -> usize {
        match self {
            WeightMat::Dense(d) => d.out_dim(),
            WeightMat::Quant(q) => q.out_dim(),
        }
    }

    fn in_dim(&self) -> usize {
        match self {
            WeightMat::Dense(d) => d.in_dim(),
            WeightMat::Quant(q) => q.in_dim(),
        }
    }

    fn resident_bytes(&self) -> usize {
        match self {
            WeightMat::Dense(d) => d.resident_bytes(),
            WeightMat::Quant(q) => q.resident_bytes(),
        }
    }

    fn col_l2_norms(&self) -> Vec<f32> {
        match self {
            WeightMat::Dense(d) => WeightRepr::col_l2_norms(d),
            WeightMat::Quant(q) => WeightRepr::col_l2_norms(q),
        }
    }

    fn to_row_major(&self) -> Tensor {
        match self {
            WeightMat::Dense(d) => WeightRepr::to_row_major(d),
            WeightMat::Quant(q) => WeightRepr::to_row_major(q),
        }
    }

    fn as_dense(&self) -> Option<&ColMajorMatrix> {
        match self {
            WeightMat::Dense(d) => Some(d),
            WeightMat::Quant(_) => None,
        }
    }

    fn gemv_dense(&self, x: &[f32], out: &mut [f32], threads: usize) -> usize {
        match self {
            WeightMat::Dense(d) => d.gemv_dense(x, out, threads),
            WeightMat::Quant(q) => q.gemv_dense(x, out, threads),
        }
    }

    fn gemv_masked(
        &self,
        x: &[f32],
        ga: Option<&[f32]>,
        tau: f32,
        out: &mut [f32],
        kept_idx: &mut Vec<u32>,
        threads: usize,
    ) -> usize {
        match self {
            WeightMat::Dense(d) => d.gemv_masked(x, ga, tau, out, kept_idx, threads),
            WeightMat::Quant(q) => q.gemv_masked(x, ga, tau, out, kept_idx, threads),
        }
    }

    fn gemv_masked_collect(
        &self,
        x: &[f32],
        ga: &[f32],
        tau: f32,
        out: &mut [f32],
        kept_buf: &mut Vec<usize>,
    ) -> usize {
        match self {
            WeightMat::Dense(d) => d.gemv_masked_collect(x, ga, tau, out, kept_buf),
            WeightMat::Quant(q) => q.gemv_masked_collect(x, ga, tau, out, kept_buf),
        }
    }

    fn gemv_dense_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        threads: usize,
    ) -> usize {
        match self {
            WeightMat::Dense(d) => d.gemv_dense_batch(xs, in_stride, outs, out_stride, n_pos, threads),
            WeightMat::Quant(q) => q.gemv_dense_batch(xs, in_stride, outs, out_stride, n_pos, threads),
        }
    }

    fn gemv_masked_batch(
        &self,
        xs: &[f32],
        in_stride: usize,
        ga: Option<&[f32]>,
        tau: f32,
        outs: &mut [f32],
        out_stride: usize,
        n_pos: usize,
        kept_out: &mut [usize],
        threads: usize,
    ) -> usize {
        match self {
            WeightMat::Dense(d) => d.gemv_masked_batch(
                xs, in_stride, ga, tau, outs, out_stride, n_pos, kept_out, threads,
            ),
            WeightMat::Quant(q) => q.gemv_masked_batch(
                xs, in_stride, ga, tau, outs, out_stride, n_pos, kept_out, threads,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_mat(m: usize, n: usize, seed: u64) -> WeightMat {
        let mut rng = Pcg64::new(seed);
        WeightMat::dense(&Tensor::randn(&[m, n], 1.0, &mut rng))
    }

    #[test]
    fn repr_roundtrip_through_trait() {
        let w = random_mat(12, 9, 4);
        assert_eq!(w.out_dim(), 12);
        assert_eq!(w.in_dim(), 9);
        assert_eq!(w.repr_name(), "f32");
        assert!(w.as_dense().is_some());
        let q = w.quantized(QuantMode::Int8, 4);
        assert_eq!(q.repr_name(), "int8");
        assert!(q.as_dense().is_none());
        assert_eq!((q.out_dim(), q.in_dim()), (12, 9));
        assert!(q.resident_bytes() < w.resident_bytes());
        assert_eq!(q.dense_equiv_bytes(), w.resident_bytes());
        // Quantizing twice must not re-round the codes.
        let q2 = q.quantized(QuantMode::Int4, 4);
        assert_eq!(q2.repr_name(), "int8");
    }

    #[test]
    fn dense_and_quant_projections_agree_within_quant_error() {
        let w = random_mat(16, 24, 9);
        let q = w.quantized(QuantMode::Int8, 8);
        let mut rng = Pcg64::new(17);
        let x: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
        let mut a = vec![0.0f32; 16];
        let mut b = vec![0.0f32; 16];
        assert_eq!(w.gemv_dense(&x, &mut a, 1), 24);
        assert_eq!(q.gemv_dense(&x, &mut b, 1), 24);
        for i in 0..16 {
            assert!((a[i] - b[i]).abs() < 0.1, "row {i}: {} vs {}", a[i], b[i]);
        }
    }

    #[test]
    fn batch_dispatch_matches_per_position() {
        let w = random_mat(16, 24, 21);
        let q = w.quantized(QuantMode::Int8, 8);
        let mut rng = Pcg64::new(5);
        let n_pos = 3;
        let xs: Vec<f32> = (0..n_pos * 24).map(|_| rng.normal() as f32).collect();
        for repr in [&w, &q] {
            let ga = WeightRepr::col_l2_norms(repr);
            let mut outs = vec![0.0f32; n_pos * 16];
            let mut kept = vec![0usize; n_pos];
            repr.gemv_masked_batch(&xs, 24, Some(&ga), 0.4, &mut outs, 16, n_pos, &mut kept, 1);
            for p in 0..n_pos {
                let mut one = vec![0.0f32; 16];
                let mut idx = Vec::new();
                let k = repr.gemv_masked(&xs[p * 24..(p + 1) * 24], Some(&ga), 0.4, &mut one, &mut idx, 1);
                assert_eq!(k, kept[p], "{} pos {p} kept", repr.repr_name());
                for r in 0..16 {
                    assert_eq!(outs[p * 16 + r].to_bits(), one[r].to_bits());
                }
            }
            let mut outs = vec![0.0f32; n_pos * 16];
            assert_eq!(repr.gemv_dense_batch(&xs, 24, &mut outs, 16, n_pos, 1), 24);
            for p in 0..n_pos {
                let mut one = vec![0.0f32; 16];
                repr.gemv_dense(&xs[p * 24..(p + 1) * 24], &mut one, 1);
                for r in 0..16 {
                    assert_eq!(outs[p * 16 + r].to_bits(), one[r].to_bits());
                }
            }
        }
    }

    #[test]
    fn quant_norms_track_deployed_weights() {
        let w = random_mat(32, 8, 3);
        let q = w.quantized(QuantMode::Int8, 8);
        let gw = WeightRepr::col_l2_norms(&w);
        let gq = WeightRepr::col_l2_norms(&q);
        for (a, b) in gw.iter().zip(&gq) {
            // int8 norms sit close to (but not exactly on) the f32 norms.
            assert!((a - b).abs() < 0.05 * a.max(1.0), "{a} vs {b}");
        }
    }
}
