//! `wisparse profile`: run a short workload with the recording
//! [`wisparse::obs::BlockObs`] sink installed and print a per-(block,
//! projection) table of calls, achieved density, tau-vs-plan drift, wall
//! time and effective weight bandwidth, against a measured STREAM-style
//! roofline ceiling.
//!
//! The JSON dump (`--json`) is what CI's profile smoke asserts against:
//! one row per (block, projection), each with nonzero traffic.

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::ModelCalib;
use wisparse::data::corpus::{detokenize, CorpusGen};
use wisparse::model::sampler::Sampling;
use wisparse::obs::roofline::stream_gb_per_s;
use wisparse::obs::{BlockObs, ObsSink};
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::util::cli::Args;
use wisparse::util::json::Json;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new(
        "profile",
        "per-block density/bandwidth profile of a decode workload",
    )
    .opt("artifacts", "artifacts", "artifacts root")
    .opt("model", "llama-micro", "model preset")
    .opt("method", "wisparse", "sparsification method (or `dense`)")
    .opt("target", "0.5", "sparsity target (plan must exist or be calibratable)")
    .opt("budget", "quick", "calibration budget if no cached plan")
    .opt("prompts", "4", "number of synthetic prompts to run")
    .opt("prompt-len", "24", "tokens per synthetic prompt")
    .opt("max-new", "16", "tokens to decode per prompt")
    .opt("json", "", "also write the profile as JSON to this path")
    .flag("synthetic", "use random weights (no artifacts needed)")
    .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let mut model =
        common::load_model(artifacts, args.get("model"), args.get_flag("synthetic"))?;
    let method = args.get("method");
    // Build the sparsifier BEFORE installing the recording sink, so
    // calibration forwards don't pollute the workload's telemetry.
    let sparsifier = if method == "dense" {
        Arc::new(wisparse::sparsity::Dense) as Arc<dyn wisparse::sparsity::Sparsifier>
    } else {
        let search_cfg =
            common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
        let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
        let calib = ModelCalib::collect(&model, &calib_set);
        let plan = common::plan_for(
            artifacts,
            &model,
            &calib,
            method,
            args.get_f64("target")?,
            &search_cfg,
            true,
        )?;
        common::sparsifier_for(&model, method, &plan)?
    };
    let obs = Arc::new(BlockObs::new(model.cfg.n_layers));
    model.set_obs_sink(Arc::clone(&obs) as Arc<dyn ObsSink>);
    let engine = Engine::new(Arc::new(model), sparsifier, EngineCfg::default());

    // The workload: a handful of synthetic prompts decoded to completion.
    let n_prompts = args.get_usize("prompts")?.max(1);
    let prompt_len = args.get_usize("prompt-len")?.max(1);
    let max_new = args.get_usize("max-new")?.max(1);
    let mut corpus = CorpusGen::new(0xBEEF);
    let t0 = std::time::Instant::now();
    for seq in corpus.calib_sequences(n_prompts, prompt_len) {
        let prompt = detokenize(&seq);
        let _ = engine.run_to_completion(&prompt, max_new, Sampling::Greedy);
    }
    let workload_s = t0.elapsed().as_secs_f64();

    println!(
        "measuring STREAM roofline ({} threads)...",
        wisparse::util::threadpool::num_threads()
    );
    let roof = stream_gb_per_s();
    println!(
        "workload: {n_prompts} prompts x {prompt_len} tok + {max_new} new in {workload_s:.2}s; roofline {roof:.1} GB/s\n"
    );

    println!("block proj        calls  density  plan   drift    time_ms    GB/s   %roof");
    let mut rows = Vec::new();
    for st in obs.snapshot() {
        let planned = engine.sparsifier.planned_density(st.id);
        let drift = planned.map(|p| st.density() - p);
        println!(
            "{:>5} {:<10} {:>6} {:>8.3} {:>5} {:>7} {:>10.3} {:>7.2} {:>7.1}",
            st.id.block,
            st.id.kind.name(),
            st.calls,
            st.density(),
            planned.map_or("   -".to_string(), |p| format!("{p:.2}")),
            drift.map_or("      -".to_string(), |d| format!("{d:+.3}")),
            st.ns as f64 / 1e6,
            st.gb_per_s(),
            if roof > 0.0 {
                100.0 * st.gb_per_s() / roof
            } else {
                0.0
            },
        );
        let mut fields = vec![
            ("block", Json::Num(st.id.block as f64)),
            ("proj", Json::Str(st.id.kind.name().to_string())),
            ("calls", Json::Num(st.calls as f64)),
            ("density", Json::Num(st.density())),
            ("ns", Json::Num(st.ns as f64)),
            ("bytes", Json::Num(st.bytes as f64)),
            ("gb_s", Json::Num(st.gb_per_s())),
        ];
        if let Some(p) = planned {
            fields.push(("planned_density", Json::Num(p)));
            fields.push(("drift", Json::Num(st.density() - p)));
        }
        rows.push(Json::obj(fields));
    }
    let report = Json::obj(vec![
        ("cmd", Json::Str("profile".to_string())),
        ("model", Json::Str(engine.model.cfg.name.clone())),
        ("method", Json::Str(method.to_string())),
        ("n_prompts", Json::Num(n_prompts as f64)),
        ("max_new", Json::Num(max_new as f64)),
        ("workload_s", Json::Num(workload_s)),
        ("roofline_gb_s", Json::Num(roof)),
        ("rows", Json::Arr(rows)),
    ]);
    let out = args.get("json");
    if !out.is_empty() {
        if let Some(dir) = Path::new(out).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(out, report.to_string_pretty())?;
        println!("\nwrote {out}");
    }
    Ok(())
}
