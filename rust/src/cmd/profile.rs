//! `wisparse profile`: run a short workload with the recording
//! [`wisparse::obs::BlockObs`] sink installed and print a per-(block,
//! projection) table of calls, achieved density, tau-vs-plan drift, wall
//! time and effective weight bandwidth, against a measured STREAM-style
//! roofline ceiling.
//!
//! The JSON dump (`--json`) is what CI's profile smoke asserts against:
//! one row per (block, projection), each with nonzero traffic.
//!
//! With `--quality-sample-rate` > 0 (default 1.0: every decode step) the
//! table also carries per-projection shadow-dense columns — replay samples
//! and relative L2 reconstruction error of the sparse output against a
//! dense re-execution — plus a workload-level shadow-KL summary.
//! `--chrome-trace out.json` exports the workload's span timeline as Chrome
//! trace-event JSON for ui.perfetto.dev.

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::ModelCalib;
use wisparse::data::corpus::{detokenize, CorpusGen};
use wisparse::model::sampler::Sampling;
use wisparse::obs::roofline::stream_gb_per_s;
use wisparse::obs::{BlockObs, ObsSink};
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::util::cli::Args;
use wisparse::util::json::Json;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new(
        "profile",
        "per-block density/bandwidth profile of a decode workload",
    )
    .opt("artifacts", "artifacts", "artifacts root")
    .opt("model", "llama-micro", "model preset")
    .opt("method", "wisparse", "sparsification method (or `dense`)")
    .opt("target", "0.5", "sparsity target (plan must exist or be calibratable)")
    .opt("budget", "quick", "calibration budget if no cached plan")
    .opt("prompts", "4", "number of synthetic prompts to run")
    .opt("prompt-len", "24", "tokens per synthetic prompt")
    .opt("max-new", "16", "tokens to decode per prompt")
    .opt("json", "", "also write the profile as JSON to this path")
    .opt(
        "quality-sample-rate",
        "1.0",
        "shadow-dense sampling rate for the recon-error/KL columns (0 = off)",
    )
    .opt(
        "chrome-trace",
        "",
        "write the workload's spans as Chrome trace-event JSON to this path",
    )
    .flag("synthetic", "use random weights (no artifacts needed)")
    .parse(argv)?;
    let quality_rate = args.get_f64("quality-sample-rate")?;
    if !(0.0..=1.0).contains(&quality_rate) {
        anyhow::bail!("--quality-sample-rate must be in [0, 1], got {quality_rate}");
    }
    let artifacts = Path::new(args.get("artifacts"));
    let mut model =
        common::load_model(artifacts, args.get("model"), args.get_flag("synthetic"))?;
    let method = args.get("method");
    // Build the sparsifier BEFORE installing the recording sink, so
    // calibration forwards don't pollute the workload's telemetry.
    let sparsifier = if method == "dense" {
        Arc::new(wisparse::sparsity::Dense) as Arc<dyn wisparse::sparsity::Sparsifier>
    } else {
        let search_cfg =
            common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
        let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
        let calib = ModelCalib::collect(&model, &calib_set);
        let plan = common::plan_for(
            artifacts,
            &model,
            &calib,
            method,
            args.get_f64("target")?,
            &search_cfg,
            true,
        )?;
        common::sparsifier_for(&model, method, &plan)?
    };
    let obs = Arc::new(BlockObs::new(model.cfg.n_layers));
    model.set_obs_sink(Arc::clone(&obs) as Arc<dyn ObsSink>);
    let engine_cfg = EngineCfg {
        quality_sample_rate: quality_rate,
        ..EngineCfg::default()
    };
    let engine = Engine::new(Arc::new(model), sparsifier, engine_cfg);

    // The workload: a handful of synthetic prompts decoded to completion.
    // Each prompt is admitted under its own trace id (1-based) so the
    // Chrome export lays requests out on separate tracks.
    let n_prompts = args.get_usize("prompts")?.max(1);
    let prompt_len = args.get_usize("prompt-len")?.max(1);
    let max_new = args.get_usize("max-new")?.max(1);
    let mut corpus = CorpusGen::new(0xBEEF);
    let t0 = std::time::Instant::now();
    for (i, seq) in corpus
        .calib_sequences(n_prompts, prompt_len)
        .into_iter()
        .enumerate()
    {
        let prompt = detokenize(&seq);
        let mut s = engine.admit(i as u64 + 1, &prompt, max_new, Sampling::Greedy);
        engine.prefill(&mut s);
        while !s.finished() {
            engine.decode_one(&mut s);
        }
    }
    let workload_s = t0.elapsed().as_secs_f64();

    println!(
        "measuring STREAM roofline ({} threads)...",
        wisparse::util::threadpool::num_threads()
    );
    let roof = stream_gb_per_s();
    println!(
        "workload: {n_prompts} prompts x {prompt_len} tok + {max_new} new in {workload_s:.2}s; roofline {roof:.1} GB/s\n"
    );

    println!(
        "block proj        calls  density  plan   drift    time_ms    GB/s   %roof  shadow  rel_err"
    );
    let mut rows = Vec::new();
    for st in obs.snapshot() {
        let planned = engine.sparsifier.planned_density(st.id);
        let drift = planned.map(|p| st.density() - p);
        println!(
            "{:>5} {:<10} {:>6} {:>8.3} {:>5} {:>7} {:>10.3} {:>7.2} {:>7.1} {:>7} {:>8}",
            st.id.block,
            st.id.kind.name(),
            st.calls,
            st.density(),
            planned.map_or("   -".to_string(), |p| format!("{p:.2}")),
            drift.map_or("      -".to_string(), |d| format!("{d:+.3}")),
            st.ns as f64 / 1e6,
            st.gb_per_s(),
            if roof > 0.0 {
                100.0 * st.gb_per_s() / roof
            } else {
                0.0
            },
            st.shadow_samples,
            if st.shadow_samples > 0 {
                format!("{:.2e}", st.shadow_rel_err())
            } else {
                "-".to_string()
            },
        );
        let mut fields = vec![
            ("block", Json::Num(st.id.block as f64)),
            ("proj", Json::Str(st.id.kind.name().to_string())),
            ("calls", Json::Num(st.calls as f64)),
            ("density", Json::Num(st.density())),
            ("ns", Json::Num(st.ns as f64)),
            ("bytes", Json::Num(st.bytes as f64)),
            ("gb_s", Json::Num(st.gb_per_s())),
            ("shadow_samples", Json::Num(st.shadow_samples as f64)),
            ("shadow_rel_err", Json::Num(st.shadow_rel_err())),
        ];
        if let Some(p) = planned {
            fields.push(("planned_density", Json::Num(p)));
            fields.push(("drift", Json::Num(st.density() - p)));
        }
        rows.push(Json::obj(fields));
    }
    let mut report_fields = vec![
        ("cmd", Json::Str("profile".to_string())),
        ("model", Json::Str(engine.model.cfg.name.clone())),
        ("method", Json::Str(method.to_string())),
        ("n_prompts", Json::Num(n_prompts as f64)),
        ("max_new", Json::Num(max_new as f64)),
        ("workload_s", Json::Num(workload_s)),
        ("roofline_gb_s", Json::Num(roof)),
        ("rows", Json::Arr(rows)),
    ];
    if let Some(q) = &engine.quality {
        println!(
            "\nshadow quality: {} samples, KL(dense||sparse) mean {:.3e} max {:.3e}, top-1 agreement {:.1}%",
            q.samples(),
            q.mean_kl(),
            q.max_kl(),
            100.0 * q.top1_agreement()
        );
        report_fields.push(("quality", q.snapshot_json()));
    }
    let report = Json::obj(report_fields);
    let out = args.get("json");
    if !out.is_empty() {
        if let Some(dir) = Path::new(out).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(out, report.to_string_pretty())?;
        println!("\nwrote {out}");
    }
    let trace_out = args.get("chrome-trace");
    if !trace_out.is_empty() {
        // One track per prompt (tid = trace id assigned at admission).
        let mut spans = Vec::new();
        for id in 1..=n_prompts as u64 {
            spans.extend(wisparse::obs::tracer().trace(id));
        }
        if let Some(dir) = Path::new(trace_out).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(
            trace_out,
            wisparse::obs::chrome_trace(&spans).to_string_pretty(),
        )?;
        println!("wrote {trace_out} ({} spans) — open in ui.perfetto.dev", spans.len());
    }
    Ok(())
}
