//! `wisparse serve`: start the HTTP serving coordinator.

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::ModelCalib;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg, SpecCfg, SpecEngine};
use wisparse::server::{Coordinator, CoordinatorCfg, ReactorCfg, Router, RouterCfg};
use wisparse::util::cli::Args;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("serve", "start the serving coordinator")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset")
        .opt("method", "wisparse", "sparsification method (or `dense`)")
        .opt("target", "0.5", "sparsity target (plan must exist or be calibratable)")
        .opt("addr", "127.0.0.1:8077", "listen address")
        .opt(
            "frontend",
            "reactor",
            "HTTP front end: epoll reactor or legacy thread-per-connection (reactor|blocking)",
        )
        .opt(
            "replicas",
            "1",
            "engine replicas behind the prefix-affinity router (each gets its own scheduler and an equal share of the KV pool)",
        )
        .opt(
            "route-prefix-k",
            "64",
            "prompt-prefix bytes hashed for replica affinity (keep a multiple of --kv-block-size)",
        )
        .opt("max-conns", "1024", "reactor connection cap; accept throttles above it")
        .opt("max-batch", "8", "max concurrent sequences (per replica)")
        .opt("max-queue", "256", "per-replica wait-queue cap; excess load sheds 503 + Retry-After")
        .opt("deadline-ms", "0", "default per-request deadline in ms (0 = none)")
        .opt(
            "drain-timeout",
            "30",
            "seconds active sequences may keep running after a drain starts",
        )
        .opt("budget", "quick", "calibration budget if no cached plan")
        .opt("kv-pool-blocks", "256", "paged-KV pool size in blocks")
        .opt("kv-block-size", "16", "positions per KV block")
        .opt(
            "prefill-chunk",
            "64",
            "prompt tokens per prefill chunk (per-iteration token budget)",
        )
        .opt("prefix-cache", "on", "radix-tree prompt prefix sharing (on|off)")
        .opt(
            "fused-batch",
            "on",
            "batch-fused decode: stream weights once per step across the batch (on|off)",
        )
        .opt("draft-sparsity", "0.75", "draft sparsity target for --speculative")
        .opt("spec-k", "4", "initial speculative draft-chain length")
        .opt(
            "block-telemetry",
            "on",
            "per-block density/bandwidth rows in /metrics?format=prometheus (on|off)",
        )
        .opt(
            "quality-sample-rate",
            "0.0",
            "shadow-dense sampling rate: replay ~this fraction of decode steps densely and record KL/top-1 drift (0 = off)",
        )
        .opt(
            "shadow-kl-ceiling",
            "0.05",
            "shadow-KL value above which a sample counts against the shadow_kl SLO",
        )
        .opt("quant", "off", "weight quantization (off|int8|int4)")
        .opt("quant-group", "64", "rows per scale group when quantizing in-process")
        .flag("speculative", "self-speculative decoding (high-sparsity draft, production verify)")
        .flag("synthetic", "use random weights (no artifacts needed)")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let base = args.get("model");
    let quant = args.get("quant");
    let mut model = if quant == "off" {
        common::load_model(artifacts, base, args.get_flag("synthetic"))?
    } else {
        let mode = wisparse::quant::QuantMode::parse(quant)
            .ok_or_else(|| anyhow::anyhow!("--quant must be off|int8|int4, got `{quant}`"))?;
        let qname = mode.checkpoint_name(base);
        let qdir = artifacts.join("models").join(&qname);
        // --synthetic means synthetic: never silently substitute a saved
        // real checkpoint for the requested random weights.
        if !args.get_flag("synthetic") && qdir.join("weights.bin").exists() {
            // A `wisparse quantize` checkpoint: codes, scales and manifest
            // load directly.
            wisparse::info!("loading quantized checkpoint {}", qdir.display());
            wisparse::model::transformer::Model::load_dir(&qdir)?
        } else {
            let mut m = common::load_model(artifacts, base, args.get_flag("synthetic"))?;
            m.quantize(mode, args.get_usize("quant-group")?);
            if m.weight_repr_name() != mode.name() {
                // quantize() never re-rounds existing codes, so a checkpoint
                // already quantized in another mode cannot honor --quant.
                anyhow::bail!(
                    "model {base} already carries {} weights; cannot serve it as {}",
                    m.weight_repr_name(),
                    mode.name()
                );
            }
            m.cfg.name = qname;
            m
        }
    };
    // Installed before Arc'ing (the sink setter needs `&mut Model`); the
    // calibration forwards below run through it too, so the stats are wiped
    // again right before serving starts.
    let block_obs = if args.get("block-telemetry") != "off" {
        let o = Arc::new(wisparse::obs::BlockObs::new(model.cfg.n_layers));
        model.set_obs_sink(Arc::clone(&o) as Arc<dyn wisparse::obs::ObsSink>);
        Some(o)
    } else {
        None
    };
    let model = Arc::new(model);
    let method = args.get("method");
    let speculative = args.get_flag("speculative");
    // Calibration activations feed both the production plan (non-dense
    // methods) and the speculative draft plan.
    let search_cfg =
        common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
    let calib = if method != "dense" || speculative {
        let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
        Some(ModelCalib::collect(&model, &calib_set))
    } else {
        None
    };
    let sparsifier = if method == "dense" {
        Arc::new(wisparse::sparsity::Dense) as Arc<dyn wisparse::sparsity::Sparsifier>
    } else {
        let plan = common::plan_for(
            artifacts,
            &model,
            calib.as_ref().expect("calib collected for sparse methods"),
            method,
            args.get_f64("target")?,
            &search_cfg,
            true,
        )?;
        common::sparsifier_for(&model, method, &plan)?
    };
    let kv_cfg = wisparse::kv::KvCfg {
        pool_blocks: args.get_usize("kv-pool-blocks")?,
        block_size: args.get_usize("kv-block-size")?,
        prefix_cache: args.get("prefix-cache") != "off",
    };
    let quality_sample_rate = args.get_f64("quality-sample-rate")?;
    if !(0.0..=1.0).contains(&quality_sample_rate) {
        anyhow::bail!("--quality-sample-rate must be in [0, 1], got {quality_sample_rate}");
    }
    let shadow_kl_ceiling = args.get_f64("shadow-kl-ceiling")?;
    if shadow_kl_ceiling <= 0.0 {
        anyhow::bail!("--shadow-kl-ceiling must be > 0, got {shadow_kl_ceiling}");
    }
    let engine_cfg = EngineCfg {
        prefill_chunk: args.get_usize("prefill-chunk")?.max(1),
        fused_batch: args.get("fused-batch") != "off",
        quality_sample_rate,
        shadow_kl_ceiling,
        ..EngineCfg::default()
    };
    let replicas_n = args.get_usize("replicas")?.max(1);
    let frontend = args.get("frontend");
    if frontend != "reactor" && frontend != "blocking" {
        anyhow::bail!("--frontend must be reactor|blocking, got `{frontend}`");
    }
    if kv_cfg.pool_blocks / replicas_n == 0 {
        anyhow::bail!(
            "--kv-pool-blocks {} cannot be split across {replicas_n} replicas",
            kv_cfg.pool_blocks
        );
    }
    // Each replica carves an equal share out of the configured pool budget
    // so N replicas never hold more KV memory than one replica would.
    let replica_kv = wisparse::kv::KvCfg {
        pool_blocks: kv_cfg.pool_blocks / replicas_n,
        ..kv_cfg
    };
    let coord_cfg = CoordinatorCfg {
        batcher: BatcherCfg {
            max_batch: args.get_usize("max-batch")?,
            max_queue: args.get_usize("max-queue")?,
        },
        default_deadline: match args.get_usize("deadline-ms")? {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms as u64)),
        },
        drain_timeout: std::time::Duration::from_secs(args.get_usize("drain-timeout")? as u64),
        // The shadow_kl objective's threshold tracks the engine's ceiling so
        // the burn-rate alert and the per-sample breach counter agree.
        slos: wisparse::obs::SloSpec::default_set(shadow_kl_ceiling),
        replica_id: 0,
    };
    let prefill_chunk = engine_cfg.prefill_chunk;
    // The draft is the same weights at higher sparsity: a calibrated plan
    // for the production method (or TEAL magnitude masks when the
    // production path is dense) at `--draft-sparsity`. Shared by every
    // replica's SpecEngine.
    let spec_setup = if speculative {
        let draft_method = if method == "dense" { "teal" } else { method };
        let draft_target = args.get_f64("draft-sparsity")?;
        let draft_plan = common::plan_for(
            artifacts,
            &model,
            calib.as_ref().expect("calib collected for --speculative"),
            draft_method,
            draft_target,
            &search_cfg,
            true,
        )?;
        let draft = common::sparsifier_for(&model, draft_method, &draft_plan)?;
        let spec_cfg = SpecCfg {
            k: args.get_usize("spec-k")?,
            ..SpecCfg::default()
        };
        println!(
            "speculative decode: draft {draft_method} @ {:.0}% sparsity, k={} (adaptive)",
            draft_target * 100.0,
            spec_cfg.k
        );
        Some((draft, spec_cfg))
    } else {
        None
    };
    let mut replicas = Vec::with_capacity(replicas_n);
    let mut sched_handles = Vec::with_capacity(replicas_n);
    for r in 0..replicas_n {
        let engine = Arc::new(Engine::paged(
            Arc::clone(&model),
            Arc::clone(&sparsifier),
            engine_cfg.clone(),
            &replica_kv,
        ));
        let cfg_r = CoordinatorCfg {
            replica_id: r,
            ..coord_cfg.clone()
        };
        let coord = if let Some((draft, spec_cfg)) = &spec_setup {
            let spec = Arc::new(SpecEngine::new(engine, Arc::clone(draft), spec_cfg.clone()));
            Coordinator::new_spec(spec, cfg_r)
        } else {
            Coordinator::new(engine, cfg_r)
        };
        let sched = Arc::clone(&coord);
        sched_handles.push(std::thread::spawn(move || sched.run_scheduler()));
        replicas.push(coord);
    }
    let router = Router::new(
        replicas,
        RouterCfg {
            prefix_k: args.get_usize("route-prefix-k")?.max(1),
            ..RouterCfg::default()
        },
    );
    if let Some(o) = &block_obs {
        // Calibration forwards above went through the sink; serve clean.
        o.reset();
    }
    // SIGTERM/SIGINT start a graceful drain: admission stops, active
    // sequences finish (bounded by --drain-timeout), then every scheduler
    // and the front-end loop below both exit on their own.
    wisparse::server::install_sigterm_drain_router(Arc::clone(&router));
    println!(
        "serving {} ({}, weights {}, {:.1} MB resident) — POST /generate, GET /metrics, GET /healthz, GET /readyz, POST /admin/drain",
        model.cfg.name,
        method,
        model.weight_repr_name(),
        model.weight_bytes_resident() as f64 / 1e6
    );
    println!(
        "replicas: {replicas_n} ({} front end, prefix-affinity k={}); paged KV per replica: {} blocks x {} positions, prefix cache {}; chunked prefill {} tok/iter; fused batch decode {}",
        frontend,
        router.cfg().prefix_k,
        replica_kv.pool_blocks,
        replica_kv.block_size,
        if replica_kv.prefix_cache { "on" } else { "off" },
        prefill_chunk,
        if engine_cfg.fused_batch { "on" } else { "off" }
    );
    if quality_sample_rate > 0.0 {
        println!(
            "shadow-dense quality sampling: ~1 in {} decode steps, KL ceiling {shadow_kl_ceiling}",
            (1.0 / quality_sample_rate).round().max(1.0) as u64
        );
    }
    match frontend {
        "reactor" => wisparse::server::reactor::serve(
            Arc::clone(&router),
            args.get("addr"),
            ReactorCfg {
                max_conns: args.get_usize("max-conns")?.max(1),
                ..ReactorCfg::default()
            },
            |addr| {
                println!("listening on http://{addr}");
            },
        )?,
        _ => wisparse::server::http::serve_blocking(Arc::clone(&router), args.get("addr"), |addr| {
            println!("listening on http://{addr}");
        })?,
    }
    // The front-end loop only exits once every replica is shut down (drain
    // complete or explicit); join the schedulers so every response has been
    // delivered before the process exits.
    for h in sched_handles {
        h.join().ok();
    }
    println!("drained: schedulers joined, all streams flushed");
    Ok(())
}
