//! `wisparse serve`: start the HTTP serving coordinator.

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::ModelCalib;
use wisparse::server::batcher::BatcherCfg;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::server::{Coordinator, CoordinatorCfg};
use wisparse::util::cli::Args;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("serve", "start the serving coordinator")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset")
        .opt("method", "wisparse", "sparsification method (or `dense`)")
        .opt("target", "0.5", "sparsity target (plan must exist or be calibratable)")
        .opt("addr", "127.0.0.1:8077", "listen address")
        .opt("max-batch", "8", "max concurrent sequences")
        .opt("budget", "quick", "calibration budget if no cached plan")
        .opt("kv-pool-blocks", "256", "paged-KV pool size in blocks")
        .opt("kv-block-size", "16", "positions per KV block")
        .opt("prefix-cache", "on", "radix-tree prompt prefix sharing (on|off)")
        .flag("synthetic", "use random weights (no artifacts needed)")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let model = Arc::new(common::load_model(
        artifacts,
        args.get("model"),
        args.get_flag("synthetic"),
    )?);
    let method = args.get("method");
    let sparsifier = if method == "dense" {
        Arc::new(wisparse::sparsity::Dense) as Arc<dyn wisparse::sparsity::Sparsifier>
    } else {
        let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
        let calib = ModelCalib::collect(&model, &calib_set);
        let cfg = common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
        let plan = common::plan_for(
            artifacts,
            &model,
            &calib,
            method,
            args.get_f64("target")?,
            &cfg,
            true,
        )?;
        common::sparsifier_for(&model, method, &plan)?
    };
    let kv_cfg = wisparse::kv::KvCfg {
        pool_blocks: args.get_usize("kv-pool-blocks")?,
        block_size: args.get_usize("kv-block-size")?,
        prefix_cache: args.get("prefix-cache") != "off",
    };
    let engine = Arc::new(Engine::paged(
        model,
        sparsifier,
        EngineCfg::default(),
        &kv_cfg,
    ));
    let coord = Coordinator::new(
        engine,
        CoordinatorCfg {
            batcher: BatcherCfg {
                max_batch: args.get_usize("max-batch")?,
                max_queue: 256,
            },
        },
    );
    let sched = Arc::clone(&coord);
    std::thread::spawn(move || sched.run_scheduler());
    println!(
        "serving {} ({}) — POST /generate, GET /metrics, GET /health",
        args.get("model"),
        method
    );
    println!(
        "paged KV: {} blocks x {} positions, prefix cache {}",
        kv_cfg.pool_blocks,
        kv_cfg.block_size,
        if kv_cfg.prefix_cache { "on" } else { "off" }
    );
    wisparse::server::http::serve(coord, args.get("addr"), |addr| {
        println!("listening on http://{addr}");
    })
}
