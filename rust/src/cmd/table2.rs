//! `wisparse table2`: the component ablation (Table 2) — activation-only ->
//! +weight importance -> +coarse search -> +fine search, llama-micro @ 50%.

use std::path::Path;
use wisparse::calib::ModelCalib;
use wisparse::data::tasks::full_suite;
use wisparse::eval::harness::{evaluate_suite, EvalReport};
use wisparse::report::csv::{f, write_csv};
use wisparse::sparsity::allocator::{calibrate_wisparse, PipelineStages};
use wisparse::sparsity::methods::ScoredSparsifier;
use wisparse::sparsity::Dense;
use wisparse::util::cli::Args;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("table2", "component ablation (Table 2)")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset")
        .opt("target", "0.5", "sparsity")
        .opt("items", "40", "items per task")
        .opt("budget", "default", "search budget")
        .opt("threads", "0", "worker threads (0 = all cores)")
        .flag("synthetic", "use random weights")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let threads = match args.get_usize("threads")? {
        0 => wisparse::util::threadpool::num_threads(),
        n => n,
    };
    let cfg = common::search_cfg(args.get("budget"), threads)?;
    let target = args.get_f64("target")?;
    let model = common::load_model(artifacts, args.get("model"), args.get_flag("synthetic"))?;
    let suite = full_suite(args.get_usize("items")?, 0xAB1E);
    let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
    let calib = ModelCalib::collect(&model, &calib_set);

    println!("{}", EvalReport::header());
    let dense_report = evaluate_suite(&model, &suite, &Dense, "baseline", 0.0, threads);
    println!("{}", dense_report.row());

    let mut rows = Vec::new();
    push(&mut rows, &dense_report);
    let mut prev_avg = f64::NAN;
    for (label, stages) in PipelineStages::ablation_ladder() {
        let plan = calibrate_wisparse(&model, &calib, target, &cfg, stages);
        let sp = ScoredSparsifier::from_plan("ablation", &model, &plan);
        let report = evaluate_suite(&model, &suite, &sp, label, target, threads);
        let delta = if prev_avg.is_nan() {
            String::new()
        } else {
            format!("  (Δ {:+.2})", report.average - prev_avg)
        };
        println!("{}{delta}", report.row());
        prev_avg = report.average;
        push(&mut rows, &report);
    }
    let out = common::results_dir().join("table2.csv");
    write_csv(
        &out,
        &[
            "method", "sparsity", "SIQA", "GSM8K", "WiC", "HumanEval", "MMLU", "CSQA",
            "Average",
        ],
        &rows,
    )?;
    println!("\ntable2 -> {}", out.display());
    Ok(())
}

fn push(rows: &mut Vec<Vec<String>>, r: &EvalReport) {
    let mut row = vec![r.method.clone(), f(r.sparsity)];
    for (_, _, acc) in &r.per_task {
        row.push(f(*acc));
    }
    row.push(f(r.average));
    rows.push(row);
}
