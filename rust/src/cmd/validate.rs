//! `wisparse validate`: native-engine vs PJRT-HLO cross-validation, dense
//! and (if a plan exists) wisparse variants. Requires the `pjrt` cargo
//! feature (vendored `xla` crate).

#[cfg(feature = "pjrt")]
pub fn run(argv: &[String]) -> anyhow::Result<()> {
    use std::path::Path;
    use wisparse::runtime::validate::cross_validate;
    use wisparse::sparsity::plan::SparsityPlan;
    use wisparse::util::cli::Args;

    let args = Args::new("validate", "cross-validate native vs PJRT")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset")
        .opt("tokens", "48", "sequence length to compare")
        .opt("tol", "2e-3", "max |logit diff| tolerated")
        .opt("plan", "", "sparsity plan JSON for the wisparse variant")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let model_dir = artifacts.join("models").join(args.get("model"));
    if !model_dir.join("dense.hlo.txt").exists() {
        anyhow::bail!(
            "no HLO artifacts in {} — run `make artifacts` first",
            model_dir.display()
        );
    }
    let tol = args.get_f64("tol")? as f32;
    let n = args.get_usize("tokens")?;
    // Deterministic mixed-family token stream.
    let mut gen = wisparse::data::corpus::CorpusGen::new(0xA117);
    let tokens: Vec<usize> = gen.calib_sequences(1, n).remove(0);

    let report = cross_validate(&model_dir, "dense", &tokens, None, tol)?;
    println!("{}", report.line());
    let mut all_pass = report.pass;

    // Sparse variant if a plan is available.
    let plan_path = if args.get("plan").is_empty() {
        SparsityPlan::default_path(artifacts, args.get("model"), "wisparse", 0.5)
    } else {
        args.get("plan").into()
    };
    if plan_path.exists() && model_dir.join("wisparse.hlo.txt").exists() {
        let plan = SparsityPlan::load(&plan_path)?;
        let report = cross_validate(&model_dir, "wisparse", &tokens, Some(&plan), tol)?;
        println!("{}", report.line());
        all_pass &= report.pass;
    } else {
        println!("(no wisparse plan at {} — dense only)", plan_path.display());
    }
    if !all_pass {
        anyhow::bail!("cross-validation FAILED");
    }
    println!("cross-validation OK: all layers compute the same function");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
pub fn run(_argv: &[String]) -> anyhow::Result<()> {
    anyhow::bail!(
        "this binary was built without the `pjrt` feature. Cross-validation \
         against the compiled HLO needs the vendored `xla` crate: add it as \
         a path dependency in Cargo.toml (e.g. `xla = {{ path = \"...\" }}` \
         pointing at the build image's xla checkout, see /opt/xla-example), \
         then rebuild with `cargo build --features pjrt`"
    )
}
