//! `wisparse calibrate`: run a calibration pipeline for one model/method/
//! target and persist the plan (Alg. 1 end-to-end for wisparse).

use std::path::Path;
use wisparse::calib::ModelCalib;
use wisparse::util::cli::Args;
use wisparse::util::timer::Stopwatch;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("calibrate", "calibrate a sparsity plan")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset")
        .opt("method", "wisparse", "dense|teal|rsparse|wina|activation-only|wisparse")
        .opt("target", "0.5", "global target sparsity")
        .opt("budget", "default", "search budget: quick|default|paper")
        .opt("calib-seqs", "8", "calibration sequences")
        .opt("calib-len", "96", "calibration sequence length")
        .opt("threads", "0", "worker threads (0 = all cores)")
        .flag("no-cache", "recalibrate even if a cached plan exists")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let threads = match args.get_usize("threads")? {
        0 => wisparse::util::threadpool::num_threads(),
        n => n,
    };
    let model = common::load_model(artifacts, args.get("model"), false)?;
    let calib_set = common::load_calib(
        artifacts,
        args.get("model"),
        args.get_usize("calib-seqs")?,
        args.get_usize("calib-len")?,
    );
    let sw = Stopwatch::start();
    println!(
        "collecting calibration activations ({} seqs x {} tokens)...",
        calib_set.seqs.len(),
        calib_set.seqs[0].len()
    );
    let calib = ModelCalib::collect(&model, &calib_set);
    println!("capture done in {:.1}s", sw.elapsed_secs());

    let cfg = common::search_cfg(args.get("budget"), threads)?;
    let target = args.get_f64("target")?;
    let sw = Stopwatch::start();
    let plan = common::plan_for(
        artifacts,
        &model,
        &calib,
        args.get("method"),
        target,
        &cfg,
        !args.get_flag("no-cache"),
    )?;
    println!(
        "calibrated `{}` @ {:.0}% in {:.1}s — effective sparsity {:.3}",
        plan.method,
        target * 100.0,
        sw.elapsed_secs(),
        plan.effective_sparsity(&model.cfg)
    );
    let path = wisparse::sparsity::plan::SparsityPlan::default_path(
        artifacts,
        &model.cfg.name,
        args.get("method"),
        target,
    );
    plan.save(&path)?;
    println!("plan -> {}", path.display());
    Ok(())
}
