//! Shared helpers for subcommands: locating artifacts, loading models and
//! calibration sets, building sparsifiers from plans (with on-demand
//! calibration + plan caching).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use wisparse::calib::{CalibSet, ModelCalib};
use wisparse::model::transformer::Model;
use wisparse::model::ModelConfig;
use wisparse::sparsity::allocator::{
    calibrate_activation_only, calibrate_rsparse, calibrate_teal, calibrate_wina,
    calibrate_wisparse, PipelineStages, WiSparseCfg,
};
use wisparse::sparsity::alpha_search::AlphaSearchCfg;
use wisparse::sparsity::evo::EvoCfg;
use wisparse::sparsity::greedy::GreedyCfg;
use wisparse::sparsity::methods::{RSparse, ScoredSparsifier};
use wisparse::sparsity::plan::SparsityPlan;
use wisparse::sparsity::{Dense, Sparsifier};


/// Load a trained model, or synthesize one (tests / pre-training runs) when
/// `--synthetic` was passed or no artifacts exist.
pub fn load_model(artifacts: &Path, name: &str, synthetic: bool) -> anyhow::Result<Model> {
    let dir = artifacts.join("models").join(name);
    if !synthetic && dir.join("weights.bin").exists() {
        wisparse::info!("loading trained model from {}", dir.display());
        return Model::load_dir(&dir);
    }
    wisparse::warn_!(
        "no trained weights at {} — using a synthetic (random) model; run `make artifacts` for real results",
        dir.display()
    );
    Ok(Model::synthetic(ModelConfig::preset(name)?, 0xC0DE))
}

/// Load the calibration set written by gen-data (or synthesize).
pub fn load_calib(artifacts: &Path, name: &str, n_seqs: usize, seq_len: usize) -> CalibSet {
    let path = artifacts.join("data").join(name).join("calib.json");
    match CalibSet::load(&path) {
        Ok(c) => c.subset(n_seqs, seq_len),
        Err(_) => {
            wisparse::warn_!("no calib at {} — synthesizing", path.display());
            CalibSet::synthetic(n_seqs, seq_len, 256, 0xCA11B)
        }
    }
}

/// Held-out eval sequences for perplexity work (disjoint seed from calib).
pub fn eval_seqs(n_seqs: usize, seq_len: usize) -> Vec<Vec<usize>> {
    let mut gen = wisparse::data::corpus::CorpusGen::new(0xE7A1);
    gen.calib_sequences(n_seqs, seq_len)
}

/// Search configuration scaled by a `--budget quick|default|paper` knob.
pub fn search_cfg(budget: &str, threads: usize) -> anyhow::Result<WiSparseCfg> {
    let (gens, offspring, eps, grid, passes, step) = match budget {
        "quick" => (6, 8, 0.05, 8, 1, 0.1),
        "default" => (40, 16, 0.02, 15, 1, 0.05),
        // The paper's hyperparameters (Sec 5.1).
        "paper" => (400, 64, 0.005, 30, 1, 0.05),
        _ => anyhow::bail!("--budget must be quick|default|paper"),
    };
    Ok(WiSparseCfg {
        evo: EvoCfg {
            generations: gens,
            offspring,
            eps,
            threads,
            ..EvoCfg::default()
        },
        greedy: GreedyCfg {
            step,
            threads,
            ..GreedyCfg::default()
        },
        alpha: AlphaSearchCfg {
            n_grid: grid,
            passes,
            threads,
            ..AlphaSearchCfg::default()
        },
    })
}

/// Calibrate (or load a cached) plan for a method at a target sparsity.
pub fn plan_for(
    artifacts: &Path,
    model: &Model,
    calib: &ModelCalib,
    method: &str,
    target: f64,
    cfg: &WiSparseCfg,
    cache: bool,
) -> anyhow::Result<SparsityPlan> {
    let path = SparsityPlan::default_path(artifacts, &model.cfg.name, method, target);
    if cache && path.exists() {
        let plan = SparsityPlan::load(&path)?;
        if plan.layers.len() == model.cfg.n_layers * 7 {
            wisparse::info!("loaded cached plan {}", path.display());
            return Ok(plan);
        }
    }
    wisparse::info!(
        "calibrating {} @ {:.0}% on {}",
        method,
        target * 100.0,
        model.cfg.name
    );
    let plan = match method {
        "wisparse" => calibrate_wisparse(model, calib, target, cfg, PipelineStages::FULL),
        "teal" => calibrate_teal(model, calib, target, &cfg.greedy),
        "rsparse" => calibrate_rsparse(model, calib, target),
        "wina" => calibrate_wina(model, calib, target),
        "activation-only" => calibrate_activation_only(model, calib, target),
        other => anyhow::bail!("unknown method `{other}`"),
    };
    if cache {
        plan.save(&path)?;
    }
    Ok(plan)
}

/// Build the runtime sparsifier for a calibrated plan.
pub fn sparsifier_for(
    model: &Model,
    method: &str,
    plan: &SparsityPlan,
) -> anyhow::Result<Arc<dyn Sparsifier>> {
    Ok(match method {
        "dense" => Arc::new(Dense),
        "rsparse" => Arc::new(RSparse::from_plan(model, plan, 16)),
        "teal" => Arc::new(ScoredSparsifier::from_plan("teal", model, plan)),
        "wina" => Arc::new(ScoredSparsifier::from_plan("wina", model, plan)),
        "wisparse" => Arc::new(ScoredSparsifier::from_plan("wisparse", model, plan)),
        "activation-only" => Arc::new(ScoredSparsifier::from_plan("activation-only", model, plan)),
        other => anyhow::bail!("unknown method `{other}`"),
    })
}

pub fn results_dir() -> PathBuf {
    PathBuf::from("results")
}
