//! Subcommand implementations for the leader binary.

pub mod common;
pub mod gen_data;
pub mod calibrate;
pub mod quantize;
pub mod validate;
pub mod serve;
pub mod profile;
pub mod bench_decode;
pub mod table1;
pub mod table2;
pub mod figs;
