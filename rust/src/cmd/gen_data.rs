//! `wisparse gen-data`: write the training corpus (for the Python trainer)
//! and per-model calibration sets.

use std::path::Path;
use wisparse::calib::CalibSet;
use wisparse::data::corpus::CorpusGen;
use wisparse::model::ModelConfig;
use wisparse::util::cli::Args;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("gen-data", "generate corpus + calibration sets")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("corpus-bytes", "400000", "approximate corpus size in bytes")
        .opt("calib-seqs", "16", "calibration sequences per model")
        .opt("calib-len", "96", "calibration sequence length")
        .opt("seed", "7", "master seed")
        .parse(argv)?;
    let root = Path::new(args.get("artifacts"));
    let data_dir = root.join("data");
    std::fs::create_dir_all(&data_dir)?;
    let seed = args.get_usize("seed")? as u64;

    // Training corpus (shared by all models).
    let mut gen = CorpusGen::new(seed);
    let corpus = gen.training_corpus(args.get_usize("corpus-bytes")?);
    let corpus_path = data_dir.join("corpus.txt");
    std::fs::write(&corpus_path, &corpus)?;
    println!("wrote {} bytes -> {}", corpus.len(), corpus_path.display());

    // Per-model calibration sets (held-out slices; disjoint seed per model
    // to mirror the paper's per-model calibration).
    for (i, name) in ModelConfig::all_presets().iter().enumerate() {
        let mut cgen = CorpusGen::new(seed ^ (0x1000 + i as u64));
        let seqs = cgen.calib_sequences(
            args.get_usize("calib-seqs")?,
            args.get_usize("calib-len")?,
        );
        let set = CalibSet { seqs };
        let path = data_dir.join(name).join("calib.json");
        set.save(&path)?;
        println!(
            "wrote {} calib seqs ({} tokens) -> {}",
            set.seqs.len(),
            set.n_tokens(),
            path.display()
        );
    }
    Ok(())
}
