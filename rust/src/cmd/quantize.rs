//! `wisparse quantize`: group-quantize a checkpoint and re-run calibration
//! against the quantized weights, so the deployed plan's weight-aware
//! scores and thresholds match what the fused dequant×sparse kernels will
//! actually multiply.

use std::path::Path;
use wisparse::calib::ModelCalib;
use wisparse::quant::QuantMode;
use wisparse::sparsity::plan::SparsityPlan;
use wisparse::util::cli::Args;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("quantize", "group-quantize a checkpoint and recalibrate")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset to quantize")
        .opt("mode", "int8", "quantization mode (int8|int4)")
        .opt("group", "64", "rows per scale group within a column")
        .opt("method", "wisparse", "sparsification method to recalibrate (or `dense`)")
        .opt("target", "0.5", "sparsity target for the recalibrated plan")
        .opt("budget", "quick", "calibration budget (quick|default|paper)")
        .flag("synthetic", "use random weights (no artifacts needed)")
        .flag("no-calibrate", "write the checkpoint only, skip recalibration")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let base = args.get("model");
    let mode = QuantMode::parse(args.get("mode"))
        .ok_or_else(|| anyhow::anyhow!("--mode must be int8|int4, got `{}`", args.get("mode")))?;
    let group = args.get_usize("group")?;
    if group == 0 {
        anyhow::bail!("--group must be >= 1");
    }

    let mut model = common::load_model(artifacts, base, args.get_flag("synthetic"))?;
    let dense_bytes = model.weight_bytes_dense();
    model.quantize(mode, group);
    if model.weight_repr_name() != mode.name() {
        // quantize() never re-rounds existing codes: requantizing a lossy
        // checkpoint into another mode would silently keep the old codes.
        anyhow::bail!(
            "model {base} already carries {} weights; quantize the original \
             f32 checkpoint instead",
            model.weight_repr_name()
        );
    }
    let qname = mode.checkpoint_name(base);
    model.cfg.name = qname.clone();
    let resident = model.weight_bytes_resident();
    println!(
        "quantized {base} -> {qname}: {:.2} MB -> {:.2} MB ({:.2}x compression, {} group {group})",
        dense_bytes as f64 / 1e6,
        resident as f64 / 1e6,
        dense_bytes as f64 / resident as f64,
        mode.name(),
    );

    let dir = artifacts.join("models").join(&qname);
    std::fs::create_dir_all(&dir)?;
    model.cfg.save(&dir.join("config.json"))?;
    model.export_weights().save(&dir.join("weights.bin"))?;
    println!("checkpoint -> {}", dir.display());

    let method = args.get("method");
    if args.get_flag("no-calibrate") || method == "dense" {
        return Ok(());
    }
    // Recalibrate on the quantized model: the collector's dense passes, the
    // `g^alpha` scores, and the tau quantiles all see the dequantized values
    // the kernels will multiply at serving time.
    let calib_set = common::load_calib(artifacts, base, 8, 96);
    let calib = ModelCalib::collect(&model, &calib_set);
    let cfg = common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
    let target = args.get_f64("target")?;
    common::plan_for(artifacts, &model, &calib, method, target, &cfg, true)?;
    println!(
        "plan ({} @ {:.0}% on {} weights) -> {}",
        method,
        target * 100.0,
        mode.name(),
        SparsityPlan::default_path(artifacts, &qname, method, target).display()
    );
    Ok(())
}
