//! `wisparse bench-decode`: end-to-end decode throughput for one
//! model/method/target configuration — the single-point version of Fig 4,
//! matching the paper's protocol (200 tokens from a 5-token prompt).

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::ModelCalib;
use wisparse::model::sampler::Sampling;
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::util::cli::Args;
use wisparse::util::timer::Stopwatch;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("bench-decode", "decode throughput for one config")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset")
        .opt("method", "wisparse", "method")
        .opt("target", "0.5", "sparsity target")
        .opt("prompt-len", "5", "prompt length (paper: 5)")
        .opt("new-tokens", "200", "tokens to generate (paper: 200)")
        .opt("reps", "3", "repetitions (best reported)")
        .opt("prefill-chunk", "64", "prompt tokens per prefill chunk")
        .opt("budget", "quick", "calibration budget if no cached plan")
        .opt("quant", "off", "weight quantization (off|int8|int4)")
        .opt("quant-group", "64", "rows per scale group when quantizing")
        .flag("synthetic", "use random weights")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let quant = args.get("quant");
    let mut model = common::load_model(
        artifacts,
        args.get("model"),
        args.get_flag("synthetic"),
    )?;
    if quant != "off" {
        let mode = wisparse::quant::QuantMode::parse(quant)
            .ok_or_else(|| anyhow::anyhow!("--quant must be off|int8|int4, got `{quant}`"))?;
        model.quantize(mode, args.get_usize("quant-group")?);
        if model.weight_repr_name() != mode.name() {
            // quantize() never re-rounds existing codes: refuse to mislabel
            // a run that would actually execute another representation.
            anyhow::bail!(
                "model {} already carries {} weights; cannot bench it as {}",
                args.get("model"),
                model.weight_repr_name(),
                mode.name()
            );
        }
        model.cfg.name = mode.checkpoint_name(args.get("model"));
    }
    let model = Arc::new(model);
    let method = args.get("method");
    let sparsifier = if method == "dense" {
        Arc::new(wisparse::sparsity::Dense) as Arc<dyn wisparse::sparsity::Sparsifier>
    } else {
        let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
        let calib = ModelCalib::collect(&model, &calib_set);
        let cfg = common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
        let plan = common::plan_for(
            artifacts,
            &model,
            &calib,
            method,
            args.get_f64("target")?,
            &cfg,
            true,
        )?;
        common::sparsifier_for(&model, method, &plan)?
    };
    let engine = Engine::new(
        Arc::clone(&model),
        sparsifier,
        EngineCfg {
            prefill_chunk: args.get_usize("prefill-chunk")?.max(1),
            ..EngineCfg::default()
        },
    );
    let prompt = "a".repeat(args.get_usize("prompt-len")?);
    let new_tokens = args.get_usize("new-tokens")?;
    let mut best_tps = 0.0f64;
    let mut density = 1.0f64;
    for rep in 0..args.get_usize("reps")? {
        let sw = Stopwatch::start();
        let (_, stats) = engine.run_to_completion(&prompt, new_tokens, Sampling::Greedy);
        let secs = sw.elapsed_secs();
        let tps = new_tokens as f64 / secs;
        density = stats.density();
        best_tps = best_tps.max(tps);
        println!(
            "rep {rep}: {:.1} tok/s  ({} tokens in {:.3}s, density {:.3})",
            tps, new_tokens, secs, density
        );
    }
    println!(
        "best: model={} method={} weights={} ({:.1} MB resident) density={:.3} -> {:.1} tokens/s",
        model.cfg.name,
        method,
        model.weight_repr_name(),
        model.weight_bytes_resident() as f64 / 1e6,
        density,
        best_tps
    );
    Ok(())
}
