//! `wisparse table1`: the main accuracy comparison (Table 1) — three
//! models x {0, 30, 40, 50}% x {R-Sparse, TEAL, WiSparse} x six tasks.

use std::path::Path;
use wisparse::calib::ModelCalib;
use wisparse::data::tasks::full_suite;
use wisparse::eval::harness::{evaluate_suite, EvalReport};
use wisparse::model::ModelConfig;
use wisparse::report::csv::{f, write_csv};
use wisparse::sparsity::Dense;
use wisparse::util::cli::Args;
use wisparse::util::timer::Stopwatch;

use crate::cmd::common;

pub fn run(argv: &[String]) -> anyhow::Result<()> {
    let args = Args::new("table1", "accuracy comparison (Table 1)")
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("models", "llama-micro,mistral-micro,qwen-micro", "comma list")
        .opt("methods", "rsparse,teal,wisparse", "comma list")
        .opt("sparsities", "0.3,0.4,0.5", "comma list")
        .opt("items", "40", "items per task")
        .opt("budget", "default", "search budget: quick|default|paper")
        .opt("threads", "0", "worker threads (0 = all cores)")
        .opt("calib-seqs", "8", "calibration sequences")
        .flag("synthetic", "use random weights")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let threads = match args.get_usize("threads")? {
        0 => wisparse::util::threadpool::num_threads(),
        n => n,
    };
    let cfg = common::search_cfg(args.get("budget"), threads)?;
    let items = args.get_usize("items")?;
    let mut csv_rows: Vec<Vec<String>> = Vec::new();

    for model_name in args.get("models").split(',') {
        let model_name = model_name.trim();
        let _ = ModelConfig::preset(model_name)?;
        let model = common::load_model(artifacts, model_name, args.get_flag("synthetic"))?;
        let suite = full_suite(items, 0xAB1E);
        println!("\n=== {} ===", model_name);
        println!("{}", EvalReport::header());

        // Dense baseline row.
        let dense_report = evaluate_suite(&model, &suite, &Dense, "baseline", 0.0, threads);
        println!("{}", dense_report.row());
        push_csv(&mut csv_rows, model_name, &dense_report);

        let calib_set =
            common::load_calib(artifacts, model_name, args.get_usize("calib-seqs")?, 96);
        let calib = ModelCalib::collect(&model, &calib_set);

        for target_s in args.get_f64_list("sparsities")? {
            for method in args.get("methods").split(',') {
                let method = method.trim();
                let sw = Stopwatch::start();
                let plan =
                    common::plan_for(artifacts, &model, &calib, method, target_s, &cfg, true)?;
                let sp = common::sparsifier_for(&model, method, &plan)?;
                let report =
                    evaluate_suite(&model, &suite, sp.as_ref(), method, target_s, threads);
                println!("{}   [{:.0}s]", report.row(), sw.elapsed_secs());
                push_csv(&mut csv_rows, model_name, &report);
            }
        }
    }
    let out = common::results_dir().join("table1.csv");
    write_csv(
        &out,
        &[
            "model", "method", "sparsity", "SIQA", "GSM8K", "WiC", "HumanEval", "MMLU",
            "CSQA", "Average",
        ],
        &csv_rows,
    )?;
    println!("\ntable1 -> {}", out.display());
    Ok(())
}

fn push_csv(rows: &mut Vec<Vec<String>>, model: &str, r: &EvalReport) {
    let mut row = vec![model.to_string(), r.method.clone(), f(r.sparsity)];
    for (_, _, acc) in &r.per_task {
        row.push(f(*acc));
    }
    row.push(f(r.average));
    rows.push(row);
}
