//! Figure regeneration commands (Figs 2-6). Each writes a CSV under
//! `results/` and prints an ASCII rendering of the figure's shape.

use std::path::Path;
use std::sync::Arc;
use wisparse::calib::ModelCalib;
use wisparse::eval::ppl::{delta_ppl_percent, perplexity};
use wisparse::model::layers::{LayerId, LayerKind};
use wisparse::model::sampler::Sampling;
use wisparse::report::chart::ascii_chart;
use wisparse::report::csv::{f, write_csv};
use wisparse::server::engine::{Engine, EngineCfg};
use wisparse::sparsity::evo::sparsifier_for_allocation;
use wisparse::sparsity::Dense;
use wisparse::util::cli::Args;
use wisparse::util::stats::{mean, stddev};

use crate::cmd::common;

fn base_args(cmd: &'static str, about: &'static str) -> Args {
    Args::new(cmd, about)
        .opt("artifacts", "artifacts", "artifacts root")
        .opt("model", "llama-micro", "model preset")
        .flag("synthetic", "use random weights")
}

/// Fig 2: per-channel activation magnitude vs weight column norms for one
/// layer (default: block n/2's o_proj, as in the paper's block-17 example).
pub fn fig2(argv: &[String]) -> anyhow::Result<()> {
    let args = base_args("fig2", "activation vs weight-norm distributions")
        .opt("block", "-1", "block index (-1 = middle block)")
        .opt("layer", "o_proj", "projection kind")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let model = common::load_model(artifacts, args.get("model"), args.get_flag("synthetic"))?;
    let block = match args.get("block").parse::<i64>() {
        Ok(b) if b >= 0 => b as usize,
        _ => model.cfg.n_layers / 2,
    };
    let kind = LayerKind::from_name(args.get("layer"))
        .ok_or_else(|| anyhow::anyhow!("unknown layer kind"))?;
    let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
    let calib = ModelCalib::collect(&model, &calib_set);
    let (rows, dim) = calib.blocks[block].rows_of(kind, &model.cfg);
    let id = LayerId::new(block, kind);
    let g = model.g(id);

    // Mean |x| per channel over the calibration pool.
    let n_rows = rows.len() / dim;
    let mut mean_abs = vec![0.0f64; dim];
    for row in rows.chunks_exact(dim) {
        for (c, &v) in row.iter().enumerate() {
            mean_abs[c] += v.abs() as f64;
        }
    }
    for v in mean_abs.iter_mut() {
        *v /= n_rows as f64;
    }
    let mut csv = Vec::with_capacity(dim);
    for c in 0..dim {
        csv.push(vec![
            c.to_string(),
            f(mean_abs[c]),
            f(g[c] as f64),
            f(mean_abs[c] * g[c] as f64),
        ]);
    }
    let out = common::results_dir().join("fig2_magnitudes.csv");
    write_csv(&out, &["channel", "mean_abs_activation", "weight_col_norm", "product"], &csv)?;

    // The paper's headline statistic: weight-side variance dominates.
    let g64: Vec<f64> = g.iter().map(|&v| v as f64).collect();
    let cv_w = stddev(&g64) / mean(&g64).max(1e-12);
    let cv_a = stddev(&mean_abs) / mean(&mean_abs).max(1e-12);
    // A channel in the paper's regime: low |x|, top-decile g.
    let mut by_g: Vec<usize> = (0..dim).collect();
    by_g.sort_by(|&a, &b| g[b].partial_cmp(&g[a]).unwrap());
    let mut by_a: Vec<usize> = (0..dim).collect();
    by_a.sort_by(|&a, &b| mean_abs[a].partial_cmp(&mean_abs[b]).unwrap());
    let top_g: Vec<usize> = by_g[..dim / 10 + 1].to_vec();
    let mismatched = by_a[..dim / 4]
        .iter()
        .find(|c| top_g.contains(c))
        .copied();
    println!("fig2: block {block} {} ({} channels, {} calib rows)", kind.name(), dim, n_rows);
    println!("  coef-of-variation: weight-col-norms {cv_w:.3} vs activations {cv_a:.3}");
    match mismatched {
        Some(c) => println!(
            "  Observation-1 witness: channel {c} has bottom-quartile |x| (={:.4}) but top-decile ‖W:,c‖ (={:.3})",
            mean_abs[c], g[c]
        ),
        None => println!("  (no bottom-quartile-|x| / top-decile-g channel in this layer)"),
    }
    println!("  -> {}", out.display());
    Ok(())
}

/// Fig 3: block-wise sensitivity — sparsify ONE block at a time at
/// {40, 50, 60}%, report ΔPPL vs dense.
pub fn fig3(argv: &[String]) -> anyhow::Result<()> {
    let args = base_args("fig3", "block-wise sparsity sensitivity")
        .opt("sparsities", "0.4,0.5,0.6", "per-block sparsity levels")
        .opt("eval-seqs", "6", "held-out sequences for PPL")
        .opt("eval-len", "96", "sequence length")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let model = common::load_model(artifacts, args.get("model"), args.get_flag("synthetic"))?;
    let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
    let calib = ModelCalib::collect(&model, &calib_set);
    let eval = common::eval_seqs(args.get_usize("eval-seqs")?, args.get_usize("eval-len")?);
    let dense_ppl = perplexity(&model, &eval, &Dense);
    println!("dense ppl {dense_ppl:.4}");
    let n = model.cfg.n_layers;
    let levels = args.get_f64_list("sparsities")?;
    let mut csv = Vec::new();
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for &level in &levels {
        let mut pts = Vec::new();
        for b in 0..n {
            let mut alloc = vec![0.0; n];
            alloc[b] = level;
            let sp = sparsifier_for_allocation(&model, &calib, &alloc, 1.0);
            let ppl = perplexity(&model, &eval, &sp);
            let dppl = delta_ppl_percent(dense_ppl, ppl);
            csv.push(vec![b.to_string(), f(level), f(ppl), f(dppl)]);
            pts.push((b as f64, dppl));
        }
        series.push((format!("{:.0}%", level * 100.0), pts));
    }
    let out = common::results_dir().join("fig3_sensitivity.csv");
    write_csv(&out, &["block", "sparsity", "ppl", "delta_ppl_pct"], &csv)?;
    let series_ref: Vec<(&str, Vec<(f64, f64)>)> = series
        .iter()
        .map(|(l, p)| (l.as_str(), p.clone()))
        .collect();
    println!(
        "{}",
        ascii_chart("Fig 3: ΔPPL% sparsifying one block at a time", &series_ref, 14)
    );
    println!("-> {}", out.display());
    Ok(())
}

/// Fig 4: achieved FLOPs and tokens/s vs sparsity for all models.
pub fn fig4(argv: &[String]) -> anyhow::Result<()> {
    let args = base_args("fig4", "FLOPs + throughput vs sparsity")
        .opt("models", "llama-micro,mistral-micro,qwen-micro", "comma list")
        .opt("sparsities", "0.0,0.1,0.2,0.3,0.4,0.5", "levels")
        .opt("prompt-len", "5", "prompt length (paper: 5)")
        .opt("new-tokens", "200", "decode length (paper: 200)")
        .opt("budget", "quick", "calibration budget")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let cfg = common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
    let prompt = "a".repeat(args.get_usize("prompt-len")?);
    let new_tokens = args.get_usize("new-tokens")?;
    let mut csv = Vec::new();
    let mut tput_series = Vec::new();
    let mut flop_series = Vec::new();
    for model_name in args.get("models").split(',') {
        let model_name = model_name.trim();
        let model = Arc::new(common::load_model(artifacts, model_name, args.get_flag("synthetic"))?);
        let calib_set = common::load_calib(artifacts, model_name, 8, 96);
        let calib = ModelCalib::collect(&model, &calib_set);
        let mut tput_pts = Vec::new();
        let mut flop_pts = Vec::new();
        for target_s in args.get_f64_list("sparsities")? {
            let sp: Arc<dyn wisparse::sparsity::Sparsifier> = if target_s == 0.0 {
                Arc::new(Dense)
            } else {
                let plan =
                    common::plan_for(artifacts, &model, &calib, "wisparse", target_s, &cfg, true)?;
                common::sparsifier_for(&model, "wisparse", &plan)?
            };
            let engine = Engine::new(Arc::clone(&model), sp, EngineCfg::default());
            // Warmup + 3 reps, best wins (paper protocol).
            let mut best_tps = 0.0f64;
            let mut tflops = 0.0f64;
            let mut density = 1.0;
            for _ in 0..3 {
                let sw = wisparse::util::timer::Stopwatch::start();
                let (_, stats) = engine.run_to_completion(&prompt, new_tokens, Sampling::Greedy);
                let tps = new_tokens as f64 / sw.elapsed_secs();
                best_tps = best_tps.max(tps);
                tflops = stats.flops_per_token() * 1e-9; // GFLOP/token
                density = stats.density();
            }
            println!(
                "{model_name} @ {:.0}%: density {:.3}, {:.3} GFLOP/token, {:.1} tok/s",
                target_s * 100.0,
                density,
                tflops,
                best_tps
            );
            csv.push(vec![
                model_name.to_string(),
                f(target_s),
                f(density),
                f(tflops),
                f(best_tps),
            ]);
            tput_pts.push((target_s, best_tps));
            flop_pts.push((target_s, tflops));
        }
        tput_series.push((model_name.to_string(), tput_pts));
        flop_series.push((model_name.to_string(), flop_pts));
    }
    let out = common::results_dir().join("fig4_efficiency.csv");
    write_csv(
        &out,
        &["model", "sparsity", "density", "gflop_per_token", "tokens_per_s"],
        &csv,
    )?;
    let fs: Vec<(&str, Vec<(f64, f64)>)> = flop_series
        .iter()
        .map(|(l, p)| (l.as_str(), p.clone()))
        .collect();
    println!("{}", ascii_chart("Fig 4 (left): GFLOP/token vs sparsity", &fs, 12));
    let ts: Vec<(&str, Vec<(f64, f64)>)> = tput_series
        .iter()
        .map(|(l, p)| (l.as_str(), p.clone()))
        .collect();
    println!("{}", ascii_chart("Fig 4 (right): tokens/s vs sparsity", &ts, 12));
    println!("-> {}", out.display());
    Ok(())
}

/// Fig 5: the discovered per-block and per-module sparsity at 50%.
pub fn fig5(argv: &[String]) -> anyhow::Result<()> {
    let args = base_args("fig5", "discovered sparsity allocation")
        .opt("models", "llama-micro,qwen-micro", "comma list (paper shows 2)")
        .opt("target", "0.5", "global target")
        .opt("budget", "default", "calibration budget")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let cfg = common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
    let target = args.get_f64("target")?;
    let mut csv = Vec::new();
    for model_name in args.get("models").split(',') {
        let model_name = model_name.trim();
        let model = common::load_model(artifacts, model_name, args.get_flag("synthetic"))?;
        let calib_set = common::load_calib(artifacts, model_name, 8, 96);
        let calib = ModelCalib::collect(&model, &calib_set);
        let plan = common::plan_for(artifacts, &model, &calib, "wisparse", target, &cfg, true)?;
        let mut series = Vec::new();
        let mut attn_pts = Vec::new();
        let mut mlp_pts = Vec::new();
        for b in 0..model.cfg.n_layers {
            let (mut attn_s, mut attn_w, mut mlp_s, mut mlp_w) = (0.0, 0.0, 0.0, 0.0);
            for &kind in &LayerKind::ALL {
                let w = wisparse::model::layers::layer_flops(&model.cfg, kind);
                let s = plan.layer(LayerId::new(b, kind)).sparsity;
                if kind.is_attn() {
                    attn_s += w * s;
                    attn_w += w;
                } else {
                    mlp_s += w * s;
                    mlp_w += w;
                }
            }
            let attn = attn_s / attn_w;
            let mlp = mlp_s / mlp_w;
            csv.push(vec![
                model_name.to_string(),
                b.to_string(),
                f(plan.block_sparsity[b]),
                f(attn),
                f(mlp),
            ]);
            attn_pts.push((b as f64, attn));
            mlp_pts.push((b as f64, mlp));
        }
        series.push(("attn", attn_pts));
        series.push(("mlp", mlp_pts));
        println!(
            "{}",
            ascii_chart(
                &format!("Fig 5: {} per-module sparsity @ {:.0}%", model_name, target * 100.0),
                &series,
                10
            )
        );
    }
    let out = common::results_dir().join("fig5_allocation.csv");
    write_csv(
        &out,
        &["model", "block", "block_sparsity", "attn_sparsity", "mlp_sparsity"],
        &csv,
    )?;
    println!("-> {}", out.display());
    Ok(())
}

/// Fig 6: calibrated alpha per layer (attention vs MLP panels).
pub fn fig6(argv: &[String]) -> anyhow::Result<()> {
    let args = base_args("fig6", "calibrated alpha values")
        .opt("target", "0.5", "plan target sparsity")
        .opt("budget", "default", "calibration budget")
        .parse(argv)?;
    let artifacts = Path::new(args.get("artifacts"));
    let cfg = common::search_cfg(args.get("budget"), wisparse::util::threadpool::num_threads())?;
    let model = common::load_model(artifacts, args.get("model"), args.get_flag("synthetic"))?;
    let calib_set = common::load_calib(artifacts, args.get("model"), 8, 96);
    let calib = ModelCalib::collect(&model, &calib_set);
    let target = args.get_f64("target")?;
    let plan = common::plan_for(artifacts, &model, &calib, "wisparse", target, &cfg, true)?;
    let mut csv = Vec::new();
    let mut attn_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut mlp_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for &kind in &LayerKind::ALL {
        let pts: Vec<(f64, f64)> = (0..model.cfg.n_layers)
            .map(|b| (b as f64, plan.layer(LayerId::new(b, kind)).alpha))
            .collect();
        for (b, a) in &pts {
            csv.push(vec![kind.name().to_string(), b.to_string(), f(*a)]);
        }
        if kind.is_attn() {
            attn_series.push((kind.name().to_string(), pts));
        } else {
            mlp_series.push((kind.name().to_string(), pts));
        }
    }
    let a: Vec<(&str, Vec<(f64, f64)>)> = attn_series
        .iter()
        .map(|(l, p)| (l.as_str(), p.clone()))
        .collect();
    println!("{}", ascii_chart("Fig 6 (left): attention alphas", &a, 10));
    let m: Vec<(&str, Vec<(f64, f64)>)> = mlp_series
        .iter()
        .map(|(l, p)| (l.as_str(), p.clone()))
        .collect();
    println!("{}", ascii_chart("Fig 6 (right): MLP alphas", &m, 10));
    let out = common::results_dir().join("fig6_alphas.csv");
    write_csv(&out, &["layer_kind", "block", "alpha"], &csv)?;
    println!("-> {}", out.display());
    Ok(())
}
