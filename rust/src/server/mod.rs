//! The L3 serving coordinator.
//!
//! A vLLM-router-shaped stack scaled to this testbed: an HTTP/1.1 front
//! end — the epoll reactor in [`reactor`] (default) or the legacy
//! thread-per-connection path in [`http`] (`--frontend blocking`) — over
//! a prefix-affinity [`router::Router`] of N engine replicas, each with a
//! FIFO admission queue, a continuous batcher that admits new sequences
//! between decode steps, and the sparse inference engine running every
//! sequence's per-token dynamic masks. Python is never on this path: the
//! engine serves from the native weights, with the PJRT backend available
//! for cross-validation.

pub mod request;
pub mod engine;
pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod http;
pub mod reactor;
pub mod router;
pub mod coordinator;

pub use coordinator::{Coordinator, CoordinatorCfg};
pub use engine::{Engine, EngineCfg, SpecCfg, SpecEngine};
pub use faults::{FaultPoint, Faults};
pub use reactor::ReactorCfg;
pub use request::{GenRequest, GenResponse, StreamEvent};
pub use router::{Router, RouterCfg};

use std::sync::Arc;

/// Install a SIGTERM/SIGINT handler that starts a graceful drain on every
/// replica behind the router: admission stops, active sequences finish
/// (bounded by the drain timeout), streams flush, the schedulers exit, and
/// the serve loops unwind — every in-flight request still gets its
/// response. Raw libc `signal(2)` via FFI: the handler only flips an
/// atomic (async-signal safe); a watcher thread does the actual drain.
#[cfg(unix)]
pub fn install_sigterm_drain_router(router: Arc<Router>) {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    static TERM: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_term);
        signal(SIGINT, on_term);
    }
    std::thread::spawn(move || loop {
        if TERM.load(Ordering::SeqCst) {
            crate::warn_!("SIGTERM/SIGINT: draining");
            router.drain();
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    });
}

/// Single-coordinator wrapper around [`install_sigterm_drain_router`].
pub fn install_sigterm_drain(coord: Arc<Coordinator>) {
    install_sigterm_drain_router(Router::single(coord));
}

#[cfg(not(unix))]
pub fn install_sigterm_drain_router(_router: Arc<Router>) {}
