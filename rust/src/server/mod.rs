//! The L3 serving coordinator.
//!
//! A vLLM-router-shaped stack scaled to this testbed: an HTTP/1.1 front end
//! (std::net + threads — the environment has no tokio), a FIFO admission
//! queue, a continuous batcher that admits new sequences between decode
//! steps, and the sparse inference engine running every sequence's
//! per-token dynamic masks. Python is never on this path: the engine serves
//! from the native weights, with the PJRT backend available for
//! cross-validation.

pub mod request;
pub mod engine;
pub mod batcher;
pub mod metrics;
pub mod http;
pub mod coordinator;

pub use coordinator::{Coordinator, CoordinatorCfg};
pub use engine::{Engine, EngineCfg, SpecCfg, SpecEngine};
pub use request::{GenRequest, GenResponse, StreamEvent};
