//! Request/response types for the serving path.

use crate::model::sampler::Sampling;
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// A generation request as admitted by the router.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new: usize,
    pub sampling: Sampling,
    /// Wall-clock admission timestamp (for queue-latency metrics).
    pub arrived: Instant,
    /// Set when the scheduler preempted this request's sequence for pool
    /// pressure and requeued it (surfaces as `preempted->resumed`).
    pub preempted: bool,
    /// Opt this request out of speculative decoding (`"speculative": false`
    /// in the body) on a `--speculative` server; speculative and plain
    /// sequences share the batch. Ignored when the server isn't
    /// speculative.
    pub speculative: bool,
    /// Stream each accepted token back as a chunked NDJSON line
    /// (`"stream": true` in the body) instead of one blocking response.
    pub stream: bool,
    /// Completion deadline relative to `arrived` (`"deadline_ms"` in the
    /// body, else the server default). Enforced at admission (a request
    /// already past its deadline while queued fails `deadline_exceeded`
    /// without running) and between decode steps (an active sequence past
    /// it finishes `deadline_exceeded` with whatever it generated). `None`
    /// means no deadline.
    pub deadline: Option<Duration>,
    /// Tracing correlation id, assigned by the coordinator at submission
    /// (process-global, never reused across coordinators). 0 = untraced.
    pub trace_id: u64,
    /// Span id reserved for this request's root span, so engine-level child
    /// spans can parent onto it before the root is recorded at completion.
    pub root_span: u64,
}

impl GenRequest {
    pub fn new(id: u64, prompt: &str, max_new: usize) -> Self {
        Self {
            id,
            prompt: prompt.to_string(),
            max_new,
            sampling: Sampling::Greedy,
            arrived: Instant::now(),
            preempted: false,
            speculative: true,
            stream: false,
            deadline: None,
            trace_id: 0,
            root_span: 0,
        }
    }

    /// Whether this request's deadline (if any) has already passed.
    pub fn past_deadline(&self) -> bool {
        self.deadline.is_some_and(|d| self.arrived.elapsed() >= d)
    }

    /// Parse the POST /generate body:
    /// `{"prompt": "...", "max_new": 32, "temperature": 0.0,
    /// "speculative": true, "stream": false, "deadline_ms": 0}`.
    /// A `deadline_ms` of 0 or absent leaves the deadline to the server
    /// default.
    pub fn from_json(id: u64, j: &Json) -> anyhow::Result<GenRequest> {
        let prompt = j.req_str("prompt")?.to_string();
        if prompt.is_empty() {
            anyhow::bail!("empty prompt");
        }
        let max_new = j.get("max_new").as_usize().unwrap_or(32);
        let temp = j.get("temperature").as_f64().unwrap_or(0.0);
        let speculative = j.get("speculative").as_bool().unwrap_or(true);
        let stream = j.get("stream").as_bool().unwrap_or(false);
        let deadline = match j.get("deadline_ms").as_f64() {
            Some(ms) if ms > 0.0 => Some(Duration::from_millis(ms as u64)),
            _ => None,
        };
        Ok(GenRequest {
            id,
            prompt,
            max_new,
            sampling: if temp > 0.0 {
                Sampling::Temperature(temp as f32)
            } else {
                Sampling::Greedy
            },
            arrived: Instant::now(),
            preempted: false,
            speculative,
            stream,
            deadline,
            trace_id: 0,
            root_span: 0,
        })
    }
}

/// One event on a streaming `/generate` connection.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// One committed token (speculative rounds may emit several per step).
    Token { index: usize, text: String },
    /// Generation finished: the full response summary.
    Done(GenResponse),
}

impl StreamEvent {
    pub fn to_json(&self) -> Json {
        match self {
            StreamEvent::Token { index, text } => Json::obj(vec![
                ("done", Json::Bool(false)),
                ("index", Json::Num(*index as f64)),
                ("token", Json::Str(text.clone())),
            ]),
            StreamEvent::Done(resp) => {
                let mut j = resp.to_json();
                if let Json::Obj(m) = &mut j {
                    m.insert("done".to_string(), Json::Bool(true));
                }
                j
            }
        }
    }
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub n_prompt_tokens: usize,
    pub n_generated: usize,
    pub queue_ms: f64,
    pub total_ms: f64,
    /// Achieved density over this request's linear projections.
    pub density: f64,
    /// Why generation stopped: `length`, `cache_full`, or
    /// `preempted->resumed` (see [`crate::server::engine::FinishReason`]).
    pub finish_reason: String,
    /// Prompt tokens served from the shared prefix cache (0 without one).
    pub prefix_hit_tokens: usize,
    /// The request's tracing correlation id; fetch the span timeline at
    /// `GET /debug/traces?id=<trace_id>`. 0 = untraced (terminal responses
    /// for requests that never ran).
    pub trace_id: u64,
}

impl GenResponse {
    /// A terminal no-output response: what a request that never generated
    /// anything (queued past its deadline, shed under overload, orphaned by
    /// a scheduler restart, drained at shutdown) is completed with. Exactly
    /// one of these or a real completion reaches every waiter. Timings are
    /// zero — an orphaned request's `GenRequest` no longer exists to
    /// measure against.
    pub fn terminal(id: u64, reason: &str) -> GenResponse {
        GenResponse {
            id,
            text: String::new(),
            n_prompt_tokens: 0,
            n_generated: 0,
            queue_ms: 0.0,
            total_ms: 0.0,
            density: 1.0,
            finish_reason: reason.to_string(),
            prefix_hit_tokens: 0,
            trace_id: 0,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::Str(self.text.clone())),
            ("prompt_tokens", Json::Num(self.n_prompt_tokens as f64)),
            ("generated_tokens", Json::Num(self.n_generated as f64)),
            ("queue_ms", Json::Num(self.queue_ms)),
            ("total_ms", Json::Num(self.total_ms)),
            ("density", Json::Num(self.density)),
            ("finish_reason", Json::Str(self.finish_reason.clone())),
            ("prefix_hit_tokens", Json::Num(self.prefix_hit_tokens as f64)),
            ("trace_id", Json::Num(self.trace_id as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_json() {
        let j = Json::parse(r#"{"prompt": "12+34=", "max_new": 8}"#).unwrap();
        let r = GenRequest::from_json(1, &j).unwrap();
        assert_eq!(r.prompt, "12+34=");
        assert_eq!(r.max_new, 8);
        assert_eq!(r.sampling, Sampling::Greedy);
    }

    #[test]
    fn parse_with_temperature() {
        let j = Json::parse(r#"{"prompt": "x", "temperature": 0.7}"#).unwrap();
        let r = GenRequest::from_json(2, &j).unwrap();
        assert_eq!(r.sampling, Sampling::Temperature(0.7));
        assert_eq!(r.max_new, 32); // default
        assert!(r.speculative, "speculative defaults on");
    }

    #[test]
    fn parse_speculative_opt_out() {
        let j = Json::parse(r#"{"prompt": "x", "speculative": false}"#).unwrap();
        assert!(!GenRequest::from_json(5, &j).unwrap().speculative);
    }

    #[test]
    fn parse_stream_flag() {
        let j = Json::parse(r#"{"prompt": "x"}"#).unwrap();
        assert!(!GenRequest::from_json(6, &j).unwrap().stream, "defaults off");
        let j = Json::parse(r#"{"prompt": "x", "stream": true}"#).unwrap();
        assert!(GenRequest::from_json(7, &j).unwrap().stream);
    }

    #[test]
    fn parse_deadline_ms() {
        let j = Json::parse(r#"{"prompt": "x"}"#).unwrap();
        assert!(GenRequest::from_json(8, &j).unwrap().deadline.is_none());
        let j = Json::parse(r#"{"prompt": "x", "deadline_ms": 0}"#).unwrap();
        assert!(GenRequest::from_json(9, &j).unwrap().deadline.is_none());
        let j = Json::parse(r#"{"prompt": "x", "deadline_ms": 250}"#).unwrap();
        let r = GenRequest::from_json(10, &j).unwrap();
        assert_eq!(r.deadline, Some(Duration::from_millis(250)));
        assert!(!r.past_deadline(), "freshly parsed request has time left");
    }

    #[test]
    fn terminal_response_has_no_output() {
        let t = GenResponse::terminal(3, "deadline_exceeded");
        assert_eq!(t.n_generated, 0);
        assert!(t.text.is_empty());
        assert_eq!(t.finish_reason, "deadline_exceeded");
        assert_eq!(t.to_json().get("generated_tokens").as_usize(), Some(0));
    }

    #[test]
    fn stream_events_serialize() {
        let tok = StreamEvent::Token {
            index: 3,
            text: "a".into(),
        };
        let j = tok.to_json();
        assert_eq!(j.get("done").as_bool(), Some(false));
        assert_eq!(j.get("index").as_usize(), Some(3));
        assert_eq!(j.get("token").as_str(), Some("a"));
        let done = StreamEvent::Done(GenResponse {
            id: 1,
            text: "abc".into(),
            n_prompt_tokens: 2,
            n_generated: 3,
            queue_ms: 0.0,
            total_ms: 1.0,
            density: 1.0,
            finish_reason: "length".into(),
            prefix_hit_tokens: 0,
            trace_id: 0,
        });
        let j = done.to_json();
        assert_eq!(j.get("done").as_bool(), Some(true));
        assert_eq!(j.get("text").as_str(), Some("abc"));
    }

    #[test]
    fn rejects_missing_prompt() {
        let j = Json::parse(r#"{"max_new": 8}"#).unwrap();
        assert!(GenRequest::from_json(3, &j).is_err());
        let j2 = Json::parse(r#"{"prompt": ""}"#).unwrap();
        assert!(GenRequest::from_json(4, &j2).is_err());
    }

    #[test]
    fn response_serializes() {
        let r = GenResponse {
            id: 9,
            text: "46.".into(),
            n_prompt_tokens: 6,
            n_generated: 3,
            queue_ms: 0.1,
            total_ms: 5.0,
            density: 0.55,
            finish_reason: "length".into(),
            prefix_hit_tokens: 4,
            trace_id: 17,
        };
        let j = r.to_json();
        assert_eq!(j.get("text").as_str(), Some("46."));
        assert_eq!(j.get("generated_tokens").as_usize(), Some(3));
        assert_eq!(j.get("finish_reason").as_str(), Some("length"));
        assert_eq!(j.get("prefix_hit_tokens").as_usize(), Some(4));
        assert_eq!(j.get("trace_id").as_usize(), Some(17));
    }
}
