//! Deterministic fault injection for the supervised serving runtime.
//!
//! Compiled unconditionally, inert unless armed: every injection point is a
//! single branch on `armed` when no schedule is loaded, so the production
//! fast path pays one predictable-false branch per site. Faults are
//! *scheduled by invocation count* — "fire at the Nth time this point is
//! reached" — which makes a chaos run reproducible in the number and kind
//! of faults injected regardless of thread interleaving (which sequence
//! absorbs the Nth invocation may vary; the invariants under test must hold
//! under arbitrary interleavings anyway).
//!
//! Two ways to arm:
//!   * the `WISPARSE_FAULTS` environment variable, parsed at engine
//!     construction (`Faults::from_env`), e.g.
//!     `WISPARSE_FAULTS=decode_panic@5,pool_dry@3,pool_dry@9`
//!   * programmatically via [`Faults::scripted`] (the chaos property suite
//!     builds seeded schedules this way and swaps them into the engine).
//!
//! Points:
//!   * `decode_panic`   — panic inside a sequence's decode/speculative step
//!   * `prefill_panic`  — panic inside a prefill chunk
//!   * `sched_panic`    — panic at the top of a scheduler iteration,
//!     *outside* the per-sequence isolation (exercises the supervisor
//!     restart path)
//!   * `pool_dry`       — force one KV reservation to report an exhausted
//!     pool (exercises preemption / `cache_full` paths without actually
//!     starving the pool)
//!   * `stream_stall`   — sleep briefly in the HTTP streaming write path
//!     (a slow client draining its socket)

use crate::util::sync::lock_ok;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// An injection point in the serving runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultPoint {
    DecodePanic,
    PrefillPanic,
    SchedPanic,
    PoolDry,
    StreamStall,
}

impl FaultPoint {
    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::DecodePanic => "decode_panic",
            FaultPoint::PrefillPanic => "prefill_panic",
            FaultPoint::SchedPanic => "sched_panic",
            FaultPoint::PoolDry => "pool_dry",
            FaultPoint::StreamStall => "stream_stall",
        }
    }

    fn parse(s: &str) -> Option<FaultPoint> {
        match s {
            "decode_panic" => Some(FaultPoint::DecodePanic),
            "prefill_panic" => Some(FaultPoint::PrefillPanic),
            "sched_panic" => Some(FaultPoint::SchedPanic),
            "pool_dry" => Some(FaultPoint::PoolDry),
            "stream_stall" => Some(FaultPoint::StreamStall),
            _ => None,
        }
    }
}

struct FaultState {
    /// Per point: the 1-based invocation counts at which to fire.
    schedule: HashMap<FaultPoint, Vec<u64>>,
    /// Per point: invocations seen so far.
    calls: HashMap<FaultPoint, u64>,
    fired: u64,
}

/// A fault plan. One per engine; `inert()` is the production default unless
/// `WISPARSE_FAULTS` carries a schedule.
pub struct Faults {
    armed: bool,
    state: Mutex<FaultState>,
}

impl Faults {
    /// No faults; every `should_fire` is a single false branch.
    pub fn inert() -> Arc<Faults> {
        Arc::new(Faults {
            armed: false,
            state: Mutex::new(FaultState {
                schedule: HashMap::new(),
                calls: HashMap::new(),
                fired: 0,
            }),
        })
    }

    /// Parse a schedule like `decode_panic@5,pool_dry@3,pool_dry@9`.
    /// Unknown points and malformed entries are ignored (a chaos harness
    /// must never turn a typo into a refusal to start); an empty schedule
    /// yields an inert plan.
    pub fn scripted(spec: &str) -> Arc<Faults> {
        let mut schedule: HashMap<FaultPoint, Vec<u64>> = HashMap::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let Some((point, at)) = entry.split_once('@') else {
                continue;
            };
            let (Some(p), Ok(n)) = (FaultPoint::parse(point.trim()), at.trim().parse::<u64>())
            else {
                continue;
            };
            if n > 0 {
                schedule.entry(p).or_default().push(n);
            }
        }
        let armed = !schedule.is_empty();
        Arc::new(Faults {
            armed,
            state: Mutex::new(FaultState {
                schedule,
                calls: HashMap::new(),
                fired: 0,
            }),
        })
    }

    /// The production constructor: a schedule from `WISPARSE_FAULTS`, or an
    /// inert plan when the variable is unset / carries no valid entries.
    pub fn from_env() -> Arc<Faults> {
        match std::env::var("WISPARSE_FAULTS") {
            Ok(spec) => Self::scripted(&spec),
            Err(_) => Self::inert(),
        }
    }

    pub fn armed(&self) -> bool {
        self.armed
    }

    /// Count this invocation of `point` and report whether the schedule
    /// fires here. Inert plans return false without touching the lock.
    pub fn should_fire(&self, point: FaultPoint) -> bool {
        if !self.armed {
            return false;
        }
        let mut st = lock_ok(&self.state);
        let c = st.calls.entry(point).or_insert(0);
        *c += 1;
        let c = *c;
        let fire = st.schedule.get(&point).is_some_and(|v| v.contains(&c));
        if fire {
            st.fired += 1;
        }
        fire
    }

    /// Panic at `point` when the schedule says so — the injected-panic
    /// sites. Always called inside the runtime's `catch_unwind` scopes.
    pub fn maybe_panic(&self, point: FaultPoint) {
        if self.should_fire(point) {
            panic!("injected fault: {}", point.name());
        }
    }

    /// Faults fired so far (test assertion that a schedule was exercised).
    pub fn fired(&self) -> u64 {
        lock_ok(&self.state).fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_never_fires() {
        let f = Faults::inert();
        assert!(!f.armed());
        for _ in 0..100 {
            assert!(!f.should_fire(FaultPoint::DecodePanic));
        }
        assert_eq!(f.fired(), 0);
    }

    #[test]
    fn scripted_fires_at_exact_counts() {
        let f = Faults::scripted("decode_panic@2,pool_dry@1,pool_dry@3");
        assert!(f.armed());
        assert!(!f.should_fire(FaultPoint::DecodePanic)); // call 1
        assert!(f.should_fire(FaultPoint::DecodePanic)); // call 2
        assert!(!f.should_fire(FaultPoint::DecodePanic)); // call 3
        assert!(f.should_fire(FaultPoint::PoolDry)); // call 1
        assert!(!f.should_fire(FaultPoint::PoolDry)); // call 2
        assert!(f.should_fire(FaultPoint::PoolDry)); // call 3
        assert_eq!(f.fired(), 3);
    }

    #[test]
    fn malformed_entries_ignored() {
        let f = Faults::scripted("1");
        assert!(!f.armed(), "a bare gate value arms nothing");
        let f = Faults::scripted("bogus@3,decode_panic@,decode_panic@0,pool_dry@2");
        assert!(f.armed());
        assert!(!f.should_fire(FaultPoint::PoolDry));
        assert!(f.should_fire(FaultPoint::PoolDry));
        assert!(!f.should_fire(FaultPoint::DecodePanic));
    }

    #[test]
    fn maybe_panic_panics_on_schedule() {
        let f = Faults::scripted("prefill_panic@1");
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f.maybe_panic(FaultPoint::PrefillPanic)
        }));
        assert!(r.is_err());
        assert_eq!(f.fired(), 1);
    }
}
