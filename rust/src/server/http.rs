//! Minimal HTTP/1.1 front end over std::net (no tokio in this environment).
//!
//! Routes:
//!   GET  /health, /healthz  -> {"status": "ok"} (liveness)
//!   GET  /readyz            -> 200 while accepting work, 503 once
//!                              draining or shut down (readiness)
//!   GET  /metrics           -> serving metrics JSON
//!   GET  /metrics?format=prometheus -> text exposition format 0.0.4
//!   GET  /debug/traces?id=N -> span timeline of one request's trace
//!                              (`"truncated": true` when the span ring
//!                              wrapped and evicted part of it)
//!   GET  /debug/traces/export?id=N -> the same trace as Chrome
//!                              trace-event JSON (load in ui.perfetto.dev)
//!   GET  /debug/traces/slow -> worst-N trace exemplars (by total latency
//!                              and by max decode gap)
//!   GET  /alerts            -> SLO burn-rate alerts (active + recently
//!                              resolved) and the configured objectives
//!   POST /generate          -> {"prompt", "max_new"?, "temperature"?,
//!                               "speculative"?, "stream"?, "deadline_ms"?}
//!                              (response echoes its "trace_id")
//!   POST /admin/drain       -> begin graceful drain, 202
//!
//! `/generate` maps terminal no-output responses onto statuses: a request
//! past its deadline is 504, a caught panic is 500, shed load (full queue
//! or drain) is 503 with `Retry-After`. Every 503 carries `Retry-After`.
//!
//! `"stream": true` switches `/generate` to a chunked NDJSON response: one
//! `{"done":false,"index":i,"token":"..."}` line per accepted token as it
//! commits, then a final `{"done":true, ...}` summary line (the same
//! object the blocking path returns).
//!
//! Two front ends share this module's parsing, caps and routing table:
//! the legacy blocking path below (one thread per connection, each response
//! `Connection: close`) and the epoll reactor in
//! [`crate::server::reactor`] (one thread for every connection, HTTP
//! keep-alive). Both serve the same [`crate::server::router::Router`], so
//! `wisparse serve --frontend blocking|reactor` is a pure transport swap —
//! the differential suites in `tests/sharded_serve.rs` pin the two paths
//! response-equivalent.

use crate::obs::{chrome_trace, is_truncated, tracer, Span, TraceSummary};
use crate::server::coordinator::Coordinator;
use crate::server::faults::FaultPoint;
use crate::server::request::{GenRequest, GenResponse, StreamEvent};
use crate::server::router::Router;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard caps on untrusted request framing. Without them a slow or hostile
/// client pins a connection thread forever and grows header buffers without
/// bound (one giant never-terminated line, or an endless header stream).
pub const MAX_HEADER_LINE_BYTES: usize = 8 * 1024;
pub const MAX_HEADER_COUNT: usize = 64;
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Socket read timeout: a client that stops sending mid-request gets a 408
/// and its thread back instead of a permanent hang.
pub const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// A parsed HTTP request (just what the router needs).
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
    /// Whether the client may reuse this connection (HTTP/1.1 default
    /// unless it sent `Connection: close`; HTTP/1.0 defaults closed). The
    /// blocking front end ignores this and always closes; the reactor
    /// honors it.
    pub keep_alive: bool,
}

/// Why parsing an HTTP request failed — each class maps to a distinct
/// response status (408 / 431 / 413 / 400).
#[derive(Debug)]
pub enum ParseError {
    /// A header line or the header count blew past its cap (431).
    HeadersTooLarge(&'static str),
    /// Declared Content-Length exceeds the body cap (413).
    BodyTooLarge,
    /// The socket read timed out mid-request (408).
    Timeout,
    /// Malformed request or transport error (400).
    Bad(String),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::HeadersTooLarge(what) => write!(f, "{what}"),
            ParseError::BodyTooLarge => write!(f, "body too large"),
            ParseError::Timeout => write!(f, "read timed out"),
            ParseError::Bad(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ParseError {}

impl From<std::io::Error> for ParseError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            // Unix reports an expired SO_RCVTIMEO as WouldBlock, Windows as
            // TimedOut.
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ParseError::Timeout,
            _ => ParseError::Bad(e.to_string()),
        }
    }
}

/// The status line a parse failure maps to.
pub fn error_status(e: &ParseError) -> (u16, &'static str) {
    match e {
        ParseError::HeadersTooLarge(_) => (431, "Request Header Fields Too Large"),
        ParseError::BodyTooLarge => (413, "Payload Too Large"),
        ParseError::Timeout => (408, "Request Timeout"),
        ParseError::Bad(_) => (400, "Bad Request"),
    }
}

/// Read one CRLF-terminated line with a hard byte cap: the `take` adaptor
/// bounds how much a line missing its terminator can buffer. Returns the
/// bytes consumed (0 = EOF).
fn read_line_capped<R: BufRead>(reader: &mut R, line: &mut String) -> Result<usize, ParseError> {
    line.clear();
    let n = (&mut *reader)
        .take(MAX_HEADER_LINE_BYTES as u64 + 1)
        .read_line(line)
        .map_err(ParseError::from)?;
    if line.len() > MAX_HEADER_LINE_BYTES {
        return Err(ParseError::HeadersTooLarge("header line too long"));
    }
    Ok(n)
}

/// Parse one HTTP/1.1 request from a stream, enforcing the framing caps.
pub fn parse_request<R: BufRead>(reader: &mut R) -> Result<HttpRequest, ParseError> {
    let mut line = String::new();
    read_line_capped(reader, &mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| ParseError::Bad("empty request line".to_string()))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| ParseError::Bad("missing path".to_string()))?
        .to_string();
    // HTTP/1.1 defaults to keep-alive, HTTP/1.0 (or no version) to close.
    let mut keep_alive = parts.next().is_some_and(|v| v != "HTTP/1.0");
    let mut content_length = 0usize;
    let mut n_headers = 0usize;
    loop {
        let n = read_line_capped(reader, &mut line)?;
        let h = line.trim_end();
        if n == 0 || h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADER_COUNT {
            return Err(ParseError::HeadersTooLarge("too many headers"));
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| ParseError::Bad("bad content-length".to_string()))?;
            } else if k.eq_ignore_ascii_case("connection") {
                let v = v.trim();
                if v.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if v.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(ParseError::BodyTooLarge);
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(ParseError::from)?;
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8(body).map_err(|_| ParseError::Bad("non-utf8 body".into()))?,
        keep_alive,
    })
}

/// Header-section byte ceiling for the buffered (reactor) parser: every
/// header line is individually capped, so the section as a whole is too.
const MAX_HEADER_SECTION_BYTES: usize = (MAX_HEADER_COUNT + 2) * MAX_HEADER_LINE_BYTES;

/// Incremental variant of [`parse_request`] for the reactor's nonblocking
/// reads: attempt to parse one complete request from the front of `buf`.
///
/// Returns `None` while more bytes are needed, `Some(Ok((req, consumed)))`
/// once a whole request (headers + declared body) is buffered — leftover
/// bytes past `consumed` belong to the next pipelined request — or
/// `Some(Err(..))` when the buffered prefix can already be rejected. The
/// caps are enforced incrementally, so a hostile client cannot buffer an
/// unbounded header section by withholding its terminator: validation is
/// then delegated to [`parse_request`] over the complete bytes, keeping
/// one authoritative parser for both front ends.
pub fn try_parse_buffered(buf: &[u8]) -> Option<Result<(HttpRequest, usize), ParseError>> {
    let header_end = buf.windows(4).position(|w| w == b"\r\n\r\n");
    let Some(h) = header_end else {
        // No terminator yet: reject what can already be rejected.
        if buf.len() > MAX_HEADER_SECTION_BYTES {
            return Some(Err(ParseError::HeadersTooLarge("too many headers")));
        }
        let tail = buf
            .iter()
            .rposition(|&b| b == b'\n')
            .map(|i| buf.len() - i - 1)
            .unwrap_or(buf.len());
        if tail > MAX_HEADER_LINE_BYTES {
            return Some(Err(ParseError::HeadersTooLarge("header line too long")));
        }
        if buf.iter().filter(|&&b| b == b'\n').count() > MAX_HEADER_COUNT + 1 {
            return Some(Err(ParseError::HeadersTooLarge("too many headers")));
        }
        return None;
    };
    // Light scan for Content-Length so we know how many body bytes to wait
    // for; full validation happens in parse_request below.
    let header = &buf[..h];
    let mut content_length = 0usize;
    for line in header.split(|&b| b == b'\n').skip(1) {
        let line = String::from_utf8_lossy(line);
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                match v.trim().parse::<usize>() {
                    Ok(n) => content_length = n,
                    Err(_) => {
                        return Some(Err(ParseError::Bad("bad content-length".to_string())))
                    }
                }
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Some(Err(ParseError::BodyTooLarge));
    }
    let total = h + 4 + content_length;
    if buf.len() < total {
        return None;
    }
    let mut cursor = std::io::Cursor::new(&buf[..total]);
    Some(parse_request(&mut std::io::BufReader::new(&mut cursor)).map(|req| (req, total)))
}

/// Serialize an HTTP response. Every 503 carries `Retry-After` so shed
/// clients back off instead of hammering a draining or saturated server.
pub fn response(status: u16, reason: &str, body: &str) -> String {
    response_typed(status, reason, "application/json", body)
}

/// [`response`] with an explicit content type (the Prometheus exposition
/// is text, not JSON).
pub fn response_typed(status: u16, reason: &str, content_type: &str, body: &str) -> String {
    response_conn(status, reason, content_type, body, false)
}

/// [`response_typed`] with an explicit connection disposition: the reactor
/// keeps HTTP/1.1 connections open between requests; the blocking front
/// end always closes.
pub fn response_conn(
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> String {
    let retry = if status == 503 { "Retry-After: 1\r\n" } else { "" };
    let conn = if keep_alive { "keep-alive" } else { "close" };
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{retry}Connection: {conn}\r\n\r\n{body}",
        body.len()
    )
}

/// The status a completed `/generate` maps to. Anything that produced
/// tokens is a 200 (partial output is still output — `finish_reason`
/// carries the why); terminal no-output responses surface their failure
/// class as a status.
pub(crate) fn generate_status(resp: &GenResponse) -> (u16, &'static str) {
    if resp.n_generated > 0 {
        return (200, "OK");
    }
    match resp.finish_reason.as_str() {
        "deadline_exceeded" => (504, "Gateway Timeout"),
        "internal_error" => (500, "Internal Server Error"),
        "shed" | "shutdown" => (503, "Service Unavailable"),
        _ => (200, "OK"),
    }
}

/// The Prometheus text exposition content type.
const PROM_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Fetch one key from a `k=v&k2=v2` query string.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|kv| {
        let (k, v) = kv.split_once('=')?;
        (k == key).then_some(v)
    })
}

fn span_json(s: &Span) -> Json {
    let mut fields = vec![
        ("id", Json::Num(s.id as f64)),
        ("parent", Json::Num(s.parent as f64)),
        ("name", Json::Str(s.name.to_string())),
        ("start_ms", Json::Num(s.start_ns as f64 / 1e6)),
        ("dur_ms", Json::Num(s.dur_ns as f64 / 1e6)),
    ];
    if !s.attrs().is_empty() {
        fields.push((
            "attrs",
            Json::obj(
                s.attrs()
                    .iter()
                    .map(|(k, v)| (*k, Json::Num(*v)))
                    .collect(),
            ),
        ));
    }
    Json::obj(fields)
}

/// One trace's span timeline (`GET /debug/traces?id=N`). Start offsets are
/// milliseconds since the tracer epoch, shared across threads, so nested
/// spans can be laid out on one timeline. `truncated` flags a timeline the
/// span ring partially evicted (a span parents onto a missing ancestor) —
/// the ring wrapped mid-request, so the gaps are data loss, not idle time.
fn trace_json(trace_id: u64) -> Json {
    let spans = tracer().trace(trace_id);
    let rows: Vec<Json> = spans.iter().map(span_json).collect();
    Json::obj(vec![
        ("trace_id", Json::Num(trace_id as f64)),
        ("n_spans", Json::Num(rows.len() as f64)),
        ("truncated", Json::Bool(is_truncated(&spans))),
        ("spans", Json::Arr(rows)),
    ])
}

/// One trace exported as Chrome trace-event JSON
/// (`GET /debug/traces/export?id=N`) — save the body to a file and open it
/// in ui.perfetto.dev or `chrome://tracing`.
fn trace_export_json(trace_id: u64) -> Json {
    chrome_trace(&tracer().trace(trace_id))
}

/// Worst-N exemplars (`GET /debug/traces/slow`): the same requests ranked
/// by total latency and by worst decode gap — the two ways a request is
/// slow (took long overall vs. stalled mid-decode).
fn slow_traces_json() -> Json {
    fn row(s: &TraceSummary) -> Json {
        Json::obj(vec![
            ("trace_id", Json::Num(s.trace_id as f64)),
            ("total_ms", Json::Num(s.total_ms)),
            ("decode_gap_max_ms", Json::Num(s.decode_gap_max_ms)),
        ])
    }
    let (by_total, by_gap) = tracer().slow();
    Json::obj(vec![
        ("by_total_ms", Json::Arr(by_total.iter().map(row).collect())),
        (
            "by_decode_gap_ms",
            Json::Arr(by_gap.iter().map(row).collect()),
        ),
    ])
}

/// Route one request against the router (single replica or sharded).
/// Returns `(status, reason, content_type, body)`. The reactor intercepts
/// `POST /generate` before calling this (its dispatch is asynchronous);
/// the blocking front end lets the `/generate` arm below submit-and-wait.
pub fn route(router: &Router, req: &HttpRequest) -> (u16, &'static str, &'static str, String) {
    const JSON: &str = "application/json";
    let (path, query) = req
        .path
        .split_once('?')
        .map(|(p, q)| (p, q))
        .unwrap_or((req.path.as_str(), ""));
    match (req.method.as_str(), path) {
        ("GET", "/health") | ("GET", "/healthz") => {
            (200, "OK", JSON, r#"{"status":"ok"}"#.to_string())
        }
        ("GET", "/readyz") => {
            if router.is_draining() || router.is_shutdown() {
                (
                    503,
                    "Service Unavailable",
                    JSON,
                    r#"{"status":"draining"}"#.to_string(),
                )
            } else {
                (200, "OK", JSON, r#"{"status":"ready"}"#.to_string())
            }
        }
        ("POST", "/admin/drain") => {
            router.drain();
            (202, "Accepted", JSON, r#"{"status":"draining"}"#.to_string())
        }
        ("GET", "/metrics") => {
            if query_param(query, "format") == Some("prometheus") {
                (200, "OK", PROM_CONTENT_TYPE, router.metrics_prometheus())
            } else {
                (200, "OK", JSON, router.metrics_json().to_string_pretty())
            }
        }
        ("GET", "/alerts") => (200, "OK", JSON, router.alerts_json().to_string_pretty()),
        ("GET", "/debug/traces/slow") => {
            (200, "OK", JSON, slow_traces_json().to_string_pretty())
        }
        ("GET", "/debug/traces/export") => {
            match query_param(query, "id").and_then(|v| v.parse().ok()) {
                Some(id) => (200, "OK", JSON, trace_export_json(id).to_string_pretty()),
                None => (
                    400,
                    "Bad Request",
                    JSON,
                    r#"{"error":"missing or bad ?id=<trace_id>"}"#.to_string(),
                ),
            }
        }
        ("GET", "/debug/traces") => match query_param(query, "id").and_then(|v| v.parse().ok()) {
            Some(id) => (200, "OK", JSON, trace_json(id).to_string_pretty()),
            None => (
                400,
                "Bad Request",
                JSON,
                r#"{"error":"missing or bad ?id=<trace_id>"}"#.to_string(),
            ),
        },
        ("POST", "/generate") => {
            let t_parse = Instant::now();
            let parsed = Json::parse(&req.body)
                .map_err(|e| e.to_string())
                .and_then(|j| GenRequest::from_json(0, &j).map_err(|e| e.to_string()));
            let parse_ns = t_parse.elapsed().as_nanos() as u64;
            match parsed {
                Err(e) => (
                    400,
                    "Bad Request",
                    JSON,
                    Json::obj(vec![("error", Json::Str(e))]).to_string_compact(),
                ),
                // The parsed request is handed over whole so per-request
                // fields (deadline_ms, sampling) survive; the routed
                // coordinator assigns the id and the default deadline.
                Ok(r) => match router.submit_request_blocking(r) {
                    Ok(resp) => {
                        // The trace id only exists after submission, so the
                        // body-parse interval is attached post-hoc (top-
                        // level: it predates the root request span).
                        tracer().record_at(
                            resp.trace_id,
                            0,
                            "http_parse",
                            t_parse,
                            parse_ns,
                            &[],
                        );
                        let (status, reason) = generate_status(&resp);
                        (status, reason, JSON, resp.to_json().to_string_pretty())
                    }
                    Err(e) => (
                        503,
                        "Service Unavailable",
                        JSON,
                        Json::obj(vec![("error", Json::Str(e.to_string()))])
                            .to_string_compact(),
                    ),
                },
            }
        }
        _ => (
            404,
            "Not Found",
            JSON,
            r#"{"error":"not found"}"#.to_string(),
        ),
    }
}

/// One chunk of a `Transfer-Encoding: chunked` body.
fn write_chunk(stream: &mut TcpStream, data: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{}\r\n", data.len(), data)
}

/// Wait for the next stream event without ever trusting the scheduler to
/// still be alive: the blocking recv is bounded, scheduler exit is polled,
/// and a wait far past the request deadline gives up. `None` means no
/// event is coming — the caller synthesizes the terminal line.
fn next_stream_event(
    coord: &Arc<Coordinator>,
    rx: &Receiver<StreamEvent>,
    hard: Option<Instant>,
) -> Option<StreamEvent> {
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(ev) => return Some(ev),
            Err(RecvTimeoutError::Disconnected) => return None,
            Err(RecvTimeoutError::Timeout) => {
                if coord.scheduler_exited() {
                    // The exit sweep may have raced our timeout: drain the
                    // channel one last time before giving up.
                    return rx.try_recv().ok();
                }
                if hard.is_some_and(|h| Instant::now() >= h) {
                    return None;
                }
            }
        }
    }
}

/// Streaming `/generate`: chunked NDJSON, one line per committed token,
/// then the `"done": true` summary line and the terminating zero chunk.
/// A failed socket write means the client hung up: the request is cancelled
/// so the scheduler frees its KV blocks instead of decoding the rest of the
/// sequence for nobody (dropping `rx` doubles as a backstop — the
/// scheduler also cancels on its next failed token send). A dead scheduler
/// or a wait far past the deadline still produces exactly one `done` line
/// instead of a silently pinned connection thread.
fn stream_generate(router: &Router, stream: &mut TcpStream, r: GenRequest) {
    let deadline = r.deadline.or(router
        .replica(router.affinity_replica(&r.prompt))
        .default_deadline());
    let (replica, id, rx) = match router.submit_stream_request(r) {
        Ok(ok) => ok,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string_compact();
            let _ = stream.write_all(response(503, "Service Unavailable", &body).as_bytes());
            return;
        }
    };
    let coord = router.replica(replica);
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        coord.cancel(id);
        return;
    }
    let hard = deadline.map(|d| Instant::now() + d + Duration::from_secs(5));
    loop {
        let Some(ev) = next_stream_event(coord, &rx, hard) else {
            // No event is coming (scheduler gone, or long past deadline):
            // tear the request down and still close the stream with one
            // synthetic terminal line so the client never sees a
            // truncated-but-open response.
            coord.cancel(id);
            let done = StreamEvent::Done(GenResponse::terminal(id, "internal_error"));
            let _ = write_chunk(stream, &format!("{}\n", done.to_json().to_string_compact()));
            break;
        };
        if coord.engine().faults.should_fire(FaultPoint::StreamStall) {
            // Injected slow consumer: hold the event before writing so
            // chaos schedules exercise a stalled mid-stream client.
            std::thread::sleep(Duration::from_millis(50));
        }
        let done = matches!(ev, StreamEvent::Done(_));
        let line = format!("{}\n", ev.to_json().to_string_compact());
        if write_chunk(stream, &line).is_err() {
            coord.cancel(id);
            return;
        }
        let _ = stream.flush();
        if done {
            break;
        }
    }
    let _ = stream.write_all(b"0\r\n\r\n");
}

fn handle_conn(router: Arc<Router>, stream: TcpStream) {
    let peer = stream.peer_addr().ok();
    // A stalled client trips the read timeout (408) rather than pinning
    // this thread forever. Writes (streaming responses) are unaffected.
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut stream = stream;
    match parse_request(&mut reader) {
        Ok(req) => {
            if req.method == "POST" && req.path == "/generate" && req.body.contains("\"stream\"") {
                // Streaming requests bypass the buffered router: the
                // response is written incrementally as tokens commit. The
                // substring guard keeps plain requests on the single-parse
                // route() path.
                if let Ok(j) = Json::parse(&req.body) {
                    if let Ok(r) = GenRequest::from_json(0, &j) {
                        if r.stream {
                            stream_generate(&router, &mut stream, r);
                            crate::debug!(
                                "{:?} {} {} -> 200 (stream)",
                                peer,
                                req.method,
                                req.path
                            );
                            return;
                        }
                    }
                }
            }
            let (status, reason, content_type, body) = route(&router, &req);
            let _ =
                stream.write_all(response_typed(status, reason, content_type, &body).as_bytes());
            crate::debug!("{:?} {} {} -> {status}", peer, req.method, req.path);
        }
        Err(e) => {
            let (status, reason) = error_status(&e);
            let _ = stream.write_all(
                response(status, reason, &format!(r#"{{"error":"{e}"}}"#)).as_bytes(),
            );
            crate::debug!("{:?} parse error -> {status} ({e})", peer);
        }
    }
}

/// Serve one coordinator on `addr` with the blocking front end — the
/// pre-router compatibility entry point used throughout the tests and
/// examples. Equivalent to [`serve_blocking`] over [`Router::single`].
pub fn serve(
    coord: Arc<Coordinator>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    serve_blocking(Router::single(coord), addr, on_bound)
}

/// Serve on `addr` (e.g. "127.0.0.1:8077") with the legacy blocking front
/// end (`--frontend blocking`) until every replica shuts down. Returns the
/// bound local address via the callback before blocking (useful when
/// binding port 0).
///
/// The accept loop parks in `poll(2)` on the listener — a pending
/// connection wakes it immediately and an idle listener costs ~0 CPU —
/// and still notices shutdown within one poll timeout. Accepted sockets
/// run blocking on their own thread. On exit, in-flight connection
/// threads get a bounded grace period to flush their responses (a drain
/// must deliver every response already owed, not sever sockets mid-write).
pub fn serve_blocking(
    router: Arc<Router>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    use std::os::unix::io::AsRawFd;
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let live = Arc::new(AtomicUsize::new(0));
    loop {
        if router.is_shutdown() {
            break;
        }
        match listener.accept() {
            Ok((s, _)) => {
                let _ = s.set_nonblocking(false);
                let r = Arc::clone(&router);
                let live2 = Arc::clone(&live);
                live.fetch_add(1, Ordering::SeqCst);
                std::thread::spawn(move || {
                    handle_conn(r, s);
                    live2.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Park until a connection arrives (instant wakeup) or the
                // timeout elapses (bounds shutdown-detection latency).
                crate::server::reactor::wait_readable(listener.as_raw_fd(), 50);
            }
            Err(e) => {
                crate::warn_!("accept error: {e}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    let t0 = Instant::now();
    while live.load(Ordering::SeqCst) > 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(5));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_post() {
        let raw = "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\r\n{\"prompt\":\"ab\"}";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, "{\"prompt\":\"ab\"}");
    }

    #[test]
    fn parse_get_without_body() {
        let raw = "GET /health HTTP/1.1\r\n\r\n";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_giant_body() {
        let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 1 << 22);
        let err = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert!(matches!(err, ParseError::BodyTooLarge), "{err:?}");
        assert_eq!(error_status(&err).0, 413);
    }

    #[test]
    fn rejects_oversized_header_line() {
        // One header line far past the cap — and, crucially, one with NO
        // terminator at all: the cap must bound buffering, not wait for a
        // newline that never comes.
        let raw = format!("GET / HTTP/1.1\r\nX-Big: {}\r\n\r\n", "a".repeat(64 * 1024));
        let err = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert!(matches!(err, ParseError::HeadersTooLarge(_)), "{err:?}");
        assert_eq!(error_status(&err).0, 431);
        let unterminated = format!("GET / HTTP/1.1\r\nX-Big: {}", "a".repeat(10 * 1024 * 1024));
        let err = parse_request(&mut Cursor::new(unterminated.as_bytes())).unwrap_err();
        assert!(matches!(err, ParseError::HeadersTooLarge(_)), "{err:?}");
        // A giant request *line* is capped the same way.
        let raw = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(64 * 1024));
        let err = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert!(matches!(err, ParseError::HeadersTooLarge(_)), "{err:?}");
    }

    #[test]
    fn rejects_too_many_headers() {
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADER_COUNT + 1) {
            raw.push_str(&format!("X-H{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        let err = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert!(matches!(err, ParseError::HeadersTooLarge(_)), "{err:?}");
        assert_eq!(error_status(&err).0, 431);
        // Exactly at the cap still parses.
        let mut ok = String::from("GET / HTTP/1.1\r\n");
        for i in 0..MAX_HEADER_COUNT {
            ok.push_str(&format!("X-H{i}: v\r\n"));
        }
        ok.push_str("\r\n");
        assert!(parse_request(&mut Cursor::new(ok.as_bytes())).is_ok());
    }

    /// A reader that yields its bytes, then fails like an expired
    /// `SO_RCVTIMEO` (WouldBlock) — the stalled-client shape.
    struct StallingReader(Cursor<Vec<u8>>);

    impl std::io::Read for StallingReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.0.read(buf)?;
            if n == 0 {
                return Err(std::io::Error::from(std::io::ErrorKind::WouldBlock));
            }
            Ok(n)
        }
    }

    fn stalling(partial: &[u8]) -> std::io::BufReader<StallingReader> {
        std::io::BufReader::new(StallingReader(Cursor::new(partial.to_vec())))
    }

    #[test]
    fn stalled_client_maps_to_408() {
        // The client sends a partial request then goes silent: the read
        // times out and the parser reports Timeout, not a hang.
        let mut reader = stalling(b"POST /generate HTTP/1.1\r\nContent-Le");
        let err = parse_request(&mut reader).unwrap_err();
        assert!(matches!(err, ParseError::Timeout), "{err:?}");
        assert_eq!(error_status(&err), (408, "Request Timeout"));
        // Same for a declared body that never arrives.
        let mut reader = stalling(b"POST /g HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc");
        let err = parse_request(&mut reader).unwrap_err();
        assert!(matches!(err, ParseError::Timeout), "{err:?}");
    }

    #[test]
    fn response_has_content_length() {
        let r = response(200, "OK", "{}");
        assert!(r.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(r.contains("Content-Length: 2\r\n"));
        assert!(r.ends_with("{}"));
        assert!(!r.contains("Retry-After"), "only shed responses back off");
    }

    #[test]
    fn shed_responses_carry_retry_after() {
        let r = response(503, "Service Unavailable", "{}");
        assert!(r.contains("Retry-After: 1\r\n"));
    }

    #[test]
    fn query_params_split() {
        assert_eq!(
            query_param("format=prometheus", "format"),
            Some("prometheus")
        );
        assert_eq!(query_param("a=1&id=42", "id"), Some("42"));
        assert_eq!(query_param("", "id"), None);
        assert_eq!(query_param("id", "id"), None, "bare key has no value");
    }

    #[test]
    fn typed_response_content_type() {
        let r = response_typed(200, "OK", PROM_CONTENT_TYPE, "x 1\n");
        assert!(r.contains("Content-Type: text/plain; version=0.0.4"));
        assert!(r.ends_with("x 1\n"));
    }

    #[test]
    fn generate_status_maps_terminal_reasons() {
        let mk = |n_generated: usize, reason: &str| GenResponse {
            id: 1,
            text: String::new(),
            n_prompt_tokens: 0,
            n_generated,
            queue_ms: 0.0,
            total_ms: 0.0,
            density: 1.0,
            finish_reason: reason.to_string(),
            prefix_hit_tokens: 0,
            trace_id: 0,
        };
        assert_eq!(generate_status(&mk(0, "deadline_exceeded")).0, 504);
        assert_eq!(generate_status(&mk(0, "internal_error")).0, 500);
        assert_eq!(generate_status(&mk(0, "shed")).0, 503);
        assert_eq!(generate_status(&mk(0, "shutdown")).0, 503);
        assert_eq!(generate_status(&mk(0, "length")).0, 200);
        // Partial output is still a 200: the reason rides in the body.
        assert_eq!(generate_status(&mk(3, "deadline_exceeded")).0, 200);
    }
}
