//! Minimal HTTP/1.1 front end over std::net (no tokio in this environment).
//!
//! Routes:
//!   GET  /health            -> {"status": "ok"}
//!   GET  /metrics           -> serving metrics JSON
//!   POST /generate          -> {"prompt", "max_new"?, "temperature"?,
//!                               "speculative"?, "stream"?}
//!
//! `"stream": true` switches `/generate` to a chunked NDJSON response: one
//! `{"done":false,"index":i,"token":"..."}` line per accepted token as it
//! commits, then a final `{"done":true, ...}` summary line (the same
//! object the blocking path returns).
//!
//! One thread per connection; connections are closed after each response
//! (`Connection: close`), which keeps the parser honest and is plenty for a
//! reproduction-scale router.

use crate::server::coordinator::Coordinator;
use crate::server::request::{GenRequest, StreamEvent};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

/// A parsed HTTP request (just what the router needs).
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: String,
}

/// Parse one HTTP/1.1 request from a stream.
pub fn parse_request<R: BufRead>(reader: &mut R) -> anyhow::Result<HttpRequest> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("missing path"))?
        .to_string();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad content-length"))?;
            }
        }
    }
    if content_length > 1 << 20 {
        anyhow::bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(HttpRequest {
        method,
        path,
        body: String::from_utf8(body).map_err(|_| anyhow::anyhow!("non-utf8 body"))?,
    })
}

/// Serialize an HTTP response.
pub fn response(status: u16, reason: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Route one request against the coordinator.
pub fn route(coord: &Arc<Coordinator>, req: &HttpRequest) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => (200, "OK", r#"{"status":"ok"}"#.to_string()),
        ("GET", "/metrics") => (200, "OK", coord.metrics_json().to_string_pretty()),
        ("POST", "/generate") => {
            let parsed = Json::parse(&req.body)
                .map_err(|e| e.to_string())
                .and_then(|j| GenRequest::from_json(0, &j).map_err(|e| e.to_string()));
            match parsed {
                Err(e) => (
                    400,
                    "Bad Request",
                    Json::obj(vec![("error", Json::Str(e))]).to_string_compact(),
                ),
                Ok(r) => match coord.submit_blocking_opts(
                    &r.prompt,
                    r.max_new,
                    r.sampling,
                    r.speculative,
                ) {
                    Ok(resp) => (200, "OK", resp.to_json().to_string_pretty()),
                    Err(e) => (
                        503,
                        "Service Unavailable",
                        Json::obj(vec![("error", Json::Str(e.to_string()))])
                            .to_string_compact(),
                    ),
                },
            }
        }
        _ => (
            404,
            "Not Found",
            r#"{"error":"not found"}"#.to_string(),
        ),
    }
}

/// One chunk of a `Transfer-Encoding: chunked` body.
fn write_chunk(stream: &mut TcpStream, data: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{}\r\n", data.len(), data)
}

/// Streaming `/generate`: chunked NDJSON, one line per committed token,
/// then the `"done": true` summary line and the terminating zero chunk.
fn stream_generate(coord: &Arc<Coordinator>, stream: &mut TcpStream, r: &GenRequest) {
    let rx = match coord.submit_stream(&r.prompt, r.max_new, r.sampling, r.speculative) {
        Ok(rx) => rx,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string_compact();
            let _ = stream.write_all(response(503, "Service Unavailable", &body).as_bytes());
            return;
        }
    };
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        return; // client gone; the scheduler still completes the request
    }
    for ev in rx {
        let done = matches!(ev, StreamEvent::Done(_));
        let line = format!("{}\n", ev.to_json().to_string_compact());
        if write_chunk(stream, &line).is_err() {
            return;
        }
        let _ = stream.flush();
        if done {
            break;
        }
    }
    let _ = stream.write_all(b"0\r\n\r\n");
}

fn handle_conn(coord: Arc<Coordinator>, stream: TcpStream) {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut stream = stream;
    match parse_request(&mut reader) {
        Ok(req) => {
            if req.method == "POST" && req.path == "/generate" && req.body.contains("\"stream\"") {
                // Streaming requests bypass the buffered router: the
                // response is written incrementally as tokens commit. The
                // substring guard keeps plain requests on the single-parse
                // route() path.
                if let Ok(j) = Json::parse(&req.body) {
                    if let Ok(r) = GenRequest::from_json(0, &j) {
                        if r.stream {
                            stream_generate(&coord, &mut stream, &r);
                            crate::debug!(
                                "{:?} {} {} -> 200 (stream)",
                                peer,
                                req.method,
                                req.path
                            );
                            return;
                        }
                    }
                }
            }
            let (status, reason, body) = route(&coord, &req);
            let _ = stream.write_all(response(status, reason, &body).as_bytes());
            crate::debug!("{:?} {} {} -> {status}", peer, req.method, req.path);
        }
        Err(e) => {
            let _ = stream.write_all(
                response(400, "Bad Request", &format!(r#"{{"error":"{e}"}}"#)).as_bytes(),
            );
        }
    }
}

/// Serve forever on `addr` (e.g. "127.0.0.1:8077"). Returns the bound local
/// address via the callback before blocking (useful when binding port 0).
pub fn serve(
    coord: Arc<Coordinator>,
    addr: &str,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_bound(listener.local_addr()?);
    for stream in listener.incoming() {
        if coord.is_shutdown() {
            break;
        }
        match stream {
            Ok(s) => {
                let c = Arc::clone(&coord);
                std::thread::spawn(move || handle_conn(c, s));
            }
            Err(e) => crate::warn_!("accept error: {e}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_post() {
        let raw = "POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\r\n{\"prompt\":\"ab\"}";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, "{\"prompt\":\"ab\"}");
    }

    #[test]
    fn parse_get_without_body() {
        let raw = "GET /health HTTP/1.1\r\n\r\n";
        let req = parse_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_giant_body() {
        let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 1 << 22);
        assert!(parse_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    #[test]
    fn response_has_content_length() {
        let r = response(200, "OK", "{}");
        assert!(r.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(r.contains("Content-Length: 2\r\n"));
        assert!(r.ends_with("{}"));
    }
}
