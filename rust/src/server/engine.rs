//! The sparse inference engine: sequence state + batched decode steps.
//!
//! Implements the paper's serving policy (Sec 5.1): only the second half of
//! prefill tokens run sparse, all decode tokens run sparse. Sequences carry
//! their own KV view and scratch; a decode step runs every active sequence
//! through one token, distributed over threads — each sequence's mask is
//! computed independently (the "per-sequence sparsity pattern" case the
//! paper's limitation section raises).
//!
//! KV storage is either the flat per-sequence slab (`Engine::new`, the
//! baseline) or pages from a shared [`KvManager`] pool (`Engine::paged` /
//! `Engine::with_kv`): page tables replace the `[max_seq, d_model]` buffers,
//! prompts sharing a cached prefix skip both dense and sparse prefill
//! compute for the shared tokens, and pool exhaustion surfaces as a
//! `cache_full` finish or a scheduler preemption instead of a panic.

use crate::data::corpus::{detokenize, tokenize};
use crate::kv::{KvCfg, KvManager, KvSeq, PagedSeq};
use crate::model::kv_cache::KvCache;
use crate::model::sampler::Sampling;
use crate::model::transformer::{ForwardStats, Model, Scratch};
use crate::sparsity::{Dense, Sparsifier};
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_slices;
use std::sync::Arc;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineCfg {
    /// Fraction of prefill tokens (the trailing part) run sparse (paper: 0.5).
    pub prefill_sparse_fraction: f64,
    /// Threads for batch-level decode (sequences per step). Single-sequence
    /// decode additionally uses kernel-level intra-GEMV parallelism budgeted
    /// from `WISPARSE_THREADS`; inside batched steps that budget is scoped
    /// to 1 per worker (`with_intra_op_threads`), so the two levels never
    /// multiply.
    pub threads: usize,
    pub seed: u64,
}

impl Default for EngineCfg {
    fn default() -> Self {
        Self {
            prefill_sparse_fraction: 0.5,
            threads: crate::util::threadpool::num_threads(),
            seed: 0xD_EC0DE,
        }
    }
}

/// Why a sequence stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated the requested `max_new` tokens.
    Length,
    /// Ran out of KV storage (context window or block pool) before
    /// `max_new` — previously indistinguishable from completing.
    CacheFull,
    /// Was preempted for pool pressure, resumed later, and completed.
    PreemptedResumed,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::CacheFull => "cache_full",
            FinishReason::PreemptedResumed => "preempted->resumed",
        }
    }
}

/// A sequence's KV storage: flat slab (baseline engines) or pooled pages.
pub enum SeqKv {
    Flat(KvCache),
    Paged(PagedSeq),
}

impl SeqKv {
    pub fn as_dyn(&mut self) -> &mut dyn KvSeq {
        match self {
            SeqKv::Flat(c) => c,
            SeqKv::Paged(p) => p,
        }
    }

    pub fn seq_len(&self) -> usize {
        match self {
            SeqKv::Flat(c) => c.len,
            SeqKv::Paged(p) => p.seq_len(),
        }
    }

    pub fn is_full(&self) -> bool {
        match self {
            SeqKv::Flat(c) => c.is_full(),
            SeqKv::Paged(p) => KvSeq::is_full(p),
        }
    }
}

/// One in-flight sequence.
pub struct SeqState {
    pub id: u64,
    pub prompt_tokens: Vec<usize>,
    pub generated: Vec<usize>,
    pub max_new: usize,
    pub sampling: Sampling,
    pub kv: SeqKv,
    scratch: Scratch,
    last_logits: Vec<f32>,
    pub stats: ForwardStats,
    rng: Pcg64,
    prefilled: bool,
    /// Prompt tokens served from the prefix cache (skipped in prefill).
    pub prefix_hit_tokens: usize,
    /// Set when the sequence was preempted and re-admitted.
    pub resumed: bool,
    finish_override: Option<FinishReason>,
}

impl SeqState {
    pub fn finished(&self) -> bool {
        self.finish_override.is_some()
            || self.generated.len() >= self.max_new
            || self.kv.is_full()
    }

    /// Why this (finished) sequence stopped.
    pub fn finish_reason(&self) -> FinishReason {
        if let Some(r) = self.finish_override {
            return r;
        }
        if self.generated.len() >= self.max_new {
            if self.resumed {
                FinishReason::PreemptedResumed
            } else {
                FinishReason::Length
            }
        } else {
            FinishReason::CacheFull
        }
    }

    pub fn text(&self) -> String {
        detokenize(&self.generated)
    }
}

/// The engine: shared model + sparse policy (+ optional paged-KV manager).
pub struct Engine {
    pub model: Arc<Model>,
    pub sparsifier: Arc<dyn Sparsifier>,
    pub cfg: EngineCfg,
    /// Paged-KV manager; `None` runs the flat per-sequence slabs.
    pub kv: Option<Arc<KvManager>>,
}

impl Engine {
    pub fn new(model: Arc<Model>, sparsifier: Arc<dyn Sparsifier>, cfg: EngineCfg) -> Self {
        Self {
            model,
            sparsifier,
            cfg,
            kv: None,
        }
    }

    /// Engine backed by an existing paged-KV manager.
    pub fn with_kv(
        model: Arc<Model>,
        sparsifier: Arc<dyn Sparsifier>,
        cfg: EngineCfg,
        kv: Arc<KvManager>,
    ) -> Self {
        Self {
            model,
            sparsifier,
            cfg,
            kv: Some(kv),
        }
    }

    /// Engine with a fresh paged-KV pool built from `kv_cfg`.
    pub fn paged(
        model: Arc<Model>,
        sparsifier: Arc<dyn Sparsifier>,
        cfg: EngineCfg,
        kv_cfg: &KvCfg,
    ) -> Self {
        let mgr = KvManager::new(&model.cfg, kv_cfg);
        Self::with_kv(model, sparsifier, cfg, mgr)
    }

    /// Dense-executing engine (the 0%-sparsity baseline).
    pub fn dense(model: Arc<Model>, cfg: EngineCfg) -> Self {
        Self::new(model, Arc::new(Dense), cfg)
    }

    /// Create sequence state for a prompt (tokenized, truncated to fit the
    /// context window with room for generation). Paged engines adopt any
    /// cached prefix blocks here; `prefill` then computes only the suffix.
    pub fn admit(&self, id: u64, prompt: &str, max_new: usize, sampling: Sampling) -> SeqState {
        let mut tokens = tokenize(prompt);
        let keep = self.truncated_prompt_len(tokens.len(), max_new);
        if tokens.len() > keep {
            tokens.drain(..tokens.len() - keep);
        }
        let (kv, hit) = match &self.kv {
            Some(mgr) => {
                let (seq, hit) = mgr.acquire(&tokens);
                (SeqKv::Paged(seq), hit)
            }
            None => (SeqKv::Flat(KvCache::new(&self.model.cfg)), 0),
        };
        SeqState {
            id,
            prompt_tokens: tokens,
            // Preallocated so steady-state decode never grows it.
            generated: Vec::with_capacity(max_new),
            max_new,
            sampling,
            kv,
            scratch: Scratch::new(&self.model.cfg),
            last_logits: Vec::new(),
            stats: ForwardStats::default(),
            rng: Pcg64::with_stream(self.cfg.seed, id),
            prefilled: false,
            prefix_hit_tokens: hit,
            resumed: false,
            finish_override: None,
        }
    }

    /// Prompt length `admit` keeps after context-window truncation — the
    /// single source of truth shared with admission headroom checks.
    fn truncated_prompt_len(&self, prompt_tokens: usize, max_new: usize) -> usize {
        let budget = self.model.cfg.max_seq.saturating_sub(max_new.max(1));
        if prompt_tokens > budget {
            budget.max(1)
        } else {
            prompt_tokens
        }
    }

    /// Worst-case token footprint of a request (prompt after truncation plus
    /// generation budget) — what block-aware admission reserves against.
    pub fn worst_case_tokens(&self, prompt: &str, max_new: usize) -> usize {
        // The byte tokenizer maps one byte to one token (`tokenize` is
        // `s.bytes()`), so `prompt.len()` equals the pre-truncation count.
        let ptok = self.truncated_prompt_len(prompt.len(), max_new);
        (ptok + max_new).min(self.model.cfg.max_seq)
    }

    /// Ensure the sequence can store one more token, evicting cached
    /// prefixes when the pool is dry. False means pool exhaustion (paged)
    /// or a full context window.
    pub fn reserve_seq(&self, seq: &mut SeqState) -> bool {
        match (&self.kv, &mut seq.kv) {
            (Some(mgr), SeqKv::Paged(p)) => mgr.try_reserve(p),
            (_, SeqKv::Flat(c)) => !c.is_full(),
            (None, SeqKv::Paged(p)) => p.try_reserve(),
        }
    }

    /// Prefill one sequence (paper policy: leading fraction dense, trailing
    /// fraction sparse). Tokens covered by a prefix-cache hit are skipped
    /// entirely — their K/V pages are already resident and shared. After a
    /// successful prefill the prompt's full blocks are published to the
    /// prefix cache.
    pub fn prefill(&self, seq: &mut SeqState) {
        assert!(!seq.prefilled);
        let n = seq.prompt_tokens.len();
        let start = seq.kv.seq_len();
        debug_assert_eq!(start, seq.prefix_hit_tokens);
        let dense_upto = ((1.0 - self.cfg.prefill_sparse_fraction) * n as f64).floor() as usize;
        for i in start..n {
            if !self.reserve_seq(seq) {
                seq.finish_override = Some(FinishReason::CacheFull);
                break;
            }
            let tok = seq.prompt_tokens[i];
            let sp: &dyn Sparsifier = if i < dense_upto {
                &Dense
            } else {
                self.sparsifier.as_ref()
            };
            self.model.forward_token(
                tok,
                seq.kv.as_dyn(),
                sp,
                &mut seq.scratch,
                &mut seq.stats,
                &mut seq.last_logits,
            );
        }
        seq.prefilled = true;
        if seq.finish_override.is_none() {
            if let (Some(mgr), SeqKv::Paged(p)) = (&self.kv, &seq.kv) {
                mgr.insert_prefix(&seq.prompt_tokens, p);
            }
        }
    }

    /// One decode step for a single sequence (assumes prefilled). Steady
    /// state performs no heap allocations on the projection/attention path:
    /// logits, residual, scratch and the kernel index buffers are all
    /// reused. (Projections big enough to take the intra-GEMV row-split —
    /// beyond `PAR_MIN_MACS` kept MACs — fork scoped threads, which is the
    /// one remaining allocation source on very large models.)
    pub fn decode_one(&self, seq: &mut SeqState) {
        debug_assert!(seq.prefilled && !seq.finished());
        let next = seq.sampling.sample(&seq.last_logits, &mut seq.rng);
        seq.generated.push(next);
        if seq.finished() {
            return;
        }
        if !self.reserve_seq(seq) {
            // Pool exhausted and nothing evictable: stop early rather than
            // panic. The coordinator avoids this by preempting before the
            // step; standalone engine users see a `cache_full` finish.
            seq.finish_override = Some(FinishReason::CacheFull);
            return;
        }
        self.model.forward_token(
            next,
            seq.kv.as_dyn(),
            self.sparsifier.as_ref(),
            &mut seq.scratch,
            &mut seq.stats,
            &mut seq.last_logits,
        );
    }

    /// One decode step across a batch of sequences, parallel over
    /// sequences. Finished sequences are filtered out before the split so
    /// chunks stay balanced even when completions cluster.
    pub fn step_batch(&self, seqs: &mut [SeqState]) {
        let mut active: Vec<&mut SeqState> =
            seqs.iter_mut().filter(|s| !s.finished()).collect();
        self.step_slots(&mut active[..]);
    }

    /// One decode step over a set of sequence slots — the shared policy
    /// behind [`Engine::step_batch`] and the serving coordinator: single-
    /// sequence fast path, then disjoint contiguous chunks of slots per
    /// worker (split_at_mut under the hood, kernel thread budget pinned to
    /// 1 per worker by `parallel_slices`), so there is no per-sequence lock
    /// to take. Finished slots are skipped defensively.
    pub fn step_slots(&self, slots: &mut [&mut SeqState]) {
        if slots.is_empty() {
            return;
        }
        let threads = self.cfg.threads.min(slots.len());
        if threads <= 1 {
            for seq in slots.iter_mut() {
                if !seq.finished() {
                    self.decode_one(&mut **seq);
                }
            }
            return;
        }
        parallel_slices(slots, threads, |_, _, chunk| {
            for seq in chunk.iter_mut() {
                if !seq.finished() {
                    self.decode_one(&mut **seq);
                }
            }
        });
    }

    /// Run a prompt to completion (prefill + decode loop). Returns the
    /// generated text and the sequence's forward stats.
    pub fn run_to_completion(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> (String, ForwardStats) {
        let mut seq = self.admit(0, prompt, max_new, sampling);
        self.prefill(&mut seq);
        while !seq.finished() {
            self.decode_one(&mut seq);
        }
        (seq.text(), seq.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};

    fn engine(sparse_tau: Option<f32>) -> Engine {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
        let sp: Arc<dyn Sparsifier> = match sparse_tau {
            None => Arc::new(Dense),
            Some(tau) => Arc::new(ScoredSparsifier::new(
                "teal",
                (0..model.cfg.n_layers * 7)
                    .map(|_| ScoredLayer { ga: None, tau })
                    .collect(),
            )),
        };
        Engine::new(model, sp, EngineCfg {
            threads: 2,
            ..EngineCfg::default()
        })
    }

    #[test]
    fn generates_requested_tokens() {
        let e = engine(None);
        let (text, stats) = e.run_to_completion("hello ", 10, Sampling::Greedy);
        assert_eq!(text.len(), 10);
        assert_eq!(stats.tokens as usize, 6 + 9); // prefill 6 + 9 decode fwd
    }

    #[test]
    fn batch_step_equals_sequential() {
        let e = engine(Some(0.3));
        let prompts = ["abc", "12+34=", "the sun "];
        // Sequential reference.
        let mut expected = Vec::new();
        for p in prompts {
            let (text, _) = e.run_to_completion(p, 6, Sampling::Greedy);
            expected.push(text);
        }
        // Batched.
        let mut seqs: Vec<SeqState> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| e.admit(i as u64, p, 6, Sampling::Greedy))
            .collect();
        for s in seqs.iter_mut() {
            e.prefill(s);
        }
        while seqs.iter().any(|s| !s.finished()) {
            e.step_batch(&mut seqs);
        }
        for (s, exp) in seqs.iter().zip(&expected) {
            assert_eq!(&s.text(), exp, "batched decode diverged");
        }
    }

    #[test]
    fn sparse_engine_reports_lower_density() {
        let dense_e = engine(None);
        let sparse_e = engine(Some(0.5));
        let (_, ds) = dense_e.run_to_completion("abcdef", 8, Sampling::Greedy);
        let (_, ss) = sparse_e.run_to_completion("abcdef", 8, Sampling::Greedy);
        assert!((ds.density() - 1.0).abs() < 1e-12);
        assert!(ss.density() < 1.0);
    }

    #[test]
    fn prompt_truncated_to_context() {
        let e = engine(None);
        let long_prompt: String = "x".repeat(1000);
        let seq = e.admit(0, &long_prompt, 16, Sampling::Greedy);
        assert!(seq.prompt_tokens.len() + 16 <= e.model.cfg.max_seq);
        assert_eq!(
            e.worst_case_tokens(&long_prompt, 16),
            seq.prompt_tokens.len() + 16
        );
    }

    #[test]
    fn prefill_mixes_dense_and_sparse() {
        // With fraction 0.5 and an aggressive tau, the first half of prefill
        // runs dense: density must sit strictly between all-sparse and 1.0.
        let e = engine(Some(10.0)); // tau so high sparse keeps ~nothing
        let mut seq = e.admit(0, "abcdefgh", 4, Sampling::Greedy);
        e.prefill(&mut seq);
        let d = seq.stats.density();
        assert!(d > 0.05 && d < 0.95, "density {d}");
    }

    #[test]
    fn finish_reason_length_vs_cache_full() {
        // Flat engine completing normally reports `length`.
        let e = engine(None);
        let mut seq = e.admit(0, "abc", 4, Sampling::Greedy);
        e.prefill(&mut seq);
        while !seq.finished() {
            e.decode_one(&mut seq);
        }
        assert_eq!(seq.finish_reason(), FinishReason::Length);

        // A paged engine with a starved pool stops early with `cache_full`.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
        let pe = Engine::paged(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 1,
                ..EngineCfg::default()
            },
            &KvCfg {
                pool_blocks: 2,
                block_size: 4,
                prefix_cache: true,
            },
        );
        let mut seq = pe.admit(0, "abcd", 32, Sampling::Greedy);
        pe.prefill(&mut seq);
        while !seq.finished() {
            pe.decode_one(&mut seq);
        }
        assert_eq!(seq.finish_reason(), FinishReason::CacheFull);
        assert!(
            seq.generated.len() < 32,
            "pool of 8 positions cannot satisfy max_new=32"
        );
    }
}
