//! The sparse inference engine: sequence state + batched decode steps.
//!
//! Implements the paper's serving policy (Sec 5.1): only the second half of
//! prefill tokens run sparse, all decode tokens run sparse. Sequences carry
//! their own KV view and scratch; a decode step runs every active sequence
//! through one token, distributed over threads — each sequence's mask is
//! computed independently (the "per-sequence sparsity pattern" case the
//! paper's limitation section raises).
//!
//! KV storage is either the flat per-sequence slab (`Engine::new`, the
//! baseline) or pages from a shared [`KvManager`] pool (`Engine::paged` /
//! `Engine::with_kv`): page tables replace the `[max_seq, d_model]` buffers,
//! prompts sharing a cached prefix skip both dense and sparse prefill
//! compute for the shared tokens, and pool exhaustion surfaces as a
//! `cache_full` finish or a scheduler preemption instead of a panic.

use crate::data::corpus::{detokenize, tokenize};
use crate::kv::{KvCfg, KvManager, KvSeq, PagedSeq};
use crate::model::kv_cache::KvCache;
use crate::model::sampler::{residual_sample, sample_from, spec_accept, Sampling};
use crate::model::transformer::{
    ChunkLogits, ForwardStats, FusedScratch, FusedSeqAccess, Model, Scratch,
};
use crate::eval::kl::kl_from_logits;
use crate::obs::{top2_margin, tracer, with_shadow_ctx, QualityObs};
use crate::server::faults::{FaultPoint, Faults};
use crate::sparsity::{Dense, Sparsifier};
use crate::tensor::ops::argmax;
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_slices;
use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineCfg {
    /// Fraction of prefill tokens (the trailing part) run sparse (paper: 0.5).
    pub prefill_sparse_fraction: f64,
    /// Token budget per prefill chunk (`--prefill-chunk`). Each chunk runs
    /// layer-major through [`Model::forward_chunk_mixed`], so weights stream
    /// from memory once per chunk instead of once per prompt token, and the
    /// serving scheduler interleaves decode steps between chunks.
    pub prefill_chunk: usize,
    /// Threads for batch-level decode (sequences per step). Single-sequence
    /// decode additionally uses kernel-level intra-GEMV parallelism budgeted
    /// from `WISPARSE_THREADS`; inside batched steps that budget is scoped
    /// to 1 per worker (`with_intra_op_threads`), so the two levels never
    /// multiply.
    pub threads: usize,
    pub seed: u64,
    /// Fuse multi-sequence decode steps into one layer-major pass
    /// ([`Model::forward_fused`]): the batch's weights stream from memory
    /// once per step under the union of the per-sequence masks, instead of
    /// once per sequence (`--fused-batch`). Bit-identical to the
    /// per-sequence path; batches of one fall back to it automatically.
    pub fused_batch: bool,
    /// Fraction of committed decode steps replayed dense by the online
    /// quality monitor (`--quality-sample-rate`). 0 disables shadow
    /// sampling entirely; a sampled step costs one extra dense forward but
    /// never perturbs the served token, KV or RNG.
    pub quality_sample_rate: f64,
    /// KL(dense‖sparse) in nats above which a shadow sample counts as a
    /// quality breach (feeds the `shadow_kl` SLO burn rate).
    pub shadow_kl_ceiling: f64,
}

impl Default for EngineCfg {
    fn default() -> Self {
        Self {
            prefill_sparse_fraction: 0.5,
            prefill_chunk: 64,
            threads: crate::util::threadpool::num_threads(),
            seed: 0xD_EC0DE,
            fused_batch: true,
            quality_sample_rate: 0.0,
            shadow_kl_ceiling: 0.05,
        }
    }
}

/// Why a sequence stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated the requested `max_new` tokens.
    Length,
    /// Ran out of KV storage (context window or block pool) before
    /// `max_new` — previously indistinguishable from completing.
    CacheFull,
    /// Was preempted for pool pressure, resumed later, and completed.
    PreemptedResumed,
    /// Ran past its per-request deadline mid-decode; the response carries
    /// whatever was generated before the cutoff.
    DeadlineExceeded,
    /// The sequence's step panicked (caught by the scheduler's isolation);
    /// its KV blocks were released and only this request failed.
    InternalError,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::CacheFull => "cache_full",
            FinishReason::PreemptedResumed => "preempted->resumed",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
            FinishReason::InternalError => "internal_error",
        }
    }
}

/// A sequence's KV storage: flat slab (baseline engines) or pooled pages.
pub enum SeqKv {
    Flat(KvCache),
    Paged(PagedSeq),
}

impl SeqKv {
    pub fn as_dyn(&mut self) -> &mut dyn KvSeq {
        match self {
            SeqKv::Flat(c) => c,
            SeqKv::Paged(p) => p,
        }
    }

    /// Read-only [`KvSeq`] view — what the shadow-dense quality replay
    /// forwards against, so non-mutation of the served KV is enforced at
    /// the type level rather than by convention.
    pub fn as_dyn_ref(&self) -> &dyn KvSeq {
        match self {
            SeqKv::Flat(c) => c,
            SeqKv::Paged(p) => p,
        }
    }

    pub fn seq_len(&self) -> usize {
        match self {
            SeqKv::Flat(c) => c.len,
            SeqKv::Paged(p) => p.seq_len(),
        }
    }

    pub fn is_full(&self) -> bool {
        match self {
            SeqKv::Flat(c) => c.is_full(),
            SeqKv::Paged(p) => KvSeq::is_full(p),
        }
    }
}

/// Per-sequence speculative-decoding state: acceptance counters driving the
/// adaptive draft length, plus the reusable round buffers. `cur_k == 0`
/// means the sequence decodes normally (speculative and plain sequences
/// coexist in one batch).
#[derive(Default)]
pub struct SpecState {
    /// Draft-chain length for the next round, counting the free first token
    /// (the production-quality decision already in `last_logits`). 0 = not
    /// speculative.
    pub cur_k: usize,
    /// Speculative rounds run (each = one draft pass + one verify chunk).
    pub rounds: u64,
    /// Draft tokens proposed beyond the free first token.
    pub drafted: u64,
    /// Of those, accepted by the production-sparsity verify pass.
    pub accepted: u64,
    /// Draft token chain scratch ([0] is the free first token).
    chain: Vec<usize>,
    /// Draft-pass logits for the token being drafted (reused per step).
    qstep: Vec<f32>,
    /// Draft distributions `q_i`, row-major `[m-1, vocab]` (temperature
    /// sampling only; greedy needs no accept arithmetic).
    draft_probs: Vec<f32>,
    /// Verify-chunk logits, row-major `[m, vocab]`.
    chunk_logits: Vec<f32>,
    /// Target-distribution scratch for the accept/residual math.
    pbuf: Vec<f32>,
    /// Chain length of the round in flight between the draft phase and the
    /// verify/accept phase (fused steps split the round around the shared
    /// forward pass).
    fused_m: usize,
    /// KV length at the start of the in-flight round's chain.
    fused_l0: usize,
}

impl SpecState {
    /// Fraction of proposed draft tokens the verifier accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.drafted as f64
    }
}

/// Progress of a sequence's chunked prefill (the chunk cursor lives here so
/// the scheduler can interleave decode steps between a prompt's chunks).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefillState {
    /// Next prompt index to compute. Jumps to the prefix-cache hit length
    /// when the first chunk adopts cached blocks, and always equals
    /// `kv.seq_len()` until prefill completes.
    pub cursor: usize,
    /// Chunks run so far (metrics/fairness accounting).
    pub chunks: u64,
    /// Whether the one-shot prefix-cache match has run (deferred from
    /// admission to the first chunk, so prompts admitted in the same batch
    /// can still share a prefix a batch-mate publishes first).
    matched: bool,
}

/// Outcome of one [`Engine::prefill_chunk`] call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillStep {
    /// Computed this many prompt tokens; more chunks remain.
    Advanced(usize),
    /// Computed this many prompt tokens and finished the prompt: the
    /// sequence is ready to decode (`last_logits` seeded, prefix published).
    Completed(usize),
    /// KV backing (pool or context window) exhausted before a single token
    /// of the chunk could be reserved. The caller decides between
    /// preemption (scheduler) and a terminal `cache_full` (standalone).
    PoolDry,
}

/// Per-sequence tracing context. Engine spans (prefill chunks, decode
/// steps, speculative rounds, KV events) record under `trace`/`root`; the
/// serving coordinator overwrites both at admission with the request's
/// globally-unique trace id and pre-allocated root span, so standalone
/// engine use just produces locally-scoped traces keyed by the sequence id.
#[derive(Clone, Copy, Debug, Default)]
pub struct SeqObs {
    /// Trace id all of this sequence's spans carry.
    pub trace: u64,
    /// Parent span id for engine spans (0 = no parent).
    pub root: u64,
    /// Tracer-epoch timestamp of the previous step's end (gap tracking).
    prev_step_end_ns: u64,
    /// Largest observed between-step gap — the per-request decode-gap
    /// exemplar metric.
    pub max_gap_ns: u64,
}

/// One in-flight sequence.
pub struct SeqState {
    pub id: u64,
    pub prompt_tokens: Vec<usize>,
    pub generated: Vec<usize>,
    pub max_new: usize,
    pub sampling: Sampling,
    pub kv: SeqKv,
    scratch: Scratch,
    last_logits: Vec<f32>,
    pub stats: ForwardStats,
    rng: Pcg64,
    prefilled: bool,
    /// Chunked-prefill progress (cursor + chunk count).
    pub prefill: PrefillState,
    /// Prompt tokens served from the prefix cache (skipped in prefill).
    pub prefix_hit_tokens: usize,
    /// Set when the sequence was preempted and re-admitted.
    pub resumed: bool,
    /// Speculative-decoding state (inert unless a [`SpecEngine`] armed it).
    pub spec: SpecState,
    /// Tracing context (trace id, root span, decode-gap tracking).
    pub obs: SeqObs,
    finish_override: Option<FinishReason>,
    /// Set while the sequence participates in the current fused/supervised
    /// batch step; the step's gap sweep clears it and charges the gap
    /// against the *batch* window, so time spent decoding batch-mates in
    /// the same step never counts as this sequence's idle gap.
    stepped_in_batch: bool,
    /// Deterministic shadow-sampling counter: incremented once per decode
    /// step (or speculative round); every `QualityObs::period`-th step is
    /// replayed dense. Per-sequence so runs are reproducible regardless of
    /// batch composition or thread scheduling.
    quality_ctr: u64,
}

impl SeqState {
    pub fn finished(&self) -> bool {
        self.finish_override.is_some()
            || self.generated.len() >= self.max_new
            || self.kv.is_full()
    }

    /// Whether the whole prompt has been prefilled (a mid-prompt
    /// `cache_full` abort leaves this false — the sequence is terminal but
    /// must never enter a decode step).
    pub fn prefill_complete(&self) -> bool {
        self.prefilled
    }

    /// Terminally finish the sequence with an explicit reason (scheduler-
    /// side aborts, e.g. mid-prompt pool exhaustion with nobody left to
    /// preempt). Idempotent; the first reason wins.
    pub fn abort(&mut self, reason: FinishReason) {
        self.finish_override.get_or_insert(reason);
    }

    /// Why this (finished) sequence stopped.
    pub fn finish_reason(&self) -> FinishReason {
        if let Some(r) = self.finish_override {
            return r;
        }
        if self.generated.len() >= self.max_new {
            if self.resumed {
                FinishReason::PreemptedResumed
            } else {
                FinishReason::Length
            }
        } else {
            FinishReason::CacheFull
        }
    }

    pub fn text(&self) -> String {
        detokenize(&self.generated)
    }

    /// Update decode-gap tracking around a step spanning
    /// `[start_ns, end_ns]` (tracer-epoch offsets): the gap is the idle
    /// time between the previous step's end and this step's start.
    fn note_step_gap(&mut self, start_ns: u64, end_ns: u64) {
        if self.obs.prev_step_end_ns > 0 && start_ns > self.obs.prev_step_end_ns {
            let gap = start_ns - self.obs.prev_step_end_ns;
            if gap > self.obs.max_gap_ns {
                self.obs.max_gap_ns = gap;
            }
        }
        self.obs.prev_step_end_ns = end_ns;
    }
}

/// Uniform mutable access to a step's sequence slots, so the fused decode
/// step runs unchanged over an owned batch (`&mut [SeqState]`, the
/// `step_batch` path) and over the coordinator's borrowed slot views
/// (`&mut [&mut SeqState]`).
pub trait SlotsMut {
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn get(&self, i: usize) -> &SeqState;
    fn get_mut(&mut self, i: usize) -> &mut SeqState;
}

impl SlotsMut for [SeqState] {
    fn len(&self) -> usize {
        <[SeqState]>::len(self)
    }
    fn get(&self, i: usize) -> &SeqState {
        &self[i]
    }
    fn get_mut(&mut self, i: usize) -> &mut SeqState {
        &mut self[i]
    }
}

impl<'s> SlotsMut for [&'s mut SeqState] {
    fn len(&self) -> usize {
        <[&'s mut SeqState]>::len(self)
    }
    fn get(&self, i: usize) -> &SeqState {
        &*self[i]
    }
    fn get_mut(&mut self, i: usize) -> &mut SeqState {
        &mut *self[i]
    }
}

/// How a batch member participates in a fused step: one plain decode token,
/// or a speculative verify chain.
#[derive(Clone, Copy, PartialEq, Eq)]
enum FusedMode {
    Plain,
    Spec,
}

/// Per-thread fused-step context: the member index/token lists and the
/// shared forward scratch, reused across steps so steady-state fused decode
/// allocates nothing at the batch layer.
#[derive(Default)]
struct FusedCtx {
    idx: Vec<usize>,
    toks: Vec<usize>,
    mode: Vec<FusedMode>,
    scratch: FusedScratch,
}

thread_local! {
    static FUSED_CTX: RefCell<FusedCtx> = RefCell::new(FusedCtx::default());
}

/// [`FusedSeqAccess`] view of a plain decode batch: every member contributes
/// exactly one sampled token and wants only that token's logits.
struct DecodeBatch<'a, S: SlotsMut + ?Sized> {
    slots: &'a mut S,
    idx: &'a [usize],
    toks: &'a [usize],
}

impl<S: SlotsMut + ?Sized> FusedSeqAccess for DecodeBatch<'_, S> {
    fn n_seqs(&self) -> usize {
        self.idx.len()
    }
    fn tokens(&self, i: usize) -> &[usize] {
        &self.toks[i..i + 1]
    }
    fn want(&self, _i: usize) -> ChunkLogits {
        ChunkLogits::LastOnly
    }
    fn cache(&mut self, i: usize) -> &mut dyn KvSeq {
        self.slots.get_mut(self.idx[i]).kv.as_dyn()
    }
    fn stats(&mut self, i: usize) -> &mut ForwardStats {
        &mut self.slots.get_mut(self.idx[i]).stats
    }
    fn logits(&mut self, i: usize) -> &mut Vec<f32> {
        &mut self.slots.get_mut(self.idx[i]).last_logits
    }
}

/// [`FusedSeqAccess`] view of a mixed speculative/plain batch: speculative
/// members verify their whole draft chain (per-token logits into the spec
/// verify buffer), plain members decode one token.
struct SpecBatch<'a, S: SlotsMut + ?Sized> {
    slots: &'a mut S,
    idx: &'a [usize],
    mode: &'a [FusedMode],
    toks: &'a [usize],
}

impl<S: SlotsMut + ?Sized> FusedSeqAccess for SpecBatch<'_, S> {
    fn n_seqs(&self) -> usize {
        self.idx.len()
    }
    fn tokens(&self, i: usize) -> &[usize] {
        match self.mode[i] {
            FusedMode::Plain => &self.toks[i..i + 1],
            FusedMode::Spec => {
                let seq = self.slots.get(self.idx[i]);
                &seq.spec.chain[..seq.spec.fused_m]
            }
        }
    }
    fn want(&self, i: usize) -> ChunkLogits {
        match self.mode[i] {
            FusedMode::Plain => ChunkLogits::LastOnly,
            FusedMode::Spec => ChunkLogits::PerToken,
        }
    }
    fn cache(&mut self, i: usize) -> &mut dyn KvSeq {
        self.slots.get_mut(self.idx[i]).kv.as_dyn()
    }
    fn stats(&mut self, i: usize) -> &mut ForwardStats {
        &mut self.slots.get_mut(self.idx[i]).stats
    }
    fn logits(&mut self, i: usize) -> &mut Vec<f32> {
        let seq = self.slots.get_mut(self.idx[i]);
        match self.mode[i] {
            FusedMode::Plain => &mut seq.last_logits,
            FusedMode::Spec => &mut seq.spec.chunk_logits,
        }
    }
}

/// The engine: shared model + sparse policy (+ optional paged-KV manager).
pub struct Engine {
    pub model: Arc<Model>,
    pub sparsifier: Arc<dyn Sparsifier>,
    pub cfg: EngineCfg,
    /// Paged-KV manager; `None` runs the flat per-sequence slabs.
    pub kv: Option<Arc<KvManager>>,
    /// Deterministic fault-injection plan. Inert in production (one false
    /// branch per site) unless `WISPARSE_FAULTS` carries a schedule; the
    /// chaos suite swaps in scripted plans per engine instance.
    pub faults: Arc<Faults>,
    /// Online shadow-dense quality monitor; `None` (the default, rate 0)
    /// costs one branch per decode step.
    pub quality: Option<Arc<QualityObs>>,
}

/// Build the quality monitor the engine configuration asks for (rate 0
/// disables it outright — no counter bump, no sampling branch beyond the
/// `Option` check).
fn quality_from_cfg(cfg: &EngineCfg) -> Option<Arc<QualityObs>> {
    if cfg.quality_sample_rate > 0.0 {
        Some(Arc::new(QualityObs::new(
            cfg.quality_sample_rate,
            cfg.shadow_kl_ceiling,
        )))
    } else {
        None
    }
}

impl Engine {
    pub fn new(model: Arc<Model>, sparsifier: Arc<dyn Sparsifier>, cfg: EngineCfg) -> Self {
        let quality = quality_from_cfg(&cfg);
        Self {
            model,
            sparsifier,
            cfg,
            kv: None,
            faults: Faults::from_env(),
            quality,
        }
    }

    /// Engine backed by an existing paged-KV manager.
    pub fn with_kv(
        model: Arc<Model>,
        sparsifier: Arc<dyn Sparsifier>,
        cfg: EngineCfg,
        kv: Arc<KvManager>,
    ) -> Self {
        let quality = quality_from_cfg(&cfg);
        Self {
            model,
            sparsifier,
            cfg,
            kv: Some(kv),
            faults: Faults::from_env(),
            quality,
        }
    }

    /// Engine with a fresh paged-KV pool built from `kv_cfg`.
    pub fn paged(
        model: Arc<Model>,
        sparsifier: Arc<dyn Sparsifier>,
        cfg: EngineCfg,
        kv_cfg: &KvCfg,
    ) -> Self {
        let mgr = KvManager::new(&model.cfg, kv_cfg);
        Self::with_kv(model, sparsifier, cfg, mgr)
    }

    /// Dense-executing engine (the 0%-sparsity baseline).
    pub fn dense(model: Arc<Model>, cfg: EngineCfg) -> Self {
        Self::new(model, Arc::new(Dense), cfg)
    }

    /// Create sequence state for a prompt (tokenized, truncated to fit the
    /// context window with room for generation). Prefix-cache matching is
    /// deferred to the first prefill chunk (see
    /// [`Engine::adopt_cached_prefix`]), so prompts admitted in one batch
    /// still share a prefix a batch-mate's prefill publishes first.
    pub fn admit(&self, id: u64, prompt: &str, max_new: usize, sampling: Sampling) -> SeqState {
        let mut tokens = tokenize(prompt);
        let keep = self.truncated_prompt_len(tokens.len(), max_new);
        if tokens.len() > keep {
            tokens.drain(..tokens.len() - keep);
        }
        let kv = match &self.kv {
            Some(mgr) => SeqKv::Paged(mgr.new_seq()),
            None => SeqKv::Flat(KvCache::new(&self.model.cfg)),
        };
        SeqState {
            id,
            prompt_tokens: tokens,
            // Preallocated so steady-state decode never grows it.
            generated: Vec::with_capacity(max_new),
            max_new,
            sampling,
            kv,
            scratch: Scratch::new(&self.model.cfg),
            last_logits: Vec::new(),
            stats: ForwardStats::default(),
            rng: Pcg64::with_stream(self.cfg.seed, id),
            prefilled: false,
            prefill: PrefillState::default(),
            prefix_hit_tokens: 0,
            resumed: false,
            spec: SpecState::default(),
            obs: SeqObs {
                trace: id,
                ..SeqObs::default()
            },
            finish_override: None,
            stepped_in_batch: false,
            quality_ctr: 0,
        }
    }

    /// One-shot prefix-cache adoption, run by the first prefill chunk (or
    /// the sequential reference path). Matching is schedule-aware: only
    /// cached KV whose producer ran the same dense/sparse positions this
    /// prompt's own prefill would run is adopted, so hit and miss logits
    /// are bit-identical.
    fn adopt_cached_prefix(&self, seq: &mut SeqState) {
        if seq.prefill.matched {
            return;
        }
        seq.prefill.matched = true;
        let n = seq.prompt_tokens.len();
        if let (Some(mgr), SeqKv::Paged(p)) = (&self.kv, &mut seq.kv) {
            debug_assert_eq!(p.seq_len(), 0, "prefix adoption on a started sequence");
            let hit = mgr.adopt_cached_prefix(p, &seq.prompt_tokens, self.schedule_tag(n));
            seq.prefix_hit_tokens = hit;
            seq.prefill.cursor = hit;
            if hit > 0 {
                let mut span = tracer().start(seq.obs.trace, seq.obs.root, "kv_prefix_hit");
                span.attr("tokens", hit as f64);
            }
        }
    }

    /// First prompt position run sparse under the paper's prefill policy
    /// (positions below this run dense).
    pub fn dense_upto(&self, prompt_len: usize) -> usize {
        ((1.0 - self.cfg.prefill_sparse_fraction) * prompt_len as f64).floor() as usize
    }

    /// Schedule tag for prefix-cache consistency: the dense→sparse boundary
    /// this engine's prefill would use for a prompt of `prompt_len` tokens.
    /// A dense-executing engine runs every position identically, so its KV
    /// is valid under any boundary — tagged `usize::MAX` (always dense) so
    /// prompts of different lengths keep sharing prefixes.
    pub fn schedule_tag(&self, prompt_len: usize) -> usize {
        if self.sparsifier.name() == "dense" {
            usize::MAX
        } else {
            self.dense_upto(prompt_len)
        }
    }

    /// Prompt length `admit` keeps after context-window truncation — the
    /// single source of truth shared with admission headroom checks.
    fn truncated_prompt_len(&self, prompt_tokens: usize, max_new: usize) -> usize {
        let budget = self.model.cfg.max_seq.saturating_sub(max_new.max(1));
        if prompt_tokens > budget {
            budget.max(1)
        } else {
            prompt_tokens
        }
    }

    /// Worst-case token footprint of a request (prompt after truncation plus
    /// generation budget) — what block-aware admission reserves against.
    pub fn worst_case_tokens(&self, prompt: &str, max_new: usize) -> usize {
        // The byte tokenizer maps one byte to one token (`tokenize` is
        // `s.bytes()`), so `prompt.len()` equals the pre-truncation count.
        let ptok = self.truncated_prompt_len(prompt.len(), max_new);
        (ptok + max_new).min(self.model.cfg.max_seq)
    }

    /// Ensure the sequence can store one more token, evicting cached
    /// prefixes when the pool is dry. False means pool exhaustion (paged)
    /// or a full context window.
    pub fn reserve_seq(&self, seq: &mut SeqState) -> bool {
        if self.faults.should_fire(FaultPoint::PoolDry) {
            return false;
        }
        match (&self.kv, &mut seq.kv) {
            (Some(mgr), SeqKv::Paged(p)) => mgr.try_reserve(p),
            (_, SeqKv::Flat(c)) => !c.is_full(),
            (None, SeqKv::Paged(p)) => p.try_reserve(),
        }
    }

    /// Make room for the sequence's next `n` positions without advancing it
    /// (speculative rounds reserve their whole draft-plus-verify footprint
    /// up front). Paged engines allocate tail blocks, evicting cached
    /// prefixes under pressure; flat caches are bounded by the context
    /// window. Returns how many of the `n` positions are covered.
    pub fn reserve_ahead(&self, seq: &mut SeqState, n: usize) -> usize {
        if self.faults.should_fire(FaultPoint::PoolDry) {
            return 0;
        }
        match (&self.kv, &mut seq.kv) {
            (Some(mgr), SeqKv::Paged(p)) => mgr.reserve_ahead(p, n),
            (_, SeqKv::Flat(c)) => n.min(c.max_seq.saturating_sub(c.len)),
            (None, SeqKv::Paged(p)) => p.reserve_ahead(n),
        }
    }

    /// Roll the sequence's KV back to `new_len` positions, releasing whole
    /// rejected blocks. On the managed paged path this also invalidates any
    /// prefix-cache entry overlapping the rolled-back tail, so later prefix
    /// hits can never serve rejected-token KV.
    pub fn rollback_seq(&self, seq: &mut SeqState, new_len: usize) {
        match (&self.kv, &mut seq.kv) {
            (Some(mgr), SeqKv::Paged(p)) => mgr.rollback(p, new_len),
            (_, SeqKv::Flat(c)) => c.truncate(new_len),
            (None, SeqKv::Paged(p)) => p.truncate(new_len),
        }
    }

    /// Prefill one sequence (paper policy: leading fraction dense, trailing
    /// fraction sparse), as a sequence of layer-major chunks of at most
    /// `cfg.prefill_chunk` tokens — bit-identical to the token-by-token
    /// schedule ([`Engine::prefill_sequential`]) but streaming every
    /// layer's weights once per *chunk*. Tokens covered by a prefix-cache
    /// hit are skipped entirely. Pool exhaustion mid-prompt is a terminal
    /// `cache_full` here (the serving scheduler instead preempts and
    /// retries); the sequence then stays `!prefill_complete()` and must not
    /// decode.
    pub fn prefill(&self, seq: &mut SeqState) {
        assert!(!seq.prefilled);
        while !seq.prefilled && seq.finish_override.is_none() {
            if self.prefill_chunk(seq, self.cfg.prefill_chunk) == PrefillStep::PoolDry {
                seq.finish_override = Some(FinishReason::CacheFull);
            }
        }
    }

    /// Run one chunk (at most `budget` tokens, at least 1) of `seq`'s
    /// pending prefill through [`Model::forward_chunk_mixed`]. KV for the
    /// whole chunk is reserved up front via [`Engine::reserve_ahead`]; when
    /// the pool can only back part of the chunk the chunk shrinks, and when
    /// it can back none of it [`PrefillStep::PoolDry`] is returned with the
    /// sequence untouched. The final chunk computes only the last prompt
    /// token's logits (they seed decoding; interior positions skip the
    /// lm_head entirely) and publishes the prompt's full blocks — with the
    /// engine's schedule tag — to the prefix cache. Publication therefore
    /// only ever happens after the *full* prompt has committed.
    pub fn prefill_chunk(&self, seq: &mut SeqState, budget: usize) -> PrefillStep {
        assert!(!seq.prefilled, "prefill_chunk on a prefilled sequence");
        debug_assert!(seq.finish_override.is_none());
        let mut span = tracer().start(seq.obs.trace, seq.obs.root, "prefill_chunk");
        self.faults.maybe_panic(FaultPoint::PrefillPanic);
        self.adopt_cached_prefix(seq);
        let n = seq.prompt_tokens.len();
        let cur = seq.prefill.cursor;
        span.attr("cursor", cur as f64);
        debug_assert_eq!(cur, seq.kv.seq_len());
        if cur >= n {
            // Empty prompt (nothing to forward): complete immediately, as
            // the pre-chunking token-by-token loop did.
            seq.prefilled = true;
            return PrefillStep::Completed(0);
        }
        let want = budget.max(1).min(n - cur);
        let got = self.reserve_ahead(seq, want);
        if got == 0 {
            span.attr("pool_dry", 1.0);
            return PrefillStep::PoolDry;
        }
        let m = want.min(got);
        span.attr("tokens", m as f64);
        let last = cur + m == n;
        self.model.forward_chunk_mixed(
            &seq.prompt_tokens[cur..cur + m],
            seq.kv.as_dyn(),
            &Dense,
            self.sparsifier.as_ref(),
            self.dense_upto(n),
            if last {
                ChunkLogits::LastOnly
            } else {
                ChunkLogits::Skip
            },
            &mut seq.scratch,
            &mut seq.stats,
            &mut seq.last_logits,
        );
        seq.prefill.cursor += m;
        seq.prefill.chunks += 1;
        if !last {
            return PrefillStep::Advanced(m);
        }
        seq.prefilled = true;
        if let (Some(mgr), SeqKv::Paged(p)) = (&self.kv, &seq.kv) {
            mgr.insert_prefix_scheduled(&seq.prompt_tokens, p, self.schedule_tag(n));
        }
        PrefillStep::Completed(m)
    }

    /// Token-by-token prefill — the pre-chunking reference path, kept for
    /// the differential equivalence tests and the `BENCH_prefill.json` A/B.
    /// Same per-position dense/sparse schedule, same terminal
    /// partial-prefill semantics as [`Engine::prefill`].
    pub fn prefill_sequential(&self, seq: &mut SeqState) {
        assert!(!seq.prefilled);
        self.adopt_cached_prefix(seq);
        let n = seq.prompt_tokens.len();
        debug_assert_eq!(seq.prefill.cursor, seq.kv.seq_len());
        let dense_upto = self.dense_upto(n);
        for i in seq.prefill.cursor..n {
            if !self.reserve_seq(seq) {
                seq.finish_override = Some(FinishReason::CacheFull);
                return;
            }
            let tok = seq.prompt_tokens[i];
            let sp: &dyn Sparsifier = if i < dense_upto {
                &Dense
            } else {
                self.sparsifier.as_ref()
            };
            self.model.forward_token(
                tok,
                seq.kv.as_dyn(),
                sp,
                &mut seq.scratch,
                &mut seq.stats,
                &mut seq.last_logits,
            );
            seq.prefill.cursor = i + 1;
        }
        seq.prefilled = true;
        if let (Some(mgr), SeqKv::Paged(p)) = (&self.kv, &seq.kv) {
            mgr.insert_prefix_scheduled(&seq.prompt_tokens, p, self.schedule_tag(n));
        }
    }

    /// Final logits of the last prefilled/decoded position — the
    /// distribution the next decode step samples from (test/bench hook for
    /// the chunked-vs-sequential bit-equality assertions).
    pub fn last_logits<'a>(&self, seq: &'a SeqState) -> &'a [f32] {
        &seq.last_logits
    }

    /// One decode step for a single sequence (assumes prefilled). Steady
    /// state performs no heap allocations on the projection/attention path:
    /// logits, residual, scratch and the kernel index buffers are all
    /// reused. (Projections big enough to take the intra-GEMV row-split —
    /// beyond `PAR_MIN_MACS` kept MACs — fork scoped threads, which is the
    /// one remaining allocation source on very large models.)
    pub fn decode_one(&self, seq: &mut SeqState) {
        debug_assert!(seq.prefilled && !seq.finished());
        let t = tracer();
        let step_start_ns = t.now_ns();
        self.decode_one_inner(seq);
        seq.note_step_gap(step_start_ns, t.now_ns());
    }

    /// `decode_one` without the gap bookkeeping — the batched/supervised
    /// steps measure the gap against the whole batch window instead.
    fn decode_one_inner(&self, seq: &mut SeqState) {
        if let Some(next) = self.fused_phase_a_plain(seq) {
            self.model.forward_token(
                next,
                seq.kv.as_dyn(),
                self.sparsifier.as_ref(),
                &mut seq.scratch,
                &mut seq.stats,
                &mut seq.last_logits,
            );
            self.maybe_shadow_sample(seq, next);
        }
    }

    /// Shadow-dense quality hook, run after a decode forward has committed
    /// `token`'s KV row and `seq.last_logits` holds the served
    /// distribution. Every `period`-th step of each sequence is replayed
    /// dense ([`Model::forward_shadow`]) against the *same* residual and
    /// committed KV — the cache is handed over read-only and the RNG is
    /// never touched, so the served output is bit-identical with sampling
    /// on or off (pinned by `rust/tests/quality_shadow.rs`). The extra
    /// dense forward is the entire cost: at the default 1-in-100 rate it
    /// is ~1–2% of decode throughput.
    fn maybe_shadow_sample(&self, seq: &mut SeqState, token: usize) {
        let Some(q) = &self.quality else { return };
        seq.quality_ctr += 1;
        if seq.quality_ctr % q.period() != 0 {
            return;
        }
        let mut span = tracer().start(seq.obs.trace, seq.obs.root, "shadow_sample");
        with_shadow_ctx(|ctx| {
            self.model.forward_shadow(
                token,
                seq.kv.as_dyn_ref(),
                self.sparsifier.as_ref(),
                &mut seq.scratch,
                &mut ctx.recon,
                &mut ctx.logits,
            );
            let kl = kl_from_logits(&ctx.logits, &seq.last_logits);
            let agree = argmax(&ctx.logits) == argmax(&seq.last_logits);
            span.attr("kl", kl);
            q.record_sample(kl, agree, top2_margin(&seq.last_logits));
        });
    }

    /// The sequential half of a plain decode step: sample the next token
    /// from `last_logits`, commit it, and reserve KV for its forward pass.
    /// Returns the token to forward, or `None` when the sequence finished
    /// (length reached, or `cache_full`) without needing a forward.
    ///
    /// Span + gap tracking are allocation-free (preallocated ring, fixed
    /// attrs): the steady-state zero-alloc invariant still holds.
    fn fused_phase_a_plain(&self, seq: &mut SeqState) -> Option<usize> {
        let t = tracer();
        let mut span = t.start(seq.obs.trace, seq.obs.root, "decode_step");
        span.attr("pos", seq.kv.seq_len() as f64);
        self.faults.maybe_panic(FaultPoint::DecodePanic);
        let next = seq.sampling.sample(&seq.last_logits, &mut seq.rng);
        seq.generated.push(next);
        if seq.finished() {
            return None;
        }
        if !self.reserve_seq(seq) {
            // Pool exhausted and nothing evictable: stop early rather than
            // panic. The coordinator avoids this by preempting before the
            // step; standalone engine users see a `cache_full` finish.
            seq.finish_override = Some(FinishReason::CacheFull);
            span.attr("cache_full", 1.0);
            return None;
        }
        Some(next)
    }

    /// One decode step across a batch of sequences. With `cfg.fused_batch`
    /// (the default) the step runs batch-fused: every member samples and
    /// reserves sequentially, then one [`Model::forward_fused`] pass streams
    /// each layer's weights once for the whole batch. Without it, the step
    /// falls back to per-sequence decode parallel over sequences. Neither
    /// path allocates per step: the fused member lists live in reusable
    /// thread-local scratch, and the per-sequence path iterates the slots
    /// in place instead of collecting the active subset.
    pub fn step_batch(&self, seqs: &mut [SeqState]) {
        if self.cfg.fused_batch {
            self.step_fused(seqs);
            return;
        }
        let threads = self.cfg.threads.min(seqs.len());
        if threads <= 1 {
            for seq in seqs.iter_mut() {
                if !seq.finished() && seq.prefill_complete() {
                    self.decode_one(seq);
                }
            }
            return;
        }
        parallel_slices(seqs, threads, |_, _, chunk| {
            for seq in chunk.iter_mut() {
                if !seq.finished() && seq.prefill_complete() {
                    self.decode_one(seq);
                }
            }
        });
    }

    /// One batch-fused decode step over the step's slots. Three phases:
    /// (A) per sequence, sample + commit the next token and reserve its KV
    /// slot — panics here abort only that member; (B) one shared
    /// [`Model::forward_fused`] pass over everything still standing (a
    /// single survivor takes the plain `forward_token` path instead, where
    /// fusion has nothing to amortize); (C) charge every participant's
    /// decode gap against the batch window, so time spent decoding
    /// batch-mates never inflates `decode_gap_ms_p95`.
    pub(crate) fn step_fused<S: SlotsMut + ?Sized>(&self, slots: &mut S) {
        let t = tracer();
        let batch_start_ns = t.now_ns();
        FUSED_CTX.with(|cell| {
            let ctx = &mut *cell.borrow_mut();
            let FusedCtx {
                idx, toks, scratch, ..
            } = ctx;
            idx.clear();
            toks.clear();
            for s in 0..slots.len() {
                let seq = slots.get_mut(s);
                if seq.finished() || !seq.prefill_complete() {
                    continue;
                }
                seq.stepped_in_batch = true;
                match catch_unwind(AssertUnwindSafe(|| self.fused_phase_a_plain(seq))) {
                    Ok(Some(next)) => {
                        idx.push(s);
                        toks.push(next);
                    }
                    Ok(None) => {}
                    Err(_) => seq.abort(FinishReason::InternalError),
                }
            }
            if idx.len() == 1 {
                let seq = slots.get_mut(idx[0]);
                let next = toks[0];
                let r = catch_unwind(AssertUnwindSafe(|| {
                    self.model.forward_token(
                        next,
                        seq.kv.as_dyn(),
                        self.sparsifier.as_ref(),
                        &mut seq.scratch,
                        &mut seq.stats,
                        &mut seq.last_logits,
                    );
                }));
                if r.is_err() {
                    seq.abort(FinishReason::InternalError);
                } else {
                    self.maybe_shadow_sample(seq, next);
                }
            } else if idx.len() > 1 {
                let mut batch = DecodeBatch {
                    slots: &mut *slots,
                    idx: &idx[..],
                    toks: &toks[..],
                };
                let r = catch_unwind(AssertUnwindSafe(|| {
                    self.model
                        .forward_fused(&mut batch, self.sparsifier.as_ref(), scratch);
                }));
                if r.is_err() {
                    // A panic mid-fused-pass leaves every member's KV/logits
                    // in an unknown state: the whole batch fails together.
                    for &s in idx.iter() {
                        slots.get_mut(s).abort(FinishReason::InternalError);
                    }
                } else {
                    for (j, &s) in idx.iter().enumerate() {
                        self.maybe_shadow_sample(slots.get_mut(s), toks[j]);
                    }
                }
            }
        });
        let end_ns = t.now_ns();
        for s in 0..slots.len() {
            let seq = slots.get_mut(s);
            if seq.stepped_in_batch {
                seq.stepped_in_batch = false;
                seq.note_step_gap(batch_start_ns, end_ns);
            }
        }
    }

    /// One supervised decode step over the coordinator's slot views:
    /// fused when configured, otherwise per-sequence with the same
    /// per-member panic isolation and batch-window gap attribution.
    pub fn step_slots_supervised(&self, slots: &mut [&mut SeqState]) {
        if slots.is_empty() {
            return;
        }
        if self.cfg.fused_batch {
            self.step_fused(slots);
            return;
        }
        let t = tracer();
        let batch_start_ns = t.now_ns();
        self.step_slots_with(slots, |seq| {
            seq.stepped_in_batch = true;
            if catch_unwind(AssertUnwindSafe(|| self.decode_one_inner(seq))).is_err() {
                seq.abort(FinishReason::InternalError);
            }
        });
        let end_ns = t.now_ns();
        for seq in slots.iter_mut() {
            if seq.stepped_in_batch {
                seq.stepped_in_batch = false;
                seq.note_step_gap(batch_start_ns, end_ns);
            }
        }
    }

    /// One decode step over a set of sequence slots — the shared policy
    /// behind [`Engine::step_batch`] and the serving coordinator.
    pub fn step_slots(&self, slots: &mut [&mut SeqState]) {
        self.step_slots_with(slots, |seq| self.decode_one(seq));
    }

    /// The slot-scheduling policy itself, shared with [`SpecEngine`]:
    /// single-thread fast path, then disjoint contiguous chunks of slots
    /// per worker (split_at_mut under the hood, kernel thread budget pinned
    /// to 1 per worker by `parallel_slices`), so there is no per-sequence
    /// lock to take. Finished slots are skipped defensively; `step` runs
    /// once per unfinished slot.
    pub fn step_slots_with(
        &self,
        slots: &mut [&mut SeqState],
        step: impl Fn(&mut SeqState) + Sync,
    ) {
        if slots.is_empty() {
            return;
        }
        let threads = self.cfg.threads.min(slots.len());
        if threads <= 1 {
            for seq in slots.iter_mut() {
                if !seq.finished() {
                    step(&mut **seq);
                }
            }
            return;
        }
        parallel_slices(slots, threads, |_, _, chunk| {
            for seq in chunk.iter_mut() {
                if !seq.finished() {
                    step(&mut **seq);
                }
            }
        });
    }

    /// Run a prompt to completion (prefill + decode loop). Returns the
    /// generated text and the sequence's forward stats.
    pub fn run_to_completion(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> (String, ForwardStats) {
        let mut seq = self.admit(0, prompt, max_new, sampling);
        self.prefill(&mut seq);
        while !seq.finished() {
            self.decode_one(&mut seq);
        }
        (seq.text(), seq.stats)
    }
}

/// Speculative-decoding configuration (the `wisparse serve --speculative`
/// knobs).
#[derive(Clone, Debug)]
pub struct SpecCfg {
    /// Initial draft-chain length per round, counting the free first token.
    pub k: usize,
    /// Adaptive-k floor.
    pub min_k: usize,
    /// Adaptive-k ceiling (also the cap on the verify chunk width).
    pub max_k: usize,
    /// Adapt each sequence's chain length to its observed acceptance: grow
    /// by one on a fully-accepted round, shrink to the accepted length on a
    /// rejection.
    pub adaptive: bool,
}

impl Default for SpecCfg {
    fn default() -> Self {
        Self {
            k: 4,
            min_k: 2,
            max_k: 12,
            adaptive: true,
        }
    }
}

/// Outcome of a speculative round's sequential first half
/// ([`SpecEngine::spec_phase_a`]).
enum SpecPhase {
    /// The chain is drafted and rewound: run the verify pass, then
    /// [`SpecEngine::spec_phase_c`].
    Verify,
    /// The sequence finished on the free first token; nothing to verify.
    Done,
    /// KV exhausted before the round could reserve its footprint.
    CacheFull,
}

/// Self-speculative decoding: the same weights at a high-sparsity
/// [`Sparsifier`] act as a free draft model for the production-sparsity
/// configuration. Each round drafts a chain of tokens sequentially at draft
/// sparsity, rolls the draft KV back, and re-scores the whole chain in one
/// layer-major verify chunk at production sparsity
/// ([`Model::forward_chunk`]) — weights stream once per chunk instead of
/// once per token, which is what makes decode latency scale with the
/// acceptance rate instead of the token count. Greedy acceptance keeps the
/// longest draft prefix matching the verifier's argmax, making speculative
/// output token-identical to baseline decode (the chunk pass is bit-exact
/// per token); temperature sampling uses standard rejection sampling
/// (accept with `min(1, p/q)`, correct from the residual), which preserves
/// the verifier's output distribution exactly.
///
/// Wraps a verify [`Engine`] (production sparsifier + KV manager), so flat
/// and paged KV, prefix sharing and block-aware admission all apply
/// unchanged. The per-round chain length is capped by the sequence's
/// remaining token budget, so the speculative KV peak — draft lookahead
/// included — never exceeds the baseline worst case that admission
/// reserved.
pub struct SpecEngine {
    /// The production engine: model, target sparsifier, KV manager.
    pub verify: Arc<Engine>,
    /// The high-sparsity draft configuration over the same weights.
    pub draft: Arc<dyn Sparsifier>,
    pub cfg: SpecCfg,
}

impl SpecEngine {
    pub fn new(verify: Arc<Engine>, draft: Arc<dyn Sparsifier>, cfg: SpecCfg) -> Self {
        assert!(cfg.k >= 1 && cfg.min_k >= 1 && cfg.max_k >= cfg.min_k);
        Self { verify, draft, cfg }
    }

    /// Arm a sequence for speculative decoding (idempotent; sequences left
    /// unarmed decode normally alongside speculative ones).
    pub fn init_seq(&self, seq: &mut SeqState) {
        seq.spec.cur_k = self.cfg.k.clamp(self.cfg.min_k, self.cfg.max_k);
    }

    /// Create and arm sequence state for a prompt.
    pub fn admit(&self, id: u64, prompt: &str, max_new: usize, sampling: Sampling) -> SeqState {
        let mut seq = self.verify.admit(id, prompt, max_new, sampling);
        self.init_seq(&mut seq);
        seq
    }

    /// Prefill runs on the verify engine unchanged (same paper policy, same
    /// prefix-cache publication) — speculation only touches decode.
    pub fn prefill(&self, seq: &mut SeqState) {
        self.verify.prefill(seq);
    }

    /// Worst-case token footprint for admission. Draft lookahead is already
    /// included: every round caps its chain at the remaining budget, so the
    /// speculative KV peak (prompt + committed + in-flight chain) never
    /// exceeds the baseline `prompt + max_new` reservation.
    pub fn worst_case_tokens(&self, prompt: &str, max_new: usize) -> usize {
        self.verify.worst_case_tokens(prompt, max_new)
    }

    /// One speculative round: draft up to `cur_k - 1` tokens beyond the
    /// free first token, verify the chain in one production-sparsity chunk,
    /// commit the accepted prefix and roll back the rest. Preserves
    /// `decode_one`'s invariants (every committed token's KV resident
    /// except a final unforwarded token, `last_logits` predicting the next
    /// position), so rounds and plain decode steps interleave freely.
    pub fn spec_round(&self, seq: &mut SeqState) {
        let t = tracer();
        let round_start_ns = t.now_ns();
        self.spec_round_inner(seq);
        seq.note_step_gap(round_start_ns, t.now_ns());
    }

    /// `spec_round` without the gap bookkeeping — the batched/supervised
    /// steps measure the gap against the whole batch window instead.
    fn spec_round_inner(&self, seq: &mut SeqState) {
        debug_assert!(seq.prefilled && !seq.finished());
        let t = tracer();
        let mut round = t.start(seq.obs.trace, seq.obs.root, "spec_round");
        self.verify.faults.maybe_panic(FaultPoint::DecodePanic);
        match self.spec_phase_a(seq, round.id()) {
            SpecPhase::Done => {}
            SpecPhase::CacheFull => round.attr("cache_full", 1.0),
            SpecPhase::Verify => {
                self.spec_verify_one(seq, round.id());
                let (m, a) = self.spec_phase_c(seq);
                round.attr("drafted", (m - 1) as f64);
                round.attr("accepted", (a - 1) as f64);
            }
        }
    }

    /// The sequential first half of a speculative round: commit the free
    /// first token, reserve the round's KV footprint, draft the chain at
    /// draft sparsity and rewind the draft KV. On [`SpecPhase::Verify`] the
    /// chain (`spec.chain[..spec.fused_m]`) is ready for a production-
    /// sparsity verify pass — standalone via [`SpecEngine::spec_verify_one`],
    /// batched via the shared fused forward.
    fn spec_phase_a(&self, seq: &mut SeqState, parent: u64) -> SpecPhase {
        let t = tracer();
        let model = &self.verify.model;
        let greedy = matches!(seq.sampling, Sampling::Greedy);

        // The free first token: the production-quality decision already in
        // `last_logits` — bitwise the token baseline decode would emit.
        let d1 = seq.sampling.sample(&seq.last_logits, &mut seq.rng);
        seq.generated.push(d1);
        if seq.finished() {
            return SpecPhase::Done; // hit max_new: committed unforwarded
        }

        // Chain length: capped by the remaining budget so the speculative
        // KV peak stays within the admission-time worst case, and by what
        // the pool can actually back right now.
        let rem = seq.max_new - seq.generated.len();
        let want = seq.spec.cur_k.clamp(1, self.cfg.max_k).min(rem + 1);
        let have = self.verify.reserve_ahead(seq, want);
        if have == 0 {
            seq.finish_override = Some(FinishReason::CacheFull);
            return SpecPhase::CacheFull;
        }
        let m = want.min(have);
        let l0 = seq.kv.seq_len();
        debug_assert!(
            l0 + m <= seq.prompt_tokens.len() + seq.max_new,
            "speculative lookahead exceeded the admission worst case"
        );
        seq.spec.rounds += 1;

        let mut chain = std::mem::take(&mut seq.spec.chain);
        let mut qall = std::mem::take(&mut seq.spec.draft_probs);
        let mut qstep = std::mem::take(&mut seq.spec.qstep);
        let mut pbuf = std::mem::take(&mut seq.spec.pbuf);
        chain.clear();
        chain.push(d1);
        qall.clear();

        // --- draft: m-1 sequential steps at draft sparsity ---
        {
            let mut draft_span = t.start(seq.obs.trace, parent, "spec_draft");
            draft_span.attr("tokens", (m - 1) as f64);
            for i in 1..m {
                let prev = chain[i - 1];
                model.forward_token(
                    prev,
                    seq.kv.as_dyn(),
                    self.draft.as_ref(),
                    &mut seq.scratch,
                    &mut seq.stats,
                    &mut qstep,
                );
                let next = if greedy {
                    argmax(&qstep)
                } else {
                    seq.sampling.probs_into(&qstep, &mut pbuf);
                    let d = sample_from(&pbuf, &mut seq.rng);
                    qall.extend_from_slice(&pbuf);
                    d
                };
                chain.push(next);
            }
        }
        seq.spec.drafted += (m - 1) as u64;

        // Rewind the draft KV (blocks retained — the verify pass rewrites
        // the same positions).
        seq.kv.as_dyn().rewind(l0);

        seq.spec.chain = chain;
        seq.spec.draft_probs = qall;
        seq.spec.qstep = qstep;
        seq.spec.pbuf = pbuf;
        seq.spec.fused_m = m;
        seq.spec.fused_l0 = l0;
        SpecPhase::Verify
    }

    /// Standalone verify pass for one round: re-score the drafted chain in
    /// one layer-major production chunk (the unfused counterpart of the
    /// shared fused forward).
    fn spec_verify_one(&self, seq: &mut SeqState, parent: u64) {
        let m = seq.spec.fused_m;
        let mut verify_span = tracer().start(seq.obs.trace, parent, "spec_verify");
        verify_span.attr("tokens", m as f64);
        let chain = std::mem::take(&mut seq.spec.chain);
        let mut vlog = std::mem::take(&mut seq.spec.chunk_logits);
        self.verify.model.forward_chunk(
            &chain[..m],
            seq.kv.as_dyn(),
            self.verify.sparsifier.as_ref(),
            &mut seq.scratch,
            &mut seq.stats,
            &mut vlog,
        );
        seq.spec.chain = chain;
        seq.spec.chunk_logits = vlog;
    }

    /// The sequential second half of a speculative round, after the verify
    /// logits landed in `spec.chunk_logits`: accept the longest matching
    /// prefix, roll back rejected positions, adopt the last accepted
    /// position's logits, forward the rejection-sampling correction and
    /// adapt the chain length. Returns `(m, a)` — chain length and accepted
    /// prefix length — for span attribution.
    fn spec_phase_c(&self, seq: &mut SeqState) -> (usize, usize) {
        let model = &self.verify.model;
        let vocab = model.cfg.vocab_size;
        let greedy = matches!(seq.sampling, Sampling::Greedy);
        let m = seq.spec.fused_m;
        let l0 = seq.spec.fused_l0;

        let chain = std::mem::take(&mut seq.spec.chain);
        let qall = std::mem::take(&mut seq.spec.draft_probs);
        let vlog = std::mem::take(&mut seq.spec.chunk_logits);
        let mut pbuf = std::mem::take(&mut seq.spec.pbuf);

        // --- accept the longest matching prefix ---
        let mut a = 1usize; // chain[0] came from production logits: committed
        let mut correction: Option<usize> = None;
        while a < m {
            let row = &vlog[(a - 1) * vocab..a * vocab];
            if greedy {
                if chain[a] == argmax(row) {
                    seq.generated.push(chain[a]);
                    a += 1;
                } else {
                    break; // next round's free token re-derives the argmax
                }
            } else {
                seq.sampling.probs_into(row, &mut pbuf);
                let q = &qall[(a - 1) * vocab..a * vocab];
                if spec_accept(&pbuf, q, chain[a], &mut seq.rng) {
                    seq.generated.push(chain[a]);
                    a += 1;
                } else {
                    correction = Some(residual_sample(&pbuf, q, &mut seq.rng));
                    break;
                }
            }
        }
        seq.spec.accepted += (a - 1) as u64;

        // --- commit: free rejected positions (prefix-cache entries
        // overlapping them are invalidated), adopt the last accepted
        // position's production logits ---
        if a < m {
            self.verify.rollback_seq(seq, l0 + a);
        }
        seq.last_logits.clear();
        seq.last_logits
            .extend_from_slice(&vlog[(a - 1) * vocab..a * vocab]);

        seq.spec.chain = chain;
        seq.spec.draft_probs = qall;
        seq.spec.chunk_logits = vlog;
        seq.spec.pbuf = pbuf;

        let mut forwarded_correction = None;
        if let Some(c) = correction {
            // Rejection sampling's residual draw is a committed token; it
            // must be forwarded now (production) to keep the invariants.
            seq.generated.push(c);
            if !seq.finished() {
                if self.verify.reserve_seq(seq) {
                    model.forward_token(
                        c,
                        seq.kv.as_dyn(),
                        self.verify.sparsifier.as_ref(),
                        &mut seq.scratch,
                        &mut seq.stats,
                        &mut seq.last_logits,
                    );
                    forwarded_correction = Some(c);
                } else {
                    seq.finish_override = Some(FinishReason::CacheFull);
                }
            }
        }

        if self.cfg.adaptive {
            seq.spec.cur_k = if a == m {
                (seq.spec.cur_k + 1).min(self.cfg.max_k)
            } else {
                a.clamp(self.cfg.min_k, self.cfg.max_k)
            };
        }

        // Shadow-dense quality sample, one opportunity per round: replay
        // the position whose forward produced `last_logits` — the
        // forwarded correction when there was one, else the last accepted
        // chain token (whose verify logits were adopted above). Both leave
        // that token's KV as the cache's final committed row, which is
        // exactly the state the read-only dense replay re-executes.
        let shadow_tok = forwarded_correction.unwrap_or(seq.spec.chain[a - 1]);
        self.verify.maybe_shadow_sample(seq, shadow_tok);

        (m, a)
    }

    /// One scheduling step over sequence slots: armed sequences run a full
    /// speculative round, unarmed ones a plain decode step — the chunked
    /// slot parallelism is [`Engine::step_slots_with`]'s, so speculative
    /// and normal sequences coexist in one batch.
    pub fn step_slots(&self, slots: &mut [&mut SeqState]) {
        self.verify.step_slots_with(slots, |seq| self.step_one(seq));
    }

    /// One scheduling step for one sequence: a speculative round when
    /// armed, a plain decode step otherwise. Public so the supervised
    /// coordinator can wrap exactly this unit of work in its per-sequence
    /// panic isolation.
    pub fn step_one(&self, seq: &mut SeqState) {
        if seq.spec.cur_k > 0 {
            self.spec_round(seq);
        } else {
            self.verify.decode_one(seq);
        }
    }

    /// `step_one` without the gap bookkeeping (batched/supervised steps
    /// charge the gap against the whole batch window).
    fn step_one_inner(&self, seq: &mut SeqState) {
        if seq.spec.cur_k > 0 {
            self.spec_round_inner(seq);
        } else {
            self.verify.decode_one_inner(seq);
        }
    }

    /// One batch-fused scheduling step: every member runs its sequential
    /// first half (plain sampling, or a full speculative draft), then one
    /// shared [`Model::forward_fused`] pass serves both the plain members'
    /// decode tokens and the speculative members' verify chains — weights
    /// stream once per step for the whole mixed batch — and the
    /// speculative members finish with their accept/commit phase.
    pub(crate) fn step_fused<S: SlotsMut + ?Sized>(&self, slots: &mut S) {
        let t = tracer();
        let batch_start_ns = t.now_ns();
        FUSED_CTX.with(|cell| {
            let ctx = &mut *cell.borrow_mut();
            let FusedCtx {
                idx,
                toks,
                mode,
                scratch,
            } = ctx;
            idx.clear();
            toks.clear();
            mode.clear();
            for s in 0..slots.len() {
                let seq = slots.get_mut(s);
                if seq.finished() || !seq.prefill_complete() {
                    continue;
                }
                seq.stepped_in_batch = true;
                if seq.spec.cur_k > 0 {
                    let root = seq.obs.root;
                    let r = catch_unwind(AssertUnwindSafe(|| {
                        self.verify.faults.maybe_panic(FaultPoint::DecodePanic);
                        self.spec_phase_a(seq, root)
                    }));
                    match r {
                        Ok(SpecPhase::Verify) => {
                            idx.push(s);
                            toks.push(0); // placeholder: chain carries the tokens
                            mode.push(FusedMode::Spec);
                        }
                        Ok(_) => {}
                        Err(_) => seq.abort(FinishReason::InternalError),
                    }
                } else {
                    match catch_unwind(AssertUnwindSafe(|| self.verify.fused_phase_a_plain(seq))) {
                        Ok(Some(next)) => {
                            idx.push(s);
                            toks.push(next);
                            mode.push(FusedMode::Plain);
                        }
                        Ok(None) => {}
                        Err(_) => seq.abort(FinishReason::InternalError),
                    }
                }
            }
            let mut forwarded = true;
            if idx.len() == 1 {
                let seq = slots.get_mut(idx[0]);
                let r = match mode[0] {
                    FusedMode::Plain => {
                        let next = toks[0];
                        catch_unwind(AssertUnwindSafe(|| {
                            self.verify.model.forward_token(
                                next,
                                seq.kv.as_dyn(),
                                self.verify.sparsifier.as_ref(),
                                &mut seq.scratch,
                                &mut seq.stats,
                                &mut seq.last_logits,
                            );
                        }))
                    }
                    FusedMode::Spec => {
                        let root = seq.obs.root;
                        catch_unwind(AssertUnwindSafe(|| {
                            self.spec_verify_one(seq, root);
                        }))
                    }
                };
                if r.is_err() {
                    seq.abort(FinishReason::InternalError);
                    forwarded = false;
                } else if mode[0] == FusedMode::Plain {
                    self.verify.maybe_shadow_sample(seq, toks[0]);
                }
            } else if idx.len() > 1 {
                let mut batch = SpecBatch {
                    slots: &mut *slots,
                    idx: &idx[..],
                    mode: &mode[..],
                    toks: &toks[..],
                };
                let r = catch_unwind(AssertUnwindSafe(|| {
                    self.verify.model.forward_fused(
                        &mut batch,
                        self.verify.sparsifier.as_ref(),
                        scratch,
                    );
                }));
                if r.is_err() {
                    // A panic mid-fused-pass leaves every member's KV/logits
                    // in an unknown state: the whole batch fails together.
                    for &s in idx.iter() {
                        slots.get_mut(s).abort(FinishReason::InternalError);
                    }
                    forwarded = false;
                }
            }
            if forwarded {
                for (j, &s) in idx.iter().enumerate() {
                    let seq = slots.get_mut(s);
                    match mode[j] {
                        // Plain members' shadow hook runs here, after the
                        // shared fused forward landed their logits; spec
                        // members sample inside `spec_phase_c`.
                        FusedMode::Plain => {
                            if idx.len() > 1 {
                                self.verify.maybe_shadow_sample(seq, toks[j]);
                            }
                        }
                        FusedMode::Spec => {
                            if catch_unwind(AssertUnwindSafe(|| self.spec_phase_c(seq))).is_err() {
                                seq.abort(FinishReason::InternalError);
                            }
                        }
                    }
                }
            }
        });
        let end_ns = t.now_ns();
        for s in 0..slots.len() {
            let seq = slots.get_mut(s);
            if seq.stepped_in_batch {
                seq.stepped_in_batch = false;
                seq.note_step_gap(batch_start_ns, end_ns);
            }
        }
    }

    /// One supervised scheduling step over the coordinator's slot views:
    /// fused when configured, otherwise per-sequence with the same
    /// per-member panic isolation and batch-window gap attribution.
    pub fn step_slots_supervised(&self, slots: &mut [&mut SeqState]) {
        if slots.is_empty() {
            return;
        }
        if self.verify.cfg.fused_batch {
            self.step_fused(slots);
            return;
        }
        let t = tracer();
        let batch_start_ns = t.now_ns();
        self.verify.step_slots_with(slots, |seq| {
            seq.stepped_in_batch = true;
            if catch_unwind(AssertUnwindSafe(|| self.step_one_inner(seq))).is_err() {
                seq.abort(FinishReason::InternalError);
            }
        });
        let end_ns = t.now_ns();
        for seq in slots.iter_mut() {
            if seq.stepped_in_batch {
                seq.stepped_in_batch = false;
                seq.note_step_gap(batch_start_ns, end_ns);
            }
        }
    }

    /// One step across a batch of sequences — fused by default (see
    /// [`Engine::step_batch`]), per-sequence otherwise.
    pub fn step_batch(&self, seqs: &mut [SeqState]) {
        if self.verify.cfg.fused_batch {
            self.step_fused(seqs);
            return;
        }
        let threads = self.verify.cfg.threads.min(seqs.len());
        if threads <= 1 {
            for seq in seqs.iter_mut() {
                if !seq.finished() && seq.prefill_complete() {
                    self.step_one(seq);
                }
            }
            return;
        }
        parallel_slices(seqs, threads, |_, _, chunk| {
            for seq in chunk.iter_mut() {
                if !seq.finished() && seq.prefill_complete() {
                    self.step_one(seq);
                }
            }
        });
    }

    /// Run a prompt to completion speculatively, returning the sequence for
    /// inspection (acceptance counters, stats, finish reason).
    pub fn run_seq(&self, id: u64, prompt: &str, max_new: usize, sampling: Sampling) -> SeqState {
        let mut seq = self.admit(id, prompt, max_new, sampling);
        self.prefill(&mut seq);
        while !seq.finished() {
            self.spec_round(&mut seq);
        }
        seq
    }

    /// Run a prompt to completion (prefill + speculative decode rounds).
    pub fn run_to_completion(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> (String, ForwardStats) {
        let seq = self.run_seq(0, prompt, max_new, sampling);
        (seq.text(), seq.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::sparsity::methods::{ScoredLayer, ScoredSparsifier};

    fn engine(sparse_tau: Option<f32>) -> Engine {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
        let sp: Arc<dyn Sparsifier> = match sparse_tau {
            None => Arc::new(Dense),
            Some(tau) => Arc::new(ScoredSparsifier::new(
                "teal",
                (0..model.cfg.n_layers * 7)
                    .map(|_| ScoredLayer { ga: None, tau })
                    .collect(),
            )),
        };
        Engine::new(model, sp, EngineCfg {
            threads: 2,
            ..EngineCfg::default()
        })
    }

    #[test]
    fn generates_requested_tokens() {
        let e = engine(None);
        let (text, stats) = e.run_to_completion("hello ", 10, Sampling::Greedy);
        assert_eq!(text.len(), 10);
        assert_eq!(stats.tokens as usize, 6 + 9); // prefill 6 + 9 decode fwd
    }

    #[test]
    fn batch_step_equals_sequential() {
        let e = engine(Some(0.3));
        let prompts = ["abc", "12+34=", "the sun "];
        // Sequential reference.
        let mut expected = Vec::new();
        for p in prompts {
            let (text, _) = e.run_to_completion(p, 6, Sampling::Greedy);
            expected.push(text);
        }
        // Batched.
        let mut seqs: Vec<SeqState> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| e.admit(i as u64, p, 6, Sampling::Greedy))
            .collect();
        for s in seqs.iter_mut() {
            e.prefill(s);
        }
        while seqs.iter().any(|s| !s.finished()) {
            e.step_batch(&mut seqs);
        }
        for (s, exp) in seqs.iter().zip(&expected) {
            assert_eq!(&s.text(), exp, "batched decode diverged");
        }
    }

    #[test]
    fn sparse_engine_reports_lower_density() {
        let dense_e = engine(None);
        let sparse_e = engine(Some(0.5));
        let (_, ds) = dense_e.run_to_completion("abcdef", 8, Sampling::Greedy);
        let (_, ss) = sparse_e.run_to_completion("abcdef", 8, Sampling::Greedy);
        assert!((ds.density() - 1.0).abs() < 1e-12);
        assert!(ss.density() < 1.0);
    }

    #[test]
    fn prompt_truncated_to_context() {
        let e = engine(None);
        let long_prompt: String = "x".repeat(1000);
        let seq = e.admit(0, &long_prompt, 16, Sampling::Greedy);
        assert!(seq.prompt_tokens.len() + 16 <= e.model.cfg.max_seq);
        assert_eq!(
            e.worst_case_tokens(&long_prompt, 16),
            seq.prompt_tokens.len() + 16
        );
    }

    #[test]
    fn prefill_mixes_dense_and_sparse() {
        // With fraction 0.5 and an aggressive tau, the first half of prefill
        // runs dense: density must sit strictly between all-sparse and 1.0.
        let e = engine(Some(10.0)); // tau so high sparse keeps ~nothing
        let mut seq = e.admit(0, "abcdefgh", 4, Sampling::Greedy);
        e.prefill(&mut seq);
        let d = seq.stats.density();
        assert!(d > 0.05 && d < 0.95, "density {d}");
    }

    #[test]
    fn chunked_prefill_bit_identical_to_sequential() {
        // Chunk sizes straddling the dense→sparse boundary, dividing and not
        // dividing the prompt length — logits and decode continuations must
        // match the token-by-token reference bit-for-bit.
        for chunk in [1usize, 3, 5, 64] {
            let mut e = engine(Some(0.4));
            e.cfg.prefill_chunk = chunk;
            let prompt = "the quick brown fox jumps";
            let mut a = e.admit(0, prompt, 8, Sampling::Greedy);
            e.prefill(&mut a);
            let mut b = e.admit(1, prompt, 8, Sampling::Greedy);
            e.prefill_sequential(&mut b);
            assert!(a.prefill_complete() && b.prefill_complete());
            assert!(a.prefill.chunks >= 1);
            let la = e.last_logits(&a).to_vec();
            let lb = e.last_logits(&b).to_vec();
            assert_eq!(la.len(), lb.len());
            for (x, y) in la.iter().zip(&lb) {
                assert_eq!(x.to_bits(), y.to_bits(), "chunk={chunk} logits differ");
            }
            while !a.finished() {
                e.decode_one(&mut a);
            }
            while !b.finished() {
                e.decode_one(&mut b);
            }
            assert_eq!(a.text(), b.text(), "chunk={chunk} decode diverged");
        }
    }

    #[test]
    fn empty_prompt_prefill_completes_without_work() {
        // The chunked path must keep the old loop's n=0 behaviour: complete
        // immediately instead of asserting or spinning PoolDry.
        let e = engine(None);
        let mut seq = e.admit(0, "", 0, Sampling::Greedy);
        e.prefill(&mut seq);
        assert!(seq.prefill_complete());
        assert_eq!(seq.prefill.cursor, 0);
        assert!(seq.finished(), "max_new 0 finishes with nothing to decode");
    }

    #[test]
    fn partial_prefill_is_terminal_not_decodable() {
        // Pool exhaustion mid-prompt must leave an explicitly terminal,
        // never-decodable sequence — not a half-prefilled one that passes
        // the decode guard.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
        let pe = Engine::paged(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 1,
                prefill_chunk: 4,
                ..EngineCfg::default()
            },
            &KvCfg {
                pool_blocks: 2,
                block_size: 4,
                prefix_cache: false,
            },
        );
        let mut seq = pe.admit(0, &"x".repeat(16), 8, Sampling::Greedy);
        pe.prefill(&mut seq);
        assert!(!seq.prefill_complete(), "mid-prompt abort must not mark prefilled");
        assert!(seq.finished(), "partial prefill is terminal");
        assert_eq!(seq.finish_reason(), FinishReason::CacheFull);
        assert!(seq.generated.is_empty());
        assert_eq!(seq.prefill.cursor, 8, "8 positions fit the 8-slot pool");
    }

    #[test]
    fn spec_with_identical_draft_accepts_everything() {
        // Draft config == production config: every draft token is exactly
        // the verifier's choice, so acceptance must be total and the text
        // identical to plain decode.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
        let sp: Arc<dyn Sparsifier> = Arc::new(ScoredSparsifier::new(
            "teal",
            (0..model.cfg.n_layers * 7)
                .map(|_| ScoredLayer { ga: None, tau: 0.3 })
                .collect(),
        ));
        let engine = Arc::new(Engine::new(
            Arc::clone(&model),
            Arc::clone(&sp),
            EngineCfg {
                threads: 1,
                ..EngineCfg::default()
            },
        ));
        let (baseline, _) = engine.run_to_completion("the sun ", 16, Sampling::Greedy);
        let spec = SpecEngine::new(Arc::clone(&engine), sp, SpecCfg::default());
        let seq = spec.run_seq(0, "the sun ", 16, Sampling::Greedy);
        assert_eq!(seq.text(), baseline);
        assert_eq!(seq.generated.len(), 16);
        assert!(seq.spec.drafted > 0, "rounds actually drafted");
        assert_eq!(
            seq.spec.accepted, seq.spec.drafted,
            "identical draft must be fully accepted"
        );
        assert!((seq.spec.acceptance_rate() - 1.0).abs() < 1e-12);
        // Full acceptance grows the adaptive chain toward the ceiling.
        assert!(seq.spec.cur_k > SpecCfg::default().k);
    }

    #[test]
    fn finish_reason_length_vs_cache_full() {
        // Flat engine completing normally reports `length`.
        let e = engine(None);
        let mut seq = e.admit(0, "abc", 4, Sampling::Greedy);
        e.prefill(&mut seq);
        while !seq.finished() {
            e.decode_one(&mut seq);
        }
        assert_eq!(seq.finish_reason(), FinishReason::Length);

        // A paged engine with a starved pool stops early with `cache_full`.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 81));
        let pe = Engine::paged(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 1,
                ..EngineCfg::default()
            },
            &KvCfg {
                pool_blocks: 2,
                block_size: 4,
                prefix_cache: true,
            },
        );
        let mut seq = pe.admit(0, "abcd", 32, Sampling::Greedy);
        pe.prefill(&mut seq);
        while !seq.finished() {
            pe.decode_one(&mut seq);
        }
        assert_eq!(seq.finish_reason(), FinishReason::CacheFull);
        assert!(
            seq.generated.len() < 32,
            "pool of 8 positions cannot satisfy max_new=32"
        );
    }
}
