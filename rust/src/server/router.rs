//! Prefix-affinity router over N engine replicas.
//!
//! Sharding naively round-robin would destroy the radix prefix cache hit
//! rates that make sparse prefill pay off: two requests sharing a long
//! system prompt must land on the *same* replica to reuse its cached
//! blocks. The router therefore hash-routes on the first `prefix_k` prompt
//! tokens (a multiple of the KV block size, so the hashed span aligns with
//! radix block granularity) and only spills to the least-loaded replica
//! when the affinity target's wait queue is saturated — trading a cold
//! prefill for latency under skew. When every replica is saturated the
//! submit fails and HTTP sheds with 503 + `Retry-After`.
//!
//! The router is also the fan-out point for lifecycle (drain/shutdown all
//! replicas) and observability: `/metrics` serves a unified aggregate
//! (per-replica [`Metrics`] merged at scrape time) plus a `replicas[]`
//! array and `replica`-labeled Prometheus families.

use crate::obs::PromText;
use crate::server::coordinator::Coordinator;
use crate::server::metrics::Metrics;
use crate::server::request::{GenRequest, GenResponse, StreamEvent};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// Router knobs (`wisparse serve --replicas N --route-prefix-k K`).
#[derive(Clone, Debug)]
pub struct RouterCfg {
    /// Prompt bytes (= tokens for the byte-level tokenizer) hashed for
    /// replica affinity. Keep it a multiple of the KV block size so the
    /// hashed span maps onto whole radix blocks.
    pub prefix_k: usize,
    /// Queue depth at which the affinity replica is considered saturated
    /// and the request spills to the least-loaded replica instead.
    /// Defaults to the replica's full queue capacity: spill only when
    /// affinity would otherwise shed, because every spill is a cold
    /// prefill on the other replica.
    pub spill_at: usize,
}

impl Default for RouterCfg {
    fn default() -> Self {
        Self {
            prefix_k: 64,
            spill_at: usize::MAX,
        }
    }
}

/// Where a routed request actually went (telemetry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteOutcome {
    /// Landed on its prefix-affinity replica.
    Affinity,
    /// Affinity replica saturated; spilled to the least-loaded replica.
    Spill,
}

pub struct Router {
    replicas: Vec<Arc<Coordinator>>,
    cfg: RouterCfg,
    routed_affinity: AtomicU64,
    routed_spill: AtomicU64,
    shed: AtomicU64,
}

/// FNV-1a over the first `k` prompt bytes: cheap, deterministic and
/// well-distributed for the short spans we hash. Prompts sharing at least
/// `k` bytes of prefix route identically; shorter prompts hash whole.
/// Public so benches can construct replica-balanced workloads without
/// duplicating the constants.
pub fn prefix_hash(prompt: &str, k: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in &prompt.as_bytes()[..prompt.len().min(k)] {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Router {
    pub fn new(replicas: Vec<Arc<Coordinator>>, cfg: RouterCfg) -> Arc<Self> {
        assert!(!replicas.is_empty(), "router needs at least one replica");
        Arc::new(Self {
            replicas,
            cfg,
            routed_affinity: AtomicU64::new(0),
            routed_spill: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        })
    }

    /// Wrap one coordinator (the compatibility path for `http::serve` and
    /// every pre-router caller).
    pub fn single(coord: Arc<Coordinator>) -> Arc<Self> {
        Self::new(vec![coord], RouterCfg::default())
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, i: usize) -> &Arc<Coordinator> {
        &self.replicas[i]
    }

    pub fn replicas(&self) -> &[Arc<Coordinator>] {
        &self.replicas
    }

    pub fn cfg(&self) -> &RouterCfg {
        &self.cfg
    }

    /// The replica a prompt's prefix hashes to (before load fallback).
    pub fn affinity_replica(&self, prompt: &str) -> usize {
        (prefix_hash(prompt, self.cfg.prefix_k) % self.replicas.len() as u64) as usize
    }

    /// Route one prompt: its affinity replica, unless that replica's wait
    /// queue is saturated (or its scheduler is gone), in which case the
    /// least-loaded live replica. The decision is made *before* the single
    /// submit attempt so a shed counted by a replica really was offered to
    /// the best available one.
    pub fn route_replica(&self, prompt: &str) -> (usize, RouteOutcome) {
        let idx = self.affinity_replica(prompt);
        let c = &self.replicas[idx];
        let spill_at = self.cfg.spill_at.min(c.queue_capacity());
        if self.replicas.len() > 1 && (c.scheduler_exited() || c.queue_depth() >= spill_at) {
            let fallback = self.least_loaded();
            if fallback != idx {
                self.routed_spill.fetch_add(1, Ordering::Relaxed);
                return (fallback, RouteOutcome::Spill);
            }
        }
        self.routed_affinity.fetch_add(1, Ordering::Relaxed);
        (idx, RouteOutcome::Affinity)
    }

    /// The live replica with the fewest in-flight requests.
    fn least_loaded(&self) -> usize {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, c)| !c.scheduler_exited())
            .min_by_key(|(_, c)| c.load())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Non-blocking routed submit (the reactor's path). Returns the chosen
    /// replica index alongside the id and completion channel so the caller
    /// can cancel or consult the right replica later.
    pub fn submit_request(
        &self,
        req: GenRequest,
    ) -> anyhow::Result<(usize, u64, Receiver<GenResponse>)> {
        let (idx, _) = self.route_replica(&req.prompt);
        match self.replicas[idx].submit_request(req) {
            Ok((id, rx)) => Ok((idx, id, rx)),
            Err(e) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Streaming variant of [`Router::submit_request`].
    pub fn submit_stream_request(
        &self,
        req: GenRequest,
    ) -> anyhow::Result<(usize, u64, Receiver<StreamEvent>)> {
        let (idx, _) = self.route_replica(&req.prompt);
        match self.replicas[idx].submit_stream_request(req) {
            Ok((id, rx)) => Ok((idx, id, rx)),
            Err(e) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Routed submit-and-wait (the blocking front end's path).
    pub fn submit_request_blocking(&self, req: GenRequest) -> anyhow::Result<GenResponse> {
        let (idx, _) = self.route_replica(&req.prompt);
        let r = self.replicas[idx].submit_request_blocking(req);
        if r.is_err() {
            self.shed.fetch_add(1, Ordering::Relaxed);
        }
        r
    }

    /// Cancel an in-flight request on the replica it was routed to.
    pub fn cancel(&self, replica: usize, id: u64) {
        self.replicas[replica].cancel(id);
    }

    /// Begin a graceful drain on every replica (idempotent).
    pub fn drain(&self) {
        for c in &self.replicas {
            c.drain();
        }
    }

    /// Hard-stop every replica.
    pub fn shutdown(&self) {
        for c in &self.replicas {
            c.shutdown();
        }
    }

    /// Draining if any replica is: drain is a router-wide operation, so a
    /// half-drained fleet must already refuse admission at the edge.
    pub fn is_draining(&self) -> bool {
        self.replicas.iter().any(|c| c.is_draining())
    }

    /// Shut down once every replica is (the serve loops' exit condition:
    /// responses may still be owed by stragglers until the last scheduler
    /// sweeps its waiters).
    pub fn is_shutdown(&self) -> bool {
        self.replicas.iter().all(|c| c.is_shutdown())
    }

    /// Every replica's scheduler has exited and swept its waiters.
    pub fn all_schedulers_exited(&self) -> bool {
        self.replicas.iter().all(|c| c.scheduler_exited())
    }

    /// Requests shed at the router (the chosen replica refused admission).
    pub fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// `/metrics` JSON: the merged aggregate (same keys as the
    /// single-engine view), plus a `replicas[]` array of compact
    /// per-replica blocks and a `router` block with routing counters.
    pub fn metrics_json(&self) -> Json {
        let mut j = if self.replicas.len() == 1 {
            // Single engine: the coordinator's own view, verbatim (plus the
            // replicas/router keys below) — byte-compatible with the
            // pre-router server.
            self.replicas[0].metrics_json()
        } else {
            let mut agg = Metrics::new();
            for c in &self.replicas {
                c.merge_metrics_into(&mut agg);
            }
            let mut j = agg.to_json();
            if let Some(q) = &self.replicas[0].engine().quality {
                if let Json::Obj(map) = &mut j {
                    map.insert("quality".to_string(), q.snapshot_json());
                }
            }
            j
        };
        if let Json::Obj(map) = &mut j {
            map.insert(
                "replicas".to_string(),
                Json::Arr(self.replicas.iter().map(|c| c.replica_json()).collect()),
            );
            map.insert("router".to_string(), self.router_json());
        }
        j
    }

    fn router_json(&self) -> Json {
        Json::obj(vec![
            ("replicas_n", Json::Num(self.replicas.len() as f64)),
            ("prefix_k", Json::Num(self.cfg.prefix_k as f64)),
            (
                "routed_affinity_total",
                Json::Num(self.routed_affinity.load(Ordering::Relaxed) as f64),
            ),
            (
                "routed_spill_total",
                Json::Num(self.routed_spill.load(Ordering::Relaxed) as f64),
            ),
            (
                "shed_total",
                Json::Num(self.shed.load(Ordering::Relaxed) as f64),
            ),
        ])
    }

    /// Prometheus exposition: replica 0's full single-engine page when the
    /// router wraps one coordinator (byte-compatible with the pre-router
    /// server), otherwise the merged aggregate families (unlabeled, same
    /// names as before) followed by `replica`-labeled per-replica gauges
    /// and the router's own families. Per-replica *histograms* are
    /// deliberately not emitted: mixing label sets inside one histogram
    /// family breaks bucket-monotonicity checks in strict parsers.
    pub fn metrics_prometheus(&self) -> String {
        if self.replicas.len() == 1 {
            return self.replicas[0].metrics_prometheus();
        }
        for c in &self.replicas {
            c.tick_slos();
        }
        let mut agg = Metrics::new();
        for c in &self.replicas {
            c.merge_metrics_into(&mut agg);
        }
        let mut p = PromText::new();
        agg.render_prometheus(&mut p);
        self.render_replica_prometheus(&mut p);
        p.finish()
    }

    fn render_replica_prometheus(&self, p: &mut PromText) {
        p.gauge(
            "wisparse_router_replicas",
            "Engine replicas behind the prefix-affinity router.",
            &[],
            self.replicas.len() as f64,
        );
        for (outcome, v) in [
            ("affinity", self.routed_affinity.load(Ordering::Relaxed)),
            ("spill", self.routed_spill.load(Ordering::Relaxed)),
        ] {
            p.counter(
                "wisparse_router_routed_total",
                "Requests routed, by whether they hit their affinity replica.",
                &[("outcome", outcome)],
                v as f64,
            );
        }
        p.counter(
            "wisparse_router_shed_total",
            "Requests shed at the router (chosen replica refused admission).",
            &[],
            self.shed.load(Ordering::Relaxed) as f64,
        );
        for c in &self.replicas {
            let rid = c.replica_id().to_string();
            let labels = [("replica", rid.as_str())];
            let j = c.replica_json();
            let num = |k: &str| j.get(k).as_f64().unwrap_or(0.0);
            p.gauge(
                "wisparse_replica_up",
                "1 while the replica's scheduler is running.",
                &labels,
                if c.scheduler_exited() { 0.0 } else { 1.0 },
            );
            p.gauge(
                "wisparse_replica_queue_depth",
                "Waiting (unadmitted) requests on this replica.",
                &labels,
                num("queue_depth"),
            );
            p.gauge(
                "wisparse_replica_in_flight",
                "Queued plus active requests on this replica.",
                &labels,
                num("in_flight"),
            );
            p.gauge(
                "wisparse_replica_kv_blocks_total",
                "This replica's share of the paged-KV block budget.",
                &labels,
                num("blocks_total"),
            );
            p.gauge(
                "wisparse_replica_kv_blocks_in_use",
                "Paged-KV blocks this replica currently references.",
                &labels,
                num("blocks_in_use"),
            );
            p.gauge(
                "wisparse_replica_decode_tok_s",
                "This replica's windowed decode throughput.",
                &labels,
                num("decode_tok_s"),
            );
            p.counter(
                "wisparse_replica_requests_total",
                "Requests completed by this replica.",
                &labels,
                num("requests_total"),
            );
            p.counter(
                "wisparse_replica_tokens_generated_total",
                "Tokens committed by this replica's decode.",
                &labels,
                num("tokens_generated"),
            );
            p.gauge(
                "wisparse_replica_prefix_hit_rate",
                "Fraction of this replica's prompt tokens served from its prefix cache.",
                &labels,
                num("prefix_hit_rate"),
            );
        }
    }

    /// `/alerts`: the single replica's body verbatim (compatibility), or a
    /// per-replica array when sharded.
    pub fn alerts_json(&self) -> Json {
        if self.replicas.len() == 1 {
            return self.replicas[0].alerts_json();
        }
        Json::obj(vec![(
            "replicas",
            Json::Arr(
                self.replicas
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("replica", Json::Num(c.replica_id() as f64)),
                            ("alerts", c.alerts_json()),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_hash_is_prefix_stable() {
        let a = prefix_hash("system prompt: be terse. Q1", 16);
        let b = prefix_hash("system prompt: be terse. Q2 entirely different tail", 16);
        assert_eq!(a, b, "first 16 bytes agree, hash must agree");
        let c = prefix_hash("other prompt entirely", 16);
        assert_ne!(a, c);
    }

    #[test]
    fn short_prompts_hash_whole() {
        assert_eq!(prefix_hash("ab", 64), prefix_hash("ab", 64));
        assert_ne!(prefix_hash("ab", 64), prefix_hash("ac", 64));
    }
}
