//! The coordinator: admission + continuous-batching decode loop.
//!
//! One scheduler thread owns the active set. Router threads (HTTP or
//! in-process callers) enqueue requests and block on a per-request channel;
//! the scheduler admits between decode steps, prefills new sequences,
//! steps the batch, and completes finished sequences.
//!
//! With a paged-KV engine the scheduler is block-aware: a request is only
//! admitted when its worst-case page demand fits the pool's free-plus-
//! evictable headroom, and if the pool still runs dry mid-decode (shared
//! prefix blocks make the headroom estimate optimistic) the youngest
//! active sequence is preempted — its pages released, its request requeued
//! at the head of the line — instead of any sequence failing.

use crate::data::corpus::detokenize;
use crate::model::sampler::Sampling;
use crate::server::batcher::{Batcher, BatcherCfg};
use crate::server::engine::{Engine, FinishReason, PrefillStep, SeqState, SpecEngine};
use crate::server::metrics::Metrics;
use crate::server::request::{GenRequest, GenResponse, StreamEvent};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Coordinator configuration.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorCfg {
    pub batcher: BatcherCfg,
}

struct SchedState {
    batcher: Batcher,
    waiters: HashMap<u64, Sender<GenResponse>>,
    /// Per-token event channels for streaming requests (`"stream": true`).
    streams: HashMap<u64, Sender<StreamEvent>>,
    /// Requests cancelled by their client (disconnected streams): the
    /// scheduler tears them down — queued or active — on its next pass.
    cancelled: HashSet<u64>,
}

/// The serving coordinator. Cloneable handle via Arc.
pub struct Coordinator {
    engine: Arc<Engine>,
    /// Speculative decoder over the same engine; armed requests run
    /// draft/verify rounds instead of single-token steps.
    spec: Option<Arc<SpecEngine>>,
    state: Mutex<SchedState>,
    wake: Condvar,
    pub metrics: Mutex<Metrics>,
    next_id: AtomicU64,
    shutdown: AtomicBool,
}

impl Coordinator {
    pub fn new(engine: Arc<Engine>, cfg: CoordinatorCfg) -> Arc<Self> {
        Self::build(engine, None, cfg)
    }

    /// Coordinator running speculative decode rounds (the engine is the
    /// spec engine's verify engine, so admission, KV accounting and
    /// preemption are unchanged; the per-round chain cap keeps the
    /// speculative KV peak — draft lookahead included — inside the
    /// worst-case reservation block-aware admission already makes).
    pub fn new_spec(spec: Arc<SpecEngine>, cfg: CoordinatorCfg) -> Arc<Self> {
        let engine = Arc::clone(&spec.verify);
        Self::build(engine, Some(spec), cfg)
    }

    fn build(
        engine: Arc<Engine>,
        spec: Option<Arc<SpecEngine>>,
        cfg: CoordinatorCfg,
    ) -> Arc<Self> {
        Arc::new(Self {
            engine,
            spec,
            state: Mutex::new(SchedState {
                batcher: Batcher::new(cfg.batcher),
                waiters: HashMap::new(),
                streams: HashMap::new(),
                cancelled: HashSet::new(),
            }),
            wake: Condvar::new(),
            metrics: Mutex::new(Metrics::new()),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Submit a request; returns a receiver for the completion, or Err on
    /// backpressure.
    pub fn submit(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> anyhow::Result<std::sync::mpsc::Receiver<GenResponse>> {
        self.submit_opts(prompt, max_new, sampling, true)
    }

    /// [`Coordinator::submit`] with the per-request speculative opt-out
    /// (no effect on a non-speculative coordinator).
    pub fn submit_opts(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
        speculative: bool,
    ) -> anyhow::Result<std::sync::mpsc::Receiver<GenResponse>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = GenRequest::new(id, prompt, max_new);
        req.sampling = sampling;
        req.speculative = speculative;
        let (tx, rx) = channel();
        {
            let mut st = self.state.lock().unwrap();
            if st.batcher.enqueue(req).is_err() {
                self.metrics.lock().unwrap().requests_rejected += 1;
                anyhow::bail!("queue full");
            }
            st.waiters.insert(id, tx);
        }
        self.wake.notify_all();
        Ok(rx)
    }

    /// Submit and wait for completion.
    pub fn submit_blocking(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> anyhow::Result<GenResponse> {
        self.submit_blocking_opts(prompt, max_new, sampling, true)
    }

    /// Submit a streaming request: each committed token arrives as a
    /// [`StreamEvent::Token`] on the returned channel (speculative rounds
    /// can deliver several per scheduler step), terminated by a
    /// [`StreamEvent::Done`] carrying the full response summary. Returns
    /// the request id alongside the channel so a disconnected client can be
    /// cancelled via [`Coordinator::cancel`] — dropping the receiver also
    /// cancels implicitly on the next token send.
    pub fn submit_stream(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
        speculative: bool,
    ) -> anyhow::Result<(u64, std::sync::mpsc::Receiver<StreamEvent>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = GenRequest::new(id, prompt, max_new);
        req.sampling = sampling;
        req.speculative = speculative;
        req.stream = true;
        let (tx, rx) = channel();
        {
            let mut st = self.state.lock().unwrap();
            if st.batcher.enqueue(req).is_err() {
                self.metrics.lock().unwrap().requests_rejected += 1;
                anyhow::bail!("queue full");
            }
            st.streams.insert(id, tx);
        }
        self.wake.notify_all();
        Ok((id, rx))
    }

    /// Cancel an in-flight request (a streaming client hung up): still-
    /// queued work is dropped outright; an active sequence is torn down on
    /// the scheduler's next pass, releasing its KV blocks instead of
    /// decoding to completion for nobody.
    pub fn cancel(&self, id: u64) {
        self.state.lock().unwrap().cancelled.insert(id);
        self.wake.notify_all();
    }

    /// [`Coordinator::submit_blocking`] with the per-request speculative
    /// opt-out — the one blocking completion path (HTTP router included).
    pub fn submit_blocking_opts(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
        speculative: bool,
    ) -> anyhow::Result<GenResponse> {
        let rx = self.submit_opts(prompt, max_new, sampling, speculative)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("scheduler dropped request"))
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Report-time metrics snapshot: refreshes the paged-KV gauges (pool
    /// occupancy, prefix hit/miss) before serializing, so `/metrics` always
    /// reflects live pool state.
    pub fn metrics_json(&self) -> crate::util::json::Json {
        let mut m = self.metrics.lock().unwrap();
        if let Some(mgr) = self.engine.kv.as_ref() {
            m.blocks_total = mgr.blocks_total() as u64;
            m.blocks_in_use = mgr.blocks_in_use() as u64;
            let s = mgr.stats();
            m.prefix_hit_tokens = s.prefix_hit_tokens;
            m.prefix_miss_tokens = s.prefix_miss_tokens;
        }
        let model = &self.engine.model;
        m.weight_repr = model.weight_repr_name().to_string();
        m.weight_bytes_resident = model.weight_bytes_resident() as u64;
        m.weight_bytes_dense = model.weight_bytes_dense() as u64;
        m.to_json()
    }

    /// The scheduler loop. Run on a dedicated thread:
    /// `std::thread::spawn(move || coordinator.run_scheduler())`.
    ///
    /// Each iteration runs *at most one prefill chunk* (layer-major, at
    /// most `engine.cfg.prefill_chunk` tokens, shrunk by the number of
    /// decoding sequences so the iteration's total token work stays under
    /// one budget) and then one decode step across every prefilled
    /// sequence. A long prompt therefore never stalls decode for more than
    /// one chunk's worth of work — the old inline prefill blocked every
    /// active sequence for the *entire* prompt.
    pub fn run_scheduler(self: &Arc<Self>) {
        // (request, seq, admitted_at) triples in flight.
        let mut active: Vec<(GenRequest, SeqState, Instant)> = Vec::new();
        // Per-request count of tokens already streamed. A preempted-and-
        // resumed sequence regenerates its prefix deterministically, so the
        // high-water mark naturally suppresses duplicate events.
        let mut stream_sent: HashMap<u64, usize> = HashMap::new();
        // Completion instant of the previous decode step (the decode-gap /
        // inter-token fairness metric).
        let mut last_decode: Option<Instant> = None;
        loop {
            if self.is_shutdown() {
                return;
            }
            // Tear down cancelled requests: queued ones are dropped from
            // the batcher, active ones release their KV blocks right here
            // instead of decoding to completion for a vanished client.
            let cancelled: Vec<u64> = {
                let mut st = self.state.lock().unwrap();
                if st.cancelled.is_empty() {
                    Vec::new()
                } else {
                    let ids: Vec<u64> = st.cancelled.drain().collect();
                    for &id in &ids {
                        // Still-queued requests are dropped here; active
                        // ones are torn down below. Closing the channels
                        // covers both.
                        st.batcher.remove(id);
                        st.waiters.remove(&id);
                        st.streams.remove(&id);
                    }
                    ids
                }
            };
            for id in cancelled {
                self.cancel_active(id, &mut active, &mut stream_sent);
            }
            // Admit new work. With a paged engine, admit only while the
            // head request's worst-case page demand fits the free +
            // evictable headroom; with nothing active, force-admit the head
            // anyway so oversized requests still make progress (they end
            // with `cache_full` rather than waiting forever).
            let admitted: Vec<GenRequest> = {
                let mut st = self.state.lock().unwrap();
                if active.is_empty() && st.batcher.queue_len() == 0 {
                    // Idle: wait for a submit or shutdown.
                    let st2 = self
                        .wake
                        .wait_timeout(st, std::time::Duration::from_millis(50))
                        .unwrap()
                        .0;
                    st2.batcher.queue_len(); // keep borrowck simple
                    last_decode = None;
                    continue;
                }
                let mut adm = match self.engine.kv.as_ref() {
                    Some(mgr) => {
                        // Deduct demand committed earlier in this same pass
                        // so co-admitted requests can't double-count the
                        // one headroom snapshot.
                        let mut committed = 0usize;
                        st.batcher.admit_with(active.len(), |req| {
                            let tokens =
                                self.engine.worst_case_tokens(&req.prompt, req.max_new);
                            let need = mgr.worst_case_blocks(tokens);
                            if committed + need <= mgr.admissible_blocks() {
                                committed += need;
                                true
                            } else {
                                false
                            }
                        })
                    }
                    None => st.batcher.admit(active.len()),
                };
                if adm.is_empty() && active.is_empty() {
                    if let Some(head) = st.batcher.pop_front() {
                        adm.push(head);
                    }
                }
                adm
            };
            for req in admitted {
                let queue_ms = req.arrived.elapsed().as_secs_f64() * 1e3;
                let mut seq =
                    self.engine
                        .admit(req.id, &req.prompt, req.max_new, req.sampling);
                seq.resumed = req.preempted;
                if let (Some(spec), true) = (&self.spec, req.speculative) {
                    spec.init_seq(&mut seq);
                }
                // Prefill is NOT run here: the sequence joins the active
                // set with its chunk cursor at the prefix-hit boundary and
                // the loop below advances it one chunk per iteration.
                if !req.preempted {
                    // A resumed request's wait includes its first run's
                    // decode time — sampling it again would both double-
                    // count the request and pollute queue_ms with run time.
                    self.metrics.lock().unwrap().queue_ms.add(queue_ms);
                }
                active.push((req, seq, Instant::now()));
            }
            if active.is_empty() {
                last_decode = None;
                continue;
            }
            // At most one prefill chunk this iteration, its token budget
            // shrunk by the decode batch's size so one iteration's total
            // forward work stays bounded by `prefill_chunk` tokens (the
            // `max(1)` keeps prefill live even when decode fills the
            // budget by itself).
            let decode_ready = active
                .iter()
                .filter(|(_, s, _)| s.prefill_complete() && !s.finished())
                .count();
            if let Some(idx) = active
                .iter()
                .position(|(_, s, _)| !s.prefill_complete() && !s.finished())
            {
                let budget = self
                    .engine
                    .cfg
                    .prefill_chunk
                    .saturating_sub(decode_ready)
                    .max(1);
                match self.engine.prefill_chunk(&mut active[idx].1, budget) {
                    PrefillStep::Advanced(t) | PrefillStep::Completed(t) => {
                        let mut m = self.metrics.lock().unwrap();
                        m.prefill_chunks_total += 1;
                        // Tokens actually forwarded: prefix-cache hits never
                        // enter a chunk.
                        m.tokens_prefilled += t as u64;
                    }
                    PrefillStep::PoolDry => {
                        // Mid-prompt pool exhaustion: free blocks by
                        // preempting the youngest sequence and retry the
                        // chunk next iteration. With nobody to yield to the
                        // prompt ends `cache_full` — partial prefill is an
                        // explicit terminal state, never decodable.
                        if !self.preempt_youngest(&mut active) {
                            active[idx].1.abort(FinishReason::CacheFull);
                        }
                    }
                }
            }
            self.reserve_or_preempt(&mut active);
            // One decode step across the batch: only prefilled, unfinished
            // sequences enter (chunks stay balanced when completions
            // cluster); the decode policy itself is shared with
            // `Engine::step_batch`. A speculative coordinator runs one
            // draft/verify round per armed sequence instead, which can
            // commit several tokens at once — per-token latency divides by
            // the tokens actually committed.
            let t0 = Instant::now();
            let mut decoded = false;
            let committed = {
                let mut seqs: Vec<&mut SeqState> = active
                    .iter_mut()
                    .map(|(_, s, _)| s)
                    .filter(|s| s.prefill_complete() && !s.finished())
                    .collect();
                if seqs.is_empty() {
                    0
                } else {
                    decoded = true;
                    let before: usize = seqs.iter().map(|s| s.generated.len()).sum();
                    match &self.spec {
                        Some(spec) => spec.step_slots(&mut seqs[..]),
                        None => self.engine.step_slots(&mut seqs[..]),
                    }
                    let after: usize = seqs.iter().map(|s| s.generated.len()).sum();
                    after - before
                }
            };
            if decoded {
                let now = Instant::now();
                let step_ms = (now - t0).as_secs_f64() * 1e3;
                let mut m = self.metrics.lock().unwrap();
                m.per_token_ms.add(step_ms / committed.max(1) as f64);
                if let Some(prev) = last_decode {
                    // Completion-to-completion: the stall a decoding client
                    // actually observes, interleaved prefill included.
                    m.decode_gap_ms.add((now - prev).as_secs_f64() * 1e3);
                }
                last_decode = Some(now);
            } else {
                // No decodable sequence exists (pure-prefill phase): nobody
                // observes a gap.
                last_decode = None;
            }
            // Stream newly committed tokens (one NDJSON event per accepted
            // token; a speculative round can commit several per step).
            // Finished sequences are still in `active` here, so their tail
            // tokens flush before the Done event below. A failed send means
            // the receiving client is gone: cancel the sequence instead of
            // decoding the rest of it into the void.
            let mut dead_streams: Vec<u64> = Vec::new();
            {
                let st = self.state.lock().unwrap();
                if !st.streams.is_empty() {
                    for (req, seq, _) in active.iter() {
                        if let Some(tx) = st.streams.get(&req.id) {
                            let sent = stream_sent.entry(req.id).or_insert(0);
                            while *sent < seq.generated.len() {
                                let ev = StreamEvent::Token {
                                    index: *sent,
                                    text: detokenize(&seq.generated[*sent..*sent + 1]),
                                };
                                if tx.send(ev).is_err() {
                                    dead_streams.push(req.id);
                                    break;
                                }
                                *sent += 1;
                            }
                        }
                    }
                }
            }
            for id in dead_streams {
                self.cancel_active(id, &mut active, &mut stream_sent);
            }
            // Complete finished sequences.
            let mut i = 0;
            while i < active.len() {
                if active[i].1.finished() {
                    let (req, seq, started) = active.swap_remove(i);
                    let total_ms = req.arrived.elapsed().as_secs_f64() * 1e3;
                    let resp = GenResponse {
                        id: req.id,
                        text: seq.text(),
                        n_prompt_tokens: seq.prompt_tokens.len(),
                        n_generated: seq.generated.len(),
                        queue_ms: (started - req.arrived).as_secs_f64() * 1e3,
                        total_ms,
                        density: seq.stats.density(),
                        finish_reason: seq.finish_reason().as_str().to_string(),
                        prefix_hit_tokens: seq.prefix_hit_tokens,
                    };
                    {
                        let mut m = self.metrics.lock().unwrap();
                        m.requests_total += 1;
                        m.tokens_generated += seq.generated.len() as u64;
                        m.total_ms.add(total_ms);
                        m.macs_kept += seq.stats.macs_kept + seq.stats.macs_extra;
                        m.macs_dense += seq.stats.macs_dense;
                        m.spec_rounds_total += seq.spec.rounds;
                        m.spec_drafted_tokens += seq.spec.drafted;
                        m.spec_accepted_tokens += seq.spec.accepted;
                    }
                    let (tx, stx) = {
                        let mut st = self.state.lock().unwrap();
                        (st.waiters.remove(&req.id), st.streams.remove(&req.id))
                    };
                    if let Some(stx) = stx {
                        let _ = stx.send(StreamEvent::Done(resp.clone()));
                    }
                    stream_sent.remove(&req.id);
                    if let Some(tx) = tx {
                        let _ = tx.send(resp);
                    }
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Guarantee every sequence that will forward this step has a KV page
    /// reserved. On pool exhaustion (eviction included — `reserve_seq` runs
    /// the manager's evict-then-alloc path) the youngest active unfinished
    /// sequence is preempted: pages released, request requeued at the head
    /// of the line with its `preempted` mark. Restarting the scan after a
    /// preemption is cheap because successful reservations are idempotent.
    fn reserve_or_preempt(&self, active: &mut Vec<(GenRequest, SeqState, Instant)>) {
        if self.engine.kv.is_none() {
            return;
        }
        let mut i = 0;
        while i < active.len() {
            let needs = {
                let s = &active[i].1;
                // Only prefilled sequences decode this step; decode_one
                // samples one token first, so a forward (and thus a page)
                // is only needed when that doesn't finish the seq.
                s.prefill_complete() && !s.finished() && s.generated.len() + 1 < s.max_new
            };
            if !needs || self.engine.reserve_seq(&mut active[i].1) {
                i += 1;
                continue;
            }
            if !self.preempt_youngest(active) {
                // With a single unfinished sequence there is nobody to
                // yield to: preempting it would requeue-and-fail forever.
                // Let `decode_one` surface `cache_full` instead.
                i += 1;
                continue;
            }
            i = 0;
        }
    }

    /// Tear down one active sequence whose client is gone (explicit
    /// [`Coordinator::cancel`] or a failed stream send): remove it from the
    /// active set — dropping it releases its KV blocks — close its
    /// channels, clear the stream high-water mark, and count the
    /// cancellation. No-op for ids that are not active (still-queued
    /// cancellations are handled by the batcher drain).
    fn cancel_active(
        &self,
        id: u64,
        active: &mut Vec<(GenRequest, SeqState, Instant)>,
        stream_sent: &mut HashMap<u64, usize>,
    ) {
        stream_sent.remove(&id);
        if let Some(i) = active.iter().position(|(r, _, _)| r.id == id) {
            let (_, seq, _) = active.swap_remove(i);
            drop(seq); // page table drops → blocks back to the pool
            let mut st = self.state.lock().unwrap();
            st.waiters.remove(&id);
            st.streams.remove(&id);
            drop(st);
            self.metrics.lock().unwrap().cancellations_total += 1;
        }
    }

    /// Preempt the youngest active unfinished sequence (highest id ==
    /// latest submitted; preempted-and-resumed requests keep their original
    /// low id, so they are preempted last): its pages are released and the
    /// request requeued at the head of the line. Mid-prefill sequences are
    /// legitimate victims — they restart from their (possibly now cached)
    /// prefix when re-admitted. Returns false when at most one unfinished
    /// sequence exists, i.e. there is nobody to yield to.
    fn preempt_youngest(&self, active: &mut Vec<(GenRequest, SeqState, Instant)>) -> bool {
        if active.iter().filter(|(_, s, _)| !s.finished()).count() <= 1 {
            return false;
        }
        let victim = active
            .iter()
            .enumerate()
            .filter(|(_, (_, s, _))| !s.finished())
            .max_by_key(|(_, (r, _, _))| r.id)
            .map(|(idx, _)| idx)
            .expect("checked: at least two unfinished sequences");
        let (mut req, seq, _) = active.swap_remove(victim);
        drop(seq); // releases the page table's block refs
        req.preempted = true;
        self.state.lock().unwrap().batcher.requeue_front(req);
        self.metrics.lock().unwrap().preemptions_total += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::Model;
    use crate::model::ModelConfig;
    use crate::server::engine::EngineCfg;
    use crate::sparsity::Dense;

    fn start_coordinator(max_batch: usize) -> (Arc<Coordinator>, std::thread::JoinHandle<()>) {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
        let engine = Arc::new(Engine::new(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 2,
                ..EngineCfg::default()
            },
        ));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch,
                    max_queue: 32,
                },
            },
        );
        let c2 = Arc::clone(&coord);
        let handle = std::thread::spawn(move || c2.run_scheduler());
        (coord, handle)
    }

    #[test]
    fn single_request_completes() {
        let (coord, handle) = start_coordinator(4);
        let resp = coord.submit_blocking("12+34=", 5, Sampling::Greedy).unwrap();
        assert_eq!(resp.n_generated, 5);
        assert_eq!(resp.text.len(), 5);
        assert!(resp.total_ms >= 0.0);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn concurrent_requests_all_complete_and_match_sequential() {
        let (coord, handle) = start_coordinator(3);
        // Sequential references using a fresh engine.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
        let engine = Engine::new(model, Arc::new(Dense), EngineCfg::default());
        let prompts = ["abc", "hello w", "1+2=", "xyzw", "the sun"];
        let expected: Vec<String> = prompts
            .iter()
            .map(|p| engine.run_to_completion(p, 6, Sampling::Greedy).0)
            .collect();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| coord.submit(p, 6, Sampling::Greedy).unwrap())
            .collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let resp = rx.recv().unwrap();
            assert_eq!(&resp.text, exp, "batched text diverged");
        }
        let m = coord.metrics.lock().unwrap();
        assert_eq!(m.requests_total, 5);
        assert_eq!(m.tokens_generated, 30);
        drop(m);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn streaming_request_emits_per_token_events() {
        let (coord, handle) = start_coordinator(2);
        let reference = coord
            .submit_blocking("stream me", 6, Sampling::Greedy)
            .unwrap();
        let (_, rx) = coord
            .submit_stream("stream me", 6, Sampling::Greedy, true)
            .unwrap();
        let mut text = String::new();
        let mut n = 0usize;
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Token { index, text: t } => {
                    assert_eq!(index, n, "events arrive in order");
                    n += 1;
                    text.push_str(&t);
                }
                StreamEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
            }
        }
        let done = done.expect("terminal done event");
        assert_eq!(n, 6, "one event per generated token");
        assert_eq!(text, done.text, "token stream reassembles the text");
        assert_eq!(done.text, reference.text, "greedy stream matches blocking");
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn backpressure_rejects() {
        // Tiny queue: flood and expect at least one rejection.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 92));
        let engine = Arc::new(Engine::dense(model, EngineCfg::default()));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch: 1,
                    max_queue: 2,
                },
            },
        );
        // No scheduler running -> queue fills up.
        assert!(coord.submit("a", 1, Sampling::Greedy).is_ok());
        assert!(coord.submit("b", 1, Sampling::Greedy).is_ok());
        assert!(coord.submit("c", 1, Sampling::Greedy).is_err());
        assert_eq!(coord.metrics.lock().unwrap().requests_rejected, 1);
    }
}
