//! The coordinator: admission + continuous-batching decode loop.
//!
//! One scheduler thread owns the active set. Router threads (HTTP or
//! in-process callers) enqueue requests and block on a per-request channel;
//! the scheduler admits between decode steps, prefills new sequences,
//! steps the batch, and completes finished sequences.
//!
//! With a paged-KV engine the scheduler is block-aware: a request is only
//! admitted when its worst-case page demand fits the pool's free-plus-
//! evictable headroom, and if the pool still runs dry mid-decode (shared
//! prefix blocks make the headroom estimate optimistic) the youngest
//! active sequence is preempted — its pages released, its request requeued
//! at the head of the line — instead of any sequence failing.
//!
//! # Supervision
//!
//! The scheduler is supervised: [`Coordinator::run_scheduler`] is a
//! restart loop around the actual iteration loop. A panic inside one
//! sequence's forward work is caught *per sequence* (the step closure
//! wraps `decode_one`/`step_one` in `catch_unwind`), so one poisoned
//! request finishes `internal_error` while its batchmates keep decoding.
//! A panic that escapes per-sequence isolation (scheduler bookkeeping
//! itself) unwinds the whole iteration loop: the stack-owned active set
//! drops, which returns every in-flight sequence's KV blocks to the pool,
//! the supervisor fails the orphaned waiters with `internal_error`, and a
//! fresh iteration loop resumes serving the still-queued survivors.
//!
//! # Deadlines, shedding, drain
//!
//! Requests carry an optional deadline (`deadline_ms`, else the server
//! default). Queued requests past their deadline fail `deadline_exceeded`
//! without ever running; active sequences past theirs finish
//! `deadline_exceeded` with whatever they generated. A full wait queue
//! sheds new work immediately (`queue full`, HTTP 503) instead of queueing
//! unboundedly. [`Coordinator::drain`] stops admission, sheds the queue,
//! lets active sequences finish (bounded by `drain_timeout`), then exits
//! the scheduler — every submitted request still gets exactly one
//! response.

use crate::data::corpus::detokenize;
use crate::model::sampler::Sampling;
use crate::obs::{tracer, PromText, SloEngine, SloSpec, Span, TraceSummary};
use crate::server::batcher::{Batcher, BatcherCfg};
use crate::server::engine::{Engine, FinishReason, PrefillStep, SeqState, SpecEngine};
use crate::server::faults::FaultPoint;
use crate::server::metrics::Metrics;
use crate::server::request::{GenRequest, GenResponse, StreamEvent};
use crate::util::sync::lock_ok;
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How often blocking waiters poll their completion channel for scheduler
/// death (the channel itself delivers the response; the poll is a backstop
/// so a wedged or exited scheduler can't strand a client forever).
const WAIT_POLL: Duration = Duration::from_millis(50);

/// Grace added past a request's deadline before a blocking waiter gives up
/// on the scheduler delivering the `deadline_exceeded` terminal itself.
const WAIT_GRACE: Duration = Duration::from_secs(5);

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorCfg {
    pub batcher: BatcherCfg,
    /// Deadline applied to requests that don't carry their own
    /// `deadline_ms`. `None` (the default) means no deadline.
    pub default_deadline: Option<Duration>,
    /// How long [`Coordinator::drain`] lets active sequences run before
    /// aborting the stragglers `deadline_exceeded`.
    pub drain_timeout: Duration,
    /// Declarative serving objectives evaluated by the burn-rate engine
    /// and surfaced at `GET /alerts`. The `latency_p95_ms` and
    /// `decode_gap_p95_ms` entries also set the per-event breach
    /// thresholds the metrics feed applies.
    pub slos: Vec<SloSpec>,
    /// This coordinator's position in the router's replica set (0 for a
    /// single-engine server). Carried in per-replica metric labels.
    pub replica_id: usize,
}

impl Default for CoordinatorCfg {
    fn default() -> Self {
        Self {
            batcher: BatcherCfg::default(),
            default_deadline: None,
            drain_timeout: Duration::from_secs(30),
            slos: SloSpec::default_set(0.05),
            replica_id: 0,
        }
    }
}

struct SchedState {
    batcher: Batcher,
    waiters: HashMap<u64, Sender<GenResponse>>,
    /// Per-token event channels for streaming requests (`"stream": true`).
    streams: HashMap<u64, Sender<StreamEvent>>,
    /// Requests cancelled by their client (disconnected streams): the
    /// scheduler tears them down — queued or active — on its next pass.
    cancelled: HashSet<u64>,
}

/// The serving coordinator. Cloneable handle via Arc.
pub struct Coordinator {
    engine: Arc<Engine>,
    /// Speculative decoder over the same engine; armed requests run
    /// draft/verify rounds instead of single-token steps.
    spec: Option<Arc<SpecEngine>>,
    cfg: CoordinatorCfg,
    state: Mutex<SchedState>,
    wake: Condvar,
    pub metrics: Mutex<Metrics>,
    /// Burn-rate SLO evaluator. Locked *after* (never while holding)
    /// `metrics` — `tick_slos` snapshots the feed counters first, drops the
    /// metrics lock, then ticks.
    slo: Mutex<SloEngine>,
    /// Per-event breach thresholds mirrored out of `cfg.slos` (infinite
    /// when the objective is absent, so nothing counts as a breach).
    latency_slo_ms: f64,
    gap_slo_ms: f64,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    /// Graceful drain in progress: admission refused, queue shed, active
    /// sequences finishing out.
    draining: AtomicBool,
    drain_started: Mutex<Option<Instant>>,
    /// The scheduler thread has exited (clean shutdown or drain complete)
    /// and swept every remaining waiter. Blocking submitters poll this so
    /// they can never hang on a scheduler that is gone.
    sched_exited: AtomicBool,
}

impl Coordinator {
    pub fn new(engine: Arc<Engine>, cfg: CoordinatorCfg) -> Arc<Self> {
        Self::build(engine, None, cfg)
    }

    /// Coordinator running speculative decode rounds (the engine is the
    /// spec engine's verify engine, so admission, KV accounting and
    /// preemption are unchanged; the per-round chain cap keeps the
    /// speculative KV peak — draft lookahead included — inside the
    /// worst-case reservation block-aware admission already makes).
    pub fn new_spec(spec: Arc<SpecEngine>, cfg: CoordinatorCfg) -> Arc<Self> {
        let engine = Arc::clone(&spec.verify);
        Self::build(engine, Some(spec), cfg)
    }

    fn build(
        engine: Arc<Engine>,
        spec: Option<Arc<SpecEngine>>,
        cfg: CoordinatorCfg,
    ) -> Arc<Self> {
        // Pin the global tracer's epoch no later than construction, so no
        // request arrival instant can predate it (and the lazy init never
        // lands inside the allocation-counted decode steady state).
        tracer();
        let threshold_of = |name: &str| {
            cfg.slos
                .iter()
                .find(|s| s.name == name)
                .map(|s| s.threshold)
                .unwrap_or(f64::INFINITY)
        };
        let latency_slo_ms = threshold_of("latency_p95_ms");
        let gap_slo_ms = threshold_of("decode_gap_p95_ms");
        let slo = Mutex::new(SloEngine::new(cfg.slos.clone()));
        Arc::new(Self {
            engine,
            spec,
            state: Mutex::new(SchedState {
                batcher: Batcher::new(cfg.batcher.clone()),
                waiters: HashMap::new(),
                streams: HashMap::new(),
                cancelled: HashSet::new(),
            }),
            cfg,
            slo,
            latency_slo_ms,
            gap_slo_ms,
            wake: Condvar::new(),
            metrics: Mutex::new(Metrics::new()),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            drain_started: Mutex::new(None),
            sched_exited: AtomicBool::new(false),
        })
    }

    /// The engine this coordinator schedules (tests and the fault layer
    /// reach its pool counters and fault injector through here).
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Server default deadline (applied to requests without their own).
    pub fn default_deadline(&self) -> Option<Duration> {
        self.cfg.default_deadline
    }

    /// This coordinator's position in the router's replica set.
    pub fn replica_id(&self) -> usize {
        self.cfg.replica_id
    }

    /// Waiting (unadmitted) requests right now.
    pub fn queue_depth(&self) -> usize {
        lock_ok(&self.state).batcher.queue_len()
    }

    /// Wait-queue capacity (`BatcherCfg::max_queue`).
    pub fn queue_capacity(&self) -> usize {
        self.cfg.batcher.max_queue
    }

    /// In-flight requests (queued + active): every request with a
    /// registered completion or stream channel. The router's least-loaded
    /// fallback reads this as the replica's load signal.
    pub fn load(&self) -> usize {
        let st = lock_ok(&self.state);
        st.waiters.len() + st.streams.len()
    }

    /// Refresh this replica's report-time gauges and fold its metrics into
    /// a scrape-time aggregate (see [`Metrics::merge_from`]).
    pub fn merge_metrics_into(&self, agg: &mut Metrics) {
        let depth = lock_ok(&self.state).batcher.queue_len() as u64;
        let mut m = lock_ok(&self.metrics);
        self.refresh_gauges(&mut m, depth);
        agg.merge_from(&m);
    }

    /// Compact per-replica block for the `/metrics` JSON `replicas[]`
    /// array: identity, load, KV pool occupancy, windowed throughput and
    /// the health counters that distinguish a sick replica from its peers.
    pub fn replica_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let (depth, in_flight) = {
            let st = lock_ok(&self.state);
            (
                st.batcher.queue_len() as u64,
                (st.waiters.len() + st.streams.len()) as u64,
            )
        };
        let mut m = lock_ok(&self.metrics);
        self.refresh_gauges(&mut m, depth);
        Json::obj(vec![
            ("replica", Json::Num(self.cfg.replica_id as f64)),
            ("queue_depth", Json::Num(depth as f64)),
            ("in_flight", Json::Num(in_flight as f64)),
            ("blocks_total", Json::Num(m.blocks_total as f64)),
            ("blocks_in_use", Json::Num(m.blocks_in_use as f64)),
            ("decode_tok_s", Json::Num(m.throughput_window())),
            ("requests_total", Json::Num(m.requests_total as f64)),
            ("tokens_generated", Json::Num(m.tokens_generated as f64)),
            ("prefix_hit_rate", Json::Num(m.prefix_hit_rate())),
            (
                "panics_caught_total",
                Json::Num(m.panics_caught_total as f64),
            ),
            (
                "scheduler_restarts_total",
                Json::Num(m.scheduler_restarts_total as f64),
            ),
            ("draining", Json::Bool(self.is_draining())),
            ("scheduler_exited", Json::Bool(self.scheduler_exited())),
        ])
    }

    /// Register a request under the scheduler lock: refuse while draining
    /// or shut down, shed on a full queue, otherwise enqueue and register
    /// its completion channel atomically (so the scheduler's exit sweep —
    /// which flips `shutdown` under this same lock — can never miss a
    /// waiter).
    fn enqueue_request(
        &self,
        req: GenRequest,
        register: impl FnOnce(&mut SchedState),
    ) -> anyhow::Result<()> {
        {
            let mut st = lock_ok(&self.state);
            if self.is_shutdown() || self.is_draining() {
                drop(st);
                lock_ok(&self.metrics).shed_total += 1;
                anyhow::bail!("draining: not accepting new requests");
            }
            match st.batcher.enqueue(req) {
                Ok(()) => register(&mut st),
                Err(_) => {
                    drop(st);
                    let mut m = lock_ok(&self.metrics);
                    m.requests_rejected += 1;
                    m.shed_total += 1;
                    anyhow::bail!("queue full");
                }
            }
        }
        self.wake.notify_all();
        Ok(())
    }

    /// Submit a fully-formed request (HTTP hands over the parsed body so
    /// per-request fields like `deadline_ms` survive). Assigns the id and
    /// the server default deadline; returns the id and completion channel.
    pub fn submit_request(
        &self,
        mut req: GenRequest,
    ) -> anyhow::Result<(u64, Receiver<GenResponse>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        let t = tracer();
        req.trace_id = t.next_trace_id();
        req.root_span = t.next_span_id();
        if req.deadline.is_none() {
            req.deadline = self.cfg.default_deadline;
        }
        let (tx, rx) = channel();
        self.enqueue_request(req, |st| {
            st.waiters.insert(id, tx);
        })?;
        Ok((id, rx))
    }

    /// Streaming variant of [`Coordinator::submit_request`]: each committed
    /// token arrives as a [`StreamEvent::Token`], terminated by exactly one
    /// [`StreamEvent::Done`].
    pub fn submit_stream_request(
        &self,
        mut req: GenRequest,
    ) -> anyhow::Result<(u64, Receiver<StreamEvent>)> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        req.id = id;
        let t = tracer();
        req.trace_id = t.next_trace_id();
        req.root_span = t.next_span_id();
        req.stream = true;
        if req.deadline.is_none() {
            req.deadline = self.cfg.default_deadline;
        }
        let (tx, rx) = channel();
        self.enqueue_request(req, |st| {
            st.streams.insert(id, tx);
        })?;
        Ok((id, rx))
    }

    /// Submit a request; returns a receiver for the completion, or Err on
    /// backpressure.
    pub fn submit(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> anyhow::Result<Receiver<GenResponse>> {
        self.submit_opts(prompt, max_new, sampling, true)
    }

    /// [`Coordinator::submit`] with the per-request speculative opt-out
    /// (no effect on a non-speculative coordinator).
    pub fn submit_opts(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
        speculative: bool,
    ) -> anyhow::Result<Receiver<GenResponse>> {
        let mut req = GenRequest::new(0, prompt, max_new);
        req.sampling = sampling;
        req.speculative = speculative;
        self.submit_request(req).map(|(_, rx)| rx)
    }

    /// Submit and wait for completion.
    pub fn submit_blocking(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
    ) -> anyhow::Result<GenResponse> {
        self.submit_blocking_opts(prompt, max_new, sampling, true)
    }

    /// Submit a streaming request: each committed token arrives as a
    /// [`StreamEvent::Token`] on the returned channel (speculative rounds
    /// can deliver several per scheduler step), terminated by a
    /// [`StreamEvent::Done`] carrying the full response summary. Returns
    /// the request id alongside the channel so a disconnected client can be
    /// cancelled via [`Coordinator::cancel`] — dropping the receiver also
    /// cancels implicitly on the next token send.
    pub fn submit_stream(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
        speculative: bool,
    ) -> anyhow::Result<(u64, Receiver<StreamEvent>)> {
        let mut req = GenRequest::new(0, prompt, max_new);
        req.sampling = sampling;
        req.speculative = speculative;
        self.submit_stream_request(req)
    }

    /// Cancel an in-flight request (a streaming client hung up): still-
    /// queued work is dropped outright; an active sequence is torn down on
    /// the scheduler's next pass, releasing its KV blocks instead of
    /// decoding to completion for nobody.
    pub fn cancel(&self, id: u64) {
        lock_ok(&self.state).cancelled.insert(id);
        self.wake.notify_all();
    }

    /// [`Coordinator::submit_blocking`] with the per-request speculative
    /// opt-out.
    pub fn submit_blocking_opts(
        &self,
        prompt: &str,
        max_new: usize,
        sampling: Sampling,
        speculative: bool,
    ) -> anyhow::Result<GenResponse> {
        let mut req = GenRequest::new(0, prompt, max_new);
        req.sampling = sampling;
        req.speculative = speculative;
        self.submit_request_blocking(req)
    }

    /// The one blocking completion path (HTTP router included): submit and
    /// wait, without ever trusting the scheduler to still be alive. The
    /// wait polls for scheduler exit and gives up `WAIT_GRACE` past the
    /// request deadline, so a dead or wedged scheduler turns into an error
    /// response instead of a connection thread blocked forever.
    pub fn submit_request_blocking(&self, req: GenRequest) -> anyhow::Result<GenResponse> {
        let deadline = req.deadline.or(self.cfg.default_deadline);
        let (id, rx) = self.submit_request(req)?;
        self.wait_response(id, rx, deadline)
    }

    fn wait_response(
        &self,
        id: u64,
        rx: Receiver<GenResponse>,
        deadline: Option<Duration>,
    ) -> anyhow::Result<GenResponse> {
        let hard = deadline.map(|d| Instant::now() + d + WAIT_GRACE);
        loop {
            match rx.recv_timeout(WAIT_POLL) {
                Ok(resp) => return Ok(resp),
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("scheduler dropped request {id}")
                }
                Err(RecvTimeoutError::Timeout) => {
                    if self.scheduler_exited() {
                        // The exit sweep may have delivered the terminal
                        // response between our timeout and the flag read.
                        if let Ok(resp) = rx.try_recv() {
                            return Ok(resp);
                        }
                        anyhow::bail!("scheduler exited");
                    }
                    if hard.is_some_and(|h| Instant::now() >= h) {
                        // Scheduler alive but long past this request's
                        // deadline: stop waiting and make sure the
                        // sequence is torn down rather than decoding for
                        // a departed caller.
                        self.cancel(id);
                        anyhow::bail!("request {id} timed out waiting on the scheduler");
                    }
                }
            }
        }
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.wake.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Begin a graceful drain: admission stops (new submits fail and HTTP
    /// sheds 503), the wait queue is shed with terminal responses, active
    /// sequences finish out (bounded by `cfg.drain_timeout`), streams
    /// flush, and the scheduler thread exits on its own — at which point
    /// [`Coordinator::is_shutdown`] turns true and `serve` loops unwind.
    /// Idempotent; the first call starts the drain clock.
    pub fn drain(&self) {
        if !self.draining.swap(true, Ordering::SeqCst) {
            *lock_ok(&self.drain_started) = Some(Instant::now());
        }
        self.wake.notify_all();
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Whether the scheduler thread has exited and swept all waiters.
    pub fn scheduler_exited(&self) -> bool {
        self.sched_exited.load(Ordering::SeqCst)
    }

    /// Report-time metrics snapshot: refreshes the paged-KV gauges (pool
    /// occupancy, prefix hit/miss) and the queue-depth gauge before
    /// serializing, so `/metrics` always reflects live state.
    pub fn metrics_json(&self) -> crate::util::json::Json {
        // Lock order is state -> metrics everywhere (submit counts
        // rejections while holding state), so take the queue depth first.
        let depth = lock_ok(&self.state).batcher.queue_len() as u64;
        let mut j = {
            let mut m = lock_ok(&self.metrics);
            self.refresh_gauges(&mut m, depth);
            m.to_json()
        };
        if let Some(q) = &self.engine.quality {
            if let crate::util::json::Json::Obj(map) = &mut j {
                map.insert("quality".to_string(), q.snapshot_json());
            }
        }
        j
    }

    /// Refresh the report-time gauges (paged-KV pool occupancy, prefix
    /// hit/miss, queue depth, weight representation) on a held metrics
    /// guard, shared by the JSON and Prometheus views.
    fn refresh_gauges(&self, m: &mut Metrics, depth: u64) {
        m.queue_depth = depth;
        if let Some(mgr) = self.engine.kv.as_ref() {
            m.blocks_total = mgr.blocks_total() as u64;
            m.blocks_in_use = mgr.blocks_in_use() as u64;
            let s = mgr.stats();
            m.prefix_hit_tokens = s.prefix_hit_tokens;
            m.prefix_miss_tokens = s.prefix_miss_tokens;
        }
        let model = &self.engine.model;
        m.weight_repr = model.weight_repr_name().to_string();
        m.weight_bytes_resident = model.weight_bytes_resident() as u64;
        m.weight_bytes_dense = model.weight_bytes_dense() as u64;
    }

    /// Prometheus text exposition (format 0.0.4) of the same state
    /// `metrics_json` reports, plus per-(block, projection) sparsity
    /// telemetry when the model carries a recording [`crate::obs::ObsSink`].
    pub fn metrics_prometheus(&self) -> String {
        self.tick_slos();
        let depth = lock_ok(&self.state).batcher.queue_len() as u64;
        let mut p = PromText::new();
        {
            let mut m = lock_ok(&self.metrics);
            self.refresh_gauges(&mut m, depth);
            m.render_prometheus(&mut p);
        }
        self.render_block_telemetry(&mut p);
        if let Some(q) = &self.engine.quality {
            q.render_prometheus(&mut p);
        }
        lock_ok(&self.slo).render_prometheus(&mut p);
        p.finish()
    }

    /// Feed the SLO burn-rate engine the current cumulative counters and
    /// evaluate every objective. Called from the scheduler loop each
    /// iteration and from the `/alerts` and `/metrics` handlers, so alerts
    /// fire and resolve even on an idle or scrape-only server. Lock
    /// discipline: the metrics lock is released before the SLO lock is
    /// taken, and the SLO lock is never held across any other lock.
    pub fn tick_slos(&self) {
        let (lat, lat_bad, gap, gap_bad, err, err_bad) = {
            let m = lock_ok(&self.metrics);
            (
                m.latency_events_total,
                m.latency_breaches_total,
                m.decode_gap_events_total,
                m.decode_gap_breaches_total,
                m.finished_events(),
                m.internal_errors(),
            )
        };
        let (kl, kl_bad) = match &self.engine.quality {
            Some(q) => (q.samples(), q.kl_breaches()),
            None => (0, 0),
        };
        lock_ok(&self.slo).tick(&[
            ("latency_p95_ms", lat, lat_bad),
            ("decode_gap_p95_ms", gap, gap_bad),
            ("shadow_kl", kl, kl_bad),
            ("error_rate", err, err_bad),
        ]);
    }

    /// The `GET /alerts` body: objectives with their config, active alerts,
    /// and recently-resolved history. Ticks first, so a scrape always sees
    /// the freshest evaluation.
    pub fn alerts_json(&self) -> crate::util::json::Json {
        self.tick_slos();
        lock_ok(&self.slo).alerts_json()
    }

    /// Per-(block, projection) achieved density, call counts, effective
    /// weight bandwidth and tau-vs-plan drift. Empty (no families emitted)
    /// when the model runs the no-op sink.
    fn render_block_telemetry(&self, p: &mut PromText) {
        let obs = &self.engine.model.obs;
        if !obs.enabled() {
            return;
        }
        for st in obs.snapshot() {
            if st.calls == 0 {
                continue;
            }
            let block = st.id.block.to_string();
            let labels = [("block", block.as_str()), ("proj", st.id.kind.name())];
            p.counter(
                "wisparse_block_proj_calls_total",
                "Projection invocations per (block, projection).",
                &labels,
                st.calls as f64,
            );
            p.gauge(
                "wisparse_block_density",
                "Achieved keep-fraction per (block, projection).",
                &labels,
                st.density(),
            );
            p.gauge(
                "wisparse_block_gb_per_s",
                "Effective weight-streaming bandwidth per (block, projection).",
                &labels,
                st.gb_per_s(),
            );
            if let Some(planned) = self.engine.sparsifier.planned_density(st.id) {
                p.gauge(
                    "wisparse_block_plan_drift",
                    "Achieved minus planned density per (block, projection).",
                    &labels,
                    st.density() - planned,
                );
            }
            if st.shadow_samples > 0 {
                p.gauge(
                    "wisparse_block_shadow_rel_err",
                    "Relative L2 error of the sparse projection output vs a \
                     dense shadow replay, per (block, projection).",
                    &labels,
                    st.shadow_rel_err(),
                );
            }
        }
    }

    /// Deliver a terminal no-output response for a request that never
    /// produced one (shed, expired in queue, orphaned by a restart):
    /// removes both channels under the lock, so exactly one terminal event
    /// reaches the client and later sweeps can't double-send.
    fn send_terminal(&self, id: u64, reason: &str) {
        let (tx, stx) = {
            let mut st = lock_ok(&self.state);
            (st.waiters.remove(&id), st.streams.remove(&id))
        };
        lock_ok(&self.metrics).count_finish(reason);
        let resp = GenResponse::terminal(id, reason);
        if let Some(stx) = stx {
            let _ = stx.send(StreamEvent::Done(resp.clone()));
        }
        if let Some(tx) = tx {
            let _ = tx.send(resp);
        }
    }

    /// The supervised scheduler entry point. Run on a dedicated thread:
    /// `std::thread::spawn(move || coordinator.run_scheduler())`.
    ///
    /// Wraps the iteration loop in `catch_unwind`: a panic that escapes
    /// per-sequence isolation unwinds the loop's stack (dropping the active
    /// set frees every in-flight sequence's KV blocks), the orphaned
    /// waiters are failed with `internal_error`, and the loop restarts to
    /// serve the still-queued survivors. Returns only after a clean exit
    /// (shutdown or drain complete), with every remaining waiter swept.
    pub fn run_scheduler(self: &Arc<Self>) {
        loop {
            match catch_unwind(AssertUnwindSafe(|| self.scheduler_loop())) {
                Ok(()) => break,
                Err(_) => {
                    lock_ok(&self.metrics).scheduler_restarts_total += 1;
                    self.fail_orphaned_waiters();
                    if self.is_shutdown() {
                        break;
                    }
                }
            }
        }
        self.finish_scheduler_exit();
    }

    /// After a scheduler panic: every registered waiter whose request is
    /// *not* still sitting in the wait queue was in flight when the stack
    /// unwound — its sequence (and KV) is gone, so fail it terminally.
    /// Still-queued requests keep their waiters and are served by the
    /// restarted loop.
    fn fail_orphaned_waiters(&self) {
        let orphans: Vec<u64> = {
            let st = lock_ok(&self.state);
            let queued: HashSet<u64> = st.batcher.queued_ids().into_iter().collect();
            let mut ids: HashSet<u64> = HashSet::new();
            ids.extend(st.waiters.keys().filter(|id| !queued.contains(*id)));
            ids.extend(st.streams.keys().filter(|id| !queued.contains(*id)));
            ids.into_iter().collect()
        };
        for id in orphans {
            self.send_terminal(id, "internal_error");
        }
    }

    /// Final sweep when the scheduler exits for good: flip `shutdown`
    /// *under the state lock* (submission checks the flag under the same
    /// lock, so no new waiter can register after this point), shed any
    /// queued leftovers, and close every remaining channel with exactly one
    /// terminal response.
    fn finish_scheduler_exit(&self) {
        let (waiters, streams, shed) = {
            let mut st = lock_ok(&self.state);
            self.shutdown.store(true, Ordering::SeqCst);
            let shed = st.batcher.drain_queue().len() as u64;
            let waiters: Vec<(u64, Sender<GenResponse>)> = st.waiters.drain().collect();
            let streams: Vec<(u64, Sender<StreamEvent>)> = st.streams.drain().collect();
            (waiters, streams, shed)
        };
        {
            let mut m = lock_ok(&self.metrics);
            m.shed_total += shed;
            for _ in 0..(waiters.len() + streams.len()) {
                m.count_finish("shutdown");
            }
        }
        for (id, tx) in waiters {
            let _ = tx.send(GenResponse::terminal(id, "shutdown"));
        }
        for (id, stx) in streams {
            let _ = stx.send(StreamEvent::Done(GenResponse::terminal(id, "shutdown")));
        }
        // Only now: blocking waiters that see the flag will find their
        // terminal response already in the channel.
        self.sched_exited.store(true, Ordering::SeqCst);
        self.wake.notify_all();
    }

    /// One scheduler incarnation. Each iteration runs *at most one prefill
    /// chunk* (layer-major, at most `engine.cfg.prefill_chunk` tokens,
    /// shrunk by the number of decoding sequences so the iteration's total
    /// token work stays under one budget) and then one decode step across
    /// every prefilled sequence. A long prompt therefore never stalls
    /// decode for more than one chunk's worth of work.
    ///
    /// Returns on shutdown or when a drain completes; panics propagate to
    /// the supervisor in [`Coordinator::run_scheduler`].
    fn scheduler_loop(self: &Arc<Self>) {
        // (request, seq, admitted_at) triples in flight. Owned by this
        // stack frame on purpose: a panic anywhere in the iteration drops
        // the whole set, and `SeqState`'s page table frees its KV blocks
        // on drop — supervision never leaks pool blocks.
        let mut active: Vec<(GenRequest, SeqState, Instant)> = Vec::new();
        // Per-request count of tokens already streamed. A preempted-and-
        // resumed sequence regenerates its prefix deterministically, so the
        // high-water mark naturally suppresses duplicate events.
        let mut stream_sent: HashMap<u64, usize> = HashMap::new();
        // Completion instant of the previous decode step (the decode-gap /
        // inter-token fairness metric).
        let mut last_decode: Option<Instant> = None;
        loop {
            if self.is_shutdown() {
                return;
            }
            // Evaluate the SLO burn rates every iteration (idle waits loop
            // back through here too, so alerts resolve on a quiet server).
            self.tick_slos();
            // Scheduler-level fault point: fires *outside* per-sequence
            // isolation, exercising the supervisor restart path.
            self.engine.faults.maybe_panic(FaultPoint::SchedPanic);
            let draining = self.is_draining();
            // Tear down cancelled requests: queued ones are dropped from
            // the batcher, active ones release their KV blocks right here
            // instead of decoding to completion for a vanished client.
            let cancelled: Vec<u64> = {
                let mut st = lock_ok(&self.state);
                if st.cancelled.is_empty() {
                    Vec::new()
                } else {
                    let ids: Vec<u64> = st.cancelled.drain().collect();
                    for &id in &ids {
                        // Still-queued requests are dropped here; active
                        // ones are torn down below. Closing the channels
                        // covers both.
                        st.batcher.remove(id);
                        st.waiters.remove(&id);
                        st.streams.remove(&id);
                    }
                    ids
                }
            };
            for id in cancelled {
                self.cancel_active(id, &mut active, &mut stream_sent);
            }
            // Queued requests past their deadline fail without running;
            // a drain sheds the whole queue the same way.
            let (expired, shed) = {
                let mut st = lock_ok(&self.state);
                let expired = st.batcher.expire(|r| r.past_deadline());
                let shed = if draining {
                    st.batcher.drain_queue()
                } else {
                    Vec::new()
                };
                (expired, shed)
            };
            if !expired.is_empty() {
                lock_ok(&self.metrics).deadline_exceeded_total += expired.len() as u64;
                for req in &expired {
                    self.send_terminal(req.id, "deadline_exceeded");
                }
            }
            if !shed.is_empty() {
                lock_ok(&self.metrics).shed_total += shed.len() as u64;
                for req in &shed {
                    self.send_terminal(req.id, "shed");
                }
            }
            if draining {
                if active.is_empty() {
                    // Drain complete: record how long it took and exit the
                    // scheduler (the supervisor's exit sweep closes any
                    // straggler channels).
                    let started_at = *lock_ok(&self.drain_started);
                    let ms = started_at
                        .map(|t| t.elapsed().as_secs_f64() * 1e3)
                        .unwrap_or(0.0);
                    lock_ok(&self.metrics).drain_duration_ms = ms;
                    if let Some(t0) = started_at {
                        // Server-lifecycle event: trace 0 (no request).
                        tracer().record_at(0, 0, "drain", t0, (ms * 1e6) as u64, &[]);
                    }
                    return;
                }
                let overdue = lock_ok(&self.drain_started)
                    .map(|t| t.elapsed() >= self.cfg.drain_timeout)
                    .unwrap_or(false);
                if overdue {
                    let mut aborted = 0u64;
                    for (_, seq, _) in active.iter_mut() {
                        if !seq.finished() {
                            seq.abort(FinishReason::DeadlineExceeded);
                            aborted += 1;
                        }
                    }
                    if aborted > 0 {
                        lock_ok(&self.metrics).deadline_exceeded_total += aborted;
                    }
                }
            }
            // Admit new work (never while draining). With a paged engine,
            // admit only while the head request's worst-case page demand
            // fits the free + evictable headroom; with nothing active,
            // force-admit the head anyway so oversized requests still make
            // progress (they end `cache_full` rather than waiting forever).
            let admitted: Vec<GenRequest> = if draining {
                Vec::new()
            } else {
                let mut st = lock_ok(&self.state);
                if active.is_empty() && st.batcher.queue_len() == 0 {
                    // Idle: wait for a submit, drain, or shutdown.
                    let st2 = self
                        .wake
                        .wait_timeout(st, WAIT_POLL)
                        .unwrap_or_else(|e| e.into_inner())
                        .0;
                    st2.batcher.queue_len(); // keep borrowck simple
                    last_decode = None;
                    continue;
                }
                let mut adm = match self.engine.kv.as_ref() {
                    Some(mgr) => {
                        // Deduct demand committed earlier in this same pass
                        // so co-admitted requests can't double-count the
                        // one headroom snapshot.
                        let mut committed = 0usize;
                        st.batcher.admit_with(active.len(), |req| {
                            let tokens =
                                self.engine.worst_case_tokens(&req.prompt, req.max_new);
                            let need = mgr.worst_case_blocks(tokens);
                            if committed + need <= mgr.admissible_blocks() {
                                committed += need;
                                true
                            } else {
                                false
                            }
                        })
                    }
                    None => st.batcher.admit(active.len()),
                };
                if adm.is_empty() && active.is_empty() {
                    if let Some(head) = st.batcher.pop_front() {
                        adm.push(head);
                    }
                }
                adm
            };
            for req in admitted {
                let waited = req.arrived.elapsed();
                let queue_ms = waited.as_secs_f64() * 1e3;
                let mut seq =
                    self.engine
                        .admit(req.id, &req.prompt, req.max_new, req.sampling);
                seq.resumed = req.preempted;
                // Engine-level spans (prefill chunks, decode steps) parent
                // onto this request's reserved root span.
                seq.obs.trace = req.trace_id;
                seq.obs.root = req.root_span;
                if let (Some(spec), true) = (&self.spec, req.speculative) {
                    spec.init_seq(&mut seq);
                }
                // Prefill is NOT run here: the sequence joins the active
                // set with its chunk cursor at the prefix-hit boundary and
                // the loop below advances it one chunk per iteration.
                if !req.preempted {
                    // A resumed request's wait includes its first run's
                    // decode time — sampling it again would both double-
                    // count the request and pollute queue_ms with run time.
                    lock_ok(&self.metrics).observe_queue(queue_ms);
                    tracer().record_at(
                        req.trace_id,
                        req.root_span,
                        "queue",
                        req.arrived,
                        waited.as_nanos() as u64,
                        &[],
                    );
                }
                active.push((req, seq, Instant::now()));
            }
            if active.is_empty() {
                last_decode = None;
                continue;
            }
            // Active sequences past their deadline finish now with
            // whatever they have (possibly nothing, mid-prefill).
            {
                let mut expired_now = 0u64;
                for (req, seq, _) in active.iter_mut() {
                    if !seq.finished() && req.past_deadline() {
                        seq.abort(FinishReason::DeadlineExceeded);
                        expired_now += 1;
                    }
                }
                if expired_now > 0 {
                    lock_ok(&self.metrics).deadline_exceeded_total += expired_now;
                }
            }
            // At most one prefill chunk this iteration, its token budget
            // shrunk by the decode batch's size so one iteration's total
            // forward work stays bounded by `prefill_chunk` tokens (the
            // `max(1)` keeps prefill live even when decode fills the
            // budget by itself).
            let decode_ready = active
                .iter()
                .filter(|(_, s, _)| s.prefill_complete() && !s.finished())
                .count();
            if let Some(idx) = active
                .iter()
                .position(|(_, s, _)| !s.prefill_complete() && !s.finished())
            {
                let budget = self
                    .engine
                    .cfg
                    .prefill_chunk
                    .saturating_sub(decode_ready)
                    .max(1);
                let step = catch_unwind(AssertUnwindSafe(|| {
                    self.engine.prefill_chunk(&mut active[idx].1, budget)
                }));
                match step {
                    Ok(PrefillStep::Advanced(t)) | Ok(PrefillStep::Completed(t)) => {
                        let mut m = lock_ok(&self.metrics);
                        m.prefill_chunks_total += 1;
                        // Tokens actually forwarded: prefix-cache hits never
                        // enter a chunk.
                        m.tokens_prefilled += t as u64;
                    }
                    Ok(PrefillStep::PoolDry) => {
                        // Mid-prompt pool exhaustion: free blocks by
                        // preempting the youngest sequence and retry the
                        // chunk next iteration. With nobody to yield to the
                        // prompt ends `cache_full` — partial prefill is an
                        // explicit terminal state, never decodable.
                        if !self.preempt_youngest(&mut active) {
                            active[idx].1.abort(FinishReason::CacheFull);
                        }
                    }
                    Err(_) => {
                        // A panic mid-prompt is isolated to this sequence:
                        // it finishes `internal_error`, its partially-built
                        // page table frees on drop, batchmates continue.
                        active[idx].1.abort(FinishReason::InternalError);
                    }
                }
            }
            self.reserve_or_preempt(&mut active);
            // One decode step across the batch: only prefilled, unfinished
            // sequences enter (chunks stay balanced when completions
            // cluster); the decode policy itself is shared with
            // `Engine::step_batch` — batch-fused by default (`--fused-batch`:
            // one forward pass streams each layer's weights once for the
            // whole batch), per-sequence otherwise. A speculative
            // coordinator runs one draft/verify round per armed sequence
            // instead, which can commit several tokens at once — per-token
            // latency divides by the tokens actually committed.
            //
            // Per-sequence panic isolation lives inside the supervised
            // step: each member's sequential phase runs under its own
            // `catch_unwind`, so a poisoned sequence aborts
            // `internal_error` while batchmates keep decoding.
            let t0 = Instant::now();
            let mut decoded = false;
            let committed = {
                let mut seqs: Vec<&mut SeqState> = active
                    .iter_mut()
                    .map(|(_, s, _)| s)
                    .filter(|s| s.prefill_complete() && !s.finished())
                    .collect();
                if seqs.is_empty() {
                    0
                } else {
                    decoded = true;
                    let before: usize = seqs.iter().map(|s| s.generated.len()).sum();
                    match &self.spec {
                        Some(spec) => spec.step_slots_supervised(&mut seqs[..]),
                        None => self.engine.step_slots_supervised(&mut seqs[..]),
                    }
                    let after: usize = seqs.iter().map(|s| s.generated.len()).sum();
                    after - before
                }
            };
            if decoded {
                let now = Instant::now();
                let step_ms = (now - t0).as_secs_f64() * 1e3;
                let mut m = lock_ok(&self.metrics);
                m.observe_per_token(step_ms / committed.max(1) as f64);
                m.record_decoded(committed as u64);
                if let Some(prev) = last_decode {
                    // Completion-to-completion: the stall a decoding client
                    // actually observes, interleaved prefill included.
                    let gap_ms = (now - prev).as_secs_f64() * 1e3;
                    m.observe_decode_gap(gap_ms);
                    m.decode_gap_events_total += 1;
                    if gap_ms > self.gap_slo_ms {
                        m.decode_gap_breaches_total += 1;
                    }
                }
                last_decode = Some(now);
            } else {
                // No decodable sequence exists (pure-prefill phase): nobody
                // observes a gap.
                last_decode = None;
            }
            // Stream newly committed tokens (one NDJSON event per accepted
            // token; a speculative round can commit several per step).
            // Finished sequences are still in `active` here, so their tail
            // tokens flush before the Done event below. A failed send means
            // the receiving client is gone: cancel the sequence instead of
            // decoding the rest of it into the void.
            let mut dead_streams: Vec<u64> = Vec::new();
            {
                let st = lock_ok(&self.state);
                if !st.streams.is_empty() {
                    for (req, seq, _) in active.iter() {
                        if let Some(tx) = st.streams.get(&req.id) {
                            let sent = stream_sent.entry(req.id).or_insert(0);
                            while *sent < seq.generated.len() {
                                let ev = StreamEvent::Token {
                                    index: *sent,
                                    text: detokenize(&seq.generated[*sent..*sent + 1]),
                                };
                                if tx.send(ev).is_err() {
                                    dead_streams.push(req.id);
                                    break;
                                }
                                *sent += 1;
                            }
                        }
                    }
                }
            }
            for id in dead_streams {
                self.cancel_active(id, &mut active, &mut stream_sent);
            }
            // Complete finished sequences.
            let mut i = 0;
            while i < active.len() {
                if active[i].1.finished() {
                    let (req, seq, started) = active.swap_remove(i);
                    let total_ms = req.arrived.elapsed().as_secs_f64() * 1e3;
                    let resp = GenResponse {
                        id: req.id,
                        text: seq.text(),
                        n_prompt_tokens: seq.prompt_tokens.len(),
                        n_generated: seq.generated.len(),
                        queue_ms: (started - req.arrived).as_secs_f64() * 1e3,
                        total_ms,
                        density: seq.stats.density(),
                        finish_reason: seq.finish_reason().as_str().to_string(),
                        prefix_hit_tokens: seq.prefix_hit_tokens,
                        trace_id: req.trace_id,
                    };
                    // Close the trace: the root span (its reserved id is
                    // what every child already parents onto) plus the
                    // slow-exemplar rollup.
                    {
                        let t = tracer();
                        let gap_ms = seq.obs.max_gap_ns as f64 / 1e6;
                        let mut root = Span::new(req.trace_id, req.root_span, 0, "request");
                        root.start_ns = t.ns_of(req.arrived);
                        root.dur_ns = (total_ms * 1e6) as u64;
                        root.push_attr("total_ms", total_ms);
                        root.push_attr("gap_max_ms", gap_ms);
                        root.push_attr("generated", seq.generated.len() as f64);
                        t.record(root);
                        t.note_trace(TraceSummary {
                            trace_id: req.trace_id,
                            total_ms,
                            decode_gap_max_ms: gap_ms,
                        });
                    }
                    {
                        let mut m = lock_ok(&self.metrics);
                        m.requests_total += 1;
                        m.tokens_generated += seq.generated.len() as u64;
                        m.observe_total(total_ms);
                        m.latency_events_total += 1;
                        if total_ms > self.latency_slo_ms {
                            m.latency_breaches_total += 1;
                        }
                        m.count_finish(seq.finish_reason().as_str());
                        m.macs_kept += seq.stats.macs_kept + seq.stats.macs_extra;
                        m.macs_dense += seq.stats.macs_dense;
                        m.spec_rounds_total += seq.spec.rounds;
                        m.spec_drafted_tokens += seq.spec.drafted;
                        m.spec_accepted_tokens += seq.spec.accepted;
                        if matches!(seq.finish_reason(), FinishReason::InternalError) {
                            // A sequence only ever finishes `internal_error`
                            // through a caught panic (prefill or decode).
                            m.panics_caught_total += 1;
                        }
                    }
                    let (tx, stx) = {
                        let mut st = lock_ok(&self.state);
                        (st.waiters.remove(&req.id), st.streams.remove(&req.id))
                    };
                    if let Some(stx) = stx {
                        let _ = stx.send(StreamEvent::Done(resp.clone()));
                    }
                    stream_sent.remove(&req.id);
                    if let Some(tx) = tx {
                        let _ = tx.send(resp);
                    }
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Guarantee every sequence that will forward this step has a KV page
    /// reserved. On pool exhaustion (eviction included — `reserve_seq` runs
    /// the manager's evict-then-alloc path) the youngest active unfinished
    /// sequence is preempted: pages released, request requeued at the head
    /// of the line with its `preempted` mark. Restarting the scan after a
    /// preemption is cheap because successful reservations are idempotent.
    fn reserve_or_preempt(&self, active: &mut Vec<(GenRequest, SeqState, Instant)>) {
        if self.engine.kv.is_none() {
            return;
        }
        let mut i = 0;
        while i < active.len() {
            let needs = {
                let s = &active[i].1;
                // Only prefilled sequences decode this step; decode_one
                // samples one token first, so a forward (and thus a page)
                // is only needed when that doesn't finish the seq.
                s.prefill_complete() && !s.finished() && s.generated.len() + 1 < s.max_new
            };
            if !needs || self.engine.reserve_seq(&mut active[i].1) {
                i += 1;
                continue;
            }
            if !self.preempt_youngest(active) {
                // With a single unfinished sequence there is nobody to
                // yield to: preempting it would requeue-and-fail forever.
                // Let `decode_one` surface `cache_full` instead.
                i += 1;
                continue;
            }
            i = 0;
        }
    }

    /// Tear down one active sequence whose client is gone (explicit
    /// [`Coordinator::cancel`] or a failed stream send): remove it from the
    /// active set — dropping it releases its KV blocks — close its
    /// channels, clear the stream high-water mark, and count the
    /// cancellation. No-op for ids that are not active (still-queued
    /// cancellations are handled by the batcher drain).
    fn cancel_active(
        &self,
        id: u64,
        active: &mut Vec<(GenRequest, SeqState, Instant)>,
        stream_sent: &mut HashMap<u64, usize>,
    ) {
        stream_sent.remove(&id);
        if let Some(i) = active.iter().position(|(r, _, _)| r.id == id) {
            let (_, seq, _) = active.swap_remove(i);
            drop(seq); // page table drops → blocks back to the pool
            let mut st = lock_ok(&self.state);
            st.waiters.remove(&id);
            st.streams.remove(&id);
            drop(st);
            lock_ok(&self.metrics).cancellations_total += 1;
        }
    }

    /// Preempt the youngest active unfinished sequence (highest id ==
    /// latest submitted; preempted-and-resumed requests keep their original
    /// low id, so they are preempted last): its pages are released and the
    /// request requeued at the head of the line. Mid-prefill sequences are
    /// legitimate victims — they restart from their (possibly now cached)
    /// prefix when re-admitted. Returns false when at most one unfinished
    /// sequence exists, i.e. there is nobody to yield to.
    fn preempt_youngest(&self, active: &mut Vec<(GenRequest, SeqState, Instant)>) -> bool {
        if active.iter().filter(|(_, s, _)| !s.finished()).count() <= 1 {
            return false;
        }
        let victim = active
            .iter()
            .enumerate()
            .filter(|(_, (_, s, _))| !s.finished())
            .max_by_key(|(_, (r, _, _))| r.id)
            .map(|(idx, _)| idx)
            .expect("checked: at least two unfinished sequences");
        let (mut req, seq, _) = active.swap_remove(victim);
        drop(seq); // releases the page table's block refs
        req.preempted = true;
        // Zero-duration event span: the victim's timeline shows when its
        // first run ended and KV went back to the pool.
        tracer().record_at(
            req.trace_id,
            req.root_span,
            "kv_preempt",
            Instant::now(),
            0,
            &[],
        );
        lock_ok(&self.state).batcher.requeue_front(req);
        lock_ok(&self.metrics).preemptions_total += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::Model;
    use crate::model::ModelConfig;
    use crate::server::engine::EngineCfg;
    use crate::server::faults::Faults;
    use crate::sparsity::Dense;

    fn coordinator_with(
        cfg: CoordinatorCfg,
        faults: Option<&str>,
    ) -> (Arc<Coordinator>, std::thread::JoinHandle<()>) {
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
        let mut engine = Engine::new(
            model,
            Arc::new(Dense),
            EngineCfg {
                threads: 2,
                ..EngineCfg::default()
            },
        );
        if let Some(spec) = faults {
            engine.faults = Faults::scripted(spec);
        }
        let coord = Coordinator::new(Arc::new(engine), cfg);
        let c2 = Arc::clone(&coord);
        let handle = std::thread::spawn(move || c2.run_scheduler());
        (coord, handle)
    }

    fn start_coordinator(max_batch: usize) -> (Arc<Coordinator>, std::thread::JoinHandle<()>) {
        coordinator_with(
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch,
                    max_queue: 32,
                },
                ..CoordinatorCfg::default()
            },
            None,
        )
    }

    #[test]
    fn single_request_completes() {
        let (coord, handle) = start_coordinator(4);
        let resp = coord.submit_blocking("12+34=", 5, Sampling::Greedy).unwrap();
        assert_eq!(resp.n_generated, 5);
        assert_eq!(resp.text.len(), 5);
        assert!(resp.total_ms >= 0.0);
        coord.shutdown();
        handle.join().unwrap();
        assert!(coord.scheduler_exited());
    }

    #[test]
    fn concurrent_requests_all_complete_and_match_sequential() {
        let (coord, handle) = start_coordinator(3);
        // Sequential references using a fresh engine.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 91));
        let engine = Engine::new(model, Arc::new(Dense), EngineCfg::default());
        let prompts = ["abc", "hello w", "1+2=", "xyzw", "the sun"];
        let expected: Vec<String> = prompts
            .iter()
            .map(|p| engine.run_to_completion(p, 6, Sampling::Greedy).0)
            .collect();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| coord.submit(p, 6, Sampling::Greedy).unwrap())
            .collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let resp = rx.recv().unwrap();
            assert_eq!(&resp.text, exp, "batched text diverged");
        }
        let m = coord.metrics.lock().unwrap();
        assert_eq!(m.requests_total, 5);
        assert_eq!(m.tokens_generated, 30);
        assert_eq!(m.panics_caught_total, 0);
        assert_eq!(m.scheduler_restarts_total, 0);
        drop(m);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn streaming_request_emits_per_token_events() {
        let (coord, handle) = start_coordinator(2);
        let reference = coord
            .submit_blocking("stream me", 6, Sampling::Greedy)
            .unwrap();
        let (_, rx) = coord
            .submit_stream("stream me", 6, Sampling::Greedy, true)
            .unwrap();
        let mut text = String::new();
        let mut n = 0usize;
        let mut done = None;
        for ev in rx {
            match ev {
                StreamEvent::Token { index, text: t } => {
                    assert_eq!(index, n, "events arrive in order");
                    n += 1;
                    text.push_str(&t);
                }
                StreamEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
            }
        }
        let done = done.expect("terminal done event");
        assert_eq!(n, 6, "one event per generated token");
        assert_eq!(text, done.text, "token stream reassembles the text");
        assert_eq!(done.text, reference.text, "greedy stream matches blocking");
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn backpressure_rejects() {
        // Tiny queue: flood and expect at least one rejection.
        let model = Arc::new(Model::synthetic(ModelConfig::preset("nano").unwrap(), 92));
        let engine = Arc::new(Engine::dense(model, EngineCfg::default()));
        let coord = Coordinator::new(
            engine,
            CoordinatorCfg {
                batcher: BatcherCfg {
                    max_batch: 1,
                    max_queue: 2,
                },
                ..CoordinatorCfg::default()
            },
        );
        // No scheduler running -> queue fills up.
        assert!(coord.submit("a", 1, Sampling::Greedy).is_ok());
        assert!(coord.submit("b", 1, Sampling::Greedy).is_ok());
        assert!(coord.submit("c", 1, Sampling::Greedy).is_err());
        let m = coord.metrics.lock().unwrap();
        assert_eq!(m.requests_rejected, 1);
        assert_eq!(m.shed_total, 1, "queue-full rejections count as shed");
    }

    #[test]
    fn zero_default_deadline_expires_queued_requests() {
        let (coord, handle) = coordinator_with(
            CoordinatorCfg {
                default_deadline: Some(Duration::ZERO),
                ..CoordinatorCfg::default()
            },
            None,
        );
        let resp = coord.submit_blocking("abc", 5, Sampling::Greedy).unwrap();
        assert_eq!(resp.finish_reason, "deadline_exceeded");
        assert_eq!(resp.n_generated, 0, "expired in queue: never ran");
        assert!(coord.metrics.lock().unwrap().deadline_exceeded_total >= 1);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn decode_panic_isolated_to_one_sequence() {
        // First decode_one invocation panics; its sequence fails
        // internal_error while the batchmate completes untouched and the
        // scheduler never restarts.
        let (coord, handle) = coordinator_with(
            CoordinatorCfg::default(),
            Some("decode_panic@1"),
        );
        let rx1 = coord.submit("abc", 6, Sampling::Greedy).unwrap();
        let rx2 = coord.submit("hello w", 6, Sampling::Greedy).unwrap();
        let r1 = rx1.recv().unwrap();
        let r2 = rx2.recv().unwrap();
        let reasons = [r1.finish_reason.as_str(), r2.finish_reason.as_str()];
        assert!(
            reasons.contains(&"internal_error"),
            "one sequence fails: {reasons:?}"
        );
        assert!(
            reasons.contains(&"length"),
            "the other completes normally: {reasons:?}"
        );
        let m = coord.metrics.lock().unwrap();
        assert_eq!(m.panics_caught_total, 1);
        assert_eq!(m.scheduler_restarts_total, 0, "isolated, not restarted");
        drop(m);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn sched_panic_restarts_scheduler_and_requests_survive() {
        // The very first scheduler iteration panics outside per-sequence
        // isolation; the supervisor restarts the loop and queued requests
        // are served by the new incarnation.
        let (coord, handle) = coordinator_with(
            CoordinatorCfg::default(),
            Some("sched_panic@1"),
        );
        let resp = coord.submit_blocking("abc", 6, Sampling::Greedy).unwrap();
        assert_eq!(resp.finish_reason, "length");
        assert_eq!(resp.n_generated, 6);
        assert_eq!(coord.metrics.lock().unwrap().scheduler_restarts_total, 1);
        coord.shutdown();
        handle.join().unwrap();
    }

    #[test]
    fn drain_completes_scheduler_and_refuses_new_work() {
        let (coord, handle) = start_coordinator(2);
        let rx = coord.submit("abc", 5, Sampling::Greedy).unwrap();
        coord.drain();
        // The in-flight request still terminates with exactly one
        // response (finished normally or shed, depending on timing).
        let resp = rx.recv().unwrap();
        assert!(
            ["length", "shed", "deadline_exceeded", "shutdown"]
                .contains(&resp.finish_reason.as_str()),
            "unexpected reason {}",
            resp.finish_reason
        );
        // Drain ends the scheduler on its own — no explicit shutdown().
        handle.join().unwrap();
        assert!(coord.is_shutdown());
        assert!(coord.scheduler_exited());
        assert!(
            coord.submit("late", 1, Sampling::Greedy).is_err(),
            "admission refused after drain"
        );
        assert!(coord.metrics.lock().unwrap().drain_duration_ms >= 0.0);
    }

    #[test]
    fn blocking_submit_never_hangs_after_scheduler_exit() {
        // Scheduler exits underneath a queued blocking waiter: the exit
        // sweep must deliver a terminal response rather than leaving the
        // waiter blocked forever.
        let (coord, handle) = start_coordinator(1);
        coord.drain();
        handle.join().unwrap();
        let err = coord
            .submit_blocking("abc", 4, Sampling::Greedy)
            .expect_err("admission refused after exit");
        assert!(err.to_string().contains("draining"), "{err}");
    }
}
